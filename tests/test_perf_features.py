"""Tests for the §Perf machinery: flash-attention custom VJP, sharding
policies, grouped MoE dispatch, CCA pass reduction options."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.common import apply_policy, sharding_policy
from jax.sharding import PartitionSpec as P


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_flash_attention_matches_sdpa(window, dt):
    key = jax.random.PRNGKey(0)
    B, S, H, Kv, hd = 2, 128, 8, 2, 32
    q = jax.random.normal(key, (B, S, H, hd), dt)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd), dt)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd), dt)
    mask = A.make_mask(S, S, causal=True, window=window)
    o_ref = A._sdpa(q, k, v, mask)
    o_fl = A.flash_attention(q, k, v, mask, 32)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_fl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


def test_flash_attention_grads_match():
    key = jax.random.PRNGKey(0)
    B, S, H, Kv, hd = 2, 96, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))
    mask = A.make_mask(S, S, causal=True, window=None)

    def loss(fn):
        return lambda args: jnp.sum(jnp.sin(fn(*args, mask)))

    g_ref = jax.grad(loss(lambda q_, k_, v_, m: A._sdpa(q_, k_, v_, m)))((q, k, v))
    g_fl = jax.grad(loss(lambda q_, k_, v_, m: A.flash_attention(q_, k_, v_, m, 32)))((q, k, v))
    for name, a, b in zip("qkv", g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_in_model_train_path():
    """A full train forward with flash attention matches the dense path."""
    from repro.configs import get_config
    from repro.models import build_model
    import dataclasses

    cfg = dataclasses.replace(get_config("granite-3-2b", smoke=True), dtype="float32")
    model = build_model(cfg)
    p = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 2048), 0, cfg.vocab)
    lg0, _ = model.forward_train(p, {"tokens": tok}, remat=False)
    model.flash_attention = True
    lg1, _ = model.forward_train(p, {"tokens": tok}, remat=False)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), atol=2e-4)


def test_sharding_policy_rewrite():
    with sharding_policy("dp"):
        assert apply_policy(P(None, "model")) == P(None, None)
        assert apply_policy(P(("pod", "data"), None)) == P(("pod", "data", "model"), None)
        assert apply_policy(P(("pod", "data", "model"), None)) == P(("pod", "data", "model"), None)
    # default policy: untouched
    assert apply_policy(P(None, "model")) == P(None, "model")


def test_moe_group_consistency():
    """Grouped dispatch must be invariant to the number of groups when
    capacity is lossless."""
    import dataclasses
    from repro.models.config import MoEConfig
    from repro.models import ffn as F

    base = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=0,
                     capacity_factor=100.0, dispatch_groups=1)
    p = F.init_moe(jax.random.PRNGKey(0), base, 64, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64))
    outs = []
    for g in [1, 2, 4]:
        cfg = dataclasses.replace(base, dispatch_groups=g)
        out, _ = F.moe_forward(p, x, cfg)
        outs.append(np.asarray(out))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With a tight capacity, overflow tokens fall through (output is the
    residual-free partial sum, never NaN/garbage)."""
    from repro.models.config import MoEConfig
    from repro.models import ffn as F

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=0,
                    capacity_factor=0.25, dispatch_groups=1)
    p = F.init_moe(jax.random.PRNGKey(0), cfg, 32, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = F.moe_forward(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # tighter capacity ⇒ smaller output norm than lossless
    cfg2 = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=0,
                     capacity_factor=100.0, dispatch_groups=1)
    out2, _ = F.moe_forward(p, x, cfg2)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(out2)) + 1e-3


def test_expert_ffn_custom_vjp_matches_autodiff():
    from repro.models.ffn import _expert_ffn

    key = jax.random.PRNGKey(0)
    G, E, C, D, F_ = 2, 4, 8, 16, 32
    ex = jax.random.normal(key, (G, E, C, D))
    wg = jax.random.normal(jax.random.PRNGKey(1), (E, D, F_)) * 0.1
    wu = jax.random.normal(jax.random.PRNGKey(2), (E, D, F_)) * 0.1
    wd = jax.random.normal(jax.random.PRNGKey(3), (E, F_, D)) * 0.1

    def ref(ex, wg, wu, wd):
        a = jnp.einsum("gecd,edf->gecf", ex, wg)
        h = jnp.einsum("gecd,edf->gecf", ex, wu)
        return jnp.einsum("gecf,efd->gecd", jax.nn.silu(a) * h, wd)

    out = _expert_ffn(ex, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref(ex, wg, wu, wd)),
                               atol=1e-5)
    loss = lambda f: lambda *a: jnp.sum(jnp.sin(f(*a)))
    g1 = jax.grad(loss(_expert_ffn), argnums=(0, 1, 2, 3))(ex, wg, wu, wd)
    g2 = jax.grad(loss(ref), argnums=(0, 1, 2, 3))(ex, wg, wu, wd)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_cca_reduce_options_equivalent():
    """bf16/bucketed reduction options stay within sketch tolerance."""
    # runs in-process: single device → psums are identity; the numerics
    # of the dtype cast path still execute
    from repro.core.rcca_dist import power_pass_local

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 32))
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 24))
    Qa = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    Qb = jax.random.normal(jax.random.PRNGKey(3), (24, 16))
    ref = np.asarray(a.T @ (b @ Qb))

    def run(**kw):
        Ya, *_ = power_pass_local(a, b, Qa, Qb, row_axes=(), col_axis=None,
                                  microbatch=64, compute_dtype=jnp.float32, **kw)
        return np.asarray(Ya)

    np.testing.assert_allclose(run(), ref, rtol=1e-4, atol=1e-3)
    bf = run(reduce_dtype=jnp.bfloat16)
    assert np.linalg.norm(bf - ref) / np.linalg.norm(ref) < 0.02
