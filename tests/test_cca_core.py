"""CCA core correctness: Algorithm 1 vs the exact oracle, streaming
equivalence, centering, Horst baseline and warm-start (paper claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HorstConfig,
    cca_objective,
    exact_cca,
    feasibility_errors,
    horst_cca,
    randomized_cca,
    randomized_cca_iterator,
    randomized_cca_streaming,
)
from repro.core.rcca import RCCAConfig
from repro.data import planted_views


@pytest.fixture(scope="module")
def views():
    A, B = planted_views(0, n=3000, da=48, db=40, rank=6, noise=0.4)
    return jnp.asarray(A), jnp.asarray(B)


LAM = 1e-3
K = 5


def test_exact_oracle_feasible(views):
    A, B = views
    sol = exact_cca(A, B, K, LAM, LAM)
    errs = feasibility_errors(A, B, sol.Xa, sol.Xb, LAM, LAM)
    for name, v in errs.items():
        assert float(v) < 1e-4, (name, float(v))
    # canonical correlations are in (0, 1] and sorted
    rho = np.asarray(sol.rho)
    assert np.all(rho[:-1] >= rho[1:] - 1e-6)
    assert np.all(rho > 0) and np.all(rho <= 1 + 1e-5)


def test_rcca_matches_exact(views):
    A, B = views
    ex = exact_cca(A, B, K, LAM, LAM)
    cfg = RCCAConfig(k=K, p=24, q=2, lam_a=LAM, lam_b=LAM)
    r = randomized_cca(A, B, cfg, jax.random.PRNGKey(1))
    # objective within 1% of exact optimum
    assert float(jnp.sum(r.rho)) > 0.99 * float(jnp.sum(ex.rho))
    # feasible to (near) machine precision — paper §4
    errs = feasibility_errors(A, B, r.Xa, r.Xb, LAM, LAM)
    for name, v in errs.items():
        assert float(v) < 1e-4, (name, float(v))


def test_rcca_objective_matches_rho(views):
    """(1/n)Tr(XaᵀAᵀBXb) must equal Σρ (definition consistency)."""
    A, B = views
    cfg = RCCAConfig(k=K, p=24, q=2, lam_a=LAM, lam_b=LAM)
    r = randomized_cca(A, B, cfg, jax.random.PRNGKey(1))
    obj = float(cca_objective(A, B, r.Xa, r.Xb))
    assert abs(obj - float(jnp.sum(r.rho))) < 1e-2


def test_streaming_equals_inmemory(views):
    A, B = views
    cfg = RCCAConfig(k=K, p=16, q=1, lam_a=LAM, lam_b=LAM)
    r_mem = randomized_cca(A, B, cfg, jax.random.PRNGKey(1))
    Ac = A.reshape(10, 300, A.shape[1])
    Bc = B.reshape(10, 300, B.shape[1])
    r_str = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(r_mem.rho), np.asarray(r_str.rho), atol=1e-4)


def test_streaming_kernel_path(views):
    A, B = views
    cfg = RCCAConfig(k=K, p=16, q=1, lam_a=LAM, lam_b=LAM)
    Ac = A.reshape(10, 300, A.shape[1])
    Bc = B.reshape(10, 300, B.shape[1])
    r0 = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(1))
    r1 = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(1), use_kernels=True)
    np.testing.assert_allclose(np.asarray(r0.rho), np.asarray(r1.rho), atol=1e-4)


def test_iterator_resume_equivalence(views):
    """Fault tolerance: a run killed mid-pass and resumed must agree."""
    A, B = views
    da, db = A.shape[1], B.shape[1]
    cfg = RCCAConfig(k=K, p=12, q=1, lam_a=LAM, lam_b=LAM)
    chunks = [(np.asarray(A[i::4]), np.asarray(B[i::4])) for i in range(4)]

    full = randomized_cca_iterator(lambda: iter(chunks), da, db, cfg, jax.random.PRNGKey(2))

    # capture state mid final pass (pass_idx=1 after q=1 power pass)
    snap = {}

    def capture(pass_idx, chunk_idx, acc, Qa, Qb):
        if pass_idx == 1 and chunk_idx == 1:
            snap["state"] = {
                "pass_idx": 1, "chunk_idx": 2, "acc": acc.state(),
                "Qa": Qa, "Qb": Qb,
            }

    randomized_cca_iterator(lambda: iter(chunks), da, db, cfg,
                            jax.random.PRNGKey(2), on_pass_end=capture)
    resumed = randomized_cca_iterator(
        lambda: iter(chunks), da, db, cfg, jax.random.PRNGKey(2),
        resume_state=snap["state"],
    )
    np.testing.assert_allclose(np.asarray(full.rho), np.asarray(resumed.rho), atol=1e-5)


def test_centering_matches_exact(views):
    A, B = views
    A2, B2 = A + 5.0, B - 3.0
    ex = exact_cca(A2, B2, K, LAM, LAM, do_center=True)
    cfg = RCCAConfig(k=K, p=24, q=2, lam_a=LAM, lam_b=LAM, center=True)
    r = randomized_cca(A2, B2, cfg, jax.random.PRNGKey(1))
    assert float(jnp.sum(r.rho)) > 0.99 * float(jnp.sum(ex.rho))


def test_scale_free_regularization(views):
    """ν-parameterization: λ = ν·Tr(XᵀX)/d (paper §4)."""
    A, B = views
    cfg = RCCAConfig(k=K, p=16, q=1, nu=0.01)
    r = randomized_cca(A, B, cfg, jax.random.PRNGKey(1))
    expect_a = 0.01 * float(jnp.sum(A**2)) / A.shape[1]
    assert abs(float(r.diagnostics["lam_a"]) - expect_a) / expect_a < 1e-4


def test_horst_matches_exact(views):
    A, B = views
    ex = exact_cca(A, B, K, LAM, LAM)
    # convergence rate is set by the ρ_k/ρ_{k+1} eigengap — the planted
    # corpus has a small one, so give the power method room
    h = horst_cca(A, B, HorstConfig(k=K, iters=120, lam_a=LAM, lam_b=LAM),
                  key=jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(h.rho), np.asarray(ex.rho), atol=1e-3)
    # objective history is (eventually) monotone non-decreasing
    hist = np.asarray(h.objective_history)
    assert hist[-1] >= hist[5] - 1e-4


def test_horst_cg_solver(views):
    """Approximate LS solves (paper fn.5) still converge."""
    A, B = views
    ex = exact_cca(A, B, K, LAM, LAM)
    h = horst_cca(A, B, HorstConfig(k=K, iters=60, lam_a=LAM, lam_b=LAM,
                                    solver="cg", cg_iters=8),
                  key=jax.random.PRNGKey(3))
    assert float(np.sum(np.asarray(h.rho))) > 0.98 * float(jnp.sum(ex.rho))


def test_horst_rcca_warmstart_faster(views):
    """Paper claim: RandomizedCCA is an excellent Horst initializer
    (120 → 34 passes).  With warm start, hitting 99.9% of optimum takes
    strictly fewer iterations than from a random start."""
    A, B = views
    ex = exact_cca(A, B, K, LAM, LAM)
    target = 0.999 * float(jnp.sum(ex.rho))

    cold = horst_cca(A, B, HorstConfig(k=K, iters=40, lam_a=LAM, lam_b=LAM),
                     key=jax.random.PRNGKey(4))
    r = randomized_cca(A, B, RCCAConfig(k=K, p=16, q=1, lam_a=LAM, lam_b=LAM),
                       jax.random.PRNGKey(5))
    warm = horst_cca(A, B, HorstConfig(k=K, iters=40, lam_a=LAM, lam_b=LAM),
                     init_Xb=r.Xb)

    def first_hit(hist):
        idx = np.nonzero(np.asarray(hist) >= target)[0]
        return int(idx[0]) if len(idx) else 10_000

    assert first_hit(warm.objective_history) < first_hit(cold.objective_history)


def test_streaming_horst_and_warmstart_passes(views):
    """Out-of-core Horst (CG solves via shared data passes) converges,
    and the rcca warm start cuts the data-pass count ~5× — the paper's
    Table 2b claim (120 → 34 passes) in pass-count currency."""
    from repro.core.horst import horst_cca_streaming

    A, B = views
    ex = exact_cca(A, B, K, LAM, LAM)
    chunks = lambda: ((A[i::4], B[i::4]) for i in range(4))

    cold = horst_cca_streaming(chunks, A.shape[1], B.shape[1],
                               HorstConfig(k=K, iters=25, cg_iters=4),
                               key=jax.random.PRNGKey(3), lam_a=LAM, lam_b=LAM)
    cold_passes = float(cold.objective_history[0])
    assert float(jnp.sum(cold.rho)) > 0.985 * float(jnp.sum(ex.rho))

    r = randomized_cca(A, B, RCCAConfig(k=K, p=16, q=1, lam_a=LAM, lam_b=LAM),
                       jax.random.PRNGKey(5))
    warm = horst_cca_streaming(chunks, A.shape[1], B.shape[1],
                               HorstConfig(k=K, iters=5, cg_iters=4),
                               init_Xb=r.Xb, init_Xa=r.Xa, lam_a=LAM, lam_b=LAM)
    warm_passes = float(warm.objective_history[0]) + (1 + 1)  # + rcca's q+1
    assert float(jnp.sum(warm.rho)) > 0.985 * float(jnp.sum(ex.rho))
    assert warm_passes < cold_passes / 3  # ≥3× fewer data passes


def test_rcca_warmstart_cuts_horst_sweeps(views):
    """Paper Table 2b (Horst+rcca): warm-starting the Horst iteration
    from the RandomizedCCA output reaches the same correlation in
    measurably fewer sweeps than a random init (seeded, tolerance on a
    fixed target).  Uses the streaming Horst — sweeps are data passes."""
    from repro.core.horst import horst_cca_streaming

    A, B = views
    da, db = A.shape[1], B.shape[1]

    def src():
        for lo in range(0, A.shape[0], 750):
            yield np.asarray(A[lo:lo + 750]), np.asarray(B[lo:lo + 750])

    ex = exact_cca(A, B, K, LAM, LAM)
    # calibrated so the verdict has margin on both sides: at 0.997·opt
    # the cold start sits at 0.9911 after one sweep (clearly below) and
    # the warm start at 0.9983 (clearly above)
    target = 0.997 * float(jnp.sum(ex.rho))
    rc = randomized_cca(A, B, RCCAConfig(k=K, p=16, q=1, lam_a=LAM, lam_b=LAM),
                        jax.random.PRNGKey(7))

    def min_sweeps(**init):
        for iters in (1, 2, 3, 4, 6, 8):
            h = horst_cca_streaming(
                src, da, db, HorstConfig(k=K, iters=iters, cg_iters=2),
                key=jax.random.PRNGKey(11), lam_a=LAM, lam_b=LAM, **init)
            if float(jnp.sum(h.rho)) >= target:
                return iters
        return 99

    warm = min_sweeps(init_Xb=rc.Xb, init_Xa=rc.Xa)
    cold = min_sweeps()
    assert warm < cold, (warm, cold)
    assert warm <= max(1, cold // 2), (warm, cold)  # ≥2× fewer sweeps
