"""End-to-end system behaviour: the paper's claims reproduced at test
scale, plus full pipeline integration (train driver, CCA driver,
activation harvesting)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HorstConfig,
    cca_objective,
    exact_cca,
    horst_cca,
    randomized_cca,
)
from repro.core.rcca import RCCAConfig
from repro.data import PlantedCCAData


@pytest.fixture(scope="module")
def europarl_like():
    """Train/test split of a planted-correlation corpus (paper §4 setup)."""
    d = PlantedCCAData(n=4000, da=64, db=56, rank=24, decay=0.8, noise=0.6,
                       seed=11, chunk=500)
    A, B = d.materialize()
    n_tr = 3600
    return (jnp.asarray(A[:n_tr]), jnp.asarray(B[:n_tr]),
            jnp.asarray(A[n_tr:]), jnp.asarray(B[n_tr:]))


K = 8


def test_paper_fig2a_objective_improves_with_p_and_q(europarl_like):
    """Fig 2a: the objective increases with oversampling p and passes q,
    approaching the Horst (near-exact) optimum."""
    A, B, _, _ = europarl_like
    lam = 1e-3
    ex = exact_cca(A, B, K, lam, lam)
    opt = float(jnp.sum(ex.rho))

    def obj(p, q, seed=0):
        cfg = RCCAConfig(k=K, p=p, q=q, lam_a=lam, lam_b=lam)
        r = randomized_cca(A, B, cfg, jax.random.PRNGKey(seed))
        return float(jnp.sum(r.rho))

    o_p4_q0 = obj(4, 0)
    o_p16_q0 = obj(16, 0)
    o_p16_q1 = obj(16, 1)
    o_p32_q2 = obj(32, 2)
    assert o_p16_q0 >= o_p4_q0 - 0.02  # more oversampling helps (q=0 row)
    assert o_p16_q1 >= o_p16_q0       # a power pass helps
    assert o_p32_q2 >= 0.995 * opt    # converges to the optimum
    assert o_p32_q2 <= opt + 1e-3     # never exceeds it


def test_paper_inherent_regularization(europarl_like):
    """§4: RandomizedCCA generalizes; its train/test gap is no worse
    than Horst's at the same regularization."""
    A, B, At, Bt = europarl_like
    nu = 0.01
    r = randomized_cca(A, B, RCCAConfig(k=K, p=16, q=1, nu=nu), jax.random.PRNGKey(0))
    h = horst_cca(A, B, HorstConfig(k=K, iters=40, nu=nu), key=jax.random.PRNGKey(1))

    def gap(Xa, Xb):
        tr = float(cca_objective(A, B, Xa, Xb))
        te = float(cca_objective(At, Bt, Xa, Xb))
        return tr - te

    assert gap(r.Xa, r.Xb) <= gap(h.Xa, h.Xb) + 0.05
    # and rcca's test objective is competitive (within 2%)
    te_r = float(cca_objective(At, Bt, r.Xa, r.Xb))
    te_h = float(cca_objective(At, Bt, h.Xa, h.Xb))
    assert te_r >= te_h - 0.02 * abs(te_h)


def test_train_driver_integration(tmp_path):
    """launch.train runs, checkpoints, and resumes."""
    from repro.launch.train import main as train_main

    ck = str(tmp_path / "ck")
    train_main(["--arch", "granite-3-2b", "--smoke", "--steps", "3",
                "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
                "--ckpt-every", "1", "--loss-chunks", "2"])
    train_main(["--arch", "granite-3-2b", "--smoke", "--steps", "5",
                "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
                "--ckpt-every", "2", "--loss-chunks", "2"])


def test_cca_driver_integration():
    from repro.launch.cca_fit import main as cca_main

    cca_main(["--smoke", "--mode", "dist"])


def test_serve_driver_integration():
    from repro.launch.serve import main as serve_main

    serve_main(["--arch", "granite-3-2b", "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen", "4"])


def test_activation_cca_harvest():
    """The paper's technique applied to the model zoo: CCA between two
    LMs' hidden representations of THE SAME token stream recovers high
    canonical correlation; destroying the row alignment (shuffle one
    view) destroys it — CCA finds aligned structure."""
    from repro.configs import get_config
    from repro.core.harvest import activation_views
    from repro.models import build_model

    cfg = get_config("granite-3-2b", smoke=True)
    m1 = build_model(cfg)
    m2 = build_model(cfg)
    p1 = m1.init(jax.random.PRNGKey(0))
    p2 = m2.init(jax.random.PRNGKey(1))  # different weights, same stream

    toks = jax.random.randint(jax.random.PRNGKey(2), (8, 64), 0, cfg.vocab)

    A = activation_views(m1, p1, {"tokens": toks})
    B = activation_views(m2, p2, {"tokens": toks})
    perm = jax.random.permutation(jax.random.PRNGKey(3), B.shape[0])

    k = 4
    cfg_r = RCCAConfig(k=k, p=16, q=2, nu=0.01, center=True)
    r_same = randomized_cca(A, B, cfg_r, jax.random.PRNGKey(4))
    r_shuf = randomized_cca(A, B[perm], cfg_r, jax.random.PRNGKey(4))
    assert float(jnp.sum(r_same.rho)) > float(jnp.sum(r_shuf.rho)) + 0.5
