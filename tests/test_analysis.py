"""repro.analysis: lint fixtures (must-trip AND must-pass per rule),
kernel contract checker (clean registry + injected inconsistencies),
autotune-cache validation, and the determinism sanitizer.

The protocol/race-detector half lives in tests/test_analysis_protocol.py.
"""

import json
import textwrap

import numpy as np
import pytest

from repro.analysis import kernel_check, lint, sanitize
from repro.analysis.report import Violation, render_report
from repro.kernels.plan import BlockDef, KernelPlan, ScratchDef


def codes(violations):
    return sorted(v.code for v in violations)


def lint_src(src, relpath):
    return lint.lint_source(textwrap.dedent(src), relpath)


# ---------------------------------------------------------------------------
# architecture lint: one must-trip + one must-pass fixture per rule
# ---------------------------------------------------------------------------


def test_lint_unparsable_is_rcca000():
    assert codes(lint_src("def broken(:\n", "repro/x.py")) == ["RCCA000"]


def test_rcca001_fold_loop_outside_exec_trips():
    src = """
    def merge_all(partials, acc):
        for p in partials:
            acc = merge_stats(acc, p)
        return acc
    """
    vs = lint_src(src, "repro/cluster/bad.py")
    assert codes(vs) == ["RCCA001"]
    assert "pairwise tree" in vs[0].message


def test_rcca001_comprehension_and_update_fn_trip():
    src = """
    def f(groups, acc):
        [acc.push_group(g, s) for g, s in groups]
        while groups:
            acc2 = jit_update_fn(acc, *groups.pop())
    """
    vs = lint_src(src, "repro/core/bad.py")
    assert codes(vs) == ["RCCA001", "RCCA001"]


def test_rcca001_same_loop_inside_exec_passes():
    src = """
    def merge_all(partials, acc):
        for p in partials:
            acc = merge_stats(acc, p)
        return acc
    """
    assert lint_src(src, "repro/exec/accumulate.py") == []


def test_rcca001_unlooped_call_passes():
    # a single straight-line fold call is delegation, not reimplementation
    src = "def f(acc, s):\n    acc.push_group(0, s)\n"
    assert lint_src(src, "repro/cluster/ok.py") == []


def test_rcca002_version_sensitive_import_trips():
    for src in (
        "from jax.experimental.shard_map import shard_map\n",
        "import jax.experimental.pallas.tpu as pltpu\n",
        "from jax.experimental import shard_map\n",
        "def f(x):\n    return pltpu.roll(x, 1, 0)\n",
    ):
        vs = lint_src(src, "repro/exec/bad.py")
        assert codes(vs) == ["RCCA002"], src


def test_rcca002_compat_shim_is_exempt_and_plain_pallas_passes():
    src = "from jax.experimental.shard_map import shard_map\n"
    assert lint_src(src, "repro/kernels/compat.py") == []
    # plain (non-tpu) pallas is not version-pinned
    assert lint_src("from jax.experimental import pallas as pl\n",
                    "repro/kernels/matmul.py") == []


def test_rcca003_shard_file_reference_trips():
    src = "def f(d, i):\n    return load(f'{d}/shard_{i:05d}.a.npy')\n"
    vs = lint_src(src, "repro/cluster/bad.py")
    assert codes(vs) == ["RCCA003"]


def test_rcca003_store_scope_and_docstrings_pass():
    src = "def f(d, i):\n    return load(f'{d}/shard_{i:05d}.b.npy')\n"
    assert lint_src(src, "repro/store/format.py") == []
    doc = '"""Reads shard_00000.a.npy via the manifest."""\n'
    assert lint_src(doc, "repro/cluster/ok.py") == []


def test_rcca004_nondeterminism_in_pass_path_trips():
    src = """
    def f(groups):
        t = time.time()
        fit = uuid.uuid4()
        x = np.random.randn(3)
        for g in set(groups):
            pass
        return [g for g in set(groups)]
    """
    vs = lint_src(src, "repro/exec/bad.py")
    assert codes(vs) == ["RCCA004"] * 5


def test_rcca004_outside_pass_path_and_deterministic_iter_pass():
    src = "def f():\n    return time.time(), np.random.randn(3)\n"
    assert lint_src(src, "repro/launch/bench.py") == []  # not pass-path
    src = """
    def f(groups):
        for g in sorted(set(groups)):
            pass
        for g in dict.fromkeys(groups):
            pass
    """
    assert lint_src(src, "repro/exec/ok.py") == []


def test_rcca005_bare_write_in_cluster_scope_trips():
    src = """
    def publish(path, obj, arr):
        with open(path, "w") as f:
            f.write(obj)
        np.save(path + ".npy", arr)
    """
    vs = lint_src(src, "repro/cluster/bad.py")
    assert codes(vs) == ["RCCA005", "RCCA005"]


def test_rcca005_appends_reads_and_other_scopes_pass():
    src = """
    def f(path):
        with open(path) as f:
            f.read()
        with open(path, "a") as f:
            f.write("x")
    """
    assert lint_src(src, "repro/cluster/ok.py") == []
    # writes outside cluster/store scope are not this rule's business
    src = "def f(p, a):\n    np.save(p, a)\n"
    assert lint_src(src, "repro/launch/bench.py") == []


def test_rcca007_raw_monotonic_clock_in_pass_path_trips():
    src = """
    def f():
        t0 = time.perf_counter()
        t1 = time.monotonic()
        t2 = time.monotonic_ns()
        return time.perf_counter_ns() - t0
    """
    for relpath in ("repro/exec/bad.py", "repro/store/prefetch.py"):
        assert codes(lint_src(src, relpath)) == ["RCCA007"] * 4


def test_rcca007_obs_clocks_and_other_scopes_pass():
    src = """
    def f():
        t0 = obs.monotonic()
        obs.counter("io", read_s=obs.monotonic() - t0, at=obs.wall())
    """
    assert lint_src(src, "repro/exec/ok.py") == []
    # raw clocks are fine outside the pass path and in obs itself
    src = "def f():\n    return time.perf_counter()\n"
    assert lint_src(src, "repro/launch/bench.py") == []
    assert lint_src(src, "repro/obs/trace.py") == []


def test_noqa_suppression_bare_and_coded():
    trip = "def f(p, a):\n    np.save(p, a)\n"
    base = lint_src(trip, "repro/cluster/x.py")
    assert codes(base) == ["RCCA005"]
    for tail in ("  # rcca: noqa", "  # rcca: noqa[RCCA005]",
                 "  # rcca: noqa[RCCA001, RCCA005]"):
        src = trip.replace("np.save(p, a)", "np.save(p, a)" + tail)
        assert lint_src(src, "repro/cluster/x.py") == [], tail
    # a noqa for a DIFFERENT code does not suppress
    src = trip.replace("np.save(p, a)", "np.save(p, a)  # rcca: noqa[RCCA001]")
    assert codes(lint_src(src, "repro/cluster/x.py")) == ["RCCA005"]


def test_lint_tree_is_clean():
    """Dogfood: the shipped tree has zero unsuppressed violations."""
    assert lint.lint_tree() == []


# ---------------------------------------------------------------------------
# kernel contract checker
# ---------------------------------------------------------------------------


def _plan_2x2(block=(128, 128), padded=(256, 256), *,
              index_map=None, out_dtype="float32", scratch=(),
              accum_outputs=(), out_shape=None, in_dtype="float32"):
    """A minimal one-operand copy-style plan: 2×2 grid of 128² tiles."""
    imap = index_map if index_map is not None else (lambda i, j: (i, j))
    spec = lambda dt: BlockDef(shape=block, index_map=imap,
                               padded=padded, dtype=dt)
    return KernelPlan(
        name="fixture", grid=(2, 2),
        in_specs=(spec(in_dtype),), out_specs=(spec(out_dtype),),
        scratch=tuple(scratch),
        out_shape=(out_shape if out_shape is not None else (250, 250),),
        accum_outputs=tuple(accum_outputs))


def test_check_plan_fixture_is_clean():
    assert kernel_check.check_plan(_plan_2x2()) == []


def test_rcca101_block_does_not_tile_padded():
    vs = kernel_check.check_plan(_plan_2x2(block=(100, 128)))
    assert "RCCA101" in codes(vs)


def test_rcca101_logical_exceeds_padded():
    vs = kernel_check.check_plan(_plan_2x2(out_shape=(300, 250)))
    assert codes(vs) == ["RCCA101"]


def test_rcca102_index_map_arity_and_oob():
    vs = kernel_check.check_plan(_plan_2x2(index_map=lambda i: (i, 0)))
    assert "RCCA102" in codes(vs)
    vs = kernel_check.check_plan(_plan_2x2(index_map=lambda i, j: (i, j + 1)))
    assert "RCCA102" in codes(vs)


def test_rcca103_uncovered_output_tile():
    # every grid point writes tile (i, 0): column 1 never covered
    vs = kernel_check.check_plan(_plan_2x2(index_map=lambda i, j: (i, 0)))
    assert codes(vs) == ["RCCA103"]
    assert "uncovered" in vs[0].message


def test_rcca104_vmem_budget():
    vs = kernel_check.check_plan(_plan_2x2(), budget=128 * 128 - 1)
    assert codes(vs) == ["RCCA104", "RCCA104"]  # the in block and out block
    vs = kernel_check.check_plan(
        _plan_2x2(scratch=(ScratchDef((4096, 4096), "float32"),)))
    assert codes(vs) == ["RCCA104"]


def test_rcca105_dtype_rules():
    vs = kernel_check.check_plan(
        _plan_2x2(scratch=(ScratchDef((8, 128), "bfloat16"),)))
    assert codes(vs) == ["RCCA105"]
    vs = kernel_check.check_plan(_plan_2x2(out_dtype="bfloat16",
                                           accum_outputs=(0,)))
    assert codes(vs) == ["RCCA105"]  # declared accumulator must be f32
    vs = kernel_check.check_plan(_plan_2x2(in_dtype="bfloat16",
                                           out_dtype="bfloat16"))
    assert codes(vs) == ["RCCA105"]  # bf16-in/bf16-out, no f32 accumulator


def test_registry_is_clean():
    """The production kernels pass their own contract (incl. RCCA106
    abstract-eval agreement) — the `make analyze` kernel gate."""
    assert kernel_check.check_registry(cache=False) == []


def test_check_kernel_rejects_inconsistent_registered_plan():
    """A registry entry whose plan is inconsistent IS caught — the gate
    is not vacuous."""
    from repro.kernels import KernelDef

    bad = KernelDef(
        name="bad_fixture",
        plan=lambda probe: _plan_2x2(index_map=lambda i, j: (i, 0)),
        probes=({"M": 256, "N": 256, "dtype": "float32"},),
        abstract=None)
    vs = kernel_check.check_kernel(bad, abstract=False)
    assert codes(vs) == ["RCCA103"]


# ---------------------------------------------------------------------------
# autotune-cache validation (RCCA107)
# ---------------------------------------------------------------------------


VALID_KEY = "cpu|matmul_nn|float32|256x256x256"


def _write_cache(tmp_path, cache):
    p = tmp_path / "autotune.json"
    p.write_text(json.dumps(cache))
    return str(p)


def test_autotune_cache_valid_entry_is_clean(tmp_path):
    p = _write_cache(tmp_path, {VALID_KEY: {"blocks": [128, 128, 128]}})
    assert kernel_check.check_autotune_cache(p) == []


def test_autotune_cache_missing_is_clean(tmp_path):
    assert kernel_check.check_autotune_cache(str(tmp_path / "nope.json")) == []


@pytest.mark.parametrize("key,entry", [
    ("not-a-key", {"blocks": [128, 128, 128]}),            # unparsable key
    ("cpu|mystery_op|float32|256x256x256",
     {"blocks": [128, 128, 128]}),                         # unknown op
    ("cpu|matmul_nn|float32|256x256", {"blocks": [128, 128, 128]}),  # ndims
    ("cpu|matmul_nn|float32|256x200x256",
     {"blocks": [128, 128, 128]}),                         # not x128-padded
    (VALID_KEY, {"blocks": [128, 128]}),                   # two blocks
    (VALID_KEY, {"blocks": [128, -128, 128]}),             # negative block
    (VALID_KEY, "not-an-object"),                          # malformed entry
])
def test_autotune_cache_mutations_trip_rcca107(tmp_path, key, entry):
    p = _write_cache(tmp_path, {key: entry})
    vs = kernel_check.check_autotune_cache(p)
    assert vs and all(v.code == "RCCA107" for v in vs)


def test_autotune_cache_unreadable_trips(tmp_path):
    p = tmp_path / "autotune.json"
    p.write_text("{truncated")
    vs = kernel_check.check_autotune_cache(str(p))
    assert codes(vs) == ["RCCA107"]


# ---------------------------------------------------------------------------
# determinism sanitizer (RCCA301)
# ---------------------------------------------------------------------------


@pytest.fixture
def sanitizing(monkeypatch):
    monkeypatch.setenv("RCCA_SANITIZE", "1")
    sanitize.reset()
    yield
    sanitize.reset()


def test_observe_is_noop_when_disabled(monkeypatch):
    monkeypatch.delenv("RCCA_SANITIZE", raising=False)
    sanitize.reset()
    sanitize.observe("group:0", {"y": np.ones(3, np.float32)})
    assert sanitize.snapshot() == []


def test_identical_states_identical_digests(sanitizing):
    tree = {"y": np.arange(4, dtype=np.float32), "n": np.float32(2)}
    sanitize.observe("group:0", tree)
    sanitize.observe("group:0", {k: v.copy() if hasattr(v, "copy") else v
                                 for k, v in tree.items()})
    a, b = sanitize.snapshot()
    assert a["digest"] == b["digest"]
    assert sanitize.first_divergence([a], [b]) is None


def test_first_divergence_pinpoints_bit_flip(sanitizing):
    good = np.arange(8, dtype=np.float32)
    bad = good.copy()
    bad[5] = np.nextafter(bad[5], np.inf)  # one ulp — invisible to allclose
    sanitize.set_context(pass_idx=1, kind="power")
    for g in range(3):
        sanitize.observe(f"group:{g}", {"y": good})
    run_a = sanitize.snapshot()
    sanitize.reset()
    sanitize.set_context(pass_idx=1, kind="power")
    for g in range(3):
        sanitize.observe(f"group:{g}", {"y": bad if g == 2 else good})
    run_b = sanitize.snapshot()
    d = sanitize.first_divergence(run_a, run_b)
    assert d["code"] == "RCCA301" and d["reason"] == "digest"
    assert d["index"] == 2 and d["a"]["label"] == "group:2"


def test_first_divergence_label_and_length(sanitizing):
    sanitize.observe("group:0", {"y": np.ones(2, np.float32)})
    a = sanitize.snapshot()
    sanitize.reset()
    sanitize.observe("group:1", {"y": np.ones(2, np.float32)})
    b = sanitize.snapshot()
    assert sanitize.first_divergence(a, b)["reason"] == "label"
    assert sanitize.first_divergence(a, a + b)["reason"] == "length"


def test_dump_load_roundtrip(sanitizing, tmp_path):
    sanitize.set_context(pass_idx=0, kind="final", site="stream")
    sanitize.observe("pass_end", {"y": np.zeros(2, np.float32)})
    out = str(tmp_path / "trace.json")
    assert sanitize.dump(out) == out
    assert sanitize.load(out) == sanitize.snapshot()


def test_sanitized_fit_trace_is_reproducible(sanitizing):
    """End to end: two identical iterator fits leave identical traces,
    and the trace lands in diagnostics."""
    import jax

    from repro.core.rcca import RCCAConfig, randomized_cca_iterator

    rng = np.random.default_rng(7)
    chunks = [(rng.standard_normal((32, 6), dtype=np.float32),
               rng.standard_normal((32, 5), dtype=np.float32))
              for _ in range(4)]
    cfg = RCCAConfig(k=2, p=1, q=1)
    key = jax.random.PRNGKey(3)

    def run():
        sanitize.reset()
        res = randomized_cca_iterator(lambda: iter(chunks), 6, 5, cfg, key)
        return res.diagnostics["sanitize"]

    t1, t2 = run(), run()
    assert t1 and t1 == t2
    assert sanitize.first_divergence(t1, t2) is None


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------


def test_render_report_sorts_and_counts():
    vs = [Violation("RCCA005", "b.py", 9, "later"),
          Violation("RCCA001", "a.py", 2, "earlier")]
    text = render_report(vs, title="lint")
    assert text.index("a.py:2") < text.index("b.py:9")
    assert "-> 2 violations" in text
    assert "-> clean" in render_report([], title="lint")
