"""Kill/resume fault tolerance of store-backed data passes: a pass
interrupted mid-chunk and restored from its repro.ckpt cursor must
reproduce the uninterrupted RCCAResult BIT-IDENTICALLY — the update
sequence is deterministic and the cursor checkpoints the exact f32
accumulators, so not even the last ulp may move.  Exercised for both
data-pass engines (fused Pallas kernels in interpret mode, pure jnp)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.rcca import RCCAConfig
from repro.data import PlantedCCAData
from repro.store import PassRunner, ingest_planted


class Kill(Exception):
    """Simulated mid-pass crash."""


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    data = PlantedCCAData(n=1200, da=32, db=24, rank=5, noise=0.4,
                          seed=9, chunk=150)  # 8 chunks per pass
    return ingest_planted(str(tmp_path_factory.mktemp("resume") / "store"), data)


CFG = RCCAConfig(k=4, p=8, q=1, nu=0.01, center=True)
KEY = 7


def _assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs after resume"


@pytest.mark.parametrize("engine", ["jnp", "kernels"])
@pytest.mark.parametrize("kill_at", [(0, 5), (1, 3)],
                         ids=["mid-power-pass", "mid-final-pass"])
def test_kill_resume_bit_identical(store, tmp_path, engine, kill_at):
    key = jax.random.PRNGKey(KEY)
    baseline = PassRunner(store, CFG, engine=engine, prefetch=2).fit(key)

    ck = str(tmp_path / f"ck_{engine}_{kill_at[0]}_{kill_at[1]}")
    runner = PassRunner(store, CFG, engine=engine, prefetch=2,
                        ckpt_dir=ck, ckpt_every=2)

    def crash(pass_idx, chunk_idx, *_):
        if (pass_idx, chunk_idx) == kill_at:
            raise Kill

    with pytest.raises(Kill):
        runner.fit(key, resume=False, on_chunk=crash)

    resumed = PassRunner(store, CFG, engine=engine, prefetch=2,
                         ckpt_dir=ck).fit(key, resume=True)
    assert resumed.diagnostics["io"]["resumed"]
    # the resumed run must not have re-read the whole corpus: at least
    # the checkpointed prefix of the killed pass is skipped
    assert resumed.diagnostics["io"]["rows"] < 2 * store.n
    _assert_bit_identical(baseline, resumed)


def test_resume_guards(store, tmp_path):
    """Cursors are bound to store content, engine, and hyper-params."""
    ck = str(tmp_path / "ck")
    runner = PassRunner(store, CFG, engine="jnp", prefetch=0,
                        ckpt_dir=ck, ckpt_every=2)

    def crash(pass_idx, chunk_idx, *_):
        if (pass_idx, chunk_idx) == (0, 5):
            raise Kill

    with pytest.raises(Kill):
        runner.fit(jax.random.PRNGKey(KEY), on_chunk=crash)

    with pytest.raises(ValueError, match="engine"):
        PassRunner(store, CFG, engine="kernels",
                   ckpt_dir=ck).fit(jax.random.PRNGKey(KEY), resume=True)

    other_cfg = dataclasses.replace(CFG, p=CFG.p + 2)
    with pytest.raises(ValueError, match="hyper-parameters"):
        PassRunner(store, other_cfg, engine="jnp",
                   ckpt_dir=ck).fit(jax.random.PRNGKey(KEY), resume=True)

    with pytest.raises(ValueError, match="different store"):
        other = ingest_planted(
            str(tmp_path / "other"),
            PlantedCCAData(n=1200, da=32, db=24, rank=5, seed=10, chunk=150))
        PassRunner(other, CFG, engine="jnp",
                   ckpt_dir=ck).fit(jax.random.PRNGKey(KEY), resume=True)


def test_resume_without_cursor_is_fresh_run(store, tmp_path):
    """resume=True with an empty ckpt dir falls through to a full fit."""
    res = PassRunner(store, CFG, engine="jnp", prefetch=0,
                     ckpt_dir=str(tmp_path / "empty")).fit(
        jax.random.PRNGKey(KEY), resume=True)
    assert not res.diagnostics["io"]["resumed"]
    base = PassRunner(store, CFG, engine="jnp", prefetch=0).fit(
        jax.random.PRNGKey(KEY))
    _assert_bit_identical(base, res)
