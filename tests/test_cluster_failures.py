"""Fault tolerance of the cluster barrier: killed workers, stale
partials from earlier fits, duplicate publications and unrecoverable
shards.  The invariant under every recoverable failure is the same as
the happy path — the coordinator output stays bit-identical to the
single-process ``randomized_cca_streaming`` on the same store."""

import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.rcca import RCCAConfig, randomized_cca_streaming
from repro.cluster import ClusterCoordinator
from repro.cluster import partials as pt
from repro.cluster.worker import KILL_ENV
from repro.data import PlantedCCAData
from repro.store import ingest_planted

N, DA, DB, CHUNK = 1536, 28, 20, 128  # 12 chunks, 6 merge groups
G = 2
CFG = RCCAConfig(k=4, p=8, q=1, nu=0.01, center=True)
KEY = 5


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    data = PlantedCCAData(n=N, da=DA, db=DB, rank=5, noise=0.4,
                          seed=11, chunk=CHUNK)
    return ingest_planted(str(tmp_path_factory.mktemp("clfail") / "store"),
                          data)


@pytest.fixture(scope="module")
def ref(store):
    A, B = store.materialize()
    Ac = jnp.asarray(A).reshape(store.n_chunks, CHUNK, DA)
    Bc = jnp.asarray(B).reshape(store.n_chunks, CHUNK, DB)
    return randomized_cca_streaming(Ac, Bc, CFG, jax.random.PRNGKey(KEY),
                                    engine="jnp", merge_group=G)


def assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs"


@pytest.mark.parametrize("kill", ["0:2", "1:2"],
                         ids=["mid-power-pass", "mid-final-pass"])
def test_killed_worker_redispatches_bit_identical(store, ref, tmp_path, kill):
    """Worker 0 dies hard (os._exit, no cleanup) mid-pass; the barrier
    re-dispatches its unfinished merge groups to a repair worker and the
    merged result still matches single-process bitwise."""
    co = ClusterCoordinator(store, CFG, str(tmp_path / "cl"), n_workers=2,
                            engine="jnp", merge_group=G, worker_timeout=300,
                            env_overrides={0: {KILL_ENV: kill}})
    res = co.fit(jax.random.PRNGKey(KEY))
    assert_bit_identical(ref, res)
    killed_pass = int(kill.split(":")[0])
    passes = res.diagnostics["cluster"]["passes"]
    assert passes[killed_pass]["redispatched_groups"]  # repair happened
    other = 1 - killed_pass
    assert passes[other]["redispatched_groups"] == []


def test_stale_partials_from_previous_fit_are_replaced(store, ref, tmp_path):
    """Re-using a cluster dir across fits: partials/rounds of the first
    fit carry a different fit id, so the second fit must not merge them
    — stale work is re-dispatched (here: recomputed) and replaced."""
    cd = str(tmp_path / "cl")
    co = ClusterCoordinator(store, CFG, cd, n_workers=2, engine="jnp",
                            merge_group=G)
    co.fit(jax.random.PRNGKey(123))  # different key → different partials
    res = co.fit(jax.random.PRNGKey(KEY))
    assert_bit_identical(ref, res)


def test_duplicate_publication_merges_once(store, ref, tmp_path):
    """Two workers racing the same merge group (the presumed-dead owner
    coming back) is safe: content is deterministic and each group id
    enters the merge exactly once."""
    from repro.cluster import run_worker

    cd = str(tmp_path / "cl")
    co = ClusterCoordinator(store, CFG, cd, n_workers=2, engine="jnp",
                            merge_group=G)
    res = co.fit(jax.random.PRNGKey(KEY))
    assert_bit_identical(ref, res)
    # the "zombie owner" republishes every group of pass 0 after the
    # fit finished — recognized as already-valid, nothing double-merges
    assert run_worker(store.path, cd, 0, 2, 0, prefetch=0) == 0
    assert run_worker(store.path, cd, 1, 2, 0, prefetch=0) == 0


def test_stale_heartbeat_worker_redispatched(store, ref, tmp_path):
    """A worker that WEDGES (alive process, no progress — the failure
    mode exit codes can't see) stops beating its heartbeat; the
    coordinator declares it stale, kills it and re-dispatches its
    missing groups WITHOUT waiting for the wall-clock pass timeout.
    The merged result stays bit-identical."""
    from repro.cluster.worker import HANG_ENV

    co = ClusterCoordinator(store, CFG, str(tmp_path / "cl"), n_workers=2,
                            engine="jnp", merge_group=G,
                            worker_timeout=600, heartbeat_timeout=12,
                            env_overrides={0: {HANG_ENV: "0:2"}})
    res = co.fit(jax.random.PRNGKey(KEY))
    assert_bit_identical(ref, res)
    passes = res.diagnostics["cluster"]["passes"]
    assert passes[0]["stale_heartbeat_shards"] == [0]
    assert passes[0]["redispatched_groups"]  # the hung shard's groups
    assert passes[1]["stale_heartbeat_shards"] == []
    # wall-clock worker_timeout (600s) was clearly NOT the trigger
    assert passes[0]["wall_s"] < 300


def test_stale_beacon_from_previous_fit_is_ignored(store, ref, tmp_path):
    """Reusing a cluster_dir leaves the previous fit's heartbeat
    beacons behind (same shard/pass keys).  Staleness is bounded by
    time-since-spawn, so an hour-old beacon must not condemn a freshly
    spawned worker that hasn't had time to beat yet."""
    cd = str(tmp_path / "cl")
    pt.touch_heartbeat(cd, 0, 0)  # "previous fit's" beacon ...
    ancient = time.time() - 3600  # ... an hour stale
    os.utime(pt.heartbeat_path(cd, 0, 0), (ancient, ancient))
    co = ClusterCoordinator(store, CFG, cd, n_workers=2, engine="jnp",
                            merge_group=G, worker_timeout=300,
                            heartbeat_timeout=15)
    res = co.fit(jax.random.PRNGKey(KEY))
    assert_bit_identical(ref, res)
    passes = res.diagnostics["cluster"]["passes"]
    assert all(p["stale_heartbeat_shards"] == [] for p in passes)
    assert all(p["redispatched_groups"] == [] for p in passes)


def test_unrecoverable_shard_raises_with_missing_groups(store, tmp_path):
    """When every dispatch of a shard dies (kill env applies to repair
    workers too via a global override), the barrier gives up after
    max_redispatch rounds with a diagnosable error."""
    co = ClusterCoordinator(store, CFG, str(tmp_path / "cl"), n_workers=1,
                            engine="jnp", merge_group=G, max_redispatch=1,
                            env_overrides={0: {KILL_ENV: "0:0"}})
    # make the repair worker die too: patch _spawn to always inject
    orig = co._spawn

    def spawn_all_killed(shard, pass_idx, **kw):
        kw["extra_env"] = {KILL_ENV: "0:0"}
        return orig(shard, pass_idx, **kw)

    co._spawn = spawn_all_killed
    with pytest.raises(RuntimeError, match="missing"):
        co.fit(jax.random.PRNGKey(KEY))
