"""Staged (P-reuse) powerpass/projgram schedule: bitwise parity against
the recompute schedule across the dtype × Ω-source × shape grid, the
shared-budget crossover rule, autotuned schedule cache entries, and the
obs cost model's staged accounting (the roofline must stop charging the
per-bucket projection recompute once a launch goes staged)."""

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.europarl_cca import config as europarl_config
from repro.kernels import ops
from repro.kernels.compat import count_pallas_calls
from repro.kernels.matmul import ROOFLINE_FLOPS_PER_BYTE, pick_schedule
from repro.kernels.powerpass import (choose_powerpass_schedule,
                                     plan_powerpass_staged,
                                     power_project_accumulate,
                                     power_project_accumulate_seeded)
from repro.kernels.projgram import (choose_projgram_schedule,
                                    plan_projgram_staged, projgram,
                                    projgram_seeded)

DTYPES = [jnp.float32, jnp.bfloat16]

# single-bucket, 2-bucket (da·k̃p past the VMEM row cap), and a
# forced-16-bucket geometry; unaligned dims exercise the padding path
SHAPES = [
    (130, 500, 96, 64),       # single bucket
    (256, 4096, 256, 512),    # 2 buckets at kt=512 (row cap 2048)
    (256, 4096, 192, 1100),   # 4 buckets, unaligned db/kt
]


def _rand(key, shape, dt):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dt)


# --------------------------------------------------------------------------
# bitwise parity: staged ≡ recompute (same f32 dot sequence, P exact
# through the HBM round-trip)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,da,db,kt", SHAPES)
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_powerpass_staged_bitwise(n, da, db, kt, dt):
    a, b = _rand(0, (n, da), dt), _rand(1, (n, db), dt)
    q = _rand(2, (db, kt), dt)
    rec = power_project_accumulate(a, b, q, schedule="recompute",
                                   interpret=True)
    stg = power_project_accumulate(a, b, q, schedule="staged",
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(stg))


def test_powerpass_staged_bitwise_forced_buckets():
    """Explicit block_da forcing a 16-bucket sweep stays bitwise equal."""
    a, b = _rand(3, (256, 4096), jnp.float32), _rand(4, (256, 256), jnp.float32)
    q = _rand(5, (256, 512), jnp.float32)
    rec = power_project_accumulate(a, b, q, block_da=256,
                                   schedule="recompute", interpret=True)
    stg = power_project_accumulate(a, b, q, block_da=256,
                                   schedule="staged", interpret=True)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(stg))


@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_powerpass_staged_seeded_bitwise(dt):
    """Seeded Ω: the staged stage kernel generates each Ω tile exactly
    once (phase 1) yet stays bitwise equal to the recompute schedule,
    which regenerates tiles per bucket."""
    a, b = _rand(6, (256, 4096), dt), _rand(7, (256, 256), dt)
    seed = jnp.asarray([3, 7], jnp.uint32)
    rec = power_project_accumulate_seeded(a, b, seed, kt=300,
                                          schedule="recompute",
                                          interpret=True)
    stg = power_project_accumulate_seeded(a, b, seed, kt=300,
                                          schedule="staged", interpret=True)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(stg))


@pytest.mark.parametrize("n,d,kt", [(130, 96, 2176), (256, 512, 512)])
def test_projgram_staged_bitwise(n, d, kt):
    x, q = _rand(8, (n, d), jnp.float32), _rand(9, (d, kt), jnp.float32)
    p_rec, c_rec = projgram(x, q, schedule="recompute", interpret=True)
    p_stg, c_stg = projgram(x, q, schedule="staged", interpret=True)
    np.testing.assert_array_equal(np.asarray(p_rec), np.asarray(p_stg))
    np.testing.assert_array_equal(np.asarray(c_rec), np.asarray(c_stg))


def test_projgram_staged_seeded_bitwise():
    x = _rand(10, (256, 512), jnp.float32)
    seed = jnp.asarray([11, 5], jnp.uint32)
    p_rec, c_rec = projgram_seeded(x, seed, kt=300, schedule="recompute",
                                   interpret=True)
    p_stg, c_stg = projgram_seeded(x, seed, kt=300, schedule="staged",
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(p_rec), np.asarray(p_stg))
    np.testing.assert_array_equal(np.asarray(c_rec), np.asarray(c_stg))


# --------------------------------------------------------------------------
# Europarl eval_shape regression: auto schedule goes staged, all-Pallas
# --------------------------------------------------------------------------


def test_europarl_staged_no_fallback(monkeypatch):
    """At the Europarl chunk shape the auto chooser picks staged and the
    whole launch stays Pallas — zero pallas_matmul fallback calls."""
    from repro.kernels import powerpass as pp

    wl = europarl_config()
    kt = wl.rcca.sketch
    assert choose_powerpass_schedule(
        wl.chunk, wl.da, wl.db, kt, "float32") == "staged"

    calls = {"n": 0}
    real = pp.pallas_matmul

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pp, "pallas_matmul", counting)
    a = jax.ShapeDtypeStruct((wl.chunk, wl.da), jnp.float32)
    b = jax.ShapeDtypeStruct((wl.chunk, wl.db), jnp.float32)
    q = jax.ShapeDtypeStruct((wl.db, kt), jnp.float32)
    out = jax.eval_shape(
        functools.partial(pp.power_project_accumulate, schedule="staged",
                          interpret=True), a, b, q)
    assert out.shape == (wl.da, kt)
    assert calls["n"] == 0

    # staged = exactly 2 pallas_calls (stage + sweep) per view
    jaxpr = jax.make_jaxpr(
        lambda *xs: pp.power_project_accumulate(
            *xs, schedule="staged", interpret=True))(a, b, q)
    assert count_pallas_calls(jaxpr) == 2


# --------------------------------------------------------------------------
# crossover rule
# --------------------------------------------------------------------------


def test_pick_schedule_roofline_rule():
    # compute-bound entries compare by flops/roofline
    r = ROOFLINE_FLOPS_PER_BYTE
    assert pick_schedule({"a": (100 * r, 1), "b": (10 * r, 1)}) == "b"
    # memory-bound entries compare by bytes
    assert pick_schedule({"a": (1, 100), "b": (1, 10)}) == "b"
    # mixed: max(flops/roofline, bytes) per schedule
    assert pick_schedule({"rec": (1000 * r, 10), "stg": (10 * r, 500)}) == "stg"
    # deterministic tie-break: sorted-name order
    assert pick_schedule({"z": (5, 5), "a": (5, 5)}) == "a"


def test_choose_schedule_regimes():
    # tiny single-bucket shape: nothing to reuse → recompute
    assert choose_powerpass_schedule(256, 256, 256, 64, "float32") == "recompute"
    assert choose_projgram_schedule(256, 256, 64, "float32") == "recompute"
    # Europarl-scale many-bucket shapes → staged
    assert choose_powerpass_schedule(
        8192, 1 << 19, 2048, 2060, "float32") == "staged"
    assert choose_projgram_schedule(8192, 1 << 19, 2060, "float32") == "staged"
    # staged projgram requires a f32 P contract
    assert choose_projgram_schedule(
        8192, 1 << 19, 2060, "float32", p_dtype=jnp.bfloat16) == "recompute"
    # degenerate sketch (no plan at all) → recompute fallback
    assert choose_powerpass_schedule(128, 64, 96, 9000, "float32") == "recompute"


def test_projgram_staged_plan_requires_f32_p():
    assert plan_projgram_staged(8192, 1 << 19, 2060, "float32",
                                p_dtype=jnp.bfloat16) is None
    assert plan_projgram_staged(8192, 1 << 19, 2060, "float32") is not None


def test_staged_plans_share_recompute_geometry():
    """The staged plans tile exactly like the recompute base plan — the
    structural half of the bitwise-parity argument."""
    from repro.kernels.powerpass import plan_powerpass

    base = plan_powerpass(256, 4096, 256, 512, "float32")
    stage, sweep = plan_powerpass_staged(256, 4096, 256, 512, "float32")
    assert stage.in_specs[0].shape[0] == base.in_specs[0].shape[0]  # bn
    assert stage.in_specs[0].shape[1] == base.in_specs[1].shape[1]  # bdb
    assert sweep.in_specs[0].shape == base.in_specs[0].shape        # (bn, bda)
    assert sweep.out_specs[0].padded == base.out_specs[0].padded


# --------------------------------------------------------------------------
# autotuned schedule cache entries
# --------------------------------------------------------------------------


def test_schedule_cache_roundtrip(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("RCCA_AUTOTUNE_CACHE",
                       str(tmp_path / "sched.json"))
    autotune.reset()
    dims = (256, 256, 512, 4096)
    assert autotune.lookup_schedule("powerpass-staged", dims, "float32") is None
    autotune.record_schedule("powerpass-staged", dims, "float32", "staged",
                             us=10.0)
    assert autotune.lookup_schedule(
        "powerpass-staged", dims, "float32") == "staged"
    # the tuned entry overrides the analytic crossover in the chooser
    assert choose_powerpass_schedule(256, 4096, 256, 512, "float32") == "staged"
    # a malformed value is ignored, not trusted
    path = autotune.cache_path()
    cache = json.load(open(path))
    for k in cache:
        cache[k]["schedule"] = "bogus"
    json.dump(cache, open(path, "w"))
    autotune.reset()  # drop the in-memory copy, force a file re-read
    assert autotune.lookup_schedule("powerpass-staged", dims, "float32") is None
    autotune.reset()


def test_autotune_staged_smoke(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("RCCA_AUTOTUNE_CACHE", str(tmp_path / "sched.json"))
    autotune.reset()
    a, b = _rand(12, (256, 4096), jnp.float32), _rand(13, (256, 256), jnp.float32)
    q = _rand(14, (256, 512), jnp.float32)
    win = autotune.autotune_powerpass_staged(a, b, q, interpret=True, iters=1)
    assert win in ("staged", "recompute")
    assert autotune.lookup_schedule(
        "powerpass-staged", (256, 256, 512, 4096), "float32") == win
    x, qq = _rand(15, (256, 512), jnp.float32), _rand(16, (512, 512), jnp.float32)
    win2 = autotune.autotune_projgram_staged(x, qq, interpret=True, iters=1)
    assert win2 in ("staged", "recompute")
    autotune.reset()


def test_schedule_cache_entries_pass_kernel_check(tmp_path, monkeypatch):
    from repro.analysis.kernel_check import check_autotune_cache
    from repro.kernels import autotune

    monkeypatch.setenv("RCCA_AUTOTUNE_CACHE", str(tmp_path / "sched.json"))
    autotune.reset()
    autotune.record_schedule("powerpass-staged", (256, 256, 512, 4096),
                             "float32", "staged")
    autotune.record_schedule("projgram-staged", (256, 512, 512),
                             "float32", "recompute")
    assert check_autotune_cache() == []
    path = autotune.cache_path()
    cache = json.load(open(path))
    k = sorted(cache)[0]
    cache[k]["schedule"] = "bogus"
    json.dump(cache, open(path, "w"))
    autotune.reset()
    vs = check_autotune_cache()
    assert len(vs) == 1 and vs[0].code == "RCCA107"
    autotune.reset()


# --------------------------------------------------------------------------
# obs cost model: staged launches stop charging the recompute
# --------------------------------------------------------------------------


def test_europarl_chunk_cost_drops_recompute():
    """Acceptance: modelled chunk FLOPs at the Europarl shape drop from
    n_buckets·proj + acc (recompute) to proj + acc (staged)."""
    from repro.obs.cost import plan_cost

    wl = europarl_config()
    kt = wl.rcca.sketch
    ops.chunk_cost.cache_clear()
    auto = ops.chunk_cost("power", wl.chunk, wl.da, wl.db, kt, "float32",
                          engine="kernels")
    rec = ops.chunk_cost("power", wl.chunk, wl.da, wl.db, kt, "float32",
                         engine="kernels", schedule="recompute")
    assert auto["schedule"] == "staged"
    assert rec["schedule"] == "recompute"

    # staged chunk flops == 2 views × (proj + acc) from the plan pair
    stage, sweep = plan_powerpass_staged(wl.chunk, wl.da, wl.db, kt,
                                         "float32")
    per_view = plan_cost(stage)["flops"] + plan_cost(sweep)["flops"]
    assert auto["flops"] == 2 * per_view
    # the recompute model still charges n_buckets·proj — orders more
    assert rec["flops"] > 100 * auto["flops"]
    # jnp engine reports no kernel schedule
    assert ops.chunk_cost("power", wl.chunk, wl.da, wl.db, kt, "float32",
                          engine="jnp")["schedule"] is None


def test_chunk_span_carries_schedule(tmp_path, monkeypatch):
    """The engine stamps the resolved schedule on chunk spans, so the
    timeline shows the staged-vs-recompute choice per launch."""
    monkeypatch.setenv("RCCA_TRACE", str(tmp_path / "trace"))
    from repro.core.rcca import RCCAConfig
    from repro.data import PlantedCCAData
    from repro.exec import Local
    from repro.exec import fit as exec_fit
    from repro.obs import load_events
    from repro.store import ingest_planted

    data = PlantedCCAData(n=256, da=24, db=16, rank=4, noise=0.4,
                          seed=13, chunk=128)
    store = ingest_planted(str(tmp_path / "store"), data)
    cfg = RCCAConfig(k=3, p=5, q=1)
    exec_fit(store, cfg, jax.random.PRNGKey(7), topology=Local(),
             engine="kernels")
    spans = [e for e in load_events(str(tmp_path / "trace"))
             if e.get("ev") == "span" and e.get("name") == "chunk"]
    assert spans, "no chunk spans recorded"
    assert all("schedule" in (s.get("attrs") or {}) for s in spans)
