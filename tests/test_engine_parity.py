"""Compat-shim behaviour (both jax API spellings), kernel-vs-jnp engine
parity for the data-pass drivers, the fused power-pass acceptance
criteria, and the block-size autotuner."""

import contextlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.pallas import tpu as pltpu

from repro.core.rcca import (
    RCCAConfig,
    randomized_cca_iterator,
    randomized_cca_streaming,
    resolve_engine,
)
from repro.core.rcca_dist import dist_randomized_cca
from repro.kernels import autotune, compat, ops, ref
from repro.kernels.powerpass import power_project_accumulate
from repro.data import planted_views


# --------------------------------------------------------------------------
# compat shim
# --------------------------------------------------------------------------


def test_compiler_params_old_spelling():
    """On jax 0.4.x (no pltpu.CompilerParams) the shim must build a
    TPUCompilerParams; on newer jax, whichever class pallas accepts."""
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary")
    )
    expected = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    assert isinstance(params, expected)
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")


def test_compiler_params_new_spelling(monkeypatch):
    """When pltpu.CompilerParams exists (jax ≥ 0.5) it must win."""

    class FakeCompilerParams:
        def __init__(self, dimension_semantics=None, **kw):
            self.dimension_semantics = dimension_semantics

    monkeypatch.setattr(pltpu, "CompilerParams", FakeCompilerParams,
                        raising=False)
    params = compat.tpu_compiler_params(dimension_semantics=("arbitrary",))
    assert isinstance(params, FakeCompilerParams)


def test_set_mesh_old_spelling():
    """Without jax.set_mesh the shim enters the mesh's own context."""
    if hasattr(jax, "set_mesh"):
        pytest.skip("this jax has jax.set_mesh; old spelling unreachable")
    mesh = jax.make_mesh((1,), ("data",))
    from jax._src import mesh as mesh_lib

    with compat.set_mesh(mesh):
        assert mesh_lib.thread_resources.env.physical_mesh == mesh
    assert mesh_lib.thread_resources.env.physical_mesh.empty


def test_set_mesh_new_spelling(monkeypatch):
    """With jax.set_mesh present (jax ≥ 0.5) the shim must call it."""
    calls = []

    @contextlib.contextmanager
    def fake_set_mesh(mesh):
        calls.append(mesh)
        yield

    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    mesh = jax.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        pass
    assert calls == [mesh]


def test_cost_analysis_normalized():
    class FakeCompiledList:
        def cost_analysis(self):
            return [{"flops": 7.0}]

    class FakeCompiledDict:
        def cost_analysis(self):
            return {"flops": 7.0}

    assert compat.cost_analysis(FakeCompiledList())["flops"] == 7.0
    assert compat.cost_analysis(FakeCompiledDict())["flops"] == 7.0


def test_resolve_engine():
    assert resolve_engine("kernels") == "kernels"
    assert resolve_engine("jnp") == "jnp"
    # legacy boolean spelling wins when passed explicitly
    assert resolve_engine("kernels", use_kernels=False) == "jnp"
    assert resolve_engine("jnp", use_kernels=True) == "kernels"
    with pytest.raises(ValueError):
        resolve_engine("cuda")


# --------------------------------------------------------------------------
# fused power pass: acceptance criteria
# --------------------------------------------------------------------------


def test_power_pass_chunk_is_fused():
    """≤ 2 pallas_calls per chunk (one fused kernel per view), down from
    the 4 of the unfused project/accumulate pairs."""
    a = jnp.zeros((256, 192))
    b = jnp.zeros((256, 160))
    Qa = jnp.zeros((192, 96))
    Qb = jnp.zeros((160, 96))
    jaxpr = jax.make_jaxpr(
        lambda *xs: ops.power_pass_chunk(*xs, interpret=True)
    )(a, b, Qa, Qb)
    assert compat.count_pallas_calls(jaxpr) <= 2


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_power_project_accumulate_matches_ref(dt):
    kx = jax.random.PRNGKey(0)
    a = jax.random.normal(kx, (384, 300), dt)
    b = jax.random.normal(jax.random.PRNGKey(1), (384, 200), dt)
    q = jax.random.normal(jax.random.PRNGKey(2), (200, 160), dt)
    got = power_project_accumulate(a, b, q, interpret=True)
    want = ref.matmul_ref(a, ref.matmul_ref(b, q), transpose_lhs=True)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel <= (1e-4 if dt == jnp.float32 else 2e-2), rel


def test_power_project_accumulate_large_block_bucketed():
    """dap·k̃p over the per-block VMEM cap now runs the bucketed fused
    grid (it used to fall back to the unfused pair) and stays correct."""
    a = jax.random.normal(jax.random.PRNGKey(0), (128, 1100))  # dap = 1152
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 96))
    q = jax.random.normal(jax.random.PRNGKey(2), (96, 1100))  # ktp = 1152
    got = power_project_accumulate(a, b, q, interpret=True)
    want = ref.matmul_ref(a, ref.matmul_ref(b, q), transpose_lhs=True)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel <= 1e-4, rel


def test_power_project_accumulate_degenerate_fallback():
    """k̃p > 8192 (no 128-row block fits VMEM) still takes the unfused
    matmul pair and stays correct."""
    a = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 96))
    q = jax.random.normal(jax.random.PRNGKey(2), (96, 8300))  # ktp = 8320
    got = power_project_accumulate(a, b, q, interpret=True)
    want = ref.matmul_ref(a, ref.matmul_ref(b, q), transpose_lhs=True)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel <= 1e-4, rel


# --------------------------------------------------------------------------
# engine parity: streaming / iterator / dist drivers
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def views():
    A, B = planted_views(3, n=1200, da=40, db=32, rank=5, noise=0.4)
    return jnp.asarray(A), jnp.asarray(B)


@pytest.mark.parametrize("dt,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 3e-2)],
                         ids=["f32", "bf16"])
def test_streaming_engine_parity(views, dt, tol):
    A, B = views
    cfg = RCCAConfig(k=4, p=12, q=1, lam_a=1e-3, lam_b=1e-3, dtype=dt)
    Ac = A.astype(dt).reshape(4, 300, A.shape[1])
    Bc = B.astype(dt).reshape(4, 300, B.shape[1])
    r_k = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(0), engine="kernels")
    r_j = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(0), engine="jnp")
    np.testing.assert_allclose(np.asarray(r_k.rho), np.asarray(r_j.rho), atol=tol)
    np.testing.assert_allclose(np.asarray(jnp.abs(r_k.Xa)), np.asarray(jnp.abs(r_j.Xa)),
                               atol=max(tol, 1e-3) * 30)


def test_streaming_legacy_use_kernels_flag(views):
    A, B = views
    cfg = RCCAConfig(k=4, p=12, q=1, lam_a=1e-3, lam_b=1e-3)
    Ac = A.reshape(4, 300, A.shape[1])
    Bc = B.reshape(4, 300, B.shape[1])
    r_legacy = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(0),
                                        use_kernels=False)
    r_jnp = randomized_cca_streaming(Ac, Bc, cfg, jax.random.PRNGKey(0),
                                     engine="jnp")
    np.testing.assert_array_equal(np.asarray(r_legacy.rho), np.asarray(r_jnp.rho))


def test_iterator_engine_parity(views):
    A, B = views
    da, db = A.shape[1], B.shape[1]
    cfg = RCCAConfig(k=4, p=12, q=1, lam_a=1e-3, lam_b=1e-3)
    chunks = [(np.asarray(A[i::3]), np.asarray(B[i::3])) for i in range(3)]
    r_k = randomized_cca_iterator(lambda: iter(chunks), da, db, cfg,
                                  jax.random.PRNGKey(1), engine="kernels")
    r_j = randomized_cca_iterator(lambda: iter(chunks), da, db, cfg,
                                  jax.random.PRNGKey(1), engine="jnp")
    np.testing.assert_allclose(np.asarray(r_k.rho), np.asarray(r_j.rho), atol=1e-4)


def test_dist_engine_parity_single_device(views):
    """The dist driver's engine knob on a trivial mesh (the multi-device
    kernel path is covered by test_distributed.py)."""
    A, B = views
    mesh = jax.make_mesh((1,), ("data",))
    cfg = RCCAConfig(k=4, p=12, q=1, lam_a=1e-3, lam_b=1e-3)
    kw = dict(row_axes=("data",), col_axis=None, microbatch=300)
    r_k = dist_randomized_cca(A, B, cfg, jax.random.PRNGKey(2), mesh,
                              engine="kernels", **kw)
    r_j = dist_randomized_cca(A, B, cfg, jax.random.PRNGKey(2), mesh,
                              engine="jnp", **kw)
    np.testing.assert_allclose(np.asarray(r_k.rho), np.asarray(r_j.rho), atol=1e-4)


# --------------------------------------------------------------------------
# autotuner
# --------------------------------------------------------------------------


@pytest.fixture()
def tuned_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("RCCA_AUTOTUNE_CACHE", path)
    autotune.reset()
    yield path
    autotune.reset()


def test_autotune_record_lookup_roundtrip(tuned_cache):
    assert autotune.lookup("matmul_nn", 256, 256, 256, jnp.float32) == \
        autotune.DEFAULT_CAPS
    autotune.record("matmul_nn", 256, 256, 256, jnp.float32, (128, 256, 128),
                    us=12.5)
    assert autotune.lookup("matmul_nn", 256, 256, 256, jnp.float32) == (128, 256, 128)
    # persisted: survives an in-memory reset
    autotune.reset()
    assert autotune.lookup("matmul_nn", 256, 256, 256, jnp.float32) == (128, 256, 128)
    with open(tuned_cache) as f:
        stored = json.load(f)
    assert len(stored) == 1 and "blocks" in next(iter(stored.values()))


def test_autotune_sweep_and_matmul_pickup(tuned_cache):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 199))
    y = jax.random.normal(jax.random.PRNGKey(1), (199, 256))
    blocks = autotune.autotune_matmul(x, y, interpret=True, iters=1)
    Mp, Kp, Np = 256, 256, 256
    assert Mp % blocks[0] == 0 and Np % blocks[1] == 0 and Kp % blocks[2] == 0
    assert autotune.lookup("matmul_nn", Mp, Kp, Np, jnp.float32) == blocks
    # the default-blocks matmul path resolves through the tuned entry
    from repro.kernels import pallas_matmul

    out = pallas_matmul(x, y, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, y)),
                               atol=2e-4, rtol=2e-4)


def test_autotune_corrupt_cache_falls_back(tuned_cache):
    with open(tuned_cache, "w") as f:
        f.write("{not json")
    autotune.reset()
    assert autotune.lookup("matmul_nn", 512, 512, 512, jnp.float32) == \
        autotune.DEFAULT_CAPS


def test_autotune_malformed_entry_falls_back(tuned_cache):
    """Valid JSON but wrong schema must not break the engine."""
    key = autotune.shape_key("matmul_nn", 256, 256, 256, jnp.float32)
    with open(tuned_cache, "w") as f:
        json.dump({key: {"bm": 128}}, f)
    autotune.reset()
    assert autotune.lookup("matmul_nn", 256, 256, 256, jnp.float32) == \
        autotune.DEFAULT_CAPS
