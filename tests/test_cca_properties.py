"""Property-based tests (hypothesis) on system invariants.

hypothesis is an optional dev dependency (see requirements-dev.txt);
this module skips cleanly — instead of aborting collection — when it
is not installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import exact_cca, randomized_cca
from repro.core.linalg import orth
from repro.core.rcca import RCCAConfig
from repro.distributed import int8_decode, int8_encode

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=2, max_value=24)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=20, deadline=None)
@given(seeds, st.integers(8, 40), st.integers(2, 8), st.floats(0.0, 6.0))
def test_orth_orthonormal(seed, rows, cols, log_cond):
    """orth() returns orthonormal columns for ANY conditioning —
    power iteration squares κ, so this must hold over a wide range."""
    rng = np.random.default_rng(seed)
    rows = max(rows, cols)
    Y = rng.standard_normal((rows, cols)).astype(np.float32)
    # impose condition number ~ 10^log_cond
    scales = np.logspace(0, -log_cond, cols).astype(np.float32)
    Q = orth(jnp.asarray(Y * scales))
    G = np.asarray(Q.T @ Q)
    np.testing.assert_allclose(G, np.eye(cols), atol=5e-2)


@settings(max_examples=10, deadline=None)
@given(seeds, dims, dims)
def test_cca_correlations_bounded(seed, da, db):
    """Canonical correlations always lie in [0, 1] (λ > 0 ⇒ < 1)."""
    rng = np.random.default_rng(seed)
    n, k = 200, 2
    A = jnp.asarray(rng.standard_normal((n, da)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((n, db)).astype(np.float32))
    sol = exact_cca(A, B, k, 1e-2, 1e-2)
    rho = np.asarray(sol.rho)
    assert np.all(rho >= -1e-5) and np.all(rho <= 1.0 + 1e-5)


@settings(max_examples=10, deadline=None)
@given(seeds)
def test_cca_invariance_under_invertible_transforms(seed):
    """CCA (λ=0) is invariant to invertible per-view linear maps."""
    rng = np.random.default_rng(seed)
    n, da, db, k = 400, 8, 6, 3
    A = rng.standard_normal((n, da)).astype(np.float32)
    B = rng.standard_normal((n, db)).astype(np.float32)
    M = rng.standard_normal((da, da)).astype(np.float32) + 3 * np.eye(da, dtype=np.float32)
    N = rng.standard_normal((db, db)).astype(np.float32) + 3 * np.eye(db, dtype=np.float32)
    r1 = exact_cca(jnp.asarray(A), jnp.asarray(B), k)
    r2 = exact_cca(jnp.asarray(A @ M), jnp.asarray(B @ N), k)
    np.testing.assert_allclose(np.asarray(r1.rho), np.asarray(r2.rho), atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seeds)
def test_rcca_seed_stability(seed):
    """With ample oversampling the sketch seed barely matters."""
    rng = np.random.default_rng(seed)
    n, da, db, k = 500, 16, 12, 3
    Z = rng.standard_normal((n, k)).astype(np.float32)
    A = jnp.asarray(Z @ rng.standard_normal((k, da)).astype(np.float32)
                    + 0.3 * rng.standard_normal((n, da)).astype(np.float32))
    B = jnp.asarray(Z @ rng.standard_normal((k, db)).astype(np.float32)
                    + 0.3 * rng.standard_normal((n, db)).astype(np.float32))
    cfg = RCCAConfig(k=k, p=8, q=2, lam_a=1e-3, lam_b=1e-3)
    r1 = randomized_cca(A, B, cfg, jax.random.PRNGKey(seed % 97))
    r2 = randomized_cca(A, B, cfg, jax.random.PRNGKey(seed % 89 + 1))
    np.testing.assert_allclose(np.asarray(r1.rho), np.asarray(r2.rho), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(seeds, st.integers(1, 4), st.integers(3, 300))
def test_int8_roundtrip_error_bound(seed, lead, d):
    """Blockwise int8: |x − dec(enc(x))| ≤ scale/2 elementwise."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((lead, d)) * 10 ** rng.uniform(-3, 3)).astype(np.float32))
    q, scale = int8_encode(x, block=64)
    xr = int8_decode(q, scale, d)
    nb = q.shape[-2]
    bound = np.repeat(np.asarray(scale), 64, axis=-1)[..., :d] * 0.5 + 1e-12
    assert np.all(np.abs(np.asarray(x - xr)) <= bound * 1.001)
