"""Seeded-Ω path: the counter-based tile PRNG and everything built on it.

The bitwise contract under test (see repro/kernels/rand.py): Ω(seed) is
a pure function of ``(seed, row, col)``, so

  * any lane-aligned tile of it equals the matching slice of the
    materialized :func:`dense_omega` bit-for-bit (block-shape
    invariance — what lets the fused kernels generate Ω in-VMEM),
  * the ``*_seeded`` kernels are bitwise identical to their
    materialized twins fed ``dense_omega`` at the same block config,
  * a full fit with ``omega="seeded"`` is bitwise identical to the
    ``omega="seeded-materialized"`` oracle per engine, and a seeded
    fit kill/resumed through a pass cursor (whose pass-0 Qa/Qb slots
    hold the (2,)-uint32 seeds) reproduces it exactly,
  * the seeded pass-0 update never materializes the ``(d, k̃)`` Ω —
    pinned structurally on the jaxpr.

Plus the pass-path correctness fixes that rode along: prefetcher error
propagation (a failed read is never silently dropped), stale-partial
cleanup failures surfacing instead of passing silently, init_Q's
generate-in-f32-then-cast entropy rule, and the RCCA108/RCCA006
static-analysis rules that police the seeded plumbing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import kernel_check, lint
from repro.core.rcca import (
    OMEGA_MODES,
    RCCAConfig,
    init_Q,
    omega_seeds,
    randomized_cca_iterator,
    resolve_omega,
    seeded_update_fn,
    stats_init_fn,
    update_fn,
)
from repro.cluster import partials as pt
from repro.data import PlantedCCAData
from repro.kernels import ops, rand
from repro.kernels.plan import BlockDef, KernelPlan, ScalarDef
from repro.store import PassRunner, ingest_planted
from repro.store.prefetch import ChunkPrefetcher

U32 = jnp.uint32
SEED = jnp.array([0xDEADBEEF, 0x12345678], dtype=U32)


def codes(violations):
    return sorted(v.code for v in violations)


# --------------------------------------------------------------------------
# generator invariance: tiles == dense slices, bit-for-bit
# --------------------------------------------------------------------------


class TestGenerator:
    D, KT = 300, 200  # ragged on purpose: padded to (384, 256)

    def test_row_tiles_match_dense_slices(self):
        dense = np.asarray(rand.dense_omega(SEED, self.D, self.KT))
        for r0 in (0, 128, 256):
            tile = np.asarray(rand.normal_tile(
                SEED[0], SEED[1], U32(r0), U32(0), (128, 256),
                row_limit=self.D, col_limit=self.KT))
            rows = min(128, self.D - r0)
            assert np.array_equal(tile[:rows, :self.KT], dense[r0:r0 + rows])
            # masked padding is exactly 0.0 (matches zero-padded operands)
            assert not tile[rows:, :].any()
            assert not tile[:, self.KT:].any()

    def test_column_tile_matches_dense_slice(self):
        dense = np.asarray(rand.dense_omega(SEED, self.D, self.KT))
        tile = np.asarray(rand.normal_tile(
            SEED[0], SEED[1], U32(128), U32(128), (128, 128),
            row_limit=self.D, col_limit=self.KT))
        assert np.array_equal(tile[:, :self.KT - 128],
                              dense[128:256, 128:self.KT])

    def test_dense_omega_jit_matches_eager(self):
        eager = rand.dense_omega(SEED, self.D, self.KT)
        jitted = jax.jit(lambda s: rand.dense_omega(s, self.D, self.KT))(SEED)
        assert np.array_equal(np.asarray(eager), np.asarray(jitted))

    def test_dense_omega_bf16_is_f32_generation_cast_once(self):
        f32 = rand.dense_omega(SEED, self.D, self.KT, jnp.float32)
        bf16 = rand.dense_omega(SEED, self.D, self.KT, jnp.bfloat16)
        assert bf16.dtype == jnp.bfloat16
        assert np.array_equal(np.asarray(f32.astype(jnp.bfloat16)),
                              np.asarray(bf16))

    def test_distinct_seeds_distinct_omegas(self):
        other = jnp.array([1, 2], dtype=U32)
        a = np.asarray(rand.dense_omega(SEED, self.D, self.KT))
        b = np.asarray(rand.dense_omega(other, self.D, self.KT))
        assert not np.array_equal(a, b)


def test_resolve_omega_validates():
    for m in OMEGA_MODES:
        assert resolve_omega(m) == m
    with pytest.raises(ValueError, match="unknown omega"):
        resolve_omega("lazy")


def test_init_q_seeded_is_dense_omega_of_omega_seeds():
    """init_Q's seeded modes and the seed plumbing derive the SAME Ω:
    the materialized oracle and the in-kernel path share one source."""
    key = jax.random.PRNGKey(42)
    cfg = RCCAConfig(k=2, p=2)
    da, db = 24, 16
    seed_a, seed_b = omega_seeds(key)
    Qa, Qb = init_Q(key, da, db, cfg, omega="seeded")
    assert np.array_equal(
        np.asarray(Qa), np.asarray(rand.dense_omega(seed_a, da, cfg.sketch)))
    assert np.array_equal(
        np.asarray(Qb), np.asarray(rand.dense_omega(seed_b, db, cfg.sketch)))


def test_init_q_generates_in_f32_then_casts():
    """Entropy rule: a bf16 sketch is the f32 draw cast once — drawing
    natively in bf16 would quantize the uniforms (and diverge from the
    seeded kernels' generate-in-f32-then-cast semantics)."""
    key = jax.random.PRNGKey(7)
    da, db = 24, 16
    for omega in OMEGA_MODES:
        cfg32 = RCCAConfig(k=2, p=2, dtype=jnp.float32)
        cfg16 = RCCAConfig(k=2, p=2, dtype=jnp.bfloat16)
        Qa32, Qb32 = init_Q(key, da, db, cfg32, omega=omega)
        Qa16, Qb16 = init_Q(key, da, db, cfg16, omega=omega)
        assert Qa16.dtype == jnp.bfloat16 and Qb16.dtype == jnp.bfloat16
        assert np.array_equal(np.asarray(Qa32.astype(jnp.bfloat16)),
                              np.asarray(Qa16)), omega
        assert np.array_equal(np.asarray(Qb32.astype(jnp.bfloat16)),
                              np.asarray(Qb16)), omega


# --------------------------------------------------------------------------
# seeded kernels == materialized kernels fed dense_omega (same blocks)
# --------------------------------------------------------------------------


def _chunk(rng, c, d, dtype):
    return jnp.asarray(rng.standard_normal((c, d)), dtype)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_power_pass_chunk_seeded_matches_materialized(dtype):
    q_dtype = jnp.dtype(dtype)
    rng = np.random.default_rng(0)
    c, da, db, kt = 16, 40, 24, 12
    a, b = _chunk(rng, c, da, q_dtype), _chunk(rng, c, db, q_dtype)
    seed_a, seed_b = omega_seeds(jax.random.PRNGKey(1))
    Qa = rand.dense_omega(seed_a, da, kt, q_dtype)
    Qb = rand.dense_omega(seed_b, db, kt, q_dtype)
    dYa_s, dYb_s = ops.power_pass_chunk_seeded(a, b, seed_a, seed_b,
                                               kt=kt, q_dtype=q_dtype)
    dYa_m, dYb_m = ops.power_pass_chunk(a, b, Qa, Qb)
    assert np.array_equal(np.asarray(dYa_s), np.asarray(dYa_m))
    assert np.array_equal(np.asarray(dYb_s), np.asarray(dYb_m))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_final_pass_chunk_seeded_matches_materialized(dtype):
    q_dtype = jnp.dtype(dtype)
    rng = np.random.default_rng(2)
    c, da, db, kt = 16, 40, 24, 12
    a, b = _chunk(rng, c, da, q_dtype), _chunk(rng, c, db, q_dtype)
    seed_a, seed_b = omega_seeds(jax.random.PRNGKey(3))
    Qa = rand.dense_omega(seed_a, da, kt, q_dtype)
    Qb = rand.dense_omega(seed_b, db, kt, q_dtype)
    got = ops.final_pass_chunk_seeded(a, b, seed_a, seed_b,
                                      kt=kt, q_dtype=q_dtype)
    want = ops.final_pass_chunk(a, b, Qa, Qb)
    for g, w, name in zip(got, want, ("Ca", "Cb", "F")):
        assert np.array_equal(np.asarray(g), np.asarray(w)), name


# --------------------------------------------------------------------------
# fit-level: omega="seeded" == the seeded-materialized oracle, bitwise
# --------------------------------------------------------------------------

DA, DB = 12, 9
_CHUNKS = [
    (jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
    for a, b in (
        (np.random.default_rng(100 + i).standard_normal((8, DA)),
         np.random.default_rng(200 + i).standard_normal((8, DB)))
        for i in range(4)
    )
]


def _source_factory(start=0):
    return iter(_CHUNKS[start:])


def _fit(omega, engine, cfg):
    return randomized_cca_iterator(
        _source_factory, DA, DB, cfg, jax.random.PRNGKey(5),
        engine=engine, merge_group=2, omega=omega, n_chunks=len(_CHUNKS))


def _assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs"


@pytest.mark.parametrize("engine", ["kernels", "jnp"])
@pytest.mark.parametrize("cfg", [
    RCCAConfig(k=2, p=2, q=0, nu=0.01),
    RCCAConfig(k=2, p=2, q=1, nu=0.01, center=True),
], ids=["q0-sketch", "q1-centered"])
def test_fit_seeded_matches_oracle_bitwise(engine, cfg):
    """The acceptance criterion: under BOTH engines, the seeded path
    (in-kernel Ω tiles under "kernels"; local stateless materialization
    under "jnp") reproduces the materialized-up-front oracle exactly —
    including the q=0 direct sketch and the centered power boundary,
    the two places the engine must materialize Q from the seed."""
    _assert_bit_identical(_fit("seeded", engine, cfg),
                          _fit("seeded-materialized", engine, cfg))


# --------------------------------------------------------------------------
# no (d, k̃) Ω array exists in the seeded pass — structural jaxpr check
# --------------------------------------------------------------------------


def _sub_jaxprs(p):
    if isinstance(p, jax.core.ClosedJaxpr):
        yield p.jaxpr
    elif isinstance(p, jax.core.Jaxpr):
        yield p
    elif isinstance(p, (tuple, list)):
        for q in p:
            yield from _sub_jaxprs(q)


def _shapes(jaxpr, out):
    """All aval shapes in a jaxpr, recursing through sub-jaxprs but NOT
    into pallas kernels — in-VMEM tiles are the point of the design;
    the claim is about what exists at the XLA/HBM level."""
    for v in list(jaxpr.invars) + list(jaxpr.constvars) + list(jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            out.append(tuple(aval.shape))
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(tuple(aval.shape))
        if "pallas" in eqn.primitive.name:
            continue
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                _shapes(sub, out)
    return out


def test_seeded_final_update_never_materializes_omega():
    """In the final (q=0) update the ONLY (d, k̃)-shaped arrays of the
    materialized path are Ω themselves (stats are (k̃, k̃)) — so the
    seeded jaxpr must contain NO such aval anywhere outside the pallas
    kernels, while the materialized control must (detector is not
    vacuous)."""
    c, da, db, kt = 8, 512, 384, 256
    s = stats_init_fn("final", da, db, kt)()
    a = jnp.zeros((c, da), jnp.float32)
    b = jnp.zeros((c, db), jnp.float32)
    seed_a, seed_b = omega_seeds(jax.random.PRNGKey(0))

    seeded = jax.make_jaxpr(seeded_update_fn("final", kt, jnp.float32))(
        s, a, b, seed_a, seed_b)
    shapes = set(_shapes(seeded.jaxpr, []))
    assert (da, kt) not in shapes and (db, kt) not in shapes

    Qa = jnp.zeros((da, kt), jnp.float32)
    Qb = jnp.zeros((db, kt), jnp.float32)
    control = jax.make_jaxpr(update_fn("final", "kernels"))(s, a, b, Qa, Qb)
    cshapes = set(_shapes(control.jaxpr, []))
    assert (da, kt) in cshapes and (db, kt) in cshapes


def test_seeded_power_update_inputs_carry_seeds_not_omega():
    """The power update legitimately holds (d, k̃) arrays (the Y
    accumulators), so the structural claim is on the input signature:
    exactly ONE (d, k̃) invar per view (the accumulator) plus two
    (2,)-uint32 seeds — the materialized twin has TWO per view."""
    c, da, db, kt = 8, 512, 384, 256
    s = stats_init_fn("power", da, db, kt)()
    a = jnp.zeros((c, da), jnp.float32)
    b = jnp.zeros((c, db), jnp.float32)
    seed_a, seed_b = omega_seeds(jax.random.PRNGKey(0))

    seeded = jax.make_jaxpr(seeded_update_fn("power", kt, jnp.float32))(
        s, a, b, seed_a, seed_b)
    invars = [tuple(v.aval.shape) for v in seeded.jaxpr.invars]
    assert invars.count((da, kt)) == 1 and invars.count((db, kt)) == 1
    assert invars.count((2,)) == 2

    Qa = jnp.zeros((da, kt), jnp.float32)
    Qb = jnp.zeros((db, kt), jnp.float32)
    control = jax.make_jaxpr(update_fn("power", "kernels"))(s, a, b, Qa, Qb)
    cinvars = [tuple(v.aval.shape) for v in control.jaxpr.invars]
    assert cinvars.count((da, kt)) == 2 and cinvars.count((db, kt)) == 2


# --------------------------------------------------------------------------
# store-backed seeded fits: cursors hold seeds, resume is bit-identical
# --------------------------------------------------------------------------


class Kill(Exception):
    """Simulated mid-pass crash."""


@pytest.fixture(scope="module")
def seed_store(tmp_path_factory):
    data = PlantedCCAData(n=600, da=24, db=16, rank=4, noise=0.3,
                          seed=11, chunk=100)  # 6 chunks per pass
    return ingest_planted(str(tmp_path_factory.mktemp("seeded") / "store"),
                          data)


SCFG = RCCAConfig(k=3, p=5, q=1, nu=0.01, center=True)


def test_seeded_kill_resume_bit_identical(seed_store, tmp_path):
    """Kill a seeded kernels-engine fit mid pass 0 — where the cursor's
    Qa/Qb slots hold the (2,)-uint32 seeds, not (d, k̃) bases — and the
    resumed fit must reproduce the uninterrupted one bitwise."""
    key = jax.random.PRNGKey(3)
    base = PassRunner(seed_store, SCFG, engine="kernels", prefetch=0,
                      omega="seeded").fit(key)
    oracle = PassRunner(seed_store, SCFG, engine="kernels", prefetch=0,
                        omega="seeded-materialized").fit(key)
    _assert_bit_identical(base, oracle)

    ck = str(tmp_path / "ck")
    runner = PassRunner(seed_store, SCFG, engine="kernels", prefetch=0,
                        ckpt_dir=ck, ckpt_every=2, omega="seeded")

    def crash(pass_idx, chunk_idx, *_):
        if (pass_idx, chunk_idx) == (0, 3):
            raise Kill

    with pytest.raises(Kill):
        runner.fit(key, resume=False, on_chunk=crash)
    resumed = PassRunner(seed_store, SCFG, engine="kernels", prefetch=0,
                         ckpt_dir=ck, omega="seeded").fit(key, resume=True)
    assert resumed.diagnostics["io"]["resumed"]
    _assert_bit_identical(base, resumed)


def test_cursor_omega_binding(seed_store, tmp_path):
    """Ω provenance is part of the pass state: a cursor written by a
    seeded fit must refuse to resume a materialized one (the pass-0
    payload is a seed, not a basis)."""
    ck = str(tmp_path / "ck")
    runner = PassRunner(seed_store, SCFG, engine="kernels", prefetch=0,
                        ckpt_dir=ck, ckpt_every=2, omega="seeded")

    def crash(pass_idx, chunk_idx, *_):
        if (pass_idx, chunk_idx) == (0, 3):
            raise Kill

    with pytest.raises(Kill):
        runner.fit(jax.random.PRNGKey(3), resume=False, on_chunk=crash)
    with pytest.raises(ValueError, match="omega"):
        PassRunner(seed_store, SCFG, engine="kernels", prefetch=0,
                   ckpt_dir=ck).fit(jax.random.PRNGKey(3), resume=True)


# --------------------------------------------------------------------------
# S1: prefetcher error propagation — a failed read is never swallowed
# --------------------------------------------------------------------------


def test_prefetcher_midstream_error_raises_at_consumer():
    def gen():
        yield (np.ones(3), np.zeros(2))
        raise RuntimeError("disk died")

    pf = ChunkPrefetcher(gen(), depth=2, device_put=False)
    assert np.array_equal(next(pf)[0], np.ones(3))
    with pytest.raises(RuntimeError, match="disk died"):
        next(pf)
    pf.close()  # already delivered in __next__ — close() stays silent


def test_prefetcher_undelivered_error_raises_on_close():
    """The regression: a consumer that shuts the pipeline down before
    reaching the failing chunk must still see the producer's error."""
    def gen():
        raise RuntimeError("boom")
        yield  # pragma: no cover

    pf = ChunkPrefetcher(gen(), depth=2, device_put=False)
    with pytest.raises(RuntimeError, match="boom"):
        pf.close()
    pf.close()  # idempotent: the error is raised exactly once


def test_prefetcher_clean_streams_unaffected():
    chunks = [(np.zeros(1), np.ones(1))] * 6
    pf = ChunkPrefetcher(iter(chunks), depth=2, device_put=False)
    assert len(list(pf)) == 6
    pf.close()
    # early close of a healthy stream: no error, no producer wedge
    pf2 = ChunkPrefetcher(iter(chunks), depth=1, device_put=False)
    next(pf2)
    pf2.close()


# --------------------------------------------------------------------------
# S2: stale-partial cleanup failures surface instead of passing silently
# --------------------------------------------------------------------------


def _meta(fit_id, omega="materialized"):
    return pt.binding_meta(fit_id=fit_id, pass_idx=0, kind="final",
                           engine="jnp", fingerprint="fp", merge_group=2,
                           algo={"k": 1}, omega=omega)


def _publish(cluster_dir, group, meta):
    pt.write_partial(cluster_dir, 0, group, stats_init_fn("final", 4, 3, 2)(),
                     meta, shard=0, n_shards=1)


def test_clear_stale_partial_reports_failure(tmp_path, monkeypatch):
    cd = str(tmp_path)
    _publish(cd, 0, _meta("old"))

    def boom(path, **kw):
        raise OSError("read-only filesystem")

    monkeypatch.setattr(pt.shutil, "rmtree", boom)
    err = pt.clear_stale_partial(cd, 0, 0)
    assert err is not None and "read-only filesystem" in err
    assert pt.partial_meta(cd, 0, 0) is not None  # still on disk
    monkeypatch.undo()
    assert pt.clear_stale_partial(cd, 0, 0) is None  # retry succeeds
    assert pt.partial_meta(cd, 0, 0) is None
    assert pt.clear_stale_partial(cd, 0, 0) is None  # already gone


def test_sweep_stale_partials_returns_failures(tmp_path, monkeypatch):
    cd = str(tmp_path)
    expect = _meta("new")
    _publish(cd, 0, _meta("old"))       # stale, removable
    _publish(cd, 1, _meta("old"))       # stale, removal will fail
    _publish(cd, 2, expect)             # valid — must be left alone
    real_rmtree = pt.shutil.rmtree
    doomed = pt.partial_path(cd, 0, 1)

    def selective(path, **kw):
        if path == doomed:
            raise OSError("EBUSY")
        return real_rmtree(path, **kw)

    monkeypatch.setattr(pt.shutil, "rmtree", selective)
    failures = pt.sweep_stale_partials(cd, 0, n_groups=3, expect=expect)
    assert list(failures) == [1] and "EBUSY" in failures[1]
    assert pt.partial_meta(cd, 0, 0) is None          # stale one removed
    assert pt.partial_meta(cd, 0, 1) is not None      # failed removal stays
    assert pt.binding_matches(pt.partial_meta(cd, 0, 2), expect)  # untouched


def test_omega_is_binding_for_rounds_and_partials():
    """A seeded round's Qa/Qb payload is a seed, not a basis — a worker
    or sweep comparing metadata across Ω provenance must see a
    mismatch."""
    assert "omega" in pt.BINDING_KEYS
    assert not pt.binding_matches(_meta("f", omega="seeded"),
                                  _meta("f", omega="materialized"))
    assert pt.binding_matches(_meta("f", omega="seeded"),
                              _meta("f", omega="seeded"))


# --------------------------------------------------------------------------
# static analysis: RCCA108 (seeded kernel contract) + RCCA006 (RNG home)
# --------------------------------------------------------------------------


def _seeded_plan(name="fixture_seeded",
                 scalars=(ScalarDef((2,), "uint32"),)):
    spec = BlockDef(shape=(128, 128), index_map=lambda i, j: (i, j),
                    padded=(256, 256), dtype="float32")
    return KernelPlan(name=name, grid=(2, 2), in_specs=(spec,),
                      out_specs=(spec,), scratch=(),
                      out_shape=((250, 250),), scalars=tuple(scalars))


def test_rcca108_valid_seeded_plan_is_clean():
    assert kernel_check.check_plan(_seeded_plan()) == []


def test_rcca108_seeded_plan_scalar_count():
    vs = kernel_check.check_plan(_seeded_plan(scalars=()))
    assert codes(vs) == ["RCCA108"]
    vs = kernel_check.check_plan(_seeded_plan(
        scalars=(ScalarDef((2,), "uint32"), ScalarDef((2,), "uint32"))))
    assert "RCCA108" in codes(vs)


def test_rcca108_scalar_must_be_integer_seed():
    vs = kernel_check.check_plan(_seeded_plan(
        scalars=(ScalarDef((2,), "float32"),)))
    assert codes(vs) == ["RCCA108"]
    # the dtype rule guards ALL plans with scalars, seeded-named or not
    vs = kernel_check.check_plan(_seeded_plan(
        name="fixture", scalars=(ScalarDef((2,), "float32"),)))
    assert codes(vs) == ["RCCA108"]


def test_rcca108_scalar_must_not_smuggle_arrays():
    vs = kernel_check.check_plan(_seeded_plan(
        scalars=(ScalarDef((4, 4), "uint32"),)))
    assert codes(vs) == ["RCCA108"]


def test_registry_declares_seeded_kernels():
    from repro.kernels import KERNEL_REGISTRY

    assert "powerpass_seeded" in KERNEL_REGISTRY
    assert "projgram_seeded" in KERNEL_REGISTRY


def test_rcca006_random_draw_outside_rng_home_trips():
    src = "def f(key):\n    return jax.random.normal(key, (4, 4))\n"
    vs = lint.lint_source(src, "repro/exec/engine.py")
    assert codes(vs) == ["RCCA006"]
    assert "rcca" in vs[0].message
    src2 = "def f(key):\n    return jrandom.split(key)\n"
    assert codes(lint.lint_source(src2, "repro/cluster/worker.py")) == \
        ["RCCA006"]


def test_rcca006_rng_home_and_non_pass_path_pass():
    src = "def f(key):\n    return jax.random.normal(key, (4, 4))\n"
    assert lint.lint_source(src, "repro/core/rcca.py") == []     # RNG home
    assert lint.lint_source(src, "repro/launch/bench.py") == []  # not pass-path
    ok = "def f(s):\n    return rand.dense_omega(s, 8, 4)\n"
    assert lint.lint_source(ok, "repro/exec/engine.py") == []
