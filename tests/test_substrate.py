"""Substrate tests: checkpointing (incl. bf16 + retention + resume),
optimizer behaviour, data determinism, hashing, compression EF."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_pytree, save_pytree
from repro.data import HashingFeaturizer, PlantedCCAData, SyntheticTokenStream
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# ------------------------------ ckpt ------------------------------


def test_ckpt_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.zeros((), jnp.int32)},
    }
    d = str(tmp_path / "ck")
    save_pytree(tree, d, metadata={"step": 7})
    out = restore_pytree(tree, d)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_ckpt_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in [1, 2, 3, 4]:
        mgr.save(s, {"w": jnp.full((4,), float(s))}, metadata={"loss": s * 0.5})
    assert mgr.latest_step() == 4
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2  # retention
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), 4.0)


def test_ckpt_background_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"w": jnp.ones((8,))}, background=True)
    mgr.wait()
    restored, meta = mgr.restore({"w": jnp.zeros((8,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)


def test_ckpt_atomicity_no_partial_dir(tmp_path):
    """A completed save never leaves .tmp dirs behind."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"w": jnp.ones((4,))})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ------------------------------ optim ------------------------------


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"x": jnp.array([3.0, -2.0])}
    state = adamw_init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        return adamw_update(cfg, g, state, params)

    for _ in range(150):
        params, state, m = step(params, state)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"x": jnp.zeros((3,))}
    state = adamw_init(params)
    g = {"x": jnp.full((3,), 100.0)}
    _, state, metrics = adamw_update(cfg, g, state, params)
    assert float(metrics["grad_norm"]) > 100  # reported pre-clip
    # first moment reflects clipped gradient (norm ≤ 1)
    assert float(jnp.linalg.norm(state.mu["x"])) <= (1 - cfg.b1) * 1.0 + 1e-6


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6  # peak after warmup
    assert lrs[-1] == pytest.approx(0.1, abs=1e-3)  # decays to min_lr_frac
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # monotone decay


# ------------------------------ data ------------------------------


def test_planted_data_replayable():
    d = PlantedCCAData(n=1000, da=16, db=12, chunk=128, seed=3)
    a1, b1 = d.get_chunk(3)
    a2, b2 = d.get_chunk(3)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)


def test_planted_data_row_shard_partition():
    d = PlantedCCAData(n=1024, da=8, db=8, chunk=128, seed=0)
    all_rows = np.concatenate([a for a, _ in d])
    shards = [np.concatenate([a for a, _ in d.row_shard(w, 4)]) for w in range(4)]
    assert sum(s.shape[0] for s in shards) == all_rows.shape[0]


def test_planted_spectrum_decays():
    """The planted cross-covariance spectrum decays like the paper's Fig 1."""
    d = PlantedCCAData(n=4000, da=64, db=64, rank=32, chunk=1000, seed=0)
    A, B = d.materialize()
    s = np.linalg.svd(A.T @ B / A.shape[0], compute_uv=False)
    assert s[0] > 3 * s[10] > 0


def test_token_stream_deterministic():
    s = SyntheticTokenStream(vocab=100, batch=4, seq=16, seed=5)
    np.testing.assert_array_equal(s.get_batch(9), s.get_batch(9))
    assert s.get_batch(0).shape == (4, 17)


def test_hashing_inner_product_preserved():
    """Weinberger hashing approximately preserves inner products."""
    rng = np.random.default_rng(0)
    h = HashingFeaturizer(n_slots=4096, seed=1)
    docs = [rng.integers(1, 10_000, size=50) for _ in range(20)]
    X = h.featurize(docs)
    # exact BoW inner products
    from collections import Counter
    def bow_dot(d1, d2):
        c1, c2 = Counter(d1.tolist()), Counter(d2.tolist())
        return sum(v * c2.get(k2, 0) for k2, v in c1.items())
    for i in range(0, 10, 2):
        exact = bow_dot(docs[i], docs[i + 1])
        hashed = float(X[i] @ X[i + 1])
        assert abs(hashed - exact) <= 12, (exact, hashed)
    # self inner product = ‖doc‖² exactly when no collisions dominate
    self_exact = bow_dot(docs[0], docs[0])
    assert abs(float(X[0] @ X[0]) - self_exact) <= 16


def test_hashing_batch_matches_list():
    rng = np.random.default_rng(0)
    h = HashingFeaturizer(n_slots=512, seed=2)
    mat = rng.integers(1, 1000, size=(6, 20))
    mat[2, 10:] = 0  # padding
    X1 = h.featurize_batch(mat)
    X2 = h.featurize([row[row > 0] for row in mat])
    np.testing.assert_allclose(X1, X2)
