"""Trace integrity for repro.obs (the unified tracing/roofline layer).

The contract under test, in order of importance:

1. **Tracing off is free and invisible** — with ``RCCA_TRACE`` unset a
   fit produces bitwise-identical results to a traced one and writes no
   trace files (the hard acceptance bar: observability must not perturb
   the pass arithmetic).
2. **Spans nest and cover** — a multi-process Hybrid fit yields one
   trace file per process whose spans have valid parent references,
   child time contained in the parent window, and top-level spans
   covering ≥ 95% of each process's traced wall (less would mean a
   phase of the fit runs outside any span).
3. **The roofline is the KernelPlan cost model** — per-kernel
   ``kernel_cost`` counters in a trace reproduce
   :func:`repro.kernels.ops.chunk_cost` exactly.
4. **A killed worker leaves a parseable trace** — hard ``os._exit``
   mid-pass must not corrupt the stream beyond one torn final line,
   which the reader skips.
5. **The Hybrid/Sharded device fold overlaps its gather** — the
   mesh-path batch gather streams through the ChunkPrefetcher, so the
   ``mesh_gather`` io counter shows reads hidden behind device compute.
"""

import json
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cluster.worker import KILL_ENV
from repro.core.rcca import RCCAConfig
from repro.data import PlantedCCAData
from repro.exec import Cluster, Hybrid, Local, Sharded
from repro.exec import fit as exec_fit
from repro.kernels import ops as kernel_ops
from repro.obs import load_events
from repro.obs import report as obs_report
from repro.store import ingest_planted

N, DA, DB, CHUNK = 1024, 24, 16, 128  # 8 chunks
G = 2  # 4 merge groups
CFG = RCCAConfig(k=3, p=5, q=1, nu=0.01, center=True)
KEY = 7


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    data = PlantedCCAData(n=N, da=DA, db=DB, rank=4, noise=0.4,
                          seed=13, chunk=CHUNK)
    return ingest_planted(str(tmp_path_factory.mktemp("obs") / "store"), data)


def _fit(store, tmp_path, topology=Local(), **kw):
    return exec_fit(store, CFG, jax.random.PRNGKey(KEY), topology=topology,
                    engine="jnp", merge_group=G, **kw)


def assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs"


# ---------------------------------------------------------------------------
# 1. tracing off: bitwise identical, no files
# ---------------------------------------------------------------------------


def test_trace_off_is_bitwise_invisible(store, tmp_path, monkeypatch):
    trace_dir = str(tmp_path / "trace")
    monkeypatch.setenv("RCCA_TRACE", trace_dir)
    traced = _fit(store, tmp_path)
    assert os.path.isdir(trace_dir) and os.listdir(trace_dir)

    monkeypatch.delenv("RCCA_TRACE")
    off_dir = str(tmp_path / "off")
    monkeypatch.chdir(tmp_path)  # a stray default rcca_trace/ would land here
    plain = _fit(store, tmp_path)
    assert_bit_identical(traced, plain)
    assert not os.path.exists(off_dir)
    assert not os.path.exists(str(tmp_path / "rcca_trace"))


# ---------------------------------------------------------------------------
# 2. hybrid fit: spans nest, parents resolve, coverage >= 95%
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hybrid_trace(store, tmp_path_factory):
    tmp = tmp_path_factory.mktemp("hybrid")
    trace_dir = str(tmp / "trace")
    os.environ["RCCA_TRACE"] = trace_dir  # inherited by worker subprocesses
    try:
        res = _fit(store, tmp,
                   topology=Hybrid(n_workers=2, devices_per_worker=2),
                   cluster_dir=str(tmp / "cl"), worker_timeout=300)
    finally:
        del os.environ["RCCA_TRACE"]
    return trace_dir, res


def test_hybrid_spans_nest_and_cover(hybrid_trace):
    trace_dir, _ = hybrid_trace
    events = load_events(trace_dir)
    spans = [ev for ev in events if ev.get("ev") == "span"]
    pids = {ev["pid"] for ev in spans}
    # coordinator + at least one worker process per pass
    assert len(pids) >= 3
    by_pid = {}
    for sp in spans:
        by_pid.setdefault(sp["pid"], {})[sp["sid"]] = sp
    for pid, sids in by_pid.items():
        for sp in sids.values():
            if sp["parent"] is None:
                continue
            parent = sids.get(sp["parent"])
            assert parent is not None, \
                f"pid {pid}: span {sp['name']} has dangling parent"
            # child window inside the parent window (50ms clock slack:
            # t is wall, dur is monotonic)
            assert sp["t"] >= parent["t"] - 0.05
            assert sp["t"] + sp["dur"] <= parent["t"] + parent["dur"] + 0.05
    # roles stamped via set_context reach every record
    roles = {sp.get("ctx", {}).get("role") for sp in spans}
    assert "coordinator" in roles
    assert any(r and r.startswith("worker") for r in roles)

    report = obs_report.analyze(trace_dir)
    for pid, proc in report["processes"].items():
        assert proc["coverage"]["fraction"] >= 0.95, \
            f"pid {pid} ({proc['role']}): only " \
            f"{proc['coverage']['fraction']:.0%} of the traced window is " \
            "inside top-level spans"
    # the coordinator decomposes into the protocol phases
    coord = next(p for p in report["processes"].values()
                 if p["role"] == "coordinator")
    for phase in ("fit", "pass", "publish", "barrier", "merge"):
        assert phase in coord["phases"], f"missing {phase} span"
    # one trace serves the race detector too
    assert "protocol" in report
    assert report["protocol"]["violations"] == []


def test_hybrid_gather_overlaps_prefetch(hybrid_trace):
    """The device-parallel group fold streams its batch gather through
    the ChunkPrefetcher: reads happen on the producer thread while the
    devices fold the previous batch, so stall < read time."""
    trace_dir, _ = hybrid_trace
    gather = [ev for ev in load_events(trace_dir)
              if ev.get("ev") == "ctr" and ev.get("name") == "io"
              and ev.get("fields", {}).get("site") == "mesh_gather"]
    assert gather, "hybrid workers emitted no mesh_gather io counter"
    chunks = sum(ev["fields"]["chunks"] for ev in gather)
    assert chunks == N // CHUNK * 2  # every chunk, both passes
    stall = sum(ev["fields"]["io_stall_s"] for ev in gather)
    read = sum(ev["fields"]["read_s"] for ev in gather)
    # local reads are near-instant, so allow scheduling noise; the
    # strict overlap assertion runs against a slow reader below
    assert stall <= read + 0.05


# ---------------------------------------------------------------------------
# 3. roofline counters == the KernelPlan cost model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["jnp", "kernels"])
def test_kernel_cost_counters_match_cost_model(store, tmp_path, monkeypatch,
                                               engine):
    trace_dir = str(tmp_path / f"trace_{engine}")
    monkeypatch.setenv("RCCA_TRACE", trace_dir)
    exec_fit(store, CFG, jax.random.PRNGKey(KEY), engine=engine,
             merge_group=G)
    monkeypatch.delenv("RCCA_TRACE")

    counted = {}
    for ev in load_events(trace_dir):
        if ev.get("ev") != "ctr" or ev.get("name") != "kernel_cost":
            continue
        f = ev["fields"]
        t = counted.setdefault(f["kernel"], {"calls": 0, "flops": 0,
                                             "bytes": 0})
        for k in t:
            t[k] += f[k]

    n_chunks = N // CHUNK
    kt = CFG.sketch
    expected = {}
    for kind in ("power", "final"):
        cost = kernel_ops.chunk_cost(kind, CHUNK, DA, DB, kt, "float32",
                                     engine=engine)
        for part in cost["kernels"]:
            t = expected.setdefault(part["kernel"], {"calls": 0, "flops": 0,
                                                     "bytes": 0})
            for k in t:
                t[k] += part[k] * n_chunks
    assert counted == expected


# ---------------------------------------------------------------------------
# 4. killed worker: parseable trace, torn-line tolerance
# ---------------------------------------------------------------------------


def test_killed_worker_leaves_parseable_trace(store, tmp_path):
    trace_dir = str(tmp_path / "trace")
    os.environ["RCCA_TRACE"] = trace_dir
    try:
        res = exec_fit(store, CFG, jax.random.PRNGKey(KEY),
                       topology=Cluster(n_workers=2),
                       cluster_dir=str(tmp_path / "cl"), engine="jnp",
                       merge_group=G, worker_timeout=300,
                       env_overrides={0: {KILL_ENV: "0:2"}})
    finally:
        del os.environ["RCCA_TRACE"]
    assert res.diagnostics["cluster"]["passes"][0]["redispatched_groups"]

    # simulate the torn final line a mid-write kill can leave
    files = sorted(os.listdir(trace_dir))
    with open(os.path.join(trace_dir, files[0]), "a") as f:
        f.write('{"ev": "span", "name": "torn')

    events = load_events(trace_dir)
    assert all(isinstance(ev, dict) for ev in events)
    report = obs_report.analyze(trace_dir)
    assert report["redispatches"] >= 1
    assert report["protocol"]["violations"] == []
    # the repair round's publishes are in the trace despite the kill
    publishes = [ev for ev in events if ev.get("ev") == "span"
                 and ev["name"] == "publish"
                 and ev.get("ctx", {}).get("role", "").startswith("worker")]
    assert len(publishes) >= store.n_chunks // G  # every group published


# ---------------------------------------------------------------------------
# 5. sharded mesh fold overlaps a slow reader
# ---------------------------------------------------------------------------


class _SlowReader:
    """Store delegate whose chunk reads cost a visible ~2ms each."""

    def __init__(self, reader):
        self._reader = reader

    def __getattr__(self, name):
        return getattr(self._reader, name)

    def get_chunk(self, i):
        time.sleep(0.002)
        return self._reader.get_chunk(i)


def test_mesh_gather_hides_slow_reads(store, tmp_path, monkeypatch):
    from repro.exec import PassEngine

    eng = PassEngine(CFG, engine="jnp", topology=Sharded(), merge_group=G)
    trace_dir = str(tmp_path / "trace")
    monkeypatch.setenv("RCCA_TRACE", trace_dir)
    slow = eng.run_mesh(_SlowReader(store), jax.random.PRNGKey(KEY))
    monkeypatch.delenv("RCCA_TRACE")
    plain = eng.run_mesh(store, jax.random.PRNGKey(KEY))
    assert_bit_identical(slow, plain)

    report = obs_report.analyze(trace_dir)
    gather = report["io"]["mesh_gather"]
    assert gather["chunks"] == N // CHUNK * 2
    # the prefetch thread reads ahead while the mesh folds: some read
    # time is hidden, so the consumer stalled for less than the reads
    assert gather["io_stall_s"] < gather["read_s"]
    assert gather["overlap"] > 0


# ---------------------------------------------------------------------------
# trajectory schema
# ---------------------------------------------------------------------------


def test_trajectory_build_and_validate(tmp_path):
    from repro.obs import trajectory

    results = tmp_path / "results"
    results.mkdir()
    (results / "BENCH_x.json").write_text(json.dumps({
        "bench": "x", "schema": 1, "meta": {"commit": "abc"},
        "speedup": 2.0,
        "results": [{"name": "r0", "us": 10.0, "note": "text ignored"}],
    }))
    # legacy artifact: no schema/meta stamp — still folded, meta=None
    (results / "BENCH_y.json").write_text(json.dumps({
        "bench": "y", "wall_s": 1.5}))
    out = trajectory.write(str(results))
    traj = json.loads((results / "TRAJECTORY.json").read_text())
    assert trajectory.validate(traj) == []
    assert out.endswith("TRAJECTORY.json")
    by_bench = {e["bench"]: e for e in traj["entries"]}
    assert by_bench["x"]["metrics"] == {"speedup": 2.0, "r0.us": 10.0}
    assert by_bench["x"]["meta"] == {"commit": "abc"}
    assert by_bench["y"]["meta"] is None
    assert by_bench["x"]["deltas"] == {}  # first trajectory: no previous

    # regression deltas against the previous trajectory
    (results / "BENCH_x.json").write_text(json.dumps({
        "bench": "x", "speedup": 3.0,
        "results": [{"name": "r0", "us": 10.0}]}))
    traj2 = trajectory.build(str(results))
    d = {e["bench"]: e["deltas"] for e in traj2["entries"]}["x"]
    assert d["speedup"] == {"prev": 2.0, "cur": 3.0, "rel": 0.5}
    assert "r0.us" not in d  # unchanged metrics carry no delta

    # malformed trajectories are named, not swallowed
    assert trajectory.validate({"schema": 99, "entries": []})
    assert trajectory.validate({"schema": 1, "entries": [{"bench": "z"}]})
    (results / "TRAJECTORY.json").write_text("{not json")
    errs = trajectory.validate_file(str(results / "TRAJECTORY.json"))
    assert errs and "not valid JSON" in errs[0]
