"""Per-architecture smoke tests (REQUIRED): a reduced same-family config
runs one forward + one train step on CPU; output shapes + no NaNs.
Also: serve-path consistency (prefill+decode == teacher-forced logits)
in float32 for every family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_archs
from repro.launch import steps as S
from repro.models import EncDecModel, build_model
from repro.optim import AdamWConfig, adamw_init

ARCHS = model_archs()


def _batch(cfg, B, S_, key):
    tok = jax.random.randint(key, (B, S_ + 1), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.kind == "encdec":
        batch["frames"] = jax.random.normal(key, (B, 16, cfg.d_model))
    elif cfg.frontend == "vision_patches":
        batch["embeds"] = jax.random.normal(key, (B, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S_ = 2, 32
    batch = _batch(cfg, B, S_, jax.random.PRNGKey(1))
    logits, aux = model.forward_train(
        params, {**batch, "tokens": batch["tokens"][:, :-1]}, remat=False
    )
    assert logits.shape == (B, S_, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = S.make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10),
                             loss_chunks=2, remat=True)
    batch = _batch(cfg, 2, 32, jax.random.PRNGKey(1))
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S_ = 2, 16
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S_ + 1), 0, cfg.vocab)
    if isinstance(model, EncDecModel):
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, 16, cfg.d_model))
        full, _ = model.forward_train(params, {"frames": frames, "tokens": tok}, remat=False)
        cache = model.init_cache(B, cfg.max_seq, enc_len=16)
        _, cache = model.prefill(params, frames, tok[:, :S_], cache)
    else:
        full, _ = model.forward_train(params, {"tokens": tok}, remat=False)
        cache = model.init_cache(B, 64)
        _, cache = model.prefill(params, tok[:, :S_], cache)
    logits, _ = model.decode_step(params, tok[:, S_ : S_ + 1], cache)
    err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, S_])))
    assert err < 5e-4, err


@pytest.mark.parametrize("arch", ["gemma3-1b", "kimi-k2-1t-a32b", "xlstm-350m", "zamba2-7b"])
def test_multistep_decode(arch):
    """Greedy decode runs several steps without shape/NaN issues."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    cache = model.init_cache(B, 32)
    logits, cache = model.prefill(params, tok, cache)
    nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(4):
        logits, cache = model.decode_step(params, nxt, cache)
        assert bool(jnp.all(jnp.isfinite(logits)))
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published hyper-parameters."""
    table = {
        "gemma3-1b": dict(n_layers=26, d_model=1152, vocab=262_144),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, vocab=49_152),
        "gemma-7b": dict(n_layers=28, d_model=3072, vocab=256_000),
        "granite-3-2b": dict(n_layers=40, d_model=2048, vocab=49_155),
        "whisper-small": dict(n_layers=12, d_model=768, vocab=51_865),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, vocab=163_840),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, vocab=102_400),
        "xlstm-350m": dict(n_layers=24, d_model=1024, vocab=50_304),
        "zamba2-7b": dict(n_layers=81, d_model=3584, vocab=32_000),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, vocab=151_936),
    }
    for arch, want in table.items():
        cfg = get_config(arch)
        for field, v in want.items():
            assert getattr(cfg, field) == v, (arch, field)
    # family-specific invariants
    assert get_config("deepseek-v2-236b").attn.mla.kv_lora == 512
    assert get_config("kimi-k2-1t-a32b").moe.n_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_config("deepseek-v2-236b").moe.n_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    pat = get_config("gemma3-1b").pattern()
    assert pat.count("attn") == 4 and pat.count("local") == 22  # 5:1 local:global
    assert get_config("zamba2-7b").ssm.d_state == 64
    assert get_config("qwen2-vl-2b").attn.mrope


def test_moe_param_count_kimi():
    """kimi-k2 full config should land near 1T params."""
    cfg = get_config("kimi-k2-1t-a32b")
    moe, d = cfg.moe, cfg.d_model
    per_layer = moe.n_experts * 3 * d * moe.d_ff_expert
    total = 60 * per_layer  # MoE layers dominate
    assert 0.5e12 < total < 2e12, total
