"""Multi-worker map/combine/reduce correctness (repro.cluster).

The acceptance bar is MERGE PARITY: for any worker count and any merge
arrival order, the coordinator's output is bit-identical to the
single-process ``randomized_cca_streaming`` on the same store — the
merge is a sum of disjoint-row statistics reduced through a fixed
pairwise tree, so not even the last ulp may move."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.rcca import (
    MERGE_GROUP_CHUNKS,
    PairwiseStack,
    RCCAConfig,
    SegmentedAccumulator,
    init_Q,
    jit_update_fn,
    merge_final_stats,
    merge_power_stats,
    randomized_cca_streaming,
    reduce_group_partials,
    stats_init_fn,
)
from repro.cluster import ClusterCoordinator, run_worker
from repro.cluster import partials as pt
from repro.cluster.worker import WorkerKilled
from repro.data import PlantedCCAData
from repro.store import ingest_planted

N, DA, DB, CHUNK = 1536, 28, 20, 128  # 12 chunks
G = 2  # merge group: 6 groups → interesting splits at 1/2/4 workers
CFG = RCCAConfig(k=4, p=8, q=1, nu=0.01, center=True)
KEY = 5


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    data = PlantedCCAData(n=N, da=DA, db=DB, rank=5, noise=0.4,
                          seed=11, chunk=CHUNK)
    return ingest_planted(str(tmp_path_factory.mktemp("cluster") / "store"),
                          data)


@pytest.fixture(scope="module")
def streaming_ref(store):
    """Single-process reference per engine, on the exact store bytes."""
    A, B = store.materialize()
    Ac = jnp.asarray(A).reshape(store.n_chunks, CHUNK, DA)
    Bc = jnp.asarray(B).reshape(store.n_chunks, CHUNK, DB)
    cache = {}

    def get(engine):
        if engine not in cache:
            cache[engine] = randomized_cca_streaming(
                Ac, Bc, CFG, jax.random.PRNGKey(KEY), engine=engine,
                merge_group=G)
        return cache[engine]

    return get


def assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs"


# -- mergeable statistics --------------------------------------------------


def _chunk_stats(store, kind, idxs, Qa, Qb, engine="jnp"):
    upd = jit_update_fn(kind, engine)
    s = stats_init_fn(kind, store.da, store.db, CFG.sketch)()
    for i in idxs:
        a, b = store.get_chunk(i)
        s = upd(s, jnp.asarray(a), jnp.asarray(b), Qa, Qb)
    return s


@pytest.mark.parametrize("kind,merge", [("power", merge_power_stats),
                                        ("final", merge_final_stats)])
def test_merge_stats_is_exact_combiner(store, kind, merge):
    """stats(S₁ ∪ S₂) == stats(S₁) ⊕ stats(S₂) when the sets split on
    the accumulation boundary — the map/reduce combiner law."""
    Qa, Qb = init_Q(jax.random.PRNGKey(KEY), DA, DB, CFG)
    s_all = _chunk_stats(store, kind, [0, 1, 2, 3], Qa, Qb)
    s_left = _chunk_stats(store, kind, [0, 1], Qa, Qb)
    s_right = _chunk_stats(store, kind, [2, 3], Qa, Qb)
    merged = merge(s_left, s_right)
    for f, x, y in zip(s_all._fields, s_all, merged):
        # exact as algebra; fp reassociation moves near-zero entries,
        # hence the absolute term
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5,
                                   atol=1e-2, err_msg=f)
    assert float(merged.n) == 4 * CHUNK


def test_pairwise_tree_reduce_is_order_independent(store):
    """reduce_group_partials gives the bitwise single-process result no
    matter what order the partials dict was populated in (completion
    order must not matter)."""
    Qa, Qb = init_Q(jax.random.PRNGKey(KEY), DA, DB, CFG)
    upd = jit_update_fn("power", "jnp")
    init = stats_init_fn("power", DA, DB, CFG.sketch)
    nc = store.n_chunks
    partials = {}
    for g in range(-(-nc // G)):
        partials[g] = _chunk_stats(store, "power",
                                   range(g * G, min(nc, (g + 1) * G)), Qa, Qb)
    acc = SegmentedAccumulator(init, nc, G)
    for i in range(nc):
        a, b = store.get_chunk(i)
        acc.update(i, upd, jnp.asarray(a), jnp.asarray(b), Qa, Qb)
    single = acc.result()
    for order in (sorted(partials), sorted(partials, reverse=True),
                  [3, 0, 5, 1, 4, 2]):
        merged = reduce_group_partials({g: partials[g] for g in order},
                                       init, nc, G)
        for x, y in zip(single, merged):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_reduce_rejects_missing_group(store):
    init = stats_init_fn("power", DA, DB, CFG.sketch)
    with pytest.raises(ValueError, match="missing"):
        reduce_group_partials({0: init()}, init, store.n_chunks, G)


def test_pairwise_stack_depth_matches_popcount():
    init = stats_init_fn("power", 4, 3, 2)
    for m in (0, 1, 2, 3, 7, 8, 12, 37):
        st = PairwiseStack()
        for _ in range(m):
            st.push(init())
        assert len(st.stack) == PairwiseStack.depth_after(m) == bin(m).count("1")


# -- coordinator merge parity (the acceptance criterion) -------------------


@pytest.mark.parametrize("engine", ["jnp", "kernels"])
@pytest.mark.parametrize("workers", [1, 2, 4])
def test_coordinator_bit_identical_to_streaming(store, streaming_ref,
                                                tmp_path, engine, workers):
    co = ClusterCoordinator(store, CFG, str(tmp_path / "cl"),
                            n_workers=workers, engine=engine, merge_group=G)
    res = co.fit(jax.random.PRNGKey(KEY))
    assert_bit_identical(streaming_ref(engine), res)
    cl = res.diagnostics["cluster"]
    assert cl["n_workers"] == workers and cl["n_groups"] == 6
    assert all(p["redispatched_groups"] == [] for p in cl["passes"])


def test_coordinator_default_merge_group_matches_core(store, tmp_path):
    """Left to defaults, coordinator and streaming share
    MERGE_GROUP_CHUNKS — the bit-parity contract holds out of the box."""
    co = ClusterCoordinator(store, CFG, str(tmp_path / "cl"), n_workers=2,
                            engine="jnp")
    assert co.merge_group == MERGE_GROUP_CHUNKS


# -- worker unit behavior --------------------------------------------------


def _publish_round(store, cluster_dir, pass_idx=0, kind="power",
                   engine="jnp", fit_id="fitX"):
    from repro.cluster.coordinator import algo_meta

    Qa, Qb = init_Q(jax.random.PRNGKey(KEY), store.da, store.db, CFG)
    expect = pt.binding_meta(fit_id=fit_id, pass_idx=pass_idx, kind=kind,
                             engine=engine, fingerprint=store.fingerprint(),
                             merge_group=G, algo=algo_meta(CFG))
    pt.write_round(cluster_dir, pass_idx, Qa, Qb, {**expect, "n_shards": 2})
    return expect


def test_worker_killed_mid_shard_resumes_from_cursor(store, tmp_path):
    """A killed worker re-run with the same shard id picks up mid-shard:
    published groups are skipped, the in-flight group resumes from the
    cursor, and the partial set ends up identical to an unkilled run."""
    cd_kill = str(tmp_path / "kill")
    cd_ref = str(tmp_path / "ref")
    expect = _publish_round(store, cd_kill)
    _publish_round(store, cd_ref)

    # worker 0 of 2 with G=2 owns groups 0,2,4 → chunks 0,1,4,5,8,9;
    # kill after global chunk 5 (mid-shard, cursor at every chunk)
    with pytest.raises(WorkerKilled):
        run_worker(store.path, cd_kill, 0, 2, 0, ckpt_every=1, prefetch=0,
                   kill_at_chunk=5)
    have = pt.collect_partials(cd_kill, 0, 6, expect)
    assert set(have) == {0, 2}  # groups before the kill are published

    resumed = run_worker(store.path, cd_kill, 0, 2, 0, prefetch=0)
    assert resumed == 1  # only group 4 was left
    run_worker(store.path, cd_ref, 0, 2, 0, prefetch=0)
    for g in (0, 2, 4):
        s1, m1 = pt.read_partial(cd_kill, 0, g)
        s2, _ = pt.read_partial(cd_ref, 0, g)
        for x, y in zip(s1, s2):
            assert np.array_equal(np.asarray(x), np.asarray(y)), g


def test_worker_is_idempotent_after_completion(store, tmp_path):
    """Re-running a finished shard publishes nothing new (at-most-once:
    valid partials are recognized and skipped)."""
    cd = str(tmp_path / "idem")
    _publish_round(store, cd)
    assert run_worker(store.path, cd, 0, 2, 0, prefetch=0) == 3
    assert run_worker(store.path, cd, 0, 2, 0, prefetch=0) == 0


def test_worker_rejects_foreign_store(store, tmp_path):
    """A round published against different data must not fold: the
    fingerprint guard fires before any chunk is read."""
    cd = str(tmp_path / "foreign")
    other = ingest_planted(
        str(tmp_path / "other_store"),
        PlantedCCAData(n=N, da=DA, db=DB, rank=5, seed=99, chunk=CHUNK))
    _publish_round(other, cd)
    with pytest.raises(ValueError, match="different\\s+store"):
        run_worker(store.path, cd, 0, 2, 0, prefetch=0)
