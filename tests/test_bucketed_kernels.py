"""Column-bucketed fused kernels: parity across the old 2^20 VMEM
threshold, forced-bucket agreement, and the Europarl-shape fallback
regression (the fused path must NOT silently degrade to the unfused
matmul pair for the paper's d = 2^19 workload)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.europarl_cca import config as europarl_config
from repro.kernels import ops, ref
from repro.kernels.compat import count_pallas_calls
from repro.kernels.matmul import VMEM_BLOCK_ELEMS, vmem_row_cap
from repro.kernels.powerpass import power_project_accumulate
from repro.kernels.projgram import projgram

DTYPES = [jnp.float32, jnp.bfloat16]


def _rel(got, want):
    return float(jnp.linalg.norm(got.astype(jnp.float32) - want)
                 / jnp.maximum(jnp.linalg.norm(want), 1e-30))


# --------------------------------------------------------------------------
# parity across the old threshold (da·k̃p ≤ 2^20 no longer binds)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("da", [500, 8192, 1 << 17])
@pytest.mark.parametrize("kt", [64, 1024])
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_bucketed_powerpass_parity(da, kt, dt):
    """ΔY = aᵀ(b q) vs the jnp oracle on shapes spanning single-bucket
    (da=500) through 128-bucket (da=2^17, k̃=1024) grids."""
    n, db = 130, 96  # unaligned rows exercise the padding path
    a = jax.random.normal(jax.random.PRNGKey(da % 1000), (n, da), dt)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, db), dt)
    q = jax.random.normal(jax.random.PRNGKey(2), (db, kt), dt)
    got = power_project_accumulate(a, b, q, interpret=True)
    want = ref.matmul_ref(a, ref.matmul_ref(b, q), transpose_lhs=True)
    tol = 1e-4 if dt == jnp.float32 else 2e-2
    assert _rel(got, want) <= tol


@pytest.mark.parametrize("n,d,kt", [
    (256, 192, 1100),   # k̃ just past the old 1024 fused limit
    (130, 96, 2176),    # the Europarl sketch width (k=60, p=2000 padded)
    (300, 260, 1024),   # at the single-bucket boundary
])
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_bucketed_projgram_parity(n, d, kt, dt):
    x = jax.random.normal(jax.random.PRNGKey(n + kt), (n, d), dt)
    q = jax.random.normal(jax.random.PRNGKey(3), (d, kt), dt)
    p, c = projgram(x, q, interpret=True)
    pw, cw = ref.projgram_ref(x, q)
    tol = dict(atol=2e-4, rtol=2e-4) if dt == jnp.float32 else dict(atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pw), **tol)
    np.testing.assert_allclose(np.asarray(c) / n, np.asarray(cw) / n, **tol)


def test_forced_buckets_match_auto():
    """Explicit small buckets and the auto-sized bucket agree exactly —
    bucketing is pure scheduling, not a numerical change."""
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 700))
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 96))
    q = jax.random.normal(jax.random.PRNGKey(2), (96, 200))
    auto = power_project_accumulate(a, b, q, interpret=True)
    forced = power_project_accumulate(a, b, q, block_da=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))

    x = jax.random.normal(jax.random.PRNGKey(3), (256, 192))
    qq = jax.random.normal(jax.random.PRNGKey(4), (192, 640))
    _, c_auto = projgram(x, qq, interpret=True)
    _, c_forced = projgram(x, qq, block_c=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_auto), np.asarray(c_forced))


# --------------------------------------------------------------------------
# fallback regression: the Europarl shape must run fused
# --------------------------------------------------------------------------


def test_europarl_powerpass_shape_stays_fused(monkeypatch):
    """A europarl_cca-config-shaped power_project_accumulate call (chunk
    8192 × da 2^19, k̃ = 2060) must take the fused bucketed kernel —
    zero pallas_matmul fallback calls.  Traced abstractly (eval_shape):
    the fallback decision is trace-time Python, no compute needed."""
    from repro.kernels import powerpass as pp

    wl = europarl_config()
    kt = wl.rcca.sketch
    calls = {"n": 0}
    real = pp.pallas_matmul

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pp, "pallas_matmul", counting)
    a = jax.ShapeDtypeStruct((wl.chunk, wl.da), jnp.float32)
    b = jax.ShapeDtypeStruct((wl.chunk, wl.db), jnp.float32)
    q = jax.ShapeDtypeStruct((wl.db, kt), jnp.float32)
    out = jax.eval_shape(
        functools.partial(pp.power_project_accumulate, interpret=True), a, b, q
    )
    assert out.shape == (wl.da, kt)
    assert calls["n"] == 0, "Europarl shape fell back to the unfused pair"

    # ... and the chunk update stays all-Pallas in both schedules: the
    # Europarl shape auto-selects the staged (P-reuse) schedule — 2
    # pallas_calls per view (stage + sweep) — while the forced recompute
    # schedule keeps the single fused call per view.
    qa = jax.ShapeDtypeStruct((wl.da, kt), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda *xs: ops.power_pass_chunk(*xs, interpret=True)
    )(a, b, qa, q)
    assert count_pallas_calls(jaxpr) == 4
    jaxpr = jax.make_jaxpr(
        lambda *xs: ops.power_pass_chunk(*xs, schedule="recompute",
                                         interpret=True)
    )(a, b, qa, q)
    assert count_pallas_calls(jaxpr) == 2


def test_europarl_projgram_shape_stays_fused(monkeypatch):
    import importlib

    # the module, not the function the package re-exports under this name
    pg = importlib.import_module("repro.kernels.projgram")

    wl = europarl_config()
    kt = wl.rcca.sketch
    calls = {"n": 0}
    real = pg.pallas_matmul

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pg, "pallas_matmul", counting)
    x = jax.ShapeDtypeStruct((wl.chunk, wl.da), jnp.float32)
    q = jax.ShapeDtypeStruct((wl.da, kt), jnp.float32)
    jax.eval_shape(functools.partial(pg.projgram, interpret=True), x, q)
    assert calls["n"] == 0, "Europarl sketch fell back to the unfused pair"


def test_degenerate_sketch_still_falls_back(monkeypatch):
    """Negative control for the call-counting harness: k̃p > 8192 (no
    128-row block fits the budget) must still take the unfused pair —
    and prove the counter actually observes fallback calls."""
    from repro.kernels import powerpass as pp

    calls = {"n": 0}
    real = pp.pallas_matmul

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pp, "pallas_matmul", counting)
    a = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 96), jnp.float32)
    q = jax.ShapeDtypeStruct((96, 9000), jnp.float32)  # k̃p = 9088 > 8192
    jax.eval_shape(
        functools.partial(pp.power_project_accumulate, interpret=True), a, b, q
    )
    assert calls["n"] == 2


# --------------------------------------------------------------------------
# the shared VMEM-budget helper (one source of truth)
# --------------------------------------------------------------------------


def test_vmem_budget_helper():
    assert vmem_row_cap(1024) == 1024
    assert vmem_row_cap(2176) == 384          # Europarl k̃p: 2^20//2176 → 481 → 384
    assert vmem_row_cap(VMEM_BLOCK_ELEMS // 128) == 128
    assert vmem_row_cap(VMEM_BLOCK_ELEMS // 128 + 128) == 0  # degenerate
    # the bucketed resolvers build on this cap — a degenerate k̃p must
    # push both kernels to the unfused fallback
    from repro.kernels.powerpass import resolve_blocks as resolve_pp
    from repro.kernels.projgram import resolve_blocks as resolve_pg

    assert resolve_pp(256, 512, 256, 8320, 256, 512, 1 << 20) is None
    assert resolve_pg(256, 512, 8320, 256, 512, 1 << 20) is None
