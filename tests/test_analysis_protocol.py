"""Cluster-protocol race detector: trace invariants (RCCA201–204),
live trace recording through the real partial store (including a
broken-atomic-rename injection the checker must catch), and the
small-model interleaving explorer (RCCA205) with its mutation tests.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import protocol
from repro.cluster import partials
from repro.core.rcca import init_final_stats


def codes(violations):
    return sorted(v.code for v in violations)


# ---------------------------------------------------------------------------
# offline invariant checking over synthetic traces
# ---------------------------------------------------------------------------


def ev(op, path, **meta):
    e = {"op": op, "path": path, "pid": 1}
    if meta:
        e["meta"] = meta
    return e


GOOD_TRACE = [
    ev("stage_write", "/c/pass0/partial_0.stage7", group=0),
    ev("commit", "/c/pass0/partial_0", group=0),
    ev("read", "/c/pass0/partial_0", group=0),
    ev("merge", "/c/pass0/partial_0", fit_id="f", pass_idx=0, group=0),
    ev("merge", "/c/pass0/partial_1", fit_id="f", pass_idx=0, group=1),
    ev("merge", "/c/pass1/partial_0", fit_id="f", pass_idx=1, group=0),
]


def test_clean_trace_passes():
    assert protocol.check_trace(GOOD_TRACE) == []


def test_rcca201_read_of_staging_path():
    trace = GOOD_TRACE + [ev("read", "/c/pass0/partial_0.stage7", group=0)]
    assert codes(protocol.check_trace(trace)) == ["RCCA201"]


def test_rcca202_double_merge_of_same_group():
    trace = GOOD_TRACE + [
        ev("merge", "/c/pass0/partial_1", fit_id="f", pass_idx=0, group=1)]
    vs = protocol.check_trace(trace)
    assert codes(vs) == ["RCCA202"]
    assert "twice" in vs[0].message
    # same group in a DIFFERENT pass or fit is fine
    ok = GOOD_TRACE + [
        ev("merge", "/x", fit_id="f2", pass_idx=0, group=1),
        ev("merge", "/y", fit_id="f", pass_idx=2, group=1)]
    assert protocol.check_trace(ok) == []


def test_rcca203_read_without_commit():
    trace = [ev("read", "/c/pass0/partial_0", group=0)]
    vs = protocol.check_trace(trace)
    assert codes(vs) == ["RCCA203"]
    assert "bypassed" in vs[0].message


def test_rcca204_stale_replace_with_identical_binding():
    b = {"fit_id": "f", "pass_idx": 0}
    trace = [ev("stale_replace", "/c/p", old_binding=b, new_binding=dict(b))]
    assert codes(protocol.check_trace(trace)) == ["RCCA204"]
    trace = [ev("stale_replace", "/c/p", old_binding=b,
                new_binding={"fit_id": "g", "pass_idx": 0})]
    assert protocol.check_trace(trace) == []


def test_check_trace_file_missing_is_clean(tmp_path, monkeypatch):
    monkeypatch.delenv(protocol.TRACE_ENV, raising=False)
    assert protocol.check_trace_file() == []
    assert protocol.check_trace_file(str(tmp_path / "nope.jsonl")) == []


# ---------------------------------------------------------------------------
# live recording through the real partial store
# ---------------------------------------------------------------------------


def _stats(k=2, da=3, db=3, val=1.0):
    z = init_final_stats(k, da, db, jnp.float32)
    return z._replace(n=jnp.float32(val))


def _meta(fit_id="fit-a", pass_idx=0, group=0):
    return partials.binding_meta(
        fit_id=fit_id, pass_idx=pass_idx, kind="final", engine="jnp",
        fingerprint="fp", merge_group=8, algo={"k": 2})


@pytest.fixture
def traced(tmp_path, monkeypatch):
    trace = str(tmp_path / "trace.jsonl")
    monkeypatch.setenv(protocol.TRACE_ENV, trace)
    return trace


def test_trace_event_roundtrip(traced):
    protocol.trace_event("commit", "/a/b", group=3)
    protocol.trace_event("read", "/a/b")
    events = protocol.read_trace(traced)
    assert [e["op"] for e in events] == ["commit", "read"]
    assert events[0]["meta"]["group"] == 3 and events[0]["path"] == "/a/b"


def test_trace_event_noop_when_unset(tmp_path, monkeypatch):
    monkeypatch.delenv(protocol.TRACE_ENV, raising=False)
    protocol.trace_event("commit", "/a/b")  # must not raise or write
    assert list(tmp_path.iterdir()) == []


def test_real_write_read_partial_trace_is_clean(tmp_path, traced):
    cdir = str(tmp_path / "cluster")
    partials.write_partial(cdir, 0, 0, _stats(), _meta(),
                           shard=0, n_shards=1)
    got = partials.read_partial(cdir, 0, 0)
    assert got is not None
    events = protocol.read_trace(traced)
    assert [e["op"] for e in events] == ["stage_write", "commit", "read"]
    assert protocol.check_trace(events) == []


def test_broken_atomic_rename_injection_is_caught(tmp_path, traced):
    """A writer that skips the staging+rename publish: the partial
    appears on disk and the read succeeds, but the trace has no commit
    — exactly the torn-write signature RCCA203 exists for."""
    from repro.ckpt import save_pytree

    cdir = str(tmp_path / "cluster")
    meta = _meta()

    def broken_write_partial(cluster_dir, pass_idx, group, stats, meta, *,
                             shard, n_shards):
        final = partials.partial_path(cluster_dir, pass_idx, group)
        # writes DIRECTLY to the final path: no staging, no commit
        save_pytree(stats._asdict(), final,
                    metadata={**meta, "group": group, "shard": shard,
                              "n_shards": n_shards})

    broken_write_partial(cdir, 0, 0, _stats(), meta, shard=0, n_shards=1)
    assert partials.read_partial(cdir, 0, 0) is not None  # reader can't tell
    vs = protocol.check_trace_file(traced)  # ...but the trace can
    assert codes(vs) == ["RCCA203"]


def test_stale_replace_records_both_bindings(tmp_path, traced):
    """Cross-fit staleness: the second fit's writer replaces the first
    fit's partial, and the recorded bindings differ (no RCCA204)."""
    cdir = str(tmp_path / "cluster")
    partials.write_partial(cdir, 0, 0, _stats(), _meta(fit_id="fit-a"),
                           shard=0, n_shards=1)
    partials.write_partial(cdir, 0, 0, _stats(val=2.0),
                           _meta(fit_id="fit-b"), shard=0, n_shards=1)
    events = protocol.read_trace(traced)
    assert "stale_replace" in [e["op"] for e in events]
    sr = next(e for e in events if e["op"] == "stale_replace")
    assert sr["meta"]["old_binding"]["fit_id"] == "fit-a"
    assert sr["meta"]["new_binding"]["fit_id"] == "fit-b"
    assert protocol.check_trace(events) == []


# ---------------------------------------------------------------------------
# small-model interleaving exploration (RCCA205)
# ---------------------------------------------------------------------------


def test_explorer_covers_all_orderings_and_agrees_bitwise():
    """2 workers × 4 groups: fault-free + every crash point, every
    interleaving — and every merged result is bitwise-identical to the
    canonical pairwise tree (the explorer's own assertion; `ok` means
    zero mismatches over the whole space)."""
    rep = protocol.explore_interleavings(n_workers=2, n_groups=4)
    assert rep.ok and rep.violations() == []
    # 1 fault-free + (2 workers × 2 owned groups) crash points
    assert rep.n_scenarios == 5
    # fault-free C(4,2)=6; crash@0 → 6; crash@1 → 12; per worker
    assert rep.n_interleavings == 42


def test_explorer_payloads_are_order_sensitive():
    """The model's fp32 payloads must make reduction order observable,
    or the bitwise assertion would be vacuous."""
    a = protocol._group_payload(0)["y"].astype(np.float32)
    b = protocol._group_payload(1)["y"].astype(np.float32)
    c = protocol._group_payload(2)["y"].astype(np.float32)
    assert ((a + b) + c != a + (b + c)).any()


def test_explorer_detects_arrival_order_merge():
    rep = protocol.explore_interleavings(mutate="arrival_order")
    assert not rep.ok
    assert all(v.code == "RCCA205" for v in rep.violations())


def test_explorer_detects_torn_publish():
    rep = protocol.explore_interleavings(mutate="torn_publish")
    assert not rep.ok


def test_explorer_rejects_large_models():
    with pytest.raises(ValueError):
        protocol.explore_interleavings(n_workers=3, n_groups=4)
    with pytest.raises(ValueError):
        protocol.explore_interleavings(n_groups=9)


# ---------------------------------------------------------------------------
# end to end: a real 2-worker cluster fit leaves a clean trace
# ---------------------------------------------------------------------------


def test_cluster_fit_trace_is_clean(tmp_path, traced):
    import jax

    from repro.cluster import ClusterCoordinator
    from repro.core.rcca import RCCAConfig
    from repro.data import PlantedCCAData
    from repro.store import ingest_planted

    data = PlantedCCAData(n=256, da=8, db=6, rank=3, noise=0.4,
                          seed=11, chunk=64)
    store = ingest_planted(str(tmp_path / "store"), data)
    cfg = RCCAConfig(k=2, p=2, q=1)
    coord = ClusterCoordinator(store, cfg, str(tmp_path / "cluster"),
                               n_workers=2, merge_group=2)
    res = coord.fit(jax.random.PRNGKey(0))
    assert res.rho.shape == (2,)
    events = protocol.read_trace(traced)
    ops = {e["op"] for e in events}
    assert {"stage_write", "commit", "read", "merge"} <= ops
    assert protocol.check_trace(events) == []
