"""Collective-fused sharded kernels: with a genuinely sharded feature
axis the staged kernel pair folds the per-microbatch psum into the
pipeline (partial-P stage → phase-boundary psum → sweep) instead of
bracketing a full-width psum with the unfused matmul pair.  Requires a
forced multi-device mesh (see scripts/verify.sh topology job)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.rcca import RCCAConfig
from repro.core.rcca_dist import dist_randomized_cca

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")

N, DA, DB = 64, 32, 24
CFG = RCCAConfig(k=4, p=4, q=1, dtype=jnp.float32)


def _mesh():
    devs = np.array(jax.devices()[:4]).reshape(2, 2)
    return Mesh(devs, ("data", "model"))


def _data():
    rng = np.random.default_rng(7)
    A = jnp.asarray(rng.standard_normal((N, DA)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((N, DB)), jnp.float32)
    return A, B


def _fit(collective, **kw):
    A, B = _data()
    return dist_randomized_cca(
        A, B, CFG, jax.random.PRNGKey(0), _mesh(), row_axes=("data",),
        col_axis="model", microbatch=16, engine="kernels",
        collective=collective, **kw)


def test_fused_matches_unfused():
    """The collective-fused staged pair reproduces the unfused matmul
    pair on a real 2×2 (data × model) mesh."""
    fused = _fit("fused")
    unfused = _fit("unfused")
    np.testing.assert_allclose(np.asarray(fused.rho), np.asarray(unfused.rho),
                               rtol=1e-4, atol=1e-5)
    for leaf in ("Xa", "Xb"):
        # canonical directions are sign-ambiguous; compare |projections|
        np.testing.assert_allclose(
            np.abs(np.asarray(getattr(fused, leaf))),
            np.abs(np.asarray(getattr(unfused, leaf))),
            rtol=5e-3, atol=1e-4)


def test_fused_int8ef_close():
    """int8+error-feedback phase-boundary psum: ~4× fewer wire bytes,
    correlations within quantization tolerance of the exact reduction."""
    i8 = _fit("fused-int8ef")
    exact = _fit("fused")
    np.testing.assert_allclose(np.asarray(i8.rho), np.asarray(exact.rho),
                               rtol=0.05, atol=0.02)


def test_sharded_mesh_runs_fused(monkeypatch):
    """Acceptance: a |model| > 1 mesh takes the collective-fused path —
    the unfused pair (project / accumulate_tn) is never invoked."""
    from repro.kernels import ops as kops

    calls = {"project": 0, "accumulate_tn": 0}
    real_p, real_a = kops.project, kops.accumulate_tn

    def count_p(*a, **kw):
        calls["project"] += 1
        return real_p(*a, **kw)

    def count_a(*a, **kw):
        calls["accumulate_tn"] += 1
        return real_a(*a, **kw)

    monkeypatch.setattr(kops, "project", count_p)
    monkeypatch.setattr(kops, "accumulate_tn", count_a)
    _fit("fused")
    assert calls == {"project": 0, "accumulate_tn": 0}, (
        f"collective-fused path fell back to the unfused pair: {calls}")
    # negative control: the legacy path does go through the pair
    _fit("unfused")
    assert calls["project"] > 0 and calls["accumulate_tn"] > 0


def test_unknown_collective_rejected():
    with pytest.raises(ValueError, match="collective"):
        _fit("bogus")
