"""Serving tier + incremental delta-refits (repro.serve, repro.exec.delta).

The acceptance bars:

- DELTA PARITY: ``delta_refit(fit(chunks 0..n), store 0..m)`` in exact
  mode is bitwise identical to a cold fit of chunks 0..m — for both
  engines and two topologies.  Not even the last ulp may move, because
  the delta folds into the same canonical pairwise tree the cold fit
  builds.
- ZERO-DROP HOT-SWAP: concurrent request batches across a version flip
  all complete, each stamped with exactly one version whose projection
  matrix reproduces the embedding bitwise — no dropped and no
  mixed-version responses.
- DRIFT → REFIT → RECOVERY: an injected distribution shift trips the
  monitor's refit signal; the refreshed model restores the held-out
  correlation.

Satellites ride along: store append semantics (atomic re-publish, old
readers keep their snapshot), the worker-side span combiner (bitwise
parity with individual group partials), the Chrome-trace exporter and
the heartbeat-liveness report section.
"""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.rcca import (
    RCCAConfig,
    SegmentedAccumulator,
    stats_init_fn,
)
from repro.data import PlantedCCAData
from repro.exec import (
    Cluster,
    FitState,
    Local,
    Sharded,
    SpanCombiner,
    delta_refit,
    fit_with_state,
)
from repro.exec import fit as exec_fit
from repro.serve import (
    BatchedProjector,
    CorpusIndex,
    DriftMonitor,
    ModelRegistry,
)
from repro.serve.drift import paired_correlation
from repro.store import ViewStoreReader, extend_chunks, ingest_chunks

N0, N1, DA, DB, CHUNK = 1024, 1536, 28, 20, 128  # 8-chunk prefix, 12 total
G = 2  # merge group; chunk*G = 256 divides N0: the delta alignment contract
CFG = RCCAConfig(k=4, p=8, q=1, nu=0.01, center=True)
KEY = 5
C0, C1 = N0 // CHUNK, N1 // CHUNK


@pytest.fixture(scope="module")
def data():
    # rows past chunk C1 never enter a store: held-out serving traffic
    return PlantedCCAData(n=N1 + 512, da=DA, db=DB, rank=5, noise=0.4,
                          seed=11, chunk=CHUNK)


def _ingest(path, data, lo, hi):
    return ingest_chunks(path, (data.get_chunk(i) for i in range(lo, hi)),
                         chunk=CHUNK)


@pytest.fixture(scope="module")
def old_store(tmp_path_factory, data):
    """Chunks [0, C0): the corpus the stateful fit sees first."""
    return _ingest(str(tmp_path_factory.mktemp("serve") / "old"), data, 0, C0)


@pytest.fixture(scope="module")
def grown_store(tmp_path_factory, data):
    """Chunks [0, C0) ingested, then [C0, C1) APPENDED — the store a
    delta refit walks.  Its shard prefix is bitwise the old store's."""
    path = str(tmp_path_factory.mktemp("serve") / "grown")
    _ingest(path, data, 0, C0)
    extend_chunks(path, (data.get_chunk(i) for i in range(C0, C1)))
    return ViewStoreReader(path)


@pytest.fixture(scope="module")
def fit_old(old_store):
    """(result, FitState) of the stateful prefix fit — jnp/Local."""
    return fit_with_state(old_store, CFG, jax.random.PRNGKey(KEY),
                          merge_group=G, engine="jnp")


@pytest.fixture(scope="module")
def cold(grown_store):
    """Per-engine cold fits of the grown store: the parity reference."""
    cache = {}

    def get(engine):
        if engine not in cache:
            cache[engine] = fit_with_state(
                grown_store, CFG, jax.random.PRNGKey(KEY),
                merge_group=G, engine=engine)
        return cache[engine]

    return get


def assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs"


# -- store append semantics (the manifest re-publish contract) -------------


def test_append_matches_cold_ingest(grown_store, tmp_path, data):
    """ingest [0,C0) + append [C0,C1) serves the same rows as one cold
    ingest of [0,C1) — append is invisible to readers of the data."""
    cold_reader = _ingest(str(tmp_path / "cold"), data, 0, C1)
    assert grown_store.n == cold_reader.n == N1
    assert grown_store.n_chunks == C1
    for c in range(C1):
        a1, b1 = grown_store.get_chunk(c)
        a2, b2 = cold_reader.get_chunk(c)
        assert np.array_equal(a1, a2) and np.array_equal(b1, b2), c


def test_append_old_reader_keeps_snapshot(tmp_path, data):
    """A reader opened before the append keeps a consistent view of the
    old corpus: same n, same bytes — the manifest flip is atomic and
    old shard files are immutable."""
    path = str(tmp_path / "snap")
    _ingest(path, data, 0, C0)
    old = ViewStoreReader(path)
    before = [old.get_chunk(c) for c in range(C0)]
    extend_chunks(path, (data.get_chunk(i) for i in range(C0, C1)))
    assert old.n == N0 and old.n_chunks == C0
    for c in range(C0):  # re-read through the old manifest
        a, b = old.get_chunk(c)
        assert np.array_equal(a, before[c][0])
        assert np.array_equal(b, before[c][1])
    assert ViewStoreReader(path).n == N1  # new readers see the append


def test_append_abort_leaves_published_store_intact(tmp_path, data):
    """An append that dies mid-stream must not tear the published
    store: the manifest still describes the old corpus and a later
    append succeeds."""
    from repro.store import ViewStoreWriter

    path = str(tmp_path / "abort")
    _ingest(path, data, 0, C0)
    with pytest.raises(RuntimeError, match="boom"):
        with ViewStoreWriter.append_to(path) as w:
            w.append(*data.get_chunk(C0))
            raise RuntimeError("boom")
    r = ViewStoreReader(path)
    assert r.n == N0 and r.n_chunks == C0
    r.verify()  # every published shard hash still checks out
    extend_chunks(path, (data.get_chunk(i) for i in range(C0, C1)))
    assert ViewStoreReader(path).n == N1


def test_append_requires_published_store(tmp_path, data):
    with pytest.raises((FileNotFoundError, ValueError)):
        extend_chunks(str(tmp_path / "missing"),
                      (data.get_chunk(i) for i in range(C0, C1)))


# -- FitState persistence ---------------------------------------------------


def test_fitstate_save_load_roundtrip(fit_old, grown_store, tmp_path):
    """A FitState survives the disk round-trip losslessly: same meta,
    and a delta refit from the loaded state is bitwise the refit from
    the in-memory one."""
    res, state = fit_old
    d = str(tmp_path / "fitstate")
    state.save(d)
    loaded = FitState.load(d)
    # save() adds pass bookkeeping; everything the fit recorded survives
    for k, v in state.meta.items():
        assert loaded.meta[k] == v, k
    r_mem, _ = delta_refit(state, grown_store)
    r_disk, _ = delta_refit(loaded, grown_store)
    assert_bit_identical(r_mem, r_disk)


# -- delta refits: the bitwise-parity tentpole ------------------------------


@pytest.mark.parametrize("engine", ["jnp", "kernels"])
@pytest.mark.parametrize("topology", [Local(), Sharded()],
                         ids=["local", "sharded"])
def test_delta_refit_bitwise_parity(old_store, grown_store, cold,
                                    engine, topology):
    """fit(0..m) == delta_refit(fit(0..n), store 0..m) — bitwise, for
    both engines and two topologies."""
    res0, state = fit_with_state(old_store, CFG, jax.random.PRNGKey(KEY),
                                 merge_group=G, engine=engine,
                                 topology=topology)
    res, state2 = delta_refit(state, grown_store, topology=topology)
    ref, _ = cold(engine)
    assert_bit_identical(res, ref)
    d = res.diagnostics["delta"]
    assert d["mode"] == "exact"
    assert d["delta_chunks"] == C1 - C0
    assert state2.meta["n"] == N1  # the new state binds the grown corpus


def test_delta_refit_seeded_omega(old_store, grown_store):
    """Seeded on-the-fly Ω is key-derived, not data-derived — pass 0 of
    an exact delta refit stays delta-only and the result stays bitwise
    the cold seeded fit."""
    _, state = fit_with_state(old_store, CFG, jax.random.PRNGKey(KEY),
                              merge_group=G, engine="kernels",
                              omega="seeded")
    res, _ = delta_refit(state, grown_store)
    ref, _ = fit_with_state(grown_store, CFG, jax.random.PRNGKey(KEY),
                            merge_group=G, engine="kernels", omega="seeded")
    assert_bit_identical(res, ref)


def test_delta_refit_chains(cold, tmp_path, data):
    """Exact refits compose: 0..8 → +2 chunks → +2 chunks lands bitwise
    on the cold fit of all 12 — the persisted accumulators stay the
    canonical tree at every step.  (One store grown in place: each
    append's shard layout must prefix the next, so the chain walks a
    single directory.)"""
    path = str(tmp_path / "chain")
    _ingest(path, data, 0, C0)
    _, state = fit_with_state(ViewStoreReader(path), CFG,
                              jax.random.PRNGKey(KEY),
                              merge_group=G, engine="jnp")
    extend_chunks(path, (data.get_chunk(i) for i in range(C0, 10)))
    _, state = delta_refit(state, ViewStoreReader(path))
    extend_chunks(path, (data.get_chunk(i) for i in range(10, C1)))
    res, _ = delta_refit(state, ViewStoreReader(path))
    # the fold walks chunks, not shards: a different shard layout of
    # the same rows still lands bitwise on the grown store's cold fit
    assert_bit_identical(res, cold("jnp")[0])


def test_delta_refit_no_delta_refinalizes(fit_old, old_store):
    """Same store, no appended shards: the refit just re-finalizes the
    persisted accumulators and reproduces the original result."""
    res0, state = fit_old
    res, _ = delta_refit(state, old_store)
    assert_bit_identical(res, res0)
    assert res.diagnostics["delta"]["delta_chunks"] == 0


def test_delta_refit_frozen_mode(fit_old, grown_store, cold):
    """Frozen mode never re-touches the old corpus: the new rows enter
    under the fitted bases.  Not bitwise the cold fit — but close, and
    pass 0 stays exact so a later exact refit still reconciles."""
    _, state = fit_old
    res, state2 = delta_refit(state, grown_store, mode="frozen")
    assert res.diagnostics["delta"]["mode"] == "frozen"
    ref, _ = cold("jnp")
    np.testing.assert_allclose(np.sort(np.asarray(res.rho)),
                               np.sort(np.asarray(ref.rho)), atol=0.05)


def test_delta_refit_rejects_non_append_stores(fit_old, old_store,
                                               grown_store, tmp_path):
    _, state = fit_old
    # different rows, same geometry: the shard-hash prefix check
    other = PlantedCCAData(n=N1, da=DA, db=DB, rank=5, noise=0.4,
                           seed=99, chunk=CHUNK)
    impostor = _ingest(str(tmp_path / "impostor"), other, 0, C1)
    with pytest.raises(ValueError, match="not an append"):
        delta_refit(state, impostor)
    # different geometry entirely
    narrow = PlantedCCAData(n=N0, da=DA - 4, db=DB, rank=5, noise=0.4,
                            seed=11, chunk=CHUNK)
    skewed = _ingest(str(tmp_path / "skewed"), narrow, 0, C0)
    with pytest.raises(ValueError, match="geometry"):
        delta_refit(state, skewed)
    # shrinking is not an append either (fewer shards: the fitted
    # shard list can no longer be a prefix)
    _, full_state = fit_with_state(grown_store, CFG,
                                   jax.random.PRNGKey(KEY),
                                   merge_group=G, engine="jnp")
    with pytest.raises(ValueError, match="not an append"):
        delta_refit(full_state, old_store)


def test_delta_refit_rejects_unaligned_old_corpus(tmp_path, data):
    """The fitted corpus must end on a merge-group boundary, or its
    last group's partial sum would straddle old and new rows."""
    ragged = str(tmp_path / "ragged")  # 7 chunks: 896 % (128*2) != 0
    _ingest(ragged, data, 0, 7)
    _, state = fit_with_state(ViewStoreReader(ragged), CFG,
                              jax.random.PRNGKey(KEY), merge_group=G,
                              engine="jnp")
    extend_chunks(ragged, (data.get_chunk(i) for i in range(7, 9)))
    with pytest.raises(ValueError, match="merge-group boundary"):
        delta_refit(state, ViewStoreReader(ragged))


# -- span combiner (satellite: combiner-on-the-way-out) ---------------------


def _fake_group_stats(n_groups, seed=3):
    rng = np.random.default_rng(seed)
    proto = stats_init_fn("power", DA, DB, CFG.sketch)()
    return [
        jax.tree_util.tree_map(
            lambda x: jnp.asarray(
                rng.standard_normal(np.shape(x)).astype(np.float32)), proto)
        for _ in range(n_groups)
    ]


def test_span_combiner_bitwise_matches_individual_pushes():
    """A worker pre-merging aligned dyadic spans hands the coordinator
    exactly the subtrees the coordinator would have built itself: the
    final reduction is bitwise identical for any power-of-two span."""
    stats = _fake_group_stats(6)
    init = stats_init_fn("power", DA, DB, CFG.sketch)
    ref = SegmentedAccumulator(init, 6 * G, G)
    for g, s in enumerate(stats):
        ref.push_group(g, s)
    for span in (1, 2, 4):
        acc = SegmentedAccumulator(init, 6 * G, G)
        comb = SpanCombiner(span, lambda g0, cnt, merged:
                            acc.push_group_span(g0, merged, cnt))
        for g, s in enumerate(stats):
            comb.emit(g, s)
        comb.flush()
        for f, x, y in zip(ref.result()._fields, ref.result(), acc.result()):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (span, f)


def test_span_combiner_unaligned_run_passes_through():
    """A repair worker's arbitrary group list must stay correct: an
    unaligned start emits span-1 partials until a span boundary."""
    stats = _fake_group_stats(4)
    out = []
    comb = SpanCombiner(2, lambda g0, cnt, merged: out.append((g0, cnt)))
    for g in (1, 2, 3):  # starts mid-span
        comb.emit(g, stats[g])
    comb.flush()
    assert out == [(1, 1), (2, 2)]
    out.clear()
    comb.emit(0, stats[0])  # run break mid-span flushes a span-1 tail
    comb.emit(3, stats[3])
    comb.flush()
    assert out == [(0, 1), (3, 1)]


def test_cluster_combiner_merge_parity(old_store, tmp_path):
    """End-to-end: a 2-worker cluster fit with combine_groups=2 is
    bitwise the Local fit, and the coordinator's merge fan-in shrinks
    to the span count."""
    ref = exec_fit(old_store, CFG, jax.random.PRNGKey(KEY),
                   merge_group=G, engine="jnp")
    res = exec_fit(old_store, CFG, jax.random.PRNGKey(KEY),
                   merge_group=G, engine="jnp", topology=Cluster(2),
                   cluster_dir=str(tmp_path / "cluster"), combine_groups=2)
    assert_bit_identical(ref, res)
    assert res.diagnostics["cluster"]["combine_groups"] == 2


# -- model registry ---------------------------------------------------------


def test_registry_publish_load_roundtrip(fit_old, cold, tmp_path):
    res1, state1 = fit_old
    res2, state2 = cold("jnp")
    reg = ModelRegistry(str(tmp_path / "reg"))
    v1 = reg.publish("m", res1, fit_meta=state1.meta)
    v2 = reg.publish("m", res2, fit_meta=state2.meta)
    assert (v1, v2) == (1, 2)
    assert reg.versions("m") == [1, 2]
    assert reg.current_version("m") == 2
    m = reg.load("m")  # current
    assert m.version == 2
    assert_bit_identical(m, res2)
    m1 = reg.load("m", version=1)
    assert_bit_identical(m1, res1)
    assert reg.meta("m", 2)["parent"] == 1  # provenance chain
    assert reg.meta("m", 1)["fit"]["fingerprint"] == state1.meta["fingerprint"]


def test_registry_rollback_and_bad_version(fit_old, cold, tmp_path):
    res1, _ = fit_old
    res2, _ = cold("jnp")
    reg = ModelRegistry(str(tmp_path / "reg"))
    reg.publish("m", res1)
    reg.publish("m", res2)
    reg.set_current("m", 1)  # rollback: versions are immutable
    assert reg.load("m").version == 1
    with pytest.raises(ValueError, match="no published version"):
        reg.set_current("m", 7)


def test_registry_prune_keeps_current_and_rollback(fit_old, tmp_path):
    """prune(keep=N) drops old versions but never the current version,
    its recorded parent (the rollback target), or the newest N."""
    res, _ = fit_old
    reg = ModelRegistry(str(tmp_path / "reg"))
    for _ in range(6):
        reg.publish("m", res)
    # roll back to v3: current=3, its parent=2 → both protected even
    # though they are far from the newest versions
    reg.set_current("m", 3)
    pruned = reg.prune("m", keep=2)
    assert pruned == [1, 4]
    assert reg.versions("m") == [2, 3, 5, 6]
    assert reg.current_version("m") == 3
    reg.load("m")          # current still loads, hash-verified
    reg.load("m", version=2)  # and so does the rollback target
    # idempotent: a second prune with the same policy removes nothing
    assert reg.prune("m", keep=2) == []
    with pytest.raises(ValueError, match="keep"):
        reg.prune("m", keep=0)


def test_registry_prune_safe_under_concurrent_readers(fit_old, tmp_path):
    """Readers hammering load() during a prune never observe a torn
    artifact: every load either succeeds with a verified hash or misses
    the version cleanly (FileNotFoundError)."""
    import threading

    res, _ = fit_old
    reg = ModelRegistry(str(tmp_path / "reg"))
    for _ in range(8):
        reg.publish("m", res)
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            try:
                m = reg.load("m", version=2)  # a version prune removes
                assert m.version == 2
            except FileNotFoundError:
                pass  # pruned away between listing and open — clean miss
            except Exception as e:  # noqa: BLE001 — anything else is torn
                errors.append(e)
                return

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        pruned = reg.prune("m", keep=1)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert 2 in pruned
    assert not errors, f"reader observed a torn artifact: {errors[0]!r}"
    # survivors: current v8 + parent v7 + newest 1
    assert reg.versions("m") == [7, 8]
    reg.load("m")


def test_registry_detects_corrupted_artifact(fit_old, tmp_path):
    """The content hash catches bit-rot at load time, not in traffic."""
    res, _ = fit_old
    reg = ModelRegistry(str(tmp_path / "reg"))
    reg.publish("m", res)
    vdir = os.path.join(str(tmp_path / "reg"), "m", "v00001")
    (xa,) = [f for f in os.listdir(vdir) if f.startswith("Xa")]
    arr = np.load(os.path.join(vdir, xa))
    arr = arr.copy()
    arr.flat[0] += 1.0
    np.save(os.path.join(vdir, xa), arr)
    with pytest.raises(ValueError, match="hash mismatch"):
        reg.load("m")


# -- batched projector + hot swap -------------------------------------------


def test_hot_swap_zero_drops_no_mixed_versions(fit_old, cold, data, tmp_path):
    """N concurrent request batches across a version flip: every
    request completes, every response carries exactly one version, and
    the embedding is bitwise that version's projection of the input."""
    res1, state1 = fit_old
    res2, state2 = cold("jnp")
    reg = ModelRegistry(str(tmp_path / "reg"))
    reg.publish("m", res1, fit_meta=state1.meta)
    reg.publish("m", res2, fit_meta=state2.meta)
    m1, m2 = reg.load("m", version=1), reg.load("m", version=2)
    xa, _ = data.get_chunk(C1)  # held-out rows as traffic
    models = {1: m1, 2: m2}
    n_before = n_after = 24

    rows = [xa[i % CHUNK] for i in range(n_before + n_after)]
    with BatchedProjector(m1, max_batch=8) as proj:
        before = [proj.submit("a", rows[i]) for i in range(n_before)]
        proj.swap(m2)
        after = [proj.submit("a", rows[n_before + i]) for i in range(n_after)]
        results = [t.result(timeout=30.0) for t in before + after]
        stats = proj.stats()

    assert len(results) == n_before + n_after  # zero drops
    for i, r in enumerate(results):
        v = r["version"]
        assert v in (1, 2)
        X = models[v].Xa
        x = np.asarray(rows[i], dtype=np.float32)
        ref = np.asarray(jnp.asarray(x) @ X.astype(jnp.float32))
        assert np.array_equal(np.asarray(r["emb"]), ref), (i, v)
    # requests queued after swap() returned can only see the new model
    assert all(t.result()["version"] == 2 for t in after)
    assert stats["requests"] == n_before + n_after
    assert stats["swaps"] >= 1


def test_projector_validates_and_shuts_down(fit_old, tmp_path):
    res, _ = fit_old
    reg = ModelRegistry(str(tmp_path / "reg"))
    reg.publish("m", res)
    proj = BatchedProjector(reg.load("m"), max_batch=4)
    with pytest.raises(ValueError, match="view"):
        proj.submit("c", np.zeros(DA, np.float32))
    with pytest.raises(ValueError, match="features"):
        proj.submit("a", np.zeros(DA + 1, np.float32))
    assert proj.project_b(np.zeros(DB, np.float32))["emb"].shape == (CFG.k,)
    proj.close()
    with pytest.raises(RuntimeError, match="shut down"):
        proj.submit("a", np.zeros(DA, np.float32))


def test_corpus_index_topk(cold, grown_store, data):
    res, _ = cold("jnp")
    reg_model = None
    # an index needs a ServedModel-shaped object; build one in-memory
    from repro.serve.registry import ServedModel

    reg_model = ServedModel(name="m", version=1,
                            Xa=jnp.asarray(res.Xa), Xb=jnp.asarray(res.Xb),
                            rho=jnp.asarray(res.rho),
                            Qa=jnp.asarray(res.Qa), Qb=jnp.asarray(res.Qb),
                            meta={})
    index = CorpusIndex.from_store(reg_model, grown_store, view="b")
    assert index.emb.shape == (N1, CFG.k)
    xa, _ = grown_store.get_chunk(0)
    q = np.asarray(reg_model.project_a(xa[3]))
    idx, scores = index.topk(q, k=10)
    assert idx.shape == scores.shape == (10,)
    assert np.all(np.diff(scores) <= 0)  # descending
    weighted = q.astype(np.float32) * np.asarray(reg_model.rho, np.float32)
    np.testing.assert_array_equal(scores, (index.emb @ weighted)[idx])


# -- drift monitor: signal + recovery ---------------------------------------


def test_drift_signal_and_recovery(cold, data):
    """The acceptance loop in miniature: paired held-out traffic sets
    the baseline; an injected shift (pairing broken) trips the latched
    refit signal and the callback; rebinding to a (refreshed) model
    restores the held-out correlation."""
    res, _ = cold("jnp")
    from repro.serve.registry import ServedModel

    model = ServedModel(name="m", version=1, Xa=jnp.asarray(res.Xa),
                        Xb=jnp.asarray(res.Xb), rho=jnp.asarray(res.rho),
                        Qa=jnp.asarray(res.Qa), Qb=jnp.asarray(res.Qb),
                        meta={})
    a12, b12 = data.get_chunk(C1)
    a13, b13 = data.get_chunk(C1 + 1)
    xa = np.concatenate([a12, a13])
    xb = np.concatenate([b12, b13])
    fired = []
    mon = DriftMonitor(model, window=128, threshold=0.8,
                       on_refit_needed=fired.append)
    base = mon.observe(xa[:128], xb[:128])
    assert base is not None and base > 0.5  # planted signal is strong
    assert not mon.refit_needed

    shifted = xb[np.random.default_rng(7).permutation(xb.shape[0])]
    mon.observe(xa[:128], shifted[:128])
    assert mon.refit_needed and len(fired) == 1
    mon.observe(xa[128:256], shifted[128:256])  # latched, fires once
    assert len(fired) == 1

    mon.rebind(model)  # post-swap: re-baseline on healthy traffic
    assert not mon.refit_needed
    recovered = mon.observe(xa[128:256], xb[128:256])
    assert recovered is not None and recovered >= 0.8 * base
    assert mon.status()["windows"] == 4


def test_paired_correlation_tracks_rho(cold, grown_store):
    """On in-distribution rows the empirical projection correlation
    tracks the fitted canonical correlations — the monitor's premise."""
    res, _ = cold("jnp")
    from repro.serve.registry import ServedModel

    model = ServedModel(name="m", version=1, Xa=jnp.asarray(res.Xa),
                        Xb=jnp.asarray(res.Xb), rho=jnp.asarray(res.rho),
                        Qa=jnp.asarray(res.Qa), Qb=jnp.asarray(res.Qb),
                        meta={})
    xa, xb = grown_store.get_chunk(0)
    corr = paired_correlation(model, xa, xb)
    rho = np.asarray(res.rho)
    assert corr.shape == rho.shape
    assert abs(float(corr[0]) - float(rho[0])) < 0.25


# -- the full serving loop (CLI driver) -------------------------------------


def test_cca_serve_cli_loop(tmp_path, capsys):
    """One in-process run of the cca_serve driver: fit → publish v1 →
    drift signal on injected shift → append + exact delta-refit →
    publish v2 → zero-drop hot-swap → recovered correlation."""
    from repro.launch.cca_serve import main

    rc = main(["--smoke", "--store", str(tmp_path / "store"),
               "--registry", str(tmp_path / "reg"), "--clients", "8"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "refit_needed=True" in out
    assert "dropped: 0" in out
    reg = ModelRegistry(str(tmp_path / "reg"))
    assert reg.versions("europarl-cca") == [1, 2]
    assert reg.current_version("europarl-cca") == 2
    # the delta state persisted next to the registry binds the grown store
    state = FitState.load(str(tmp_path / "reg" / "europarl-cca" / "fitstate"))
    assert state.meta["n"] == 1536


# -- obs: chrome-trace export + liveness report -----------------------------


def _write_trace(dir_, records):
    os.makedirs(dir_, exist_ok=True)
    with open(os.path.join(dir_, "trace-1.jsonl"), "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


def test_export_trace_chrome_json(tmp_path):
    from repro.obs.chrometrace import export

    t0 = 1000.0
    trace = str(tmp_path / "trace")
    _write_trace(trace, [
        {"ev": "span", "name": "pass", "t": t0, "dur": 2.0, "sid": 1,
         "pid": 10, "ctx": {"role": "coordinator"}, "attrs": {"pass_idx": 0}},
        {"ev": "span", "name": "fold", "t": t0 + 0.5, "dur": 1.0, "sid": 2,
         "parent": 1, "pid": 10},
        {"ev": "ctr", "name": "kernel_cost", "t": t0 + 0.6, "pid": 10,
         "fields": {"kernel": "powerpass", "flops": 1e9}},
        {"ev": "ctr", "name": "heartbeat", "t": t0 + 1.0, "pid": 10,
         "fields": {"shard": 0, "age_s": 0.2}},
        {"ev": "proto", "op": "publish", "path": "/p/x", "t": t0 + 1.5,
         "pid": 11},
    ])
    out = str(tmp_path / "chrome.json")
    counts = export(trace, out)
    assert counts == {"events_in": 5, "events_out": 6}  # + process_name
    with open(out) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    phs = sorted(e["ph"] for e in evs)
    assert phs == ["C", "C", "M", "X", "X", "i"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert min(e["ts"] for e in evs if "ts" in e) == 0.0  # rebased to t0
    assert {e["name"] for e in xs} == {"pass", "fold"}
    assert next(e for e in xs if e["name"] == "fold")["args"]["parent_sid"] == 1
    # string-tagged counters split into per-value tracks
    ctr = next(e for e in evs if e["ph"] == "C" and "kernel" in e["name"])
    assert ctr["name"] == "kernel_cost[kernel=powerpass]"
    assert ctr["args"] == {"flops": 1e9}
    meta = next(e for e in evs if e["ph"] == "M")
    assert meta["args"]["name"] == "coordinator (pid 10)"
    assert doc["otherData"]["t0_epoch_s"] == t0
    # records without a tid (older traces) fall back to one track/process
    assert all(e["tid"] == 10 for e in xs)


def test_export_trace_per_thread_tracks(tmp_path):
    """Spans recorded on different threads land on different Perfetto
    tracks (tid), so the engine's prefetch I/O threads render next to
    the fold loop instead of merging into one process track."""
    from repro.obs.chrometrace import export

    t0 = 1000.0
    trace = str(tmp_path / "trace")
    _write_trace(trace, [
        {"ev": "span", "name": "fold", "t": t0, "dur": 2.0, "sid": 1,
         "pid": 10, "tid": 101},
        {"ev": "span", "name": "io_read", "t": t0 + 0.1, "dur": 1.5,
         "sid": 2, "pid": 10, "tid": 202},
    ])
    out = str(tmp_path / "chrome.json")
    export(trace, out)
    with open(out) as f:
        evs = json.load(f)["traceEvents"]
    tids = {e["name"]: e["tid"] for e in evs if e["ph"] == "X"}
    assert tids == {"fold": 101, "io_read": 202}


def test_spans_record_thread_ids(tmp_path, monkeypatch):
    """Live obs records carry the recording OS thread id: concurrent
    threads produce distinct tids, all records carry one."""
    import threading

    monkeypatch.setenv("RCCA_TRACE", str(tmp_path / "trace"))
    from repro import obs

    with obs.span("main_work"):
        pass

    def worker():
        with obs.span("thread_work"):
            obs.counter("thread_ctr", x=1)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    evs = obs.load_events(str(tmp_path / "trace"))
    assert evs and all("tid" in e for e in evs)
    by_name = {e["name"]: e["tid"] for e in evs if e.get("ev") == "span"}
    assert by_name["main_work"] != by_name["thread_work"]
    ctr = next(e for e in evs if e.get("ev") == "ctr")
    assert ctr["tid"] == by_name["thread_work"]


def test_report_includes_worker_liveness(tmp_path):
    from repro.obs import report as obs_report

    t0 = 2000.0
    trace = str(tmp_path / "trace")
    _write_trace(trace, [
        {"ev": "span", "name": "pass", "t": t0, "dur": 3.0, "sid": 1,
         "pid": 10, "ctx": {"role": "coordinator"}},
        {"ev": "ctr", "name": "heartbeat", "t": t0 + 1.0, "pid": 10,
         "fields": {"shard": 0, "age_s": 0.1, "pass_idx": 0,
                    "missing_groups": 4}},
        {"ev": "ctr", "name": "heartbeat", "t": t0 + 2.0, "pid": 10,
         "fields": {"shard": 0, "age_s": 0.7, "pass_idx": 1,
                    "missing_groups": 2}},
        {"ev": "ctr", "name": "heartbeat", "t": t0 + 2.0, "pid": 10,
         "fields": {"shard": 1, "age_s": 0.3, "pass_idx": 1,
                    "missing_groups": 2}},
    ])
    report = obs_report.analyze(trace)
    live = report["liveness"]
    assert live["0"]["samples"] == 2
    assert live["0"]["max_age_s"] == pytest.approx(0.7)
    assert live["0"]["last_age_s"] == pytest.approx(0.7)
    assert live["0"]["passes"] == [0, 1]
    assert live["1"]["samples"] == 1
    text = obs_report.render(report)
    assert "worker liveness" in text
    assert "max_age=0.700s" in text
