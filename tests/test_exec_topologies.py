"""Topology parity for the repro.exec pass engine.

THE acceptance bar of the execution-topology refactor: ``Local``,
``Sharded``, ``Cluster`` and ``Hybrid`` all produce BIT-IDENTICAL
``RCCAResult``s on the same store, for both data-pass engines, for any
(workers × devices) layout.  The argument is structural — whole merge
groups are the only unit of distribution, every group is left-folded
on a single device with the same per-chunk update, and group sums
reduce through the same fixed pairwise tree — so the tests assert
array_equal, not allclose.

Hybrid workers are subprocesses spawned with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``, so the
4-device-per-worker layout is exercised even when this pytest session
sees a single CPU device.  The in-process ``Sharded`` matrix rows use
however many devices the session has — run the suite under the same
XLA flag (CI's topology-matrix job, ``make verify-topology``) to give
them a real 4-device mesh; ``test_sharded_forced_devices_subprocess``
covers the forced-mesh case from an unflagged session.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cluster import run_worker
from repro.cluster import partials as pt
from repro.cluster.worker import KILL_ENV
from repro.core.rcca import RCCAConfig, randomized_cca_streaming
from repro.data import PlantedCCAData
from repro.exec import (
    Cluster,
    Hybrid,
    Local,
    PassEngine,
    Sharded,
    StackedChunks,
    as_topology,
    n_full_chunks,
)
from repro.exec import fit as exec_fit
from repro.store import PassRunner, ingest_planted

N, DA, DB, CHUNK = 1536, 28, 20, 128  # 12 chunks
G = 2  # merge group: 6 groups → interesting splits across workers/devices
CFG = RCCAConfig(k=4, p=8, q=1, nu=0.01, center=True)
KEY = 5
SRC_ROOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    data = PlantedCCAData(n=N, da=DA, db=DB, rank=5, noise=0.4,
                          seed=11, chunk=CHUNK)
    return ingest_planted(str(tmp_path_factory.mktemp("topo") / "store"), data)


@pytest.fixture(scope="module")
def streaming_ref(store):
    """Single-process reference per engine, on the exact store bytes."""
    A, B = store.materialize()
    Ac = jnp.asarray(A).reshape(store.n_chunks, CHUNK, DA)
    Bc = jnp.asarray(B).reshape(store.n_chunks, CHUNK, DB)
    cache = {}

    def get(engine):
        if engine not in cache:
            cache[engine] = randomized_cca_streaming(
                Ac, Bc, CFG, jax.random.PRNGKey(KEY), engine=engine,
                merge_group=G)
        return cache[engine]

    return get


def assert_bit_identical(r1, r2):
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        a1, a2 = np.asarray(getattr(r1, name)), np.asarray(getattr(r2, name))
        assert np.array_equal(a1, a2), f"{name} differs"


# -- the topology matrix (the acceptance criterion) ------------------------


TOPOLOGIES = [
    pytest.param(Local(), id="local"),
    pytest.param(Sharded(), id="sharded"),
    pytest.param(Cluster(n_workers=2), id="cluster-2w"),
    pytest.param(Hybrid(n_workers=1, devices_per_worker=4), id="hybrid-1wx4d"),
    pytest.param(Hybrid(n_workers=2, devices_per_worker=4), id="hybrid-2wx4d"),
]


@pytest.mark.parametrize("engine", ["jnp", "kernels"])
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_topology_matrix_bitwise(store, streaming_ref, tmp_path, engine,
                                 topology):
    """Local ≡ Sharded ≡ Cluster ≡ Hybrid, bitwise, both engines —
    one entry point, any way of cutting the pass across hardware."""
    res = exec_fit(store, CFG, jax.random.PRNGKey(KEY), topology=topology,
                   engine=engine, merge_group=G, prefetch=0,
                   cluster_dir=str(tmp_path / "cl"), worker_timeout=300)
    assert_bit_identical(streaming_ref(engine), res)
    if isinstance(topology, (Cluster, Hybrid)):
        cl = res.diagnostics["cluster"]
        assert cl["devices_per_worker"] == topology.devices_per_worker
        assert all(p["redispatched_groups"] == [] for p in cl["passes"])


def test_sharded_ragged_tail_bitwise(tmp_path):
    """A store whose last merge group is ragged (short chunk count AND
    a short last chunk) still folds bitwise-identically under the
    device-parallel engine — the tail falls back to the sequential
    fold with the same per-chunk update."""
    data = PlantedCCAData(n=1472, da=DA, db=DB, rank=5, noise=0.4,
                          seed=13, chunk=CHUNK)  # 12 chunks, last = 64 rows
    store = ingest_planted(str(tmp_path / "ragged"), data)
    assert n_full_chunks(store) == store.n_chunks - 1
    for engine in ("jnp", "kernels"):
        ref = PassRunner(store, CFG, engine=engine, prefetch=0,
                         merge_group=G).fit(jax.random.PRNGKey(KEY))
        res = PassEngine(CFG, engine=engine, topology=Sharded(),
                         merge_group=G).run_mesh(store,
                                                 jax.random.PRNGKey(KEY))
        assert_bit_identical(ref, res)


def test_streaming_topology_knob_matches_local(store, streaming_ref):
    """randomized_cca_streaming(topology=Sharded()) folds the stacked
    chunks through the mesh engine and still matches Local bitwise."""
    A, B = store.materialize()
    Ac = jnp.asarray(A).reshape(store.n_chunks, CHUNK, DA)
    Bc = jnp.asarray(B).reshape(store.n_chunks, CHUNK, DB)
    res = randomized_cca_streaming(Ac, Bc, CFG, jax.random.PRNGKey(KEY),
                                   engine="jnp", merge_group=G,
                                   topology=Sharded())
    assert_bit_identical(streaming_ref("jnp"), res)


def test_sharded_forced_devices_subprocess(store, streaming_ref):
    """In-process Sharded over a FORCED 4-device host mesh (fresh
    interpreter, XLA flag set before jax wakes up) reproduces the
    1-device session result bitwise — device count is invisible."""
    script = (
        "import numpy as np, jax\n"
        "from repro.core.rcca import RCCAConfig\n"
        "from repro.exec import PassEngine, Sharded\n"
        "from repro.store import ViewStoreReader\n"
        f"assert jax.local_device_count() == 4, jax.devices()\n"
        f"cfg = RCCAConfig(k={CFG.k}, p={CFG.p}, q={CFG.q}, nu={CFG.nu}, "
        "center=True)\n"
        f"r = ViewStoreReader({store.path!r})\n"
        f"res = PassEngine(cfg, engine='kernels', topology=Sharded(), "
        f"merge_group={G}).run_mesh(r, jax.random.PRNGKey({KEY}))\n"
        "for n in ('Xa', 'Xb', 'rho', 'Qa', 'Qb'):\n"
        "    np.save(f'{n}.npy', np.asarray(getattr(res, n)))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_ROOT + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    workdir = str(store.path) + ".sub"
    os.makedirs(workdir, exist_ok=True)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          cwd=workdir, capture_output=True, text=True,
                          timeout=480)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    ref = streaming_ref("kernels")
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        got = np.load(os.path.join(workdir, f"{name}.npy"))
        assert np.array_equal(np.asarray(getattr(ref, name)), got), name


# -- hybrid worker fault tolerance -----------------------------------------


def _publish_round(store, cluster_dir, pass_idx=0, kind="power",
                   engine="jnp", fit_id="fitH"):
    from repro.cluster.coordinator import algo_meta
    from repro.core.rcca import init_Q

    Qa, Qb = init_Q(jax.random.PRNGKey(KEY), store.da, store.db, CFG)
    expect = pt.binding_meta(fit_id=fit_id, pass_idx=pass_idx, kind=kind,
                             engine=engine, fingerprint=store.fingerprint(),
                             merge_group=G, algo=algo_meta(CFG))
    pt.write_round(cluster_dir, pass_idx, Qa, Qb, {**expect, "n_shards": 2})
    return expect


def _spawn_hybrid_worker(store, cluster_dir, shard, devices=4,
                         extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_ROOT + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    if extra_env:
        env.update(extra_env)
    cmd = [sys.executable, "-m", "repro.cluster.worker",
           "--store", store.path, "--cluster-dir", cluster_dir,
           "--shard", str(shard), "--n-shards", "2", "--pass-idx", "0",
           "--devices", str(devices), "--prefetch", "0"]
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=480)


def test_hybrid_worker_kill_resume_identical_partials(store, tmp_path):
    """A hybrid worker killed mid-pass resumes at group granularity:
    published groups are skipped, the rest are redone, and the final
    partial set is bitwise identical to an unkilled SEQUENTIAL worker's
    — the device mesh is invisible in the partials too."""
    cd_kill = str(tmp_path / "kill")
    cd_ref = str(tmp_path / "ref")
    expect = _publish_round(store, cd_kill)
    _publish_round(store, cd_ref)

    # worker 0 of 2 with G=2 owns groups 0,2,4 (chunks 0,1 / 4,5 / 8,9);
    # kill after chunk 5 → groups 0,2 published, group 4 lost
    proc = _spawn_hybrid_worker(store, cd_kill, 0,
                                extra_env={KILL_ENV: "0:5"})
    assert proc.returncode == 3, proc.stdout + proc.stderr
    have = pt.collect_partials(cd_kill, 0, 6, expect)
    assert set(have) == {0, 2}

    resumed = _spawn_hybrid_worker(store, cd_kill, 0)
    assert resumed.returncode == 0, resumed.stdout + resumed.stderr
    assert "published 1 partial(s)" in resumed.stdout  # only group 4 left

    run_worker(store.path, cd_ref, 0, 2, 0, prefetch=0)  # sequential ref
    for g in (0, 2, 4):
        s1, m1 = pt.read_partial(cd_kill, 0, g)
        s2, _ = pt.read_partial(cd_ref, 0, g)
        assert pt.binding_matches(m1, expect)
        for x, y in zip(s1, s2):
            assert np.array_equal(np.asarray(x), np.asarray(y)), g


# -- topology declarations -------------------------------------------------


def test_as_topology_coercion():
    assert isinstance(as_topology("local"), Local)
    assert as_topology("cluster", n_workers=3).n_workers == 3
    h = as_topology("hybrid", n_workers=2, devices_per_worker=8)
    assert (h.n_workers, h.devices_per_worker) == (2, 8)
    t = Sharded()
    assert as_topology(t) is t
    with pytest.raises(ValueError, match="unknown topology"):
        as_topology("mesh")


def test_sharded_col_axis_rejected_for_streaming(store):
    """Feature sharding (col_axis) is the resident-mode rcca_dist path;
    the streaming engine must refuse it rather than silently drop the
    bitwise contract."""
    eng = PassEngine(CFG, engine="jnp",
                     topology=Sharded(col_axis="model"), merge_group=G)
    with pytest.raises(ValueError, match="col_axis"):
        eng.run_mesh(store, jax.random.PRNGKey(KEY))


def test_stacked_chunks_validates_pairing():
    A = jnp.zeros((4, 8, 3))
    B = jnp.zeros((5, 8, 2))
    with pytest.raises(ValueError, match="paired"):
        StackedChunks(A, B)


def test_cluster_topologies_need_exec_fit(store):
    eng = PassEngine(CFG, topology=Cluster(n_workers=2), merge_group=G)
    with pytest.raises(ValueError, match="exec.fit"):
        eng.run(store, jax.random.PRNGKey(KEY))
