"""repro.store: on-disk view store round-trip, random access, sharding,
integrity, async prefetch, and the out-of-core fit path (paper claim:
"suitable for large datasets stored out of core")."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rcca import RCCAConfig, randomized_cca_streaming
from repro.data import PlantedCCAData
from repro.store import (
    ChunkPrefetcher,
    PassRunner,
    ViewStoreReader,
    ViewStoreWriter,
    ingest_chunks,
    ingest_planted,
    prefetched,
)

f32 = lambda x: np.asarray(x, np.float32)


@pytest.fixture(scope="module")
def corpus():
    # chunk 200 vs rows_per_shard 500: logical chunks straddle shard
    # boundaries, so reads exercise the multi-shard stitch path
    return PlantedCCAData(n=2000, da=40, db=32, rank=6, noise=0.4,
                          seed=3, chunk=200)


@pytest.fixture(scope="module")
def store(corpus, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("views") / "store")
    return ingest_planted(path, corpus, rows_per_shard=500)


def test_manifest_geometry(store, corpus):
    assert (store.n, store.da, store.db) == (2000, 40, 32)
    assert store.chunk == corpus.chunk
    assert store.n_chunks == corpus.n_chunks
    assert len(store.shards) == 4  # 2000 rows / 500 per shard
    assert store.dtype == "float32"
    assert store.nbytes == 2000 * (40 + 32) * 4
    # fingerprint: stable across reader instances, content-derived
    assert store.fingerprint() == ViewStoreReader(store.path).fingerprint()


def test_chunk_round_trip(store, corpus):
    """Every chunk comes back bit-equal to the ingested (f32) data."""
    for i in range(store.n_chunks):
        a0, b0 = corpus.get_chunk(i)
        a1, b1 = store.get_chunk(i)
        assert a1.dtype == np.float32 and b1.dtype == np.float32
        np.testing.assert_array_equal(f32(a0), a1)
        np.testing.assert_array_equal(f32(b0), b1)


def test_random_access_spans_shards(store, corpus):
    """Chunk 2 covers rows [400, 600) — across the shard-0/1 boundary."""
    a, b = store.get_chunk(2)
    np.testing.assert_array_equal(a, f32(corpus.get_chunk(2)[0]))
    assert a.shape == (200, 40) and b.shape == (200, 32)
    with pytest.raises(IndexError):
        store.get_chunk(store.n_chunks)


def test_iter_chunks_seek(store):
    tail = list(store.iter_chunks(start=7))
    assert len(tail) == store.n_chunks - 7
    np.testing.assert_array_equal(tail[0][0], store.get_chunk(7)[0])


def test_row_shard_partitions_corpus(store):
    """Worker shards are disjoint, strided, and their union is exact —
    same contract as PlantedCCAData.row_shard."""
    n_shards = 3
    seen = []
    for w in range(n_shards):
        got = list(store.row_shard(w, n_shards))
        assert len(got) == len(range(w, store.n_chunks, n_shards))
        seen += [(w + i * n_shards) for i in range(len(got))]
        for i, (a, _) in enumerate(got):
            np.testing.assert_array_equal(a, store.get_chunk(w + i * n_shards)[0])
    assert sorted(seen) == list(range(store.n_chunks))


def test_unaligned_appends_round_trip(tmp_path, corpus):
    """Writer input blocks need not align with chunks or shards."""
    A, B = corpus.materialize()
    path = str(tmp_path / "ragged")
    with ViewStoreWriter(path, 40, 32, chunk=200, rows_per_shard=512) as w:
        lo = 0
        for size in (1, 333, 517, 700, 449):  # sums to 2000
            w.append(A[lo:lo + size], B[lo:lo + size])
            lo += size
    r = ViewStoreReader(path)
    Am, Bm = r.materialize()
    np.testing.assert_array_equal(Am, f32(A))
    np.testing.assert_array_equal(Bm, f32(B))


def test_writer_rejects_mismatched_blocks(tmp_path):
    w = ViewStoreWriter(str(tmp_path / "bad"), 8, 6, chunk=4)
    with pytest.raises(ValueError):
        w.append(np.zeros((3, 8)), np.zeros((2, 6)))  # row mismatch
    with pytest.raises(ValueError):
        w.append(np.zeros((3, 7)), np.zeros((3, 6)))  # feature mismatch


def test_unpublished_store_is_unreadable(tmp_path):
    w = ViewStoreWriter(str(tmp_path / "unpub"), 8, 6, chunk=4)
    w.append(np.zeros((4, 8), np.float32), np.zeros((4, 6), np.float32))
    with pytest.raises(FileNotFoundError):
        ViewStoreReader(str(tmp_path / "unpub"))  # close() not called


def test_verify_detects_corruption(tmp_path, corpus):
    path = str(tmp_path / "corrupt")
    r = ingest_planted(path, corpus, rows_per_shard=1000)
    r.verify()  # pristine
    victim = os.path.join(path, r.shards[1].file_a)
    with open(victim, "r+b") as fh:
        fh.seek(-7, os.SEEK_END)
        byte = fh.read(1)
        fh.seek(-7, os.SEEK_END)
        fh.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(ValueError, match="hash mismatch"):
        ViewStoreReader(path).verify()


def test_prefetcher_parity_and_stats(store):
    """The async pipeline yields exactly the synchronous chunk stream,
    in order, and meters what moved."""
    sync = list(store.iter_chunks())
    pf = ChunkPrefetcher(store.iter_chunks(), depth=2)
    got = list(pf)
    assert len(got) == len(sync)
    for (a0, b0), (a1, b1) in zip(sync, got):
        np.testing.assert_array_equal(a0, np.asarray(a1))
        np.testing.assert_array_equal(b0, np.asarray(b1))
    st = pf.stats()
    assert st["chunks"] == store.n_chunks
    assert st["rows"] == store.n
    assert st["bytes"] == store.nbytes
    # prefetch off → same stream through the metered sync path
    sm = prefetched(store.iter_chunks(), depth=0)
    assert sum(a.shape[0] for a, _ in sm) == store.n
    assert sm.stats()["rows"] == store.n


def test_prefetcher_propagates_source_errors(store):
    def poisoned():
        yield store.get_chunk(0)
        raise RuntimeError("disk on fire")

    pf = ChunkPrefetcher(poisoned(), depth=2)
    next(pf)
    with pytest.raises(RuntimeError, match="disk on fire"):
        next(pf)


def test_larger_than_budget_fit_matches_inmemory(tmp_path):
    """The ISSUE acceptance: a corpus larger than the configured
    in-memory budget round-trips through the store and the store-backed
    fit reproduces the in-memory streaming solution."""
    budget_bytes = 4 << 20
    data = PlantedCCAData(n=8192, da=96, db=96, rank=12, noise=0.5,
                          seed=5, chunk=512)
    path = str(tmp_path / "big")
    reader = ingest_planted(path, data)
    assert reader.nbytes > budget_bytes  # 6 MB of views vs a 4 MB budget

    cfg = RCCAConfig(k=4, p=12, q=1, nu=0.01)
    key = jax.random.PRNGKey(0)
    res_store = PassRunner(reader, cfg, engine="jnp", prefetch=2).fit(key)

    A, B = data.materialize()
    Ac = jnp.asarray(f32(A)).reshape(16, 512, 96)
    Bc = jnp.asarray(f32(B)).reshape(16, 512, 96)
    res_mem = randomized_cca_streaming(Ac, Bc, cfg, key, engine="jnp")

    np.testing.assert_allclose(np.asarray(res_store.rho),
                               np.asarray(res_mem.rho), atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_store.Xa),
                               np.asarray(res_mem.Xa), atol=1e-4)
    io = res_store.diagnostics["io"]
    assert io["rows"] == 2 * reader.n  # q+1 = 2 passes
    assert io["rows_per_s"] > 0


def test_ingest_chunks_from_featurized_stream(tmp_path):
    """ingest_chunks consumes any (a, b) iterator — here a hashed
    bag-of-words stream, the europarl_cca.py --store path."""
    from repro.data import HashingFeaturizer

    rng = np.random.default_rng(0)
    docs = rng.integers(1, 1000, (600, 20))
    ha, hb = HashingFeaturizer(64, seed=1), HashingFeaturizer(48, seed=2)

    def stream():
        for lo in range(0, 600, 150):
            yield (ha.featurize_batch(docs[lo:lo + 150]),
                   hb.featurize_batch(docs[lo:lo + 150]))

    r = ingest_chunks(str(tmp_path / "hashed"), stream(), chunk=150)
    assert (r.n, r.da, r.db) == (600, 64, 48)
    np.testing.assert_array_equal(
        r.get_chunk(1)[0], ha.featurize_batch(docs[150:300]))


def test_cca_fit_data_flag(tmp_path):
    """launch.cca_fit --data round-trips: ingest + store-backed fit."""
    from repro.launch.cca_fit import main as cca_main

    store = str(tmp_path / "fitstore")
    cca_main(["--smoke", "--mode", "stream", "--data", store, "--ingest",
              "--engine", "jnp", "--ckpt-dir", str(tmp_path / "ck")])
    assert os.path.exists(os.path.join(store, "manifest.json"))
    # second invocation reuses the published store (no --ingest)
    cca_main(["--smoke", "--mode", "stream", "--data", store,
              "--engine", "jnp"])


# -- URI scheme dispatch ---------------------------------------------------


from repro.store import StoreFS


class _MemFS(StoreFS):
    """Fake distributed-FS backend: whole files in a dict.  Implements
    only open/exists — load_array falls back to the StoreFS default
    (fetch + in-memory .npy decode), like a real remote backend."""

    def __init__(self):
        self.files = {}

    def load_local(self, reader):
        for name in os.listdir(reader.path):
            with open(os.path.join(reader.path, name), "rb") as f:
                self.files[f"mem://corpus/{name}"] = f.read()

    def open(self, path, mode="rb"):
        import io

        if path not in self.files:
            raise FileNotFoundError(path)
        return io.BytesIO(self.files[path])

    def exists(self, path):
        return path in self.files


def test_uri_scheme_dispatch_mem(store):
    """A fake mem:// backend registered through the opener registry
    serves a byte-identical store: same chunks, same fingerprint, and
    verify() passes — the gs://-shaped plug-in point works."""
    from repro.store import register_scheme

    fs = _MemFS()
    fs.load_local(store)
    register_scheme("mem", fs)
    r = ViewStoreReader("mem://corpus")
    assert (r.n, r.da, r.db, r.chunk) == (store.n, store.da, store.db,
                                          store.chunk)
    assert r.fingerprint() == store.fingerprint()
    r.verify()
    for i in (0, 3, store.n_chunks - 1):
        a0, b0 = store.get_chunk(i)
        a1, b1 = r.get_chunk(i)
        np.testing.assert_array_equal(a0, a1)
        np.testing.assert_array_equal(b0, b1)


def test_uri_unregistered_scheme_fails_helpfully():
    with pytest.raises(KeyError, match="register_scheme"):
        ViewStoreReader("gs-unregistered://bucket/corpus")


def test_file_uri_is_local(store):
    r = ViewStoreReader("file://" + os.path.abspath(store.path))
    assert r.fingerprint() == store.fingerprint()


# -- fsspec-backed schemes (gs://, s3://, memory://) -----------------------


fsspec = pytest.importorskip("fsspec", reason="fsspec opener tests")


def _copy_to_fsspec_memory(store, base="memory://fsstore"):
    mem = fsspec.filesystem("memory")
    for name in os.listdir(store.path):
        with open(os.path.join(store.path, name), "rb") as f:
            with mem.open(f"{base}/{name}", "wb") as g:
                g.write(f.read())
    return base


def test_fsspec_memory_store_round_trip(store):
    """The auto-registered fsspec opener serves a byte-identical store
    from fsspec's in-memory filesystem — the gs://- and s3://-shaped
    code path, exercised without any cloud SDK."""
    from repro.store import store_exists

    base = _copy_to_fsspec_memory(store)
    assert store_exists(base)
    r = ViewStoreReader(base)
    assert r.fingerprint() == store.fingerprint()
    r.verify()
    for i in (0, 4, store.n_chunks - 1):
        a0, b0 = store.get_chunk(i)
        a1, b1 = r.get_chunk(i)
        np.testing.assert_array_equal(a0, a1)
        np.testing.assert_array_equal(b0, b1)


def test_fsspec_memory_fit_bitwise(store):
    """A full store-backed fit from memory:// matches the local-disk
    fit bitwise — the IO backend must be invisible to the numerics."""
    cfg = RCCAConfig(k=3, p=5, q=1, nu=0.01, center=True)
    base = _copy_to_fsspec_memory(store)
    key = jax.random.PRNGKey(3)
    res_local = PassRunner(store, cfg, engine="jnp", prefetch=0).fit(key)
    res_mem = PassRunner(ViewStoreReader(base), cfg, engine="jnp",
                         prefetch=0).fit(key)
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        np.testing.assert_array_equal(np.asarray(getattr(res_local, name)),
                                      np.asarray(getattr(res_mem, name)))


def test_fsspec_missing_sdk_fails_at_first_io():
    """gs:// resolves through the lazy fsspec adapter even without
    gcsfs — the SDK import error surfaces at first IO with fsspec's
    own install hint, not as an opaque unknown-scheme failure."""
    import importlib.util

    if importlib.util.find_spec("gcsfs") is not None:
        pytest.skip("gcsfs installed — the lazy failure path is moot")
    with pytest.raises(ImportError, match="gcsfs"):
        ViewStoreReader("gs://no-such-bucket/corpus")


def test_explicit_registration_overrides_fsspec(store):
    """register_scheme wins over the fsspec auto-registration — a
    custom backend for a known scheme stays pluggable."""
    from repro.store import register_scheme
    from repro.store.uri import _REGISTRY

    fs = _MemFS()
    fs.load_local(store)
    fs.files = {k.replace("mem://corpus", "s3://corpus"): v
                for k, v in fs.files.items()}
    old = _REGISTRY.get("s3")
    try:
        register_scheme("s3", fs)
        r = ViewStoreReader("s3://corpus")
        assert r.fingerprint() == store.fingerprint()
    finally:
        if old is None:
            _REGISTRY.pop("s3", None)
        else:
            _REGISTRY["s3"] = old


# -- worker sharding: seek + merge-group striping --------------------------


def test_row_shard_start_seeks(store):
    """start= resumes a worker mid-shard: exactly the owned chunks at or
    past the seek point are yielded."""
    want = [i for i in range(1, store.n_chunks, 3) if i >= 5]
    got = list(store.row_shard(1, 3, start=5))
    assert len(got) == len(want)
    for i, (a, _) in zip(want, got):
        np.testing.assert_array_equal(a, store.get_chunk(i)[0])


def test_row_shard_group_striding_partitions(store):
    """group= assigns whole merge groups; the union over workers is
    still an exact partition of the corpus."""
    from repro.store import shard_chunks

    n_shards, group = 2, 4
    seen = []
    for w in range(n_shards):
        idxs = list(shard_chunks(w, n_shards, store.n_chunks, group=group))
        assert all((i // group) % n_shards == w for i in idxs)
        got = list(store.row_shard(w, n_shards, group=group))
        assert len(got) == len(idxs)
        seen += idxs
    assert sorted(seen) == list(range(store.n_chunks))


# -- prefetch/sync_chunks auto-tuning --------------------------------------


def test_choose_pipeline_heuristic():
    from repro.store import choose_pipeline

    # page-cache regime: reads are noise → no prefetch thread
    assert choose_pipeline(0.0001, 0.1) == (0, 4)
    # balanced: classic double buffering, strict in-flight bound
    depth, sync = choose_pipeline(0.1, 0.1)
    assert depth == 2 and sync == 1
    # heavily IO-bound: deeper pipeline, capped
    depth, sync = choose_pipeline(1.0, 0.05)
    assert depth == 8 and sync == 1


def test_auto_tune_matches_fixed_depth_bitwise(store):
    """prefetch='auto' only changes pipelining, never numerics: the fit
    equals a fixed-depth fit bitwise and the chosen knobs are reported."""
    cfg = RCCAConfig(k=4, p=8, q=1, nu=0.01)
    key = jax.random.PRNGKey(0)
    fixed = PassRunner(store, cfg, engine="jnp", prefetch=2).fit(key)
    auto = PassRunner(store, cfg, engine="jnp", prefetch="auto",
                      sync_chunks="auto").fit(key)
    for name in ("Xa", "Xb", "rho", "Qa", "Qb"):
        np.testing.assert_array_equal(np.asarray(getattr(fixed, name)),
                                      np.asarray(getattr(auto, name)))
    chosen = auto.diagnostics["io"]["auto"]
    assert isinstance(chosen["prefetch"], int)
    assert isinstance(chosen["sync_chunks"], int)
    assert auto.diagnostics["io"]["prefetch_depth"] == chosen["prefetch"]
    # every chunk of every pass was still consumed exactly once
    assert auto.diagnostics["io"]["rows"] == fixed.diagnostics["io"]["rows"]
