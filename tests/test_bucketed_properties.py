"""Hypothesis property tests for the column-bucketed fused kernels:
for random shapes/dtypes, the bucketed and unbucketed schedules of
``power_project_accumulate`` (and ``projgram``) agree and never raise —
the target bug class is padding / bucket-boundary off-by-ones.

hypothesis is an optional dev dependency (requirements-dev.txt); this
module skips cleanly when it is missing, like test_cca_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.powerpass import power_project_accumulate
from repro.kernels.projgram import projgram

jax.config.update("jax_platform_name", "cpu")

seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _rel(got, want):
    return float(jnp.linalg.norm(got.astype(jnp.float32) - want)
                 / jnp.maximum(jnp.linalg.norm(want), 1e-30))


@settings(max_examples=10, deadline=None)
@given(seed=seeds, n=st.integers(1, 200), da=st.integers(1, 520),
       db=st.integers(1, 160), kt=st.integers(1, 300), bf16=st.booleans())
def test_powerpass_bucketed_unbucketed_agree(seed, n, da, db, kt, bf16):
    """Forcing 128-row ΔY buckets must match the auto (usually
    single-bucket) schedule bit-for-bit, and both must track the jnp
    oracle; no shape may raise."""
    dt = jnp.bfloat16 if bf16 else jnp.float32
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, da)), dt)
    b = jnp.asarray(rng.standard_normal((n, db)), dt)
    q = jnp.asarray(rng.standard_normal((db, kt)), dt)
    auto = power_project_accumulate(a, b, q, interpret=True)
    bucketed = power_project_accumulate(a, b, q, block_da=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(bucketed))
    want = ref.matmul_ref(a, ref.matmul_ref(b, q), transpose_lhs=True)
    assert _rel(auto, want) <= (2e-2 if bf16 else 1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=seeds, n=st.integers(1, 200), d=st.integers(1, 300),
       kt=st.integers(1, 400), bf16=st.booleans())
def test_projgram_bucketed_unbucketed_agree(seed, n, d, kt, bf16):
    dt = jnp.bfloat16 if bf16 else jnp.float32
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), dt)
    q = jnp.asarray(rng.standard_normal((d, kt)), dt)
    p_auto, c_auto = projgram(x, q, interpret=True)
    p_b, c_b = projgram(x, q, block_c=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_auto), np.asarray(c_b))
    np.testing.assert_array_equal(np.asarray(p_auto), np.asarray(p_b))
    pw, cw = ref.projgram_ref(x, q)
    assert _rel(p_auto, pw) <= (2e-2 if bf16 else 1e-4)
    assert _rel(c_auto, cw) <= (3e-2 if bf16 else 1e-4)
