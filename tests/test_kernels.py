"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, pallas_matmul, projgram, ref

SHAPES_NN = [
    (64, 64, 64),
    (128, 257, 96),     # unaligned K/N
    (300, 200, 130),
    (512, 512, 256),
    (1, 700, 130),      # single row
    (1024, 96, 1024),
]

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(atol=3e-2, rtol=3e-2) if dt == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("m,k,n", SHAPES_NN)
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_matmul_nn(m, k, n, dt):
    kx, ky = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    x = jax.random.normal(kx, (m, k), dt)
    y = jax.random.normal(ky, (k, n), dt)
    out = pallas_matmul(x, y, interpret=True)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dt))


@pytest.mark.parametrize("m,k,n", SHAPES_NN)
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_matmul_tn(m, k, n, dt):
    kx, ky = jax.random.split(jax.random.PRNGKey(m * 13 + n))
    x = jax.random.normal(kx, (k, m), dt)  # contraction over dim 0
    y = jax.random.normal(ky, (k, n), dt)
    out = pallas_matmul(x, y, transpose_lhs=True, interpret=True)
    want = ref.matmul_ref(x, y, transpose_lhs=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **_tol(dt))


@pytest.mark.parametrize("n,d,kt", [
    (128, 128, 128),
    (300, 260, 96),     # unaligned everything
    (512, 1024, 512),
    (256, 64, 1024),    # k̃ at the single-bucket boundary
    (256, 64, 1100),    # k̃ > 1024 → bucketed fused path (was: fallback)
])
@pytest.mark.parametrize("dt", DTYPES, ids=["f32", "bf16"])
def test_projgram(n, d, kt, dt):
    kx, kq = jax.random.split(jax.random.PRNGKey(n + kt))
    x = jax.random.normal(kx, (n, d), dt)
    q = jax.random.normal(kq, (d, kt), dt)
    p, c = projgram(x, q, interpret=True)
    pw, cw = ref.projgram_ref(x, q)
    tol = _tol(dt)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pw), **tol)
    # Gram accumulates n terms — scale tolerance
    np.testing.assert_allclose(np.asarray(c) / n, np.asarray(cw) / n, **tol)


def test_power_pass_chunk_matches_ref():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (384, 300))
    b = jax.random.normal(jax.random.PRNGKey(1), (384, 200))
    Qa = jax.random.normal(jax.random.PRNGKey(2), (300, 160))
    Qb = jax.random.normal(jax.random.PRNGKey(3), (200, 160))
    dYa, dYb = ops.power_pass_chunk(a, b, Qa, Qb, interpret=True)
    rYa, rYb = ref.power_pass_ref(a, b, Qa, Qb)
    np.testing.assert_allclose(np.asarray(dYa), np.asarray(rYa), atol=1e-2)
    np.testing.assert_allclose(np.asarray(dYb), np.asarray(rYb), atol=1e-2)


def test_final_pass_chunk_matches_ref():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (384, 300))
    b = jax.random.normal(jax.random.PRNGKey(1), (384, 200))
    Qa = jax.random.normal(jax.random.PRNGKey(2), (300, 160))
    Qb = jax.random.normal(jax.random.PRNGKey(3), (200, 160))
    Ca, Cb, F = ops.final_pass_chunk(a, b, Qa, Qb, interpret=True)
    rCa, rCb, rF = ref.final_pass_ref(a, b, Qa, Qb)
    for got, want in [(Ca, rCa), (Cb, rCb), (F, rF)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-2)


def test_gram_symmetry():
    """PᵀP from the fused kernel is exactly symmetric."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 192))
    q = jax.random.normal(jax.random.PRNGKey(1), (192, 256))
    _, c = projgram(x, q, interpret=True)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c.T), rtol=1e-5, atol=1e-3)
