"""Multi-device tests — run in a subprocess with 8 fake CPU devices so
the main pytest process keeps its single-device view (the dry-run spec
forbids setting the device-count flag globally)."""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_dist_rcca_matches_reference():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.rcca import RCCAConfig, randomized_cca
        from repro.core.rcca_dist import dist_randomized_cca
        from repro.core import feasibility_errors

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        key = jax.random.PRNGKey(0)
        n, da, db, k = 2048, 64, 32, 5
        kz, ka, kb, kn = jax.random.split(key, 4)
        Z = jax.random.normal(kz, (n, k))
        A = Z @ jax.random.normal(ka, (k, da)) + 0.5 * jax.random.normal(kn, (n, da))
        B = Z @ jax.random.normal(kb, (k, db)) + 0.5 * jax.random.normal(jax.random.PRNGKey(9), (n, db))
        cfg = RCCAConfig(k=k, p=16, q=2, lam_a=1e-3, lam_b=1e-3)
        r_ref = randomized_cca(A, B, cfg, jax.random.PRNGKey(1))
        r_dist = dist_randomized_cca(A, B, cfg, jax.random.PRNGKey(1), mesh, microbatch=128)
        np.testing.assert_allclose(np.asarray(r_ref.rho), np.asarray(r_dist.rho), atol=2e-4)
        errs = feasibility_errors(A, B, jnp.asarray(r_dist.Xa), jnp.asarray(r_dist.Xb), 1e-3, 1e-3)
        assert all(float(v) < 1e-4 for v in errs.values()), errs
        # centered variant
        cfgc = RCCAConfig(k=k, p=16, q=1, lam_a=1e-3, lam_b=1e-3, center=True)
        rd = dist_randomized_cca(A + 3, B - 2, cfgc, jax.random.PRNGKey(1), mesh, microbatch=128)
        rr = randomized_cca(A + 3, B - 2, cfgc, jax.random.PRNGKey(1))
        np.testing.assert_allclose(np.asarray(rd.rho), np.asarray(rr.rho), atol=2e-4)
        print("OK")
    """)


def test_dist_rcca_mesh_shapes_agree():
    """Elastic meshes: (2,2,2), (4,2), (8,) row-only — identical results."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.rcca import RCCAConfig
        from repro.core.rcca_dist import dist_randomized_cca

        key = jax.random.PRNGKey(0)
        n, da, db, k = 1024, 32, 32, 4
        Z = jax.random.normal(key, (n, k))
        A = Z @ jax.random.normal(jax.random.PRNGKey(1), (k, da)) + 0.3 * jax.random.normal(jax.random.PRNGKey(2), (n, da))
        B = Z @ jax.random.normal(jax.random.PRNGKey(3), (k, db)) + 0.3 * jax.random.normal(jax.random.PRNGKey(4), (n, db))
        cfg = RCCAConfig(k=k, p=12, q=1, lam_a=1e-3, lam_b=1e-3)
        rhos = []
        for shape, axes in [((2,2,2), ("pod","data","model")), ((4,2), ("data","model")), ((8,), ("data",))]:
            mesh = jax.make_mesh(shape, axes)
            r = dist_randomized_cca(A, B, cfg, jax.random.PRNGKey(7), mesh, microbatch=128)
            rhos.append(np.asarray(r.rho))
        for other in rhos[1:]:
            np.testing.assert_allclose(rhos[0], other, atol=2e-4)
        print("OK")
    """)


def test_compressed_psum_error_feedback():
    """int8+EF psum: relative error small, EF shrinks bias across rounds."""
    run_with_devices("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed import psum_int8_ef

        mesh = jax.make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 256))

        @functools.partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=(P("data"), P("data")), check_rep=False)
        def one_round(xl):
            out, err = psum_int8_ef(xl[0], "data")
            return out[None], err[None]

        out, err = one_round(x)
        exact = jnp.sum(x, axis=0)
        rel = float(jnp.linalg.norm(out[0] - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, rel
        # EF: accumulated over rounds, the *sum* of outputs tracks the sum of exact values
        @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")), check_rep=False)
        def with_err(xl, errl):
            out, err = psum_int8_ef(xl[0], "data", errl[0])
            return out[None], err[None]
        total_out = out
        for _ in range(4):
            o2, err = with_err(x, err)
            total_out = total_out + o2
        rel2 = float(jnp.linalg.norm(total_out[0] / 5 - exact) / jnp.linalg.norm(exact))
        assert rel2 < rel * 1.5, (rel2, rel)
        print("OK", rel, rel2)
    """)


def test_dryrun_machinery_small_mesh():
    """lower+compile one train and one decode cell of every family on a
    2×2×2 mesh with reduced configs (fast stand-in for the 512-chip run;
    the full run is results/dryrun)."""
    run_with_devices("""
        import jax
        from repro.configs import get_config
        from repro.kernels.compat import cost_analysis
        from repro.launch import steps as S
        import repro.launch.dryrun as D

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        S.SHAPES = {
            "train_4k": S.ShapeSpec("train_4k", "train", 256, 8),
            "decode_32k": S.ShapeSpec("decode_32k", "decode", 512, 8),
        }
        D.get_config = lambda a: get_config(a, smoke=True)
        for arch in ["gemma3-1b", "kimi-k2-1t-a32b", "deepseek-v2-236b",
                     "xlstm-350m", "zamba2-7b", "qwen2-vl-2b"]:
            for shape in ["train_4k", "decode_32k"]:
                lowered, meta = D.lower_cell(arch, shape, mesh, loss_chunks=4)
                compiled = lowered.compile()
                assert cost_analysis(compiled).get("flops", 0) > 0, (arch, shape)
        print("OK")
    """, timeout=1800)
