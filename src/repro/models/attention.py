"""Attention blocks: GQA (full / sliding-window / M-RoPE) and
DeepSeek-V2 MLA — train, prefill and absorbed-decode paths.

Sharding: heads over "model"; KV caches (B, S, kv_heads, hd) with batch
over ("pod","data") and kv heads over "model" (MLA caches are per-token
latent vectors, replicated over "model", batch-sharded).
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (
    BATCH_AXES,
    MODEL_AXIS,
    apply_mrope,
    apply_rope,
    dense_init,
    init_rmsnorm,
    rmsnorm,
    shard,
    softcap,
)
from .config import AttnConfig

NEG_INF = -1e30


# ==========================================================================
# GQA
# ==========================================================================


def init_gqa(key, cfg: AttnConfig, d_model: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    H, Kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d_model, H * hd, dtype),
        "wk": dense_init(ks[1], d_model, Kv * hd, dtype),
        "wv": dense_init(ks[2], d_model, Kv * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def gqa_specs(cfg: AttnConfig, d_model: int) -> Dict[str, Any]:
    s = {
        "wq": P(None, MODEL_AXIS),
        "wk": P(None, MODEL_AXIS),
        "wv": P(None, MODEL_AXIS),
        "wo": P(MODEL_AXIS, None),
    }
    if cfg.qk_norm:
        s["q_norm"] = P(None)
        s["k_norm"] = P(None)
    return s


def _sdpa(q, k, v, mask, softcap_val=None):
    """q: (B,S,H,hd), k/v: (B,T,Kv,hd) — grouped attention.

    mask: (B,1,S,T) or (1,1,S,T) additive-compatible boolean (True=keep).
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    q = q.reshape(B, S, Kv, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, softcap_val)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H * hd)


def _flash_fwd_scan(q, k, v, mask, kv_chunk):
    """Online-softmax forward over KV chunks.  Returns (out_unnormalized
    accumulator, running max m, running denom l) — shared by the
    inference path and the custom-VJP residual computation."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nc = T // kv_chunk
    qh = q.reshape(B, S, Kv, G, hd)
    k_c = k.reshape(B, nc, kv_chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, nc, kv_chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    m_b = jnp.broadcast_to(mask, (mask.shape[0], 1, S, T))
    m_c = m_b.reshape(m_b.shape[0], 1, S, nc, kv_chunk).transpose(3, 0, 1, 2, 4)
    scale = 1.0 / math.sqrt(hd)

    def body(carry, inp):
        acc, m_run, l_run = carry
        kc, vc, mc = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qh, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mc[:, :, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Kv, G, S, hd), jnp.float32)
    m0 = jnp.full((B, Kv, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, S), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(body, (acc0, m0, l0), (k_c, v_c, m_c))
    return acc, m_run, l_run


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def flash_attention(q, k, v, mask, kv_chunk=512):
    """FlashAttention with a HAND-WRITTEN backward: per-KV-chunk scores
    are RECOMPUTED in bwd, so neither pass ever materializes (…,S,T) —
    jax autodiff of the fwd scan would save every chunk's p-matrix
    (≈S² over the stack), which is what §Perf iterations 1.3/2.7
    measured and refuted.  No softcap support (callers fall back).
    Returns (B, S, H·hd)."""
    out, _ = _flash_fwd(q, k, v, mask, kv_chunk)
    return out


def _flash_fwd(q, k, v, mask, kv_chunk):
    B, S, H, hd = q.shape
    acc, m_run, l_run = _flash_fwd_scan(q, k, v, mask, kv_chunk)
    out = (acc / jnp.maximum(l_run, 1e-30)[..., None])
    out_flat = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd).astype(v.dtype)
    L = m_run + jnp.log(jnp.maximum(l_run, 1e-30))  # logsumexp per query
    return out_flat, (q, k, v, mask, out.astype(v.dtype), L)


def _flash_bwd(kv_chunk, res, dout_flat):
    import numpy as _np
    from jax import dtypes as _dtypes

    q, k, v, mask, out, L = res
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nc = T // kv_chunk
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, S, Kv, G, hd)
    dout = dout_flat.reshape(B, S, Kv, G, hd).transpose(0, 2, 3, 1, 4)  # (B,Kv,G,S,hd)
    # D_i = Σ_h dout_i·out_i  (flash-bwd identity)
    Dv = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    k_c = k.reshape(B, nc, kv_chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, nc, kv_chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    m_b = jnp.broadcast_to(mask, (mask.shape[0], 1, S, T))
    m_c = m_b.reshape(m_b.shape[0], 1, S, nc, kv_chunk).transpose(3, 0, 1, 2, 4)

    def body(dq_acc, inp):
        kc, vc, mc = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qh, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mc[:, :, None], s, NEG_INF)
        p = jnp.exp(s - L[..., None])  # exact softmax weights, recomputed
        dp = jnp.einsum("bkgsh,btkh->bkgst", dout, vc,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Dv[..., None])  # (B,Kv,G,S,Tc)
        dq_acc = dq_acc + jnp.einsum("bkgst,btkh->bskgh", ds, kc,
                                     preferred_element_type=jnp.float32) * scale
        dk_j = jnp.einsum("bkgst,bskgh->btkh", ds, qh,
                          preferred_element_type=jnp.float32) * scale
        dv_j = jnp.einsum("bkgst,bkgsh->btkh", p, dout,
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, S, Kv, G, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (k_c, v_c, m_c))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, T, Kv, hd)
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, T, Kv, hd)
    d_mask = _np.zeros(mask.shape, _dtypes.float0)  # boolean: zero cotangent
    return (dq.reshape(B, S, H, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype), d_mask)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def _sdpa_chunked(q, k, v, mask, softcap_val=None, kv_chunk: int = 512):
    """Flash-style attention: lax.scan over KV chunks with an online
    softmax, so the live score buffer is (…, S, kv_chunk) instead of
    (…, S, T) — S²·f32 never exists in HBM.  Exact (same math as
    _sdpa); §Perf iteration 1 for the memory-bound train cells.
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    if T % kv_chunk:
        return _sdpa(q, k, v, mask, softcap_val)
    G = H // Kv
    nc = T // kv_chunk
    qh = q.reshape(B, S, Kv, G, hd)
    k_c = k.reshape(B, nc, kv_chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, nc, kv_chunk, Kv, hd).transpose(1, 0, 2, 3, 4)
    m_b = jnp.broadcast_to(mask, (mask.shape[0], 1, S, T))
    m_c = m_b.reshape(m_b.shape[0], 1, S, nc, kv_chunk).transpose(3, 0, 1, 2, 4)
    scale = 1.0 / math.sqrt(hd)

    def body(carry, inp):
        acc, m_run, l_run = carry  # (B,Kv,G,S,hd) f32, (B,Kv,G,S), (B,Kv,G,S)
        kc, vc, mc = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qh, kc,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, softcap_val)
        s = jnp.where(mc[:, :, None] if mc.ndim == 4 else mc, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Kv, G, S, hd), jnp.float32)
    m0 = jnp.full((B, Kv, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, S), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(body, (acc0, m0, l0), (k_c, v_c, m_c))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4)  # (B,S,Kv,G,hd)
    return out.reshape(B, S, H * hd).astype(v.dtype)


def make_mask(S: int, T: int, *, causal: bool, window: Optional[int], offset: int = 0):
    """(1, 1, S, T) boolean mask. ``offset`` = absolute position of query 0."""
    qpos = offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


def gqa_forward(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: AttnConfig,
    *,
    window: Optional[int] = None,
    rope_theta=None,  # float or traced scalar (scanned per-layer theta)
    positions: Optional[jax.Array] = None,  # (B,S) or (B,S,3) for mrope
    cache: Optional[Dict[str, jax.Array]] = None,
    kv_x: Optional[jax.Array] = None,  # cross-attention source
    causal: bool = True,
    mask: Optional[jax.Array] = None,  # explicit (1,1,S,T) override
    chunked: bool = False,  # flash-style online-softmax attention
) -> tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (output, updated_cache).

    - train/prefill: cache=None or fresh cache dict to fill
    - decode: cache with "pos" scalar; S==1 expected (any S works)
    - ``mask`` overrides internal mask construction — used by the scan
      bodies to select local/global masks per layer without running the
      attention twice.
    """
    B, S, D = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    theta = cfg.rope_theta if rope_theta is None else rope_theta

    q = (x @ p["wq"]).reshape(B, S, H, hd)
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    k = (src @ p["wk"]).reshape(B, Skv, Kv, hd)
    v = (src @ p["wv"]).reshape(B, Skv, Kv, hd)

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])

    if positions is None:
        base = 0 if cache is None else cache["pos"]
        positions = base + jnp.arange(S)[None, :]
        kv_positions = jnp.arange(Skv)[None, :] if cache is None else positions
    else:
        kv_positions = positions

    # no rope on cross-attention; static theta == 0 disables (whisper)
    use_rope = kv_x is None and not (isinstance(theta, (int, float)) and theta == 0.0)
    if use_rope:
        if cfg.mrope:
            if positions.ndim == 2:
                positions = jnp.broadcast_to(positions[..., None], (*positions.shape, 3))
                kv_positions = positions
            q = apply_mrope(q.swapaxes(1, 2), positions[:, None], theta).swapaxes(1, 2)
            k = apply_mrope(k.swapaxes(1, 2), kv_positions[:, None], theta).swapaxes(1, 2)
        else:
            q = apply_rope(q.swapaxes(1, 2), positions[:, None], theta).swapaxes(1, 2)
            k = apply_rope(k.swapaxes(1, 2), kv_positions[:, None], theta).swapaxes(1, 2)

    new_cache = None
    if cache is not None and kv_x is None:
        pos = cache["pos"]
        if S == 1:
            # iota-masked update: elementwise, so GSPMD keeps a
            # sequence-sharded cache sharded (DUS would gather it).
            sel = (jnp.arange(cache["k"].shape[1]) == pos)[None, :, None, None]
            ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
        k, v = ck, cv
        if mask is None:
            T = k.shape[1]
            kpos = jnp.arange(T)[None, :]
            qpos = pos + jnp.arange(S)[:, None]
            m = kpos <= qpos
            if window is not None:
                m &= kpos > qpos - window
            mask = m[None, None]
    elif mask is None and kv_x is not None:
        mask = jnp.ones((1, 1, S, Skv), bool)
    elif mask is None:
        mask = make_mask(S, Skv, causal=causal, window=window)

    use_flash = (
        chunked and q.shape[1] >= 1024 and cfg.logit_softcap is None
        and k.shape[1] % 512 == 0 and mask.shape[0] == 1
    )
    if use_flash:
        out = flash_attention(q, k, v, mask, 512)
    else:
        out = _sdpa(q, k, v, mask, cfg.logit_softcap)
    out = shard(out, P(BATCH_AXES, None, MODEL_AXIS))
    return out @ p["wo"], new_cache


def init_gqa_cache(cfg: AttnConfig, B: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    Kv, hd = cfg.n_kv, cfg.head_dim
    return {
        "k": jnp.zeros((B, max_seq, Kv, hd), dtype),
        "v": jnp.zeros((B, max_seq, Kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def gqa_cache_specs(cfg: AttnConfig, *, long_ctx: bool = False) -> Dict[str, Any]:
    """long_ctx: batch is tiny (can't shard) → shard the SEQUENCE over
    the data axes instead, kv heads over model (sequence parallelism
    for the KV cache).  Axes that don't divide are dropped at launch
    time by fit_spec."""
    if long_ctx:
        kv_spec = P(None, BATCH_AXES, MODEL_AXIS, None)
    else:
        kv_spec = P(BATCH_AXES, None, MODEL_AXIS, None)
    return {"k": kv_spec, "v": kv_spec, "pos": P()}


# ==========================================================================
# MLA (DeepSeek-V2)
# ==========================================================================


def init_mla(key, cfg: AttnConfig, d_model: int, dtype) -> Dict[str, Any]:
    m = cfg.mla
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], d_model, m.q_lora, dtype),
        "q_norm": init_rmsnorm(m.q_lora, dtype),
        "w_uq": dense_init(ks[1], m.q_lora, H * (m.nope_dim + m.rope_dim), dtype),
        "w_dkv": dense_init(ks[2], d_model, m.kv_lora, dtype),
        "kv_norm": init_rmsnorm(m.kv_lora, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora, H * m.nope_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora, H * m.v_dim, dtype),
        "w_kr": dense_init(ks[5], d_model, m.rope_dim, dtype),
        "wo": dense_init(ks[6], H * m.v_dim, d_model, dtype),
    }


def mla_specs(cfg: AttnConfig, d_model: int) -> Dict[str, Any]:
    return {
        "w_dq": P(None, None),
        "q_norm": P(None),
        "w_uq": P(None, MODEL_AXIS),
        "w_dkv": P(None, None),
        "kv_norm": P(None),
        "w_uk": P(None, MODEL_AXIS),
        "w_uv": P(None, MODEL_AXIS),
        "w_kr": P(None, None),
        "wo": P(MODEL_AXIS, None),
    }


def mla_forward_train(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: AttnConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Training / prefill path: expand latents to per-head k, v."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd = m.nope_dim, m.rope_dim, m.v_dim

    q = rmsnorm(x @ p["w_dq"], p["q_norm"]) @ p["w_uq"]
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    c_kv = rmsnorm(x @ p["w_dkv"], p["kv_norm"])  # (B,S,kv_lora)
    k_rope = x @ p["w_kr"]  # (B,S,rd), shared across heads

    base = 0 if cache is None else cache["pos"]
    positions = base + jnp.arange(S)[None, :]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B,S,rd), head-shared

    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": pos + S}
        # (prefill path: S is large, caches batch-sharded — DUS is fine here)

    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, nd)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, vd)

    scale = 1.0 / math.sqrt(nd + rd)
    s_nope = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope, preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope, k_rope, preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    mask = make_mask(S, S, causal=True, window=None)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v).reshape(B, S, H * vd)
    out = shard(out, P(BATCH_AXES, None, MODEL_AXIS))
    return out @ p["wo"], new_cache


def mla_forward_decode(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: AttnConfig,
    cache: Dict[str, jax.Array],
) -> tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed decode: attention runs in the kv_lora latent space, so
    the per-step cost is O(S·(kv_lora+rope_dim)) per head-batch instead
    of materializing (S, H, nope+v) expanded keys/values — the reason
    MLA caches stay small (DESIGN.md §3)."""
    m = cfg.mla
    B, S, D = x.shape  # S == 1 in steady-state decode
    H = cfg.n_heads
    nd, rd, vd = m.nope_dim, m.rope_dim, m.v_dim

    q = rmsnorm(x @ p["w_dq"], p["q_norm"]) @ p["w_uq"]
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    c_new = rmsnorm(x @ p["w_dkv"], p["kv_norm"])
    k_rope_new = x @ p["w_kr"]
    pos = cache["pos"]
    positions = pos + jnp.arange(S)[None, :]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
    k_rope_new = apply_rope(k_rope_new, positions, cfg.rope_theta)

    if S == 1:  # iota-masked update (sequence-shardable; see gqa_forward)
        sel = (jnp.arange(cache["c_kv"].shape[1]) == pos)[None, :, None]
        c_kv = jnp.where(sel, c_new.astype(cache["c_kv"].dtype), cache["c_kv"])
        k_rope = jnp.where(sel, k_rope_new.astype(cache["k_rope"].dtype), cache["k_rope"])
    else:
        c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
        k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    new_cache = {"c_kv": c_kv, "k_rope": k_rope, "pos": pos + S}

    # absorb W_uk into the query:  q_eff[b,s,h,l] = Σ_d q_nope[b,s,h,d]·W_uk[l,h,d]
    w_uk = p["w_uk"].reshape(m.kv_lora, H, nd)
    q_eff = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk)

    scale = 1.0 / math.sqrt(nd + rd)
    T = c_kv.shape[1]
    s_lat = jnp.einsum("bshl,btl->bhst", q_eff, c_kv, preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope, k_rope, preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * scale
    kpos = jnp.arange(T)[None, :]
    qpos = pos + jnp.arange(S)[:, None]
    scores = jnp.where((kpos <= qpos)[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btl->bshl", w.astype(c_kv.dtype), c_kv)  # latent output
    w_uv = p["w_uv"].reshape(m.kv_lora, H, vd)
    out = jnp.einsum("bshl,lhd->bshd", o_lat, w_uv).reshape(B, S, H * vd)
    out = shard(out, P(BATCH_AXES, None, MODEL_AXIS))
    return out @ p["wo"], new_cache


def init_mla_cache(cfg: AttnConfig, B: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((B, max_seq, m.kv_lora), dtype),
        "k_rope": jnp.zeros((B, max_seq, m.rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_cache_specs(cfg: AttnConfig, *, long_ctx: bool = False) -> Dict[str, Any]:
    if long_ctx:
        return {
            "c_kv": P(None, BATCH_AXES, None),
            "k_rope": P(None, BATCH_AXES, None),
            "pos": P(),
        }
    return {
        "c_kv": P(BATCH_AXES, None, None),
        "k_rope": P(BATCH_AXES, None, None),
        "pos": P(),
    }
