"""Shared building blocks: norms, rotary embeddings, initializers, and
the (param-tree, spec-tree) convention.

Params are plain nested dicts of jax.Arrays.  Every ``init_*`` has a
matching ``spec_*`` returning the same tree structure with
PartitionSpec leaves (logical axes: batch→("pod","data"), tensor→"model").
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # stored as (gamma - 1), gemma-style


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings (incl. 3-section M-RoPE for qwen2-vl)
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Qwen2-VL M-RoPE: head_dim split into 3 sections (temporal, h, w)
    with independent position streams.  positions: (..., seq, 3); for
    pure text all three streams are equal, recovering plain RoPE."""
    hd = x.shape[-1]
    half = hd // 2
    # 3 sections over the half-dim frequency bands (t gets the remainder)
    s = half // 3
    sections = [half - 2 * s, s, s]
    freqs = rope_freqs(hd, theta)
    ang_parts = []
    off = 0
    for i, sec in enumerate(sections):
        f = freqs[off : off + sec]
        ang_parts.append(positions[..., i : i + 1].astype(jnp.float32) * f)
        off += sec
    ang = jnp.concatenate(ang_parts, axis=-1)  # (..., seq, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (seq, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# token-dispatch axes: MoE routing spreads tokens over the WHOLE mesh
DISPATCH_AXES = ("pod", "data", "model")

# ---------------------------------------------------------------------------
# sharding policy: "2d" = DP×TP (default); "dp" = pure data parallel + FSDP
# (the model axis joins the batch axes; per-layer TP collectives vanish —
# the right call for small-model training where TP all-reduces dominate).
# ---------------------------------------------------------------------------

_POLICY = "2d"


import contextlib


@contextlib.contextmanager
def sharding_policy(policy: str):
    global _POLICY
    old = _POLICY
    _POLICY = policy
    try:
        yield
    finally:
        _POLICY = old


def apply_policy(spec: P) -> P:
    """Rewrite one PartitionSpec under the active policy."""
    if _POLICY != "dp":
        return spec
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif e == MODEL_AXIS:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a != MODEL_AXIS)
            if set(kept) == set(BATCH_AXES):
                kept = kept + (MODEL_AXIS,)  # batch spreads over model too
            out.append(kept if kept else None)
        else:
            out.append(e)
    return P(*out)


def apply_policy_tree(tree):
    return jax.tree.map(apply_policy, tree, is_leaf=lambda x: isinstance(x, P))


def shard(x: jax.Array, spec: P) -> jax.Array:
    """Sharding-constraint helper, robust to partial meshes.

    Axes absent from the ambient mesh or not dividing the dim are
    dropped (greedy prefix), so one logical spec works on any mesh —
    including the single-device CPU used by smoke tests (no-op there).
    Honors the active sharding policy (see sharding_policy).
    """
    spec = apply_policy(spec)
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = set(mesh.axis_names) if mesh is not None else set()
        if not names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        entries = []
        for i, e in enumerate(spec):
            if e is None or i >= x.ndim:
                entries.append(None)
                continue
            axes = e if isinstance(e, tuple) else (e,)
            kept, n = [], 1
            for a in axes:
                if a in names and x.shape[i] % (n * sizes[a]) == 0:
                    kept.append(a)
                    n *= sizes[a]
            entries.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:
        return x
