"""Generic LM assembly: pattern-driven blocks, scan-over-layers,
train / prefill / decode paths, and the whisper-style encoder-decoder.

Design notes
------------
- Params are stacked per *segment* (a maximal scan-able group of
  layers) so the HLO is O(segments), not O(layers) — essential for
  compiling 61-81 layer models on the dry-run host.
- Attention LMs (incl. gemma3's 5:1 local:global) are ONE segment: all
  layers share param shapes; per-layer differences (window on/off, rope
  theta) ride along as scanned arrays.
- MoE LMs: n_dense_layers unscanned + one MoE segment.
- xLSTM: scan over (mLSTM, sLSTM) groups.  Zamba2: scan over groups of
  (shared-attention block [shared params] + 5 Mamba2 layers) + tail.
- Decode caches mirror the segment structure (stacked along layer dim).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn
from . import ffn as ffn_mod
from . import ssm as ssm_mod
from . import xlstm as xl
from .common import (
    BATCH_AXES,
    MODEL_AXIS,
    embed_init,
    init_rmsnorm,
    rmsnorm,
    shard,
    softcap,
)
from .config import ModelConfig

Params = Dict[str, Any]


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _stack_specs(spec_tree, extra_leading=1):
    """Prefix every PartitionSpec in the tree with None axes for the
    stacked layer dim(s)."""
    def add(spec):
        return P(*([None] * extra_leading), *spec)
    return jax.tree.map(add, spec_tree, is_leaf=lambda x: isinstance(x, P))


# ==========================================================================
# decoder-only LM
# ==========================================================================


class LMModel:
    """Decoder-only language model driven by ModelConfig.layer_pattern."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = cfg.pattern()
        self.dtype = jnp.dtype(cfg.dtype)
        self.family = self._family()
        # full unroll of layer scans — used by the dry-run cost
        # calibration (XLA cost_analysis counts while bodies once)
        self.scan_unroll = False
        # flash-style chunked attention in train/prefill (§Perf iter 1:
        # removes f32 S² score buffers from HBM)
        self.flash_attention = False

    def _family(self) -> str:
        pat = set(self.pattern)
        if pat <= {"attn", "local"}:
            return "attn"
        if pat <= {"attn", "attn_moe"}:
            return "moe"
        if pat <= {"mlstm", "slstm"}:
            return "xlstm"
        if pat <= {"mamba", "shared_attn"}:
            return "zamba"
        raise ValueError(f"unsupported pattern {pat}")

    # ---------------- params ----------------

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = self.dtype
        k_embed, k_layers, k_extra = jax.random.split(key, 3)
        p: Params = {
            "embed": embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
            "final_norm": init_rmsnorm(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(k_extra, cfg.vocab, cfg.d_model, dt)

        if self.family == "attn":
            L = cfg.n_layers

            def one(k):
                k1, k2, k3, k4 = jax.random.split(k, 4)
                return {
                    "ln_attn": init_rmsnorm(cfg.d_model, dt),
                    "attn": attn.init_gqa(k1, cfg.attn, cfg.d_model, dt),
                    "ln_ffn": init_rmsnorm(cfg.d_model, dt),
                    "ffn": ffn_mod.init_mlp(k2, cfg.ffn, cfg.d_model, dt),
                }

            p["layers"] = _stack_init(one, k_layers, L)
        elif self.family == "moe":
            nd = cfg.moe.n_dense_layers
            is_mla = cfg.attn.mla is not None
            a_init = attn.init_mla if is_mla else attn.init_gqa

            def dense_layer(k):
                k1, k2 = jax.random.split(k)
                return {
                    "ln_attn": init_rmsnorm(cfg.d_model, dt),
                    "attn": a_init(k1, cfg.attn, cfg.d_model, dt),
                    "ln_ffn": init_rmsnorm(cfg.d_model, dt),
                    "ffn": ffn_mod.init_mlp(k2, cfg.ffn, cfg.d_model, dt),
                }

            def moe_layer(k):
                k1, k2 = jax.random.split(k)
                return {
                    "ln_attn": init_rmsnorm(cfg.d_model, dt),
                    "attn": a_init(k1, cfg.attn, cfg.d_model, dt),
                    "ln_ffn": init_rmsnorm(cfg.d_model, dt),
                    "moe": ffn_mod.init_moe(k2, cfg.moe, cfg.d_model, dt),
                }

            kd, km = jax.random.split(k_layers)
            p["dense_layers"] = _stack_init(dense_layer, kd, nd)
            p["moe_layers"] = _stack_init(moe_layer, km, cfg.n_layers - nd)
        elif self.family == "xlstm":
            n_groups = cfg.n_layers // 2

            def group(k):
                k1, k2 = jax.random.split(k)
                return {
                    "ln_m": init_rmsnorm(cfg.d_model, dt),
                    "mlstm": xl.init_mlstm(k1, cfg.xlstm, cfg.d_model, dt),
                    "ln_s": init_rmsnorm(cfg.d_model, dt),
                    "slstm": xl.init_slstm(k2, cfg.xlstm, cfg.d_model, dt),
                }

            p["groups"] = _stack_init(group, k_layers, n_groups)
        elif self.family == "zamba":
            gsize = 6  # 1 shared-attn + 5 mamba per group
            n_groups = cfg.n_layers // gsize
            tail = cfg.n_layers - n_groups * gsize
            ks, kg, kt = jax.random.split(k_layers, 3)
            k1, k2 = jax.random.split(ks)
            p["shared_attn"] = {
                "ln_attn": init_rmsnorm(cfg.d_model, dt),
                "attn": attn.init_gqa(k1, cfg.attn, cfg.d_model, dt),
                "ln_ffn": init_rmsnorm(cfg.d_model, dt),
                "ffn": ffn_mod.init_mlp(k2, cfg.ffn, cfg.d_model, dt),
            }

            def mamba_layer(k):
                return {
                    "ln": init_rmsnorm(cfg.d_model, dt),
                    "mamba": ssm_mod.init_mamba2(k, cfg.ssm, cfg.d_model, dt),
                }

            def mgroup(k):
                return _stack_init(mamba_layer, k, gsize - 1)

            p["mamba_groups"] = _stack_init(mgroup, kg, n_groups)
            p["mamba_tail"] = _stack_init(mamba_layer, kt, tail) if tail else {}
        return p

    def specs(self) -> Params:
        cfg = self.cfg
        s: Params = {
            "embed": P(MODEL_AXIS, None),
            "final_norm": P(None),
        }
        if not cfg.tie_embeddings:
            s["lm_head"] = P(MODEL_AXIS, None)

        if self.family == "attn":
            layer = {
                "ln_attn": P(None),
                "attn": attn.gqa_specs(cfg.attn, cfg.d_model),
                "ln_ffn": P(None),
                "ffn": ffn_mod.mlp_specs(cfg.ffn, cfg.d_model),
            }
            s["layers"] = _stack_specs(layer)
        elif self.family == "moe":
            is_mla = cfg.attn.mla is not None
            a_specs = attn.mla_specs if is_mla else attn.gqa_specs
            dense = {
                "ln_attn": P(None),
                "attn": a_specs(cfg.attn, cfg.d_model),
                "ln_ffn": P(None),
                "ffn": ffn_mod.mlp_specs(cfg.ffn, cfg.d_model),
            }
            moe = {
                "ln_attn": P(None),
                "attn": a_specs(cfg.attn, cfg.d_model),
                "ln_ffn": P(None),
                "moe": ffn_mod.moe_specs(cfg.moe, cfg.d_model),
            }
            s["dense_layers"] = _stack_specs(dense)
            s["moe_layers"] = _stack_specs(moe)
        elif self.family == "xlstm":
            group = {
                "ln_m": P(None),
                "mlstm": xl.mlstm_specs(cfg.xlstm, cfg.d_model),
                "ln_s": P(None),
                "slstm": xl.slstm_specs(cfg.xlstm, cfg.d_model),
            }
            s["groups"] = _stack_specs(group)
        elif self.family == "zamba":
            s["shared_attn"] = {
                "ln_attn": P(None),
                "attn": attn.gqa_specs(cfg.attn, cfg.d_model),
                "ln_ffn": P(None),
                "ffn": ffn_mod.mlp_specs(cfg.ffn, cfg.d_model),
            }
            mamba_layer = {
                "ln": P(None),
                "mamba": ssm_mod.mamba2_specs(cfg.ssm, cfg.d_model),
            }
            s["mamba_groups"] = _stack_specs(mamba_layer, extra_leading=2)
            gsize = 6
            tail = cfg.n_layers - (cfg.n_layers // gsize) * gsize
            s["mamba_tail"] = _stack_specs(mamba_layer) if tail else {}
        return s

    # ---------------- scanned flags (attn family) ----------------

    def _attn_layer_flags(self):
        cfg = self.cfg
        is_local = jnp.array([t == "local" for t in self.pattern], bool)
        theta_g = cfg.attn.rope_theta
        theta_l = cfg.attn.local_rope_theta or theta_g
        thetas = jnp.where(is_local, theta_l, theta_g).astype(jnp.float32)
        return is_local, thetas

    # ---------------- forward ----------------

    def embed_tokens(self, p: Params, tokens: jax.Array) -> jax.Array:
        x = p["embed"][tokens]
        if self.cfg.embed_scale:
            x = x * jnp.sqrt(jnp.asarray(self.cfg.d_model, jnp.float32)).astype(x.dtype)
        return shard(x, P(BATCH_AXES, None, None))

    def logits(self, p: Params, x: jax.Array) -> jax.Array:
        x = rmsnorm(x, p["final_norm"], self.cfg.norm_eps)
        head = p["embed"] if self.cfg.tie_embeddings else p["lm_head"]
        lg = jnp.einsum("bsd,vd->bsv", x, head, preferred_element_type=jnp.float32)
        lg = softcap(lg, self.cfg.final_logit_softcap)
        return shard(lg, P(BATCH_AXES, None, MODEL_AXIS))

    def forward_hidden(
        self, p: Params, batch: Dict[str, jax.Array], *, remat: bool = True
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Final pre-norm hidden states (B,S,D) — the train step computes
        the loss from these via seq-chunked logits (never materializing
        the full (B,S,V) tensor)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed_tokens(p, tokens)
        if batch.get("embeds") is not None:
            x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        aux = {"moe_aux": jnp.zeros((), jnp.float32)}
        x, aux = self._run_layers_train(p, x, aux, remat=remat)
        if batch.get("embeds") is not None:
            x = x[:, batch["embeds"].shape[1] :]
        return x, aux

    def forward_train(
        self, p: Params, batch: Dict[str, jax.Array], *, remat: bool = True
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: {"tokens": (B,S)} (+ "embeds" (B,Se,D) for stub frontends).
        Returns (logits, aux) where aux carries MoE losses."""
        x, aux = self.forward_hidden(p, batch, remat=remat)
        return self.logits(p, x), aux

    def _run_layers_train(self, p, x, aux, *, remat):
        fam = self.family
        cfg = self.cfg

        if fam == "attn":
            is_local, thetas = self._attn_layer_flags()
            S = x.shape[1]
            has_local = cfg.attn.window is not None and any(
                t == "local" for t in self.pattern
            )
            mask_g = attn.make_mask(S, S, causal=cfg.attn.causal, window=None)
            mask_l = (
                attn.make_mask(S, S, causal=cfg.attn.causal, window=cfg.attn.window)
                if has_local
                else None
            )

            def body(carry, inp):
                x = carry
                lp, loc, th = inp
                h = rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
                # select the (cheap, boolean) mask per layer — ONE attention
                # call regardless of local/global, so HLO FLOPs stay honest.
                mask = jnp.where(loc, mask_l, mask_g) if has_local else mask_g
                out, _ = attn.gqa_forward(
                    lp["attn"], h, cfg.attn, rope_theta=th, mask=mask,
                    chunked=self.flash_attention,
                )
                x = x + out
                h = rmsnorm(x, lp["ln_ffn"], cfg.norm_eps)
                x = x + ffn_mod.mlp_forward(lp["ffn"], h, cfg.ffn)
                return x, None

            body_fn = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(body_fn, x, (p["layers"], is_local, thetas))
            return x, aux

        if fam == "moe":
            is_mla = cfg.attn.mla is not None

            def attn_fwd(lp, h):
                if is_mla:
                    out, _ = attn.mla_forward_train(lp["attn"], h, cfg.attn)
                else:
                    out, _ = attn.gqa_forward(lp["attn"], h, cfg.attn,
                                              chunked=self.flash_attention)
                return out

            def dense_body(carry, lp):
                x = carry
                h = rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
                x = x + attn_fwd(lp, h)
                h = rmsnorm(x, lp["ln_ffn"], cfg.norm_eps)
                x = x + ffn_mod.mlp_forward(lp["ffn"], h, cfg.ffn)
                return x, None

            def moe_body(carry, lp):
                x, aux_sum = carry
                x = x.astype(self.dtype)  # keep the remat-saved carry bf16
                h = rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
                x = x + attn_fwd(lp, h)
                h = rmsnorm(x, lp["ln_ffn"], cfg.norm_eps)
                out, a = ffn_mod.moe_forward(lp["moe"], h, cfg.moe)
                return ((x + out).astype(self.dtype), aux_sum + a), None

            db = jax.checkpoint(dense_body) if remat else dense_body
            mb = jax.checkpoint(moe_body) if remat else moe_body
            x, _ = jax.lax.scan(db, x, p["dense_layers"])
            (x, moe_aux), _ = jax.lax.scan(mb, (x, aux["moe_aux"]), p["moe_layers"])
            aux = {**aux, "moe_aux": moe_aux}
            return x, aux

        if fam == "xlstm":
            def body(carry, gp):
                x = carry
                h = rmsnorm(x, gp["ln_m"], cfg.norm_eps)
                x = x + xl.mlstm_forward_train(gp["mlstm"], h, cfg.xlstm, cfg.d_model)
                h = rmsnorm(x, gp["ln_s"], cfg.norm_eps)
                x = x + xl.slstm_forward_train(gp["slstm"], h, cfg.xlstm, cfg.d_model)
                return x, None

            body_fn = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(body_fn, x, p["groups"])
            return x, aux

        if fam == "zamba":
            sp = p["shared_attn"]

            def shared_block(x):
                h = rmsnorm(x, sp["ln_attn"], cfg.norm_eps)
                out, _ = attn.gqa_forward(sp["attn"], h, cfg.attn,
                                          chunked=self.flash_attention)
                x = x + out
                h = rmsnorm(x, sp["ln_ffn"], cfg.norm_eps)
                return x + ffn_mod.mlp_forward(sp["ffn"], h, cfg.ffn)

            def mamba_body(carry, lp):
                x = carry
                h = rmsnorm(x, lp["ln"], cfg.norm_eps)
                x = x + ssm_mod.mamba2_forward_train(lp["mamba"], h, cfg.ssm, cfg.d_model)
                return x, None

            mbody = jax.checkpoint(mamba_body) if remat else mamba_body

            def group_body(carry, gp):
                x = carry
                x = shared_block(x)
                x, _ = jax.lax.scan(mbody, x, gp)
                return x, None

            gbody = jax.checkpoint(group_body) if remat else group_body
            x, _ = jax.lax.scan(gbody, x, p["mamba_groups"])
            if p.get("mamba_tail"):
                x, _ = jax.lax.scan(mbody, x, p["mamba_tail"])
            return x, aux

        raise ValueError(self.family)

    # ---------------- serving: cache init / prefill / decode ----------------

    def init_cache(self, B: int, max_seq: int) -> Params:
        cfg = self.cfg
        dt = self.dtype
        fam = self.family
        if fam == "attn":
            L = cfg.n_layers

            def one(_):
                return attn.init_gqa_cache(cfg.attn, B, max_seq, dt)

            return {"layers": jax.vmap(one)(jnp.arange(L))}
        if fam == "moe":
            is_mla = cfg.attn.mla is not None
            mk = attn.init_mla_cache if is_mla else attn.init_gqa_cache
            nd = cfg.moe.n_dense_layers

            def one(_):
                return mk(cfg.attn, B, max_seq, dt)

            return {
                "dense_layers": jax.vmap(one)(jnp.arange(nd)),
                "moe_layers": jax.vmap(one)(jnp.arange(cfg.n_layers - nd)),
            }
        if fam == "xlstm":
            ng = cfg.n_layers // 2

            def one(_):
                return {
                    "mlstm": xl.init_mlstm_state(cfg.xlstm, cfg.d_model, B, dt),
                    "slstm": xl.init_slstm_state(cfg.xlstm, cfg.d_model, B, dt),
                }

            return {"groups": jax.vmap(one)(jnp.arange(ng))}
        if fam == "zamba":
            gsize = 6
            ng = cfg.n_layers // gsize
            tail = cfg.n_layers - ng * gsize

            def m_one(_):
                return ssm_mod.init_mamba2_state(cfg.ssm, cfg.d_model, B, dt)

            def g_one(_):
                return jax.vmap(m_one)(jnp.arange(gsize - 1))

            # shared attn block is invoked ng times per token → its KV
            # cache is per-invocation: (ng, B, S, ...)
            c = {
                "shared_attn": jax.vmap(lambda _: attn.init_gqa_cache(cfg.attn, B, max_seq, dt))(
                    jnp.arange(ng)
                ),
                "shared_pos": jnp.zeros((), jnp.int32),
                "mamba_groups": jax.vmap(g_one)(jnp.arange(ng)),
            }
            if tail:
                c["mamba_tail"] = jax.vmap(m_one)(jnp.arange(tail))
            return c
        raise ValueError(fam)

    def cache_specs(self, *, long_ctx: bool = False) -> Params:
        cfg = self.cfg
        fam = self.family
        if fam == "attn":
            return {"layers": _stack_specs(attn.gqa_cache_specs(cfg.attn, long_ctx=long_ctx))}
        if fam == "moe":
            is_mla = cfg.attn.mla is not None
            cs = attn.mla_cache_specs if is_mla else attn.gqa_cache_specs
            return {
                "dense_layers": _stack_specs(cs(cfg.attn, long_ctx=long_ctx)),
                "moe_layers": _stack_specs(cs(cfg.attn, long_ctx=long_ctx)),
            }
        if fam == "xlstm":
            g = {
                "mlstm": xl.mlstm_state_specs(cfg.xlstm),
                "slstm": xl.slstm_state_specs(cfg.xlstm),
            }
            return {"groups": _stack_specs(g)}
        if fam == "zamba":
            gsize = 6
            tail = cfg.n_layers - (cfg.n_layers // gsize) * gsize
            c = {
                "shared_attn": _stack_specs(attn.gqa_cache_specs(cfg.attn, long_ctx=long_ctx)),
                "shared_pos": P(),
                "mamba_groups": _stack_specs(ssm_mod.mamba2_state_specs(cfg.ssm), extra_leading=2),
            }
            if tail:
                c["mamba_tail"] = _stack_specs(ssm_mod.mamba2_state_specs(cfg.ssm))
            return c
        raise ValueError(fam)

    def decode_step(
        self, p: Params, tokens: jax.Array, cache: Params
    ) -> Tuple[jax.Array, Params]:
        """One serving step: tokens (B, S) with small S (1 for decode);
        uses and updates the KV/state caches."""
        cfg = self.cfg
        fam = self.family
        x = self.embed_tokens(p, tokens)

        if fam == "attn":
            is_local, thetas = self._attn_layer_flags()
            has_local = cfg.attn.window is not None and any(
                t == "local" for t in self.pattern
            )
            S = tokens.shape[1]
            T = cache["layers"]["k"].shape[2]  # (L, B, T, Kv, hd)
            pos0 = cache["layers"]["pos"][0]
            kpos = jnp.arange(T)[None, :]
            qpos = pos0 + jnp.arange(S)[:, None]
            mask_g = (kpos <= qpos)[None, None]
            mask_l = (
                (mask_g & (kpos > qpos - cfg.attn.window)[None, None])
                if has_local
                else None
            )

            def body(x, inp):
                lp, c, loc, th = inp
                h = rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
                mask = jnp.where(loc, mask_l, mask_g) if has_local else mask_g
                out, nc = attn.gqa_forward(
                    lp["attn"], h, cfg.attn, rope_theta=th, cache=c, mask=mask
                )
                x = x + out
                h = rmsnorm(x, lp["ln_ffn"], cfg.norm_eps)
                x = x + ffn_mod.mlp_forward(lp["ffn"], h, cfg.ffn)
                return x, nc

            x, new_caches = jax.lax.scan(
                body, x, (p["layers"], cache["layers"], is_local, thetas)
            )
            return self.logits(p, x), {"layers": new_caches}

        if fam == "moe":
            is_mla = cfg.attn.mla is not None

            def attn_step(lp, h, c):
                if is_mla:
                    return attn.mla_forward_decode(lp["attn"], h, cfg.attn, c)
                return attn.gqa_forward(lp["attn"], h, cfg.attn, cache=c)

            def dense_body(x, inp):
                lp, c = inp
                h = rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
                out, nc = attn_step(lp, h, c)
                x = x + out
                h = rmsnorm(x, lp["ln_ffn"], cfg.norm_eps)
                x = x + ffn_mod.mlp_forward(lp["ffn"], h, cfg.ffn)
                return x, nc

            def moe_body(x, inp):
                lp, c = inp
                h = rmsnorm(x, lp["ln_attn"], cfg.norm_eps)
                out, nc = attn_step(lp, h, c)
                x = x + out
                h = rmsnorm(x, lp["ln_ffn"], cfg.norm_eps)
                out, _ = ffn_mod.moe_forward(lp["moe"], h, cfg.moe)
                return x + out, nc

            x, nc_d = jax.lax.scan(dense_body, x, (p["dense_layers"], cache["dense_layers"]))
            x, nc_m = jax.lax.scan(moe_body, x, (p["moe_layers"], cache["moe_layers"]))
            return self.logits(p, x), {"dense_layers": nc_d, "moe_layers": nc_m}

        if fam == "xlstm":
            def body(x, inp):
                gp, c = inp
                h = rmsnorm(x, gp["ln_m"], cfg.norm_eps)
                out, ms = xl.mlstm_forward_decode(gp["mlstm"], h, cfg.xlstm, cfg.d_model, c["mlstm"])
                x = x + out
                h = rmsnorm(x, gp["ln_s"], cfg.norm_eps)
                out, ss = xl.slstm_forward_decode(gp["slstm"], h, cfg.xlstm, cfg.d_model, c["slstm"])
                return x + out, {"mlstm": ms, "slstm": ss}

            x, nc = jax.lax.scan(body, x, (p["groups"], cache["groups"]))
            return self.logits(p, x), {"groups": nc}

        if fam == "zamba":
            sp = p["shared_attn"]

            def mamba_body(x, inp):
                lp, c = inp
                h = rmsnorm(x, lp["ln"], cfg.norm_eps)
                out, ns = ssm_mod.mamba2_forward_decode(lp["mamba"], h, cfg.ssm, cfg.d_model, c)
                return x + out, ns

            def group_body(x, inp):
                gp, c = inp
                h = rmsnorm(x, sp["ln_attn"], cfg.norm_eps)
                out, nac = attn.gqa_forward(sp["attn"], h, cfg.attn, cache=c["shared_attn"])
                x = x + out
                h = rmsnorm(x, sp["ln_ffn"], cfg.norm_eps)
                x = x + ffn_mod.mlp_forward(sp["ffn"], h, cfg.ffn)
                x, nmc = jax.lax.scan(mamba_body, x, (gp, c["mamba"]))
                return x, {"shared_attn": nac, "mamba": nmc}

            x, nc_g = jax.lax.scan(
                group_body, x,
                (p["mamba_groups"], {"shared_attn": cache["shared_attn"], "mamba": cache["mamba_groups"]}),
            )
            new_cache = {
                "shared_attn": nc_g["shared_attn"],
                "shared_pos": cache["shared_pos"] + tokens.shape[1],
                "mamba_groups": nc_g["mamba"],
            }
            if "mamba_tail" in cache:
                x, nt = jax.lax.scan(mamba_body, x, (p["mamba_tail"], cache["mamba_tail"]))
                new_cache["mamba_tail"] = nt
            return self.logits(p, x), new_cache

        raise ValueError(fam)

    def prefill(self, p: Params, tokens: jax.Array, cache: Params):
        """Fill caches/states from a prompt; returns (logits, cache).

        Attention families reuse decode_step (S = prompt length).
        Recurrent families run the chunked/parallel train path with
        ``return_state`` so prefill stays parallel over the sequence.
        """
        cfg = self.cfg
        fam = self.family
        if fam in ("attn", "moe"):
            return self.decode_step(p, tokens, cache)

        x = self.embed_tokens(p, tokens)

        if fam == "xlstm":
            def body(x, gp):
                h = rmsnorm(x, gp["ln_m"], cfg.norm_eps)
                out, ms = xl.mlstm_forward_train(
                    gp["mlstm"], h, cfg.xlstm, cfg.d_model, return_state=True
                )
                x = x + out
                h = rmsnorm(x, gp["ln_s"], cfg.norm_eps)
                out, ss = xl.slstm_forward_train(
                    gp["slstm"], h, cfg.xlstm, cfg.d_model, return_state=True
                )
                return x + out, {"mlstm": ms, "slstm": ss}

            x, states = jax.lax.scan(body, x, p["groups"])
            return self.logits(p, x[:, -1:]), {"groups": states}

        if fam == "zamba":
            sp = p["shared_attn"]

            def mamba_body(x, lp):
                h = rmsnorm(x, lp["ln"], cfg.norm_eps)
                out, ns = ssm_mod.mamba2_forward_train(
                    lp["mamba"], h, cfg.ssm, cfg.d_model, return_state=True
                )
                return x + out, ns

            def group_body(x, inp):
                gp, c_attn = inp
                h = rmsnorm(x, sp["ln_attn"], cfg.norm_eps)
                out, nac = attn.gqa_forward(sp["attn"], h, cfg.attn, cache=c_attn)
                x = x + out
                h = rmsnorm(x, sp["ln_ffn"], cfg.norm_eps)
                x = x + ffn_mod.mlp_forward(sp["ffn"], h, cfg.ffn)
                x, nmc = jax.lax.scan(mamba_body, x, gp)
                return x, {"shared_attn": nac, "mamba": nmc}

            x, st = jax.lax.scan(group_body, x, (p["mamba_groups"], cache["shared_attn"]))
            new_cache = {
                "shared_attn": st["shared_attn"],
                "shared_pos": cache["shared_pos"] + tokens.shape[1],
                "mamba_groups": st["mamba"],
            }
            if "mamba_tail" in cache:
                x, nt = jax.lax.scan(mamba_body, x, p["mamba_tail"])
                new_cache["mamba_tail"] = nt
            return self.logits(p, x[:, -1:]), new_cache

        raise ValueError(fam)
