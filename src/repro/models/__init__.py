"""Model zoo: all assigned architectures as composable JAX modules."""

from .config import (
    AttnConfig,
    FFNConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
    repeat_pattern,
)
from .encdec import EncDecModel
from .transformer import LMModel


def build_model(cfg: ModelConfig):
    """Instantiate the right model class for a config."""
    return EncDecModel(cfg) if cfg.kind == "encdec" else LMModel(cfg)


__all__ = [
    "AttnConfig",
    "FFNConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "XLSTMConfig",
    "repeat_pattern",
    "EncDecModel",
    "LMModel",
    "build_model",
]
