"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, parallel
quadratic train form) and sLSTM (scalar memory, sequential recurrence
with recurrent head-local mixing).

Both use exponential gating with the paper's max-stabilizer; both have
O(1)-per-token decode states, so xlstm configs qualify for long_500k.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, MODEL_AXIS, dense_init, init_rmsnorm, rmsnorm, shard
from .config import XLSTMConfig

NEG_INF = -1e30


# ==========================================================================
# mLSTM
# ==========================================================================


def _mdims(cfg: XLSTMConfig, d_model: int):
    di = int(cfg.proj_factor_m * d_model)
    di -= di % cfg.n_heads
    return di, cfg.n_heads, di // cfg.n_heads


def init_mlstm(key, cfg: XLSTMConfig, d_model: int, dtype) -> Dict[str, Any]:
    di, H, Pd = _mdims(cfg, d_model)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d_model, 2 * di, dtype),  # [x, z]
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * H, dtype),  # input & forget gates
        "norm": init_rmsnorm(di, dtype),
        "w_down": dense_init(ks[6], di, d_model, dtype),
    }


def mlstm_specs(cfg: XLSTMConfig, d_model: int) -> Dict[str, Any]:
    return {
        "w_up": P(None, MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "wq": P(None, MODEL_AXIS),
        "wk": P(None, MODEL_AXIS),
        "wv": P(None, MODEL_AXIS),
        "w_if": P(None, MODEL_AXIS),
        "norm": P(MODEL_AXIS),
        "w_down": P(MODEL_AXIS, None),
    }


def _conv_silu(x, w, b):
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def mlstm_forward_train(
    p: Dict[str, Any], x: jax.Array, cfg: XLSTMConfig, d_model: int,
    *, return_state: bool = False,
):
    B, S, D = x.shape
    di, H, Pd = _mdims(cfg, d_model)
    f32 = jnp.float32

    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    xc = _conv_silu(xm, p["conv_w"], p["conv_b"])  # (B,S,di)
    q = (xc @ p["wq"]).reshape(B, S, H, Pd).astype(f32)
    k = (xc @ p["wk"]).reshape(B, S, H, Pd).astype(f32) / jnp.sqrt(Pd)
    v = (xm @ p["wv"]).reshape(B, S, H, Pd).astype(f32)
    gates = (xc @ p["w_if"]).astype(f32).reshape(B, S, 2, H)
    i_pre, f_pre = gates[:, :, 0], gates[:, :, 1]  # (B,S,H)

    logf = jax.nn.log_sigmoid(f_pre)
    cumF = jnp.cumsum(logf, axis=1)  # (B,S,H)
    # D[t,s] = cumF_t − cumF_s + i_s   (decay from s→t plus input gate)
    Dm = cumF[:, :, None, :] - cumF[:, None, :, :] + i_pre[:, None, :, :]
    tri = jnp.tril(jnp.ones((S, S), bool))
    Dm = jnp.where(tri[None, :, :, None], Dm, NEG_INF)
    m = jnp.max(Dm, axis=2, keepdims=True)  # (B,S,1,H) stabilizer
    Sm = jnp.einsum("bshp,bthp->bsth", q, k) * jnp.exp(Dm - m)
    denom = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=2, keepdims=True)), jnp.exp(-m))
    y = jnp.einsum("bsth,bthp->bshp", Sm / denom, v)  # (B,S,H,P)

    y = rmsnorm(y.reshape(B, S, di), p["norm"])
    y = y * jax.nn.silu(z.astype(f32))
    y = shard(y.astype(x.dtype), P(BATCH_AXES, None, MODEL_AXIS))
    out = y @ p["w_down"]
    if not return_state:
        return out
    # closed-form final recurrent state (= what decode would have built)
    cumF_S = cumF[:, -1]  # (B,H)
    Ds = cumF_S[:, None] - cumF + i_pre  # (B,S,H)
    m_last = jnp.max(Ds, axis=1)  # (B,H)
    w_s = jnp.exp(Ds - m_last[:, None])
    C = jnp.einsum("bsh,bshp,bshq->bhpq", w_s, k, v)
    n = jnp.einsum("bsh,bshp->bhp", w_s, k)
    W = p["conv_w"].shape[0]
    state = {"C": C, "n": n, "m": m_last, "conv": xm[:, S - (W - 1) :]}
    return out, state


def init_mlstm_state(cfg: XLSTMConfig, d_model: int, B: int, dtype) -> Dict[str, Any]:
    di, H, Pd = _mdims(cfg, d_model)
    return {
        "C": jnp.zeros((B, H, Pd, Pd), jnp.float32),
        "n": jnp.zeros((B, H, Pd), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, di), dtype),
    }


def mlstm_state_specs(cfg: XLSTMConfig) -> Dict[str, Any]:
    return {
        "C": P(BATCH_AXES, MODEL_AXIS, None, None),
        "n": P(BATCH_AXES, MODEL_AXIS, None),
        "m": P(BATCH_AXES, MODEL_AXIS),
        "conv": P(BATCH_AXES, None, MODEL_AXIS),
    }


def mlstm_forward_decode(
    p: Dict[str, Any], x: jax.Array, cfg: XLSTMConfig, d_model: int, state: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    B, S, D = x.shape
    assert S == 1
    di, H, Pd = _mdims(cfg, d_model)
    f32 = jnp.float32

    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    conv_buf = jnp.concatenate([state["conv"], xm], axis=1)
    w = p["conv_w"]
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_buf.astype(f32), w.astype(f32)) + p["conv_b"].astype(f32)
    )[:, None].astype(x.dtype)

    q = (xc @ p["wq"]).reshape(B, H, Pd).astype(f32)
    k = (xc @ p["wk"]).reshape(B, H, Pd).astype(f32) / jnp.sqrt(Pd)
    v = (xm @ p["wv"]).reshape(B, H, Pd).astype(f32)
    gates = (xc @ p["w_if"]).astype(f32).reshape(B, 2, H)
    i_pre, f_pre = gates[:, 0], gates[:, 1]  # (B,H)

    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fdec = jnp.exp(logf + state["m"] - m_new)[..., None]
    iamp = jnp.exp(i_pre - m_new)[..., None]
    C = state["C"] * fdec[..., None] + iamp[..., None] * jnp.einsum("bhp,bhq->bhpq", k, v)
    n = state["n"] * fdec + iamp * k
    num = jnp.einsum("bhp,bhpq->bhq", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, di)

    y = rmsnorm(y, p["norm"]) * jax.nn.silu(z.astype(f32))
    y = y.astype(x.dtype) @ p["w_down"]
    return y, {"C": C, "n": n, "m": m_new, "conv": conv_buf[:, 1:]}


# ==========================================================================
# sLSTM
# ==========================================================================


def _sdims(cfg: XLSTMConfig, d_model: int):
    H = cfg.n_heads
    return d_model, H, d_model // H


def init_slstm(key, cfg: XLSTMConfig, d_model: int, dtype) -> Dict[str, Any]:
    di, H, Pd = _sdims(cfg, d_model)
    ks = jax.random.split(key, 8)
    dff = int(cfg.proj_factor_s * d_model)
    return {
        "conv_w": (jax.random.normal(ks[0], (cfg.conv_width, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_gates": dense_init(ks[1], di, 4 * di, dtype),  # z,i,f,o input paths
        "r_gates": (jax.random.normal(ks[2], (4, H, Pd, Pd), jnp.float32) / jnp.sqrt(Pd)).astype(dtype),
        "b_gates": jnp.zeros((4, di), dtype),
        "norm": init_rmsnorm(di, dtype),
        "w_ff_gate": dense_init(ks[3], di, dff, dtype),
        "w_ff_up": dense_init(ks[4], di, dff, dtype),
        "w_ff_down": dense_init(ks[5], dff, di, dtype),
    }


def slstm_specs(cfg: XLSTMConfig, d_model: int) -> Dict[str, Any]:
    return {
        "conv_w": P(None, None),
        "conv_b": P(None),
        "w_gates": P(None, MODEL_AXIS),
        "r_gates": P(None, MODEL_AXIS, None, None),  # heads over model
        "b_gates": P(None, MODEL_AXIS),
        "norm": P(None),
        "w_ff_gate": P(None, MODEL_AXIS),
        "w_ff_up": P(None, MODEL_AXIS),
        "w_ff_down": P(MODEL_AXIS, None),
    }


def _slstm_step(p, cfg, d_model, carry, wx_t):
    """One sLSTM time step. carry: (h, c, n, m) each (B,H,P) / (B,H,P)."""
    di, H, Pd = _sdims(cfg, d_model)
    h, c, n, m = carry
    f32 = jnp.float32
    # recurrent head-local contribution: (B,H,P) × (4,H,P,P) → (B,4,H,P)
    r = jnp.einsum("bhp,ghpq->bghq", h, p["r_gates"].astype(f32))
    pre = wx_t.reshape(-1, 4, H, Pd).astype(f32) + r + p["b_gates"].astype(f32).reshape(4, H, Pd)
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return (h_new, c_new, n_new, m_new), h_new


def slstm_forward_train(
    p: Dict[str, Any], x: jax.Array, cfg: XLSTMConfig, d_model: int,
    *, return_state: bool = False,
):
    B, S, D = x.shape
    di, H, Pd = _sdims(cfg, d_model)
    f32 = jnp.float32
    xc = _conv_silu(x, p["conv_w"], p["conv_b"])
    wx = xc @ p["w_gates"]  # (B,S,4di)

    def body(carry, wx_t):
        return _slstm_step(p, cfg, d_model, carry, wx_t)

    z0 = jnp.zeros((B, H, Pd), f32)
    carry0 = (z0, z0, z0, jnp.full((B, H, Pd), -1e30, f32))
    carry_f, hs = jax.lax.scan(body, carry0, wx.swapaxes(0, 1))  # (S,B,H,P)
    y = hs.swapaxes(0, 1).reshape(B, S, di)
    y = rmsnorm(y, p["norm"]).astype(x.dtype)
    # gated FFN tail (proj factor 4/3)
    ff = jax.nn.silu(y @ p["w_ff_gate"]) * (y @ p["w_ff_up"])
    ff = shard(ff, P(BATCH_AXES, None, MODEL_AXIS))
    out = ff @ p["w_ff_down"]
    if not return_state:
        return out
    h, c, n, m = carry_f
    W = p["conv_w"].shape[0]
    state = {"h": h, "c": c, "n": n, "m": m, "conv": x[:, S - (W - 1) :]}
    return out, state


def init_slstm_state(cfg: XLSTMConfig, d_model: int, B: int, dtype) -> Dict[str, Any]:
    di, H, Pd = _sdims(cfg, d_model)
    z = jnp.zeros((B, H, Pd), jnp.float32)
    return {
        "h": z, "c": z, "n": z,
        "m": jnp.full((B, H, Pd), -1e30, jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, di), dtype),
    }


def slstm_state_specs(cfg: XLSTMConfig) -> Dict[str, Any]:
    s3 = P(BATCH_AXES, MODEL_AXIS, None)
    return {"h": s3, "c": s3, "n": s3, "m": s3, "conv": P(BATCH_AXES, None, None)}


def slstm_forward_decode(
    p: Dict[str, Any], x: jax.Array, cfg: XLSTMConfig, d_model: int, state: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    B, S, D = x.shape
    assert S == 1
    di, H, Pd = _sdims(cfg, d_model)
    f32 = jnp.float32
    conv_buf = jnp.concatenate([state["conv"], x], axis=1)
    w = p["conv_w"]
    xc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_buf.astype(f32), w.astype(f32)) + p["conv_b"].astype(f32)
    ).astype(x.dtype)
    wx = xc @ p["w_gates"]  # (B,4di)
    carry = (state["h"], state["c"], state["n"], state["m"])
    (h, c, n, m), _ = _slstm_step(p, cfg, d_model, carry, wx)
    y = rmsnorm(h.reshape(B, 1, di), p["norm"]).astype(x.dtype)
    ff = jax.nn.silu(y @ p["w_ff_gate"]) * (y @ p["w_ff_up"])
    out = ff @ p["w_ff_down"]
    return out, {"h": h, "c": c, "n": n, "m": m, "conv": conv_buf[:, 1:]}
