"""Declarative model configuration covering all assigned architectures.

One ``ModelConfig`` describes any member of the zoo: dense GQA LMs,
sliding-window hybrids (gemma3), MLA/MoE (deepseek-v2), giant MoE
(kimi-k2), SSM (xlstm), Mamba2+shared-attention hybrids (zamba2),
encoder–decoder audio (whisper) and M-RoPE VLMs (qwen2-vl).

``layer_pattern`` drives structure; the registry compiles it into
scan-over-layers segments so the HLO stays small even at 81 layers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    q_lora: int = 1536
    kv_lora: int = 512
    nope_dim: int = 128  # per-head non-rotary q/k dims
    rope_dim: int = 64  # shared rotary key dims
    v_dim: int = 128  # per-head value dims


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10_000.0
    local_rope_theta: Optional[float] = None  # gemma3: 10k local / 1M global
    window: Optional[int] = None  # sliding-window size for "local" layers
    mla: Optional[MLAConfig] = None
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    mrope: bool = False  # qwen2-vl M-RoPE (3-section rotary)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_ff: int
    act: str = "silu"  # silu | gelu
    gated: bool = True  # SwiGLU/GeGLU vs plain MLP


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # deepseek: 2 shared experts
    d_ff_shared: int = 0
    router_scale: float = 1.0
    aux_loss_coef: float = 0.001
    n_dense_layers: int = 1  # leading dense-FFN layers
    capacity_factor: float = 1.25  # GShard capacity (≥ E/K ⇒ lossless)
    # GShard grouped dispatch: tokens are routed within G groups whose
    # leading dim shards over the data axes, so dispatch buffers stay
    # O(T·K·D/G) per device.  Set to the data-parallel degree.
    dispatch_groups: int = 32


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: alternating mLSTM (matrix memory) / sLSTM blocks."""

    n_heads: int = 4
    proj_factor_m: float = 2.0  # mLSTM up-projection
    proj_factor_s: float = 1.3333  # sLSTM ffn factor
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    vocab: int
    attn: Optional[AttnConfig] = None
    ffn: Optional[FFNConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # layer_pattern entries: "attn" (full) | "local" (windowed attn) |
    # "mamba" | "shared_attn" | "mlstm" | "slstm".  Length == n_layers.
    layer_pattern: Optional[Tuple[str, ...]] = None
    kind: str = "decoder"  # decoder | encdec
    n_enc_layers: int = 0  # whisper encoder depth
    enc_width: int = 0  # encoder d_model (== d_model here)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    max_seq: int = 131_072
    dtype: str = "bfloat16"
    # frontend stubs ([audio]/[vlm]): inputs are precomputed embeddings
    frontend: Optional[str] = None  # None | "audio_frames" | "vision_patches"
    final_logit_softcap: Optional[float] = None

    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.n_layers, self.name
            return self.layer_pattern
        return ("attn",) * self.n_layers

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in sequence length (SSM/xLSTM)."""
        pat = set(self.pattern())
        return pat <= {"mamba", "mlstm", "slstm", "shared_attn"} and (
            "mamba" in pat or "mlstm" in pat or "slstm" in pat
        )

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (DESIGN.md §long_500k)."""
        pat = self.pattern()
        n_global = sum(1 for p in pat if p in ("attn", "shared_attn"))
        return self.is_recurrent or ("local" in pat and n_global <= len(pat) // 4)


def repeat_pattern(unit: Tuple[str, ...], n_layers: int) -> Tuple[str, ...]:
    """Tile ``unit`` cyclically to exactly n_layers entries."""
    reps = (n_layers + len(unit) - 1) // len(unit)
    return (unit * reps)[:n_layers]
