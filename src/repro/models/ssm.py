"""Mamba2 (SSD) block — chunked parallel train path + O(1)-state decode.

Train path is the SSD block-decomposition: quadratic attention-like
computation inside chunks of length ``chunk`` + a sequential scan over
chunk states (nc = S/chunk steps), all einsums (MXU-friendly).  Decode
keeps a per-head (head_dim × d_state) state and a (w-1)-deep conv tail:
cost per token is O(1) in sequence length — this is what makes the
long_500k cell runnable (DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, MODEL_AXIS, dense_init, init_rmsnorm, rmsnorm, shard
from .config import SSMConfig


def _dims(cfg: SSMConfig, d_model: int):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.d_state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg: SSMConfig, d_model: int, dtype) -> Dict[str, Any]:
    di, H, cdim = _dims(cfg, d_model)
    N = cfg.d_state
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, cdim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": dense_init(ks[2], di, d_model, dtype),
    }


def mamba2_specs(cfg: SSMConfig, d_model: int) -> Dict[str, Any]:
    return {
        "in_proj": P(None, MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "A_log": P(MODEL_AXIS),
        "D": P(MODEL_AXIS),
        "dt_bias": P(MODEL_AXIS),
        "norm": P(MODEL_AXIS),
        "out_proj": P(MODEL_AXIS, None),
    }


def _split_proj(h: jax.Array, cfg: SSMConfig, d_model: int):
    di, H, _ = _dims(cfg, d_model)
    N = cfg.d_state
    z, xb, Bm, Cm, dt = jnp.split(h, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xb, Bm, Cm, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def mamba2_forward_train(
    p: Dict[str, Any], x: jax.Array, cfg: SSMConfig, d_model: int,
    *, return_state: bool = False,
):
    """Chunked SSD forward.  With ``return_state`` also returns the
    decode state after the last token (for prefill → decode handoff)."""
    B, S, D = x.shape
    di, H, cdim = _dims(cfg, d_model)
    N, Pd, L = cfg.d_state, cfg.head_dim, min(cfg.chunk, x.shape[1])
    S0 = S
    if S % L:
        # right-pad to a chunk multiple; causal, so padded tokens cannot
        # affect real outputs.  (States must not be read off padded runs.)
        assert not return_state, "return_state requires seq % chunk == 0"
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    h = x @ p["in_proj"]
    z, xb, Bm, Cm, dt = _split_proj(h, cfg, d_model)
    xbc_raw = jnp.concatenate([xb, Bm, Cm], -1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xb, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)

    f32 = jnp.float32
    dt = jax.nn.softplus(dt.astype(f32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    l = dt * A  # per-token log-decay (B,S,H)
    xh = xb.reshape(B, S, H, Pd).astype(f32)
    u = xh * dt[..., None]  # dt-weighted input
    Bm32, Cm32 = Bm.astype(f32), Cm.astype(f32)

    # chunk
    lc = l.reshape(B, nc, L, H)
    uc = u.reshape(B, nc, L, H, Pd)
    Bc = Bm32.reshape(B, nc, L, N)
    Cc = Cm32.reshape(B, nc, L, N)
    cum = jnp.cumsum(lc, axis=2)  # inclusive (B,nc,L,H)

    # ---- intra-chunk (quadratic within chunk) ----
    G = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)  # (B,nc,L,L)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,L,H) t,s
    tri = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(dec), 0.0)
    Y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", G, M, uc)

    # ---- chunk states ----
    st_dec = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from s to chunk end
    states = jnp.einsum("bcln,bclh,bclhp->bchnp", Bc, st_dec, uc)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1])  # (B,nc,H)

    def scan_body(Hprev, inp):
        st, cd = inp  # (B,H,N,P), (B,H)
        Hnew = Hprev * cd[..., None, None] + st
        return Hnew, Hprev

    H0 = jnp.zeros((B, H, N, Pd), f32)
    Hlast, Hstates = jax.lax.scan(
        scan_body, H0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )  # (nc,B,H,N,P) = state at chunk START; Hlast = state after final token
    Hstates = Hstates.swapaxes(0, 1)  # (B,nc,H,N,P)

    # ---- inter-chunk contribution ----
    Y_inter = jnp.einsum(
        "bcln,bclh,bchnp->bclhp", Cc, jnp.exp(cum), Hstates
    )

    y = (Y_intra + Y_inter).reshape(B, S, H, Pd)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(f32)), p["norm"])
    y = shard(y.astype(x.dtype), P(BATCH_AXES, None, MODEL_AXIS))
    out = (y @ p["out_proj"])[:, :S0]
    if not return_state:
        return out
    W = p["conv_w"].shape[0]
    state = {"h": Hlast, "conv": xbc_raw[:, S - (W - 1) :]}
    return out, state


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def init_mamba2_state(cfg: SSMConfig, d_model: int, B: int, dtype) -> Dict[str, Any]:
    di, H, cdim = _dims(cfg, d_model)
    return {
        "h": jnp.zeros((B, H, cfg.d_state, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((B, cfg.d_conv - 1, cdim), dtype),
    }


def mamba2_state_specs(cfg: SSMConfig) -> Dict[str, Any]:
    return {
        "h": P(BATCH_AXES, MODEL_AXIS, None, None),
        "conv": P(BATCH_AXES, None, MODEL_AXIS),
    }


def mamba2_forward_decode(
    p: Dict[str, Any], x: jax.Array, cfg: SSMConfig, d_model: int, state: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, Any]]:
    """x: (B, 1, D) → (B, 1, D); O(1) state update."""
    B, S, D = x.shape
    assert S == 1
    di, H, cdim = _dims(cfg, d_model)
    N, Pd = cfg.d_state, cfg.head_dim
    f32 = jnp.float32

    h = x @ p["in_proj"]
    z, xb, Bm, Cm, dt = _split_proj(h, cfg, d_model)
    xbc_new = jnp.concatenate([xb, Bm, Cm], -1)  # (B,1,cdim)
    conv_buf = jnp.concatenate([state["conv"], xbc_new], axis=1)  # (B,W,cdim)
    w = p["conv_w"]
    out = jnp.einsum("bwc,wc->bc", conv_buf.astype(f32), w.astype(f32)) + p["conv_b"].astype(f32)
    xbc = jax.nn.silu(out)[:, None]  # (B,1,cdim)
    xb, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(f32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B,H)
    xh = xb[:, 0].reshape(B, H, Pd).astype(f32)
    u = xh * dt[..., None]  # (B,H,P)
    Bv, Cv = Bm[:, 0].astype(f32), Cm[:, 0].astype(f32)  # (B,N)

    hst = state["h"] * a[..., None, None] + jnp.einsum("bn,bhp->bhnp", Bv, u)
    y = jnp.einsum("bn,bhnp->bhp", Cv, hst) + p["D"][:, None] * xh  # (B,H,P)
    y = y.reshape(B, 1, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(f32)), p["norm"])
    y = y.astype(x.dtype) @ p["out_proj"]
    return y, {"h": hst, "conv": conv_buf[:, 1:]}
