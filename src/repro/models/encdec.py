"""Whisper-style encoder-decoder ([audio] backbone).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, S_audio, d_model).  The
transformer backbone is real: bidirectional encoder (sinusoidal
positions, LayerNorm, plain-GELU MLP) and causal decoder with
cross-attention (learned positions).  Serving caches both the decoder
self-attention KV and the per-layer cross-attention KV computed once
from the encoder output.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn
from .common import (
    BATCH_AXES,
    MODEL_AXIS,
    dense_init,
    embed_init,
    layernorm,
    shard,
    sinusoidal_positions,
)
from .config import ModelConfig

Params = Dict[str, Any]


def init_ln(d: int, dtype) -> Params:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def ln(x, p, eps=1e-5):
    return layernorm(x, p["g"].astype(jnp.float32), p["b"].astype(jnp.float32), eps)


def _ln_specs():
    return {"g": P(None), "b": P(None)}


def init_mlp(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w1": dense_init(k1, d, d_ff, dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": dense_init(k2, d_ff, d, dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def _mlp_specs():
    return {
        "w1": P(None, MODEL_AXIS),
        "b1": P(MODEL_AXIS),
        "w2": P(MODEL_AXIS, None),
        "b2": P(None),
    }


def mlp(p, x):
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    h = shard(h, P(BATCH_AXES, None, MODEL_AXIS))
    return h @ p["w2"] + p["b2"]


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.scan_unroll = False
        self.flash_attention = False

    # ---------------- params ----------------

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = self.dtype
        d, dff = cfg.d_model, cfg.ffn.d_ff
        ke, kd, kx = jax.random.split(key, 3)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": init_ln(d, dt),
                "attn": attn.init_gqa(k1, cfg.attn, d, dt),
                "ln2": init_ln(d, dt),
                "mlp": init_mlp(k2, d, dff, dt),
            }

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln1": init_ln(d, dt),
                "self_attn": attn.init_gqa(k1, cfg.attn, d, dt),
                "ln_x": init_ln(d, dt),
                "cross_attn": attn.init_gqa(k2, cfg.attn, d, dt),
                "ln2": init_ln(d, dt),
                "mlp": init_mlp(k3, d, dff, dt),
            }

        keys_e = jax.random.split(ke, cfg.n_enc_layers)
        keys_d = jax.random.split(kd, cfg.n_layers)
        k1, k2, k3 = jax.random.split(kx, 3)
        return {
            "embed": embed_init(k1, cfg.vocab, d, dt),
            "pos_dec": (jax.random.normal(k2, (cfg.max_seq, d), jnp.float32) * 0.01).astype(dt),
            "enc_layers": jax.vmap(enc_layer)(keys_e),
            "dec_layers": jax.vmap(dec_layer)(keys_d),
            "ln_enc_out": init_ln(d, dt),
            "ln_dec_out": init_ln(d, dt),
        }

    def specs(self) -> Params:
        cfg = self.cfg

        def stack(tree):
            return jax.tree.map(
                lambda s: P(None, *s), tree, is_leaf=lambda x: isinstance(x, P)
            )

        enc = {
            "ln1": _ln_specs(),
            "attn": attn.gqa_specs(cfg.attn, cfg.d_model),
            "ln2": _ln_specs(),
            "mlp": _mlp_specs(),
        }
        dec = {
            "ln1": _ln_specs(),
            "self_attn": attn.gqa_specs(cfg.attn, cfg.d_model),
            "ln_x": _ln_specs(),
            "cross_attn": attn.gqa_specs(cfg.attn, cfg.d_model),
            "ln2": _ln_specs(),
            "mlp": _mlp_specs(),
        }
        return {
            "embed": P(MODEL_AXIS, None),
            "pos_dec": P(None, None),
            "enc_layers": stack(enc),
            "dec_layers": stack(dec),
            "ln_enc_out": _ln_specs(),
            "ln_dec_out": _ln_specs(),
        }

    # ---------------- forward ----------------

    def encode(self, p: Params, frames: jax.Array) -> jax.Array:
        """frames: (B, S_a, d) precomputed embeddings (conv stub)."""
        cfg = self.cfg
        S = frames.shape[1]
        x = frames.astype(self.dtype) + sinusoidal_positions(S, cfg.d_model).astype(self.dtype)
        x = shard(x, P(BATCH_AXES, None, None))

        def body(x, lp):
            h = ln(x, lp["ln1"])
            out, _ = attn.gqa_forward(lp["attn"], h, cfg.attn, causal=False, rope_theta=0.0)
            x = x + out
            h = ln(x, lp["ln2"])
            return x + mlp(lp["mlp"], h), None

        x, _ = jax.lax.scan(body, x, p["enc_layers"])
        return ln(x, p["ln_enc_out"]).astype(self.dtype)

    def _dec_embed(self, p, tokens, pos0=0):
        S = tokens.shape[1]
        x = p["embed"][tokens]
        pos = jax.lax.dynamic_slice_in_dim(p["pos_dec"], pos0, S, 0) if isinstance(pos0, int) else (
            jnp.take(p["pos_dec"], pos0 + jnp.arange(S), axis=0)
        )
        return shard(x + pos[None], P(BATCH_AXES, None, None))

    def forward_hidden(self, p: Params, batch: Dict[str, jax.Array], *, remat: bool = True):
        """Final decoder hiddens (pre output-LN) — see LMModel.forward_hidden."""
        cfg = self.cfg
        enc = self.encode(p, batch["frames"])
        x = self._dec_embed(p, batch["tokens"])

        def body(x, lp):
            h = ln(x, lp["ln1"])
            out, _ = attn.gqa_forward(lp["self_attn"], h, cfg.attn, causal=True,
                                      rope_theta=0.0, chunked=self.flash_attention)
            x = x + out
            h = ln(x, lp["ln_x"])
            out, _ = attn.gqa_forward(lp["cross_attn"], h, cfg.attn, kv_x=enc,
                                      rope_theta=0.0, chunked=self.flash_attention)
            x = x + out
            h = ln(x, lp["ln2"])
            return x + mlp(lp["mlp"], h), None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, p["dec_layers"])
        return x, {}

    def logits(self, p: Params, x: jax.Array) -> jax.Array:
        x = ln(x, p["ln_dec_out"]).astype(self.dtype)
        lg = jnp.einsum("bsd,vd->bsv", x, p["embed"], preferred_element_type=jnp.float32)
        return shard(lg, P(BATCH_AXES, None, MODEL_AXIS))

    def forward_train(self, p: Params, batch: Dict[str, jax.Array], *, remat: bool = True):
        """batch: {"frames": (B,S_a,d), "tokens": (B,S_t)} → (logits, aux)."""
        x, aux = self.forward_hidden(p, batch, remat=remat)
        return self.logits(p, x), aux

    # ---------------- serving ----------------

    def init_cache(self, B: int, max_seq: int, enc_len: int) -> Params:
        cfg = self.cfg
        dt = self.dtype
        L = cfg.n_layers
        Kv, hd = cfg.attn.n_kv, cfg.attn.head_dim

        def one(_):
            return attn.init_gqa_cache(cfg.attn, B, max_seq, dt)

        return {
            "self": jax.vmap(one)(jnp.arange(L)),
            "cross_k": jnp.zeros((L, B, enc_len, Kv, hd), dt),
            "cross_v": jnp.zeros((L, B, enc_len, Kv, hd), dt),
        }

    def cache_specs(self, *, long_ctx: bool = False) -> Params:
        cfg = self.cfg
        sc = jax.tree.map(
            lambda s: P(None, *s),
            attn.gqa_cache_specs(cfg.attn, long_ctx=long_ctx),
            is_leaf=lambda x: isinstance(x, P),
        )
        cross = P(None, BATCH_AXES, None, MODEL_AXIS, None)
        return {"self": sc, "cross_k": cross, "cross_v": cross}

    def prefill(self, p: Params, frames: jax.Array, tokens: jax.Array, cache: Params):
        """Encode audio, precompute cross KV, prefill decoder self-attn."""
        cfg = self.cfg
        enc = self.encode(p, frames)
        B, Sa, d = enc.shape
        Kv, hd = cfg.attn.n_kv, cfg.attn.head_dim

        def cross_kv(lp):
            k = (enc @ lp["cross_attn"]["wk"]).reshape(B, Sa, Kv, hd)
            v = (enc @ lp["cross_attn"]["wv"]).reshape(B, Sa, Kv, hd)
            return k.astype(self.dtype), v.astype(self.dtype)

        ck, cv = jax.vmap(cross_kv)(p["dec_layers"])
        cache = {**cache, "cross_k": ck, "cross_v": cv}
        return self.decode_step(p, tokens, cache)

    def decode_step(self, p: Params, tokens: jax.Array, cache: Params):
        cfg = self.cfg
        pos0 = cache["self"]["pos"][0]
        x = self._dec_embed(p, tokens, pos0)

        def body(x, inp):
            lp, c_self, ck, cv = inp
            h = ln(x, lp["ln1"])
            out, nc = attn.gqa_forward(
                lp["self_attn"], h, cfg.attn, cache=c_self, rope_theta=0.0
            )
            x = x + out
            h = ln(x, lp["ln_x"])
            B, S, _ = h.shape
            H, Kv, hd = cfg.attn.n_heads, cfg.attn.n_kv, cfg.attn.head_dim
            q = (h @ lp["cross_attn"]["wq"]).reshape(B, S, H, hd)
            mask = jnp.ones((1, 1, S, ck.shape[1]), bool)
            out = attn._sdpa(q, ck, cv, mask)
            out = shard(out, P(BATCH_AXES, None, MODEL_AXIS))
            x = x + out @ lp["cross_attn"]["wo"]
            h = ln(x, lp["ln2"])
            return x + mlp(lp["mlp"], h), nc

        x, nc = jax.lax.scan(
            body, x, (p["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"])
        )
        return self.logits(p, x), {**cache, "self": nc}
