"""Feed-forward blocks: gated MLP (SwiGLU/GeGLU) and expert-parallel MoE.

MoE uses GShard-style capacity-based dispatch expressed as one-hot
matmuls so GSPMD can lower the dispatch/combine to all-to-alls over the
"model" (expert) mesh axis.  Router aux (load-balance) loss is returned
for the train step.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, DISPATCH_AXES, MODEL_AXIS, act_fn, dense_init, shard
from .config import FFNConfig, MoEConfig


# --------------------------------------------------------------------------
# dense MLP
# --------------------------------------------------------------------------


def init_mlp(key, cfg: FFNConfig, d_model: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, cfg.d_ff, dtype),
        "w_down": dense_init(ks[1], cfg.d_ff, d_model, dtype),
    }
    if cfg.gated:
        p["w_gate"] = dense_init(ks[2], d_model, cfg.d_ff, dtype)
    return p


def mlp_specs(cfg: FFNConfig, d_model: int) -> Dict[str, Any]:
    s = {"w_up": P(None, MODEL_AXIS), "w_down": P(MODEL_AXIS, None)}
    if cfg.gated:
        s["w_gate"] = P(None, MODEL_AXIS)
    return s


def mlp_forward(p: Dict[str, Any], x: jax.Array, cfg: FFNConfig) -> jax.Array:
    h = x @ p["w_up"]
    if cfg.gated:
        h = act_fn(cfg.act)(x @ p["w_gate"]) * h
    else:
        h = act_fn(cfg.act)(h)
    h = shard(h, P(BATCH_AXES, None, MODEL_AXIS))
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


def _ep_spec4():
    return P(BATCH_AXES, MODEL_AXIS, None, None)


@jax.custom_vjp
def _expert_ffn(ex_in, w_gate, w_up, w_down):
    """SwiGLU over the (G,E,C,D) expert buffer with a HAND-WRITTEN VJP.

    jax's automatic transpose of these einsums emits transposed (E,D,G,C)
    intermediates whose shardings the SPMD partitioner can only realize
    by full rematerialization (hundreds of GiB at kimi-k2 scale).  The
    manual backward keeps every grad a single dot_general with e as the
    batch dim (→ stays EP-sharded) and (g,c) contracted (→ partial sums
    + all-reduce over the data axes).
    """
    out, _ = _expert_ffn_fwd(ex_in, w_gate, w_up, w_down)
    return out


def _expert_ffn_fwd(ex_in, w_gate, w_up, w_down):
    a = shard(jnp.einsum("gecd,edf->gecf", ex_in, w_gate), _ep_spec4())
    h = shard(jnp.einsum("gecd,edf->gecf", ex_in, w_up), _ep_spec4())
    g_act = jax.nn.silu(a)
    out = shard(jnp.einsum("gecf,efd->gecd", g_act * h, w_down), _ep_spec4())
    return out, (ex_in, a, h, w_gate, w_up, w_down)


def _expert_ffn_bwd(res, dout):
    ex_in, a, h, w_gate, w_up, w_down = res
    dout = shard(dout, _ep_spec4())
    g_act = jax.nn.silu(a)
    gh = g_act * h
    dgh = shard(jnp.einsum("gecd,efd->gecf", dout, w_down), _ep_spec4())
    dWd = jnp.einsum("gecf,gecd->efd", gh, dout)
    dh = dgh * g_act
    # dsilu(a) = σ(a)·(1 + a·(1−σ(a)))
    sig = jax.nn.sigmoid(a.astype(jnp.float32))
    dsilu = (sig * (1 + a.astype(jnp.float32) * (1 - sig))).astype(a.dtype)
    da = dgh * h * dsilu
    dex = shard(
        jnp.einsum("gecf,edf->gecd", dh, w_up)
        + jnp.einsum("gecf,edf->gecd", da, w_gate),
        _ep_spec4(),
    )
    dWu = jnp.einsum("gecd,gecf->edf", ex_in, dh)
    dWg = jnp.einsum("gecd,gecf->edf", ex_in, da)
    ws = P(MODEL_AXIS, None, None)
    return dex, shard(dWg, ws), shard(dWu, ws), shard(dWd, ws)


_expert_ffn.defvjp(_expert_ffn_fwd, _expert_ffn_bwd)


def init_moe(key, cfg: MoEConfig, d_model: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    E, dff = cfg.n_experts, cfg.d_ff_expert
    scale = 1.0 / jnp.sqrt(d_model)
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, dff), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, dff), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, dff, d_model), jnp.float32) / jnp.sqrt(dff)).astype(dtype),
    }
    if cfg.n_shared:
        dsh = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        p["shared"] = init_mlp(ks[4], FFNConfig(d_ff=dsh, act="silu", gated=True), d_model, dtype)
    return p


def moe_specs(cfg: MoEConfig, d_model: int) -> Dict[str, Any]:
    s = {
        "router": P(None, None),
        "w_gate": P(MODEL_AXIS, None, None),  # experts sharded (EP)
        "w_up": P(MODEL_AXIS, None, None),
        "w_down": P(MODEL_AXIS, None, None),
    }
    if cfg.n_shared:
        s["shared"] = mlp_specs(FFNConfig(d_ff=1, gated=True), d_model)
    return s


def moe_forward(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: MoEConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  x: (B, S, D).

    Top-k softmax routing, renormalized gates, capacity truncation
    (GShard semantics: overflow tokens fall through to the residual).

    Dispatch is SORT-BASED, not one-hot: queue positions come from a
    stable argsort over the (T·K) assignment list + searchsorted, and
    tokens are moved with scatter/gather into an (E, C, D) expert
    buffer.  Peak footprint is O(T·K·D + E·C·D) — the one-hot
    formulation's (T,K,E) and (T,E,C) tensors (PBs at kimi-k2 scale)
    never exist.  Expert dim shards over "model" (EP); GSPMD lowers the
    data↔expert resharding to all-to-alls.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    f32 = jnp.float32

    # pin the residual-stream sharding at the block boundary so the
    # dispatch resharding below cannot propagate into the attention ops
    # (whose bwd transposes SPMD can only realize by full replication).
    x = shard(x, P(BATCH_AXES, None, None))

    # grouped dispatch: G groups, each routed independently.  G shards
    # over the WHOLE mesh (expert-data parallelism: tokens spread over
    # model devices too for routing/scatter), so every dispatch tensor
    # is device-local; the expert einsum below reshards (E → model) —
    # that resharding IS the EP all-to-all.
    G = cfg.dispatch_groups
    while T % G:
        G //= 2
    Tg = T // G
    TgK = Tg * K
    xg = x.reshape(G, Tg, D)
    xg = shard(xg, P(DISPATCH_AXES, None, None))

    logits = (xg @ p["router"].astype(x.dtype)).astype(f32)  # (G,Tg,E)
    probs = jax.nn.softmax(cfg.router_scale * logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(G, TgK)

    # load-balance aux loss (Switch-style) — scatter-add, no one-hot
    me = jnp.mean(probs, axis=(0, 1))
    counts = jnp.zeros((G, E), f32)
    counts = jax.vmap(lambda c, e: c.at[e].add(1.0))(counts, flat_e)
    ce = jnp.sum(counts, 0) / (G * TgK)
    aux = cfg.aux_loss_coef * E * jnp.sum(me * ce)

    C = max(1, int(cfg.capacity_factor * Tg * K / E))

    def route_one(e_flat):
        """Queue position of each (token, choice) within its expert."""
        order = jnp.argsort(e_flat, stable=True)  # (TgK,)
        sorted_e = e_flat[order]
        rank = jnp.arange(TgK) - jnp.searchsorted(sorted_e, sorted_e, side="left")
        return jnp.zeros((TgK,), jnp.int32).at[order].set(rank.astype(jnp.int32))

    pos = jax.vmap(route_one)(flat_e)  # (G,TgK)
    keep = pos < C
    slot = jnp.where(keep, pos, C)  # C = overflow slot, sliced away below
    tok = jnp.arange(TgK) // K
    gates_flat = (gate_vals.reshape(G, TgK) * keep).astype(x.dtype)

    # dispatch: scatter token activations into per-group expert buffers
    def scatter_one(xt, e_flat, slot_g):
        buf = jnp.zeros((E, C + 1, D), x.dtype)
        return buf.at[e_flat, slot_g].add(xt[tok])

    buf = jax.vmap(scatter_one)(xg, flat_e, slot)  # (G,E,C+1,D)
    buf = shard(buf, P(DISPATCH_AXES, None, None, None))  # scatter stays local
    ex_in = shard(buf[:, :, :C], _ep_spec4())  # EP all-to-all (g→data, e→model)
    ex_out = _expert_ffn(ex_in, p["w_gate"], p["w_up"], p["w_down"])
    ex_out = shard(ex_out, P(DISPATCH_AXES, None, None, None))  # return a2a

    # combine: gather each assignment's expert output, weight, sum over k
    def gather_one(out_g, e_flat, slot_g, gates_g):
        y = out_g[e_flat, jnp.minimum(slot_g, C - 1)] * gates_g[:, None]
        return jnp.sum(y.reshape(Tg, K, D), axis=1)

    out = jax.vmap(gather_one)(ex_out, flat_e, slot, gates_flat)  # (G,Tg,D)
    out = shard(out, P(DISPATCH_AXES, None, None)).reshape(B, S, D)
    out = shard(out, P(BATCH_AXES, None, None))

    if "shared" in p:
        # shared expert runs on the (B,S,D) view — batch stays sharded
        dsh = p["shared"]["w_up"].shape[1]
        out = out + mlp_forward(p["shared"], x, FFNConfig(d_ff=dsh, gated=True))

    return out, aux
