"""Gradient/accumulator compression for cross-pod collectives.

int8 block quantization with error feedback (EF-SGD style): the
quantization residual is carried to the next round, so compression
noise averages out instead of biasing the solve.  Intended for the
``pod`` axis where ICI/DCN bandwidth is scarcest: Y-accumulator psums
in the CCA data pass and LM gradient all-reduces are 4× cheaper in
bytes at k̃/grad scale.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def int8_encode(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization along the last axis.

    Returns (q: int8, scales: f32 with last dim = ceil(d/block)).
    """
    *lead, d = x.shape
    pad = (-d) % block
    if pad:
        x = jnp.pad(x, [*[(0, 0)] * len(lead), (0, pad)])
    xb = x.reshape(*lead, -1, block)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def int8_decode(q: jax.Array, scale: jax.Array, d: int) -> jax.Array:
    xb = q.astype(jnp.float32) * scale[..., None]
    *lead, nb, block = xb.shape
    return xb.reshape(*lead, nb * block)[..., :d]


def psum_int8_ef(
    x: jax.Array,
    axis: str,
    err: Optional[jax.Array] = None,
    *,
    block: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Compressed psum with error feedback (use inside shard_map).

    x: local contribution; err: residual carried from the previous call
    (same shape as x).  Returns (global_sum_approx, new_err).

    The int8 payload is summed in int32 across the axis (exact), then
    rescaled by the max block scale — a 1-scale-per-block all-reduce.
    """
    if err is not None:
        x = x + err
    q, scale = int8_encode(x, block)
    # use a shared scale across the axis so the int32 sum is coherent
    gscale = jax.lax.pmax(scale, axis)
    # requantize against the global scale
    d = x.shape[-1]
    xq = int8_decode(q, scale, d)  # dequantized local (matches what we'll send)
    q2 = jnp.clip(
        jnp.round(
            jnp.pad(xq, [*[(0, 0)] * (x.ndim - 1), (0, (-d) % block)])
            .reshape(*x.shape[:-1], -1, block)
            / gscale[..., None]
        ),
        -127,
        127,
    )
    new_err = x - int8_decode(q2.astype(jnp.int8), gscale, d)
    total = jax.lax.psum(q2.astype(jnp.int32), axis)
    out = int8_decode(total, gscale, d)
    return out, new_err
