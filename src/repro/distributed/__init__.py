"""Distributed-optimization substrate: compressed collectives, bucketed
overlap, straggler-tolerant pass accounting."""

from .compress import int8_decode, int8_encode, psum_int8_ef
from .overlap import bucketed_accumulate

__all__ = ["int8_encode", "int8_decode", "psum_int8_ef", "bucketed_accumulate"]
