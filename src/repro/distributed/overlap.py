"""Compute/communication overlap helpers for the CCA data pass.

The end-of-pass psum of the d×k̃ accumulator is the one large collective
in Algorithm 1.  ``bucketed_accumulate`` splits the accumulator into
column buckets and issues each bucket's psum as soon as its last
microbatch lands — XLA's async collectives then overlap bucket i's
all-reduce with bucket i+1's matmuls (the classic gradient-bucketing
trick, applied to range-finder accumulators).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def bucketed_accumulate(
    contributions: Sequence[jax.Array],
    axes,
    n_buckets: int = 4,
) -> jax.Array:
    """psum a large accumulator in column buckets.

    contributions: list of partial accumulators (already summed over
    local microbatches) — one entry per bucket-phase; in the simplest
    use, a single full accumulator that gets split.
    """
    acc = contributions if isinstance(contributions, jax.Array) else None
    if acc is None:
        acc = sum(contributions)
    d, k = acc.shape
    n_buckets = max(1, min(n_buckets, k))
    bsz = -(-k // n_buckets)
    outs = []
    for b in range(n_buckets):
        sl = acc[:, b * bsz : min((b + 1) * bsz, k)]
        outs.append(jax.lax.psum(sl, axes))  # issued independently → async overlap
    return jnp.concatenate(outs, axis=1)
