"""Step builders + assigned input shapes.

Everything the dry-run, trainer and server share:

- SHAPES: the four assigned (seq, batch) cells per LM arch;
- fit_specs: drop mesh axes that don't divide a dim (e.g. batch=1 on
  long_500k) so one logical spec tree serves every mesh;
- make_train_step: chunked-CE loss (never materializes (B,S,V)),
  AdamW, MoE aux loss, donated params/opt;
- make_decode_step / make_prefill_step: serving paths with donated
  caches;
- input_specs: ShapeDtypeStruct stand-ins for every model input.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import EncDecModel
from repro.models.common import BATCH_AXES, MODEL_AXIS
from repro.optim import AdamWConfig, adamw_update


# --------------------------------------------------------------------------
# assigned shapes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — DESIGN.md §long_500k policy."""
    if shape.name == "long_500k":
        if not cfg.sub_quadratic:
            return False, "pure full-attention arch at 500k (no sub-quadratic path)"
        if cfg.kind == "encdec":
            return False, "enc-dec audio: inputs are ≤30s clips by construction"
    return True, ""


# --------------------------------------------------------------------------
# spec fitting
# --------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes that are absent from the mesh or don't divide the
    corresponding dim.  Keeps one logical spec tree valid on any mesh /
    any batch size (elastic meshes, long-context batch=1, …)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        # greedy prefix that divides the dim
        kept = []
        n = 1
        for a in axes:
            sz = _axis_size(mesh, a)
            if shape[i] % (n * sz) == 0:
                kept.append(a)
                n *= sz
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    # pad spec to rank
    out += [None] * (len(shape) - len(out))
    return P(*out)


def fit_specs(spec_tree, shape_tree, mesh: Mesh):
    """Tree-wise fit_spec; returns NamedShardings."""
    def one(spec, like):
        return NamedSharding(mesh, fit_spec(spec, like.shape, mesh))
    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------


def chunked_ce_loss(model, params, hidden: jax.Array, labels: jax.Array,
                    n_chunks: int = 8) -> jax.Array:
    """Mean CE over (B,S) without materializing (B,S,V) logits: scan
    over sequence chunks, rematerializing each chunk's logits in bwd."""
    B, S, D = hidden.shape
    while S % n_chunks:
        n_chunks //= 2
    C = S // n_chunks
    h = hidden.reshape(B, n_chunks, C, D).swapaxes(0, 1)  # (nc,B,C,D)
    l = labels.reshape(B, n_chunks, C).swapaxes(0, 1)

    @jax.checkpoint
    def chunk(carry, hl):
        hc, lc = hl
        logits = model.logits(params, hc).astype(jnp.float32)  # (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (h, l))
    return total / (B * S)


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------


def make_train_step(model, opt_cfg: AdamWConfig, *, loss_chunks: int = 8,
                    remat: bool = True):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    batch: {"tokens": (B,S+1)} + optional stub-frontend inputs
    ("frames" for encdec, "embeds" for vlm).
    """

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            tokens = batch["tokens"]
            inner = {**batch, "tokens": tokens[:, :-1]}
            hidden, aux = model.forward_hidden(p, inner, remat=remat)
            loss = chunked_ce_loss(model, p, hidden, tokens[:, 1:], loss_chunks)
            total = loss + aux.get("moe_aux", 0.0)
            return total, (loss, aux)

        (total, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, "total_loss": total, **opt_metrics}
        if "moe_aux" in aux:
            metrics["moe_aux"] = aux["moe_aux"]
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------


def make_decode_step(model):
    def decode_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return decode_step


def make_prefill_step(model):
    if isinstance(model, EncDecModel):
        def prefill_step(params, frames, tokens, cache):
            return model.prefill(params, frames, tokens, cache)
    else:
        def prefill_step(params, tokens, cache):
            return model.prefill(params, tokens, cache)
    return prefill_step


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# --------------------------------------------------------------------------


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs + their logical PartitionSpecs for a shape cell.

    Returns {"batch": (tree, spec_tree)} for train, or
    {"tokens"/"frames"/"cache": ...} for serve kinds.
    """
    B = shape.batch
    dspec = P(BATCH_AXES, None)

    if shape.kind == "train":
        S = shape.seq
        batch = {"tokens": sds((B, S + 1), jnp.int32)}
        spec = {"tokens": dspec}
        if cfg.kind == "encdec":
            from repro.configs.whisper_small import N_FRAMES
            batch["frames"] = sds((B, N_FRAMES, cfg.d_model), jnp.float32)
            spec["frames"] = P(BATCH_AXES, None, None)
        elif cfg.frontend == "vision_patches":
            from repro.configs.qwen2_vl_2b import N_PATCHES
            # patches replace part of the text budget: total positions = S
            batch["tokens"] = sds((B, S + 1 - N_PATCHES), jnp.int32)
            batch["embeds"] = sds((B, N_PATCHES, cfg.d_model), jnp.float32)
            spec["embeds"] = P(BATCH_AXES, None, None)
        return batch, spec

    if shape.kind == "prefill":
        S = shape.seq
        batch = {"tokens": sds((B, S), jnp.int32)}
        spec = {"tokens": dspec}
        if cfg.kind == "encdec":
            from repro.configs.whisper_small import N_FRAMES
            batch["frames"] = sds((B, N_FRAMES, cfg.d_model), jnp.float32)
            spec["frames"] = P(BATCH_AXES, None, None)
        return batch, spec

    # decode: one new token against a seq-long cache
    batch = {"tokens": sds((B, 1), jnp.int32)}
    spec = {"tokens": dspec}
    return batch, spec


def cache_specs_for(model, cfg, shape: ShapeSpec, mesh: Mesh):
    """(cache ShapeDtypeStruct tree, NamedSharding tree) for serve cells."""
    B = shape.batch
    long_ctx = shape.name == "long_500k" or B < _axis_size(mesh, BATCH_AXES)
    if isinstance(model, EncDecModel):
        from repro.configs.whisper_small import N_FRAMES
        cache = jax.eval_shape(
            lambda: model.init_cache(B, shape.seq + 8, enc_len=N_FRAMES)
        )
        specs = model.cache_specs(long_ctx=long_ctx)
    else:
        cache = jax.eval_shape(lambda: model.init_cache(B, shape.seq + 8))
        specs = model.cache_specs(long_ctx=long_ctx)
    return cache, fit_specs(specs, cache, mesh)


def _fsdp_spec(spec: P, shape: Tuple[int, ...], min_elems: int,
               axes=BATCH_AXES) -> P:
    """Add a data-axes shard to the largest unsharded dim of a large
    param (ZeRO/FSDP).  fit_spec later drops non-dividing axes."""
    import math

    if math.prod(shape) < min_elems:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    free = [i for i, e in enumerate(entries) if e is None and shape[i] > 1]
    if not free:
        return spec
    i = max(free, key=lambda j: shape[j])
    entries[i] = axes
    return P(*entries)


def param_shardings(model, mesh: Mesh, params_shape=None, *, fsdp: str = "auto",
                    policy: str = "2d"):
    """NamedShardings for the param tree (eval_shape'd if not given).

    fsdp: "on" | "off" | "auto" — auto enables ZeRO-style param/optimizer
    sharding over the data axes when TP-only residency would exceed
    ~8 GB/device (DESIGN.md §5: a 1T-param MoE cannot be data-replicated).
    policy: "2d" (DP×TP) | "dp" (pure data parallel; model axis joins the
    batch axes, params fully FSDP-sharded — §Perf iteration for
    collective-bound small-model training).
    """
    from repro.models.common import apply_policy_tree, sharding_policy

    if params_shape is None:
        params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    with sharding_policy(policy):
        specs = apply_policy_tree(model.specs())
    if policy == "dp":
        fsdp = "on"
    if fsdp != "off":
        n_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(params_shape)
        )
        tp = _axis_size(mesh, MODEL_AXIS) if policy != "dp" else 1
        # params + f32 mu/nu ≈ 5× param bytes, TP-sharded only
        resident = 5 * n_bytes / max(tp, 1)
        if fsdp == "on" or resident > 8 * 2**30:
            fs_axes = BATCH_AXES + (MODEL_AXIS,) if policy == "dp" else BATCH_AXES
            specs = jax.tree.map(
                lambda s, x: _fsdp_spec(s, x.shape, 2**18, axes=fs_axes),
                specs, params_shape, is_leaf=lambda x: isinstance(x, P),
            )
    return fit_specs(specs, params_shape, mesh), params_shape


def opt_shardings(mesh: Mesh, p_shard_tree):
    """Optimizer state shards exactly like its mirrored params."""
    from repro.optim.adamw import AdamWState

    return AdamWState(step=NamedSharding(mesh, P()), mu=p_shard_tree, nu=p_shard_tree)
