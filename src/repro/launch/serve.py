"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.compat import set_mesh
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import EncDecModel, build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_host_mesh()
    B = args.batch
    max_seq = min(cfg.max_seq, args.prompt_len + args.gen + 8)

    p_sharding, p_shape = S.param_shardings(model, mesh)
    with set_mesh(mesh):
        params = jax.jit(model.init, out_shardings=p_sharding)(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len), np.int32))

    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    t0 = time.time()
    if isinstance(model, EncDecModel):
        frames = jnp.asarray(rng.standard_normal((B, 64, cfg.d_model), np.float32))
        cache = model.init_cache(B, max_seq, enc_len=64)
        logits, cache = jax.jit(model.prefill)(params, frames, prompts, cache)
    else:
        cache = model.init_cache(B, max_seq)
        logits, cache = jax.jit(model.prefill)(params, prompts, cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))
    tput = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} prefill {t_prefill*1e3:.0f} ms, "
          f"decode {t_decode*1e3:.0f} ms ({tput:.1f} tok/s), sample {np.asarray(gen[0, :8])}")


if __name__ == "__main__":
    main()
