"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("pod", "data", "model")):
    """Small mesh over however many (possibly fake) devices exist —
    used by tests and CPU examples."""
    n = len(jax.devices())
    if shape is None:
        # greedy: pod=1, square-ish data×model
        m = 1
        while (m * 2) ** 2 <= n:
            m *= 2
        shape = (1, max(1, n // m), m) if len(axes) == 3 else (max(1, n // m), m)
    return jax.make_mesh(shape, axes[-len(shape):] if len(shape) < len(axes) else axes)


def data_axes(mesh) -> tuple:
    """The row/batch axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
