import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k --mesh multi --out results/dryrun

The XLA_FLAGS line above MUST run before any other import (jax locks
the device count at first init) — hence its position at the top.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, model_archs  # noqa: E402
from repro.kernels.compat import cost_analysis, set_mesh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.models import EncDecModel, build_model  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402

# ---------------------------------------------------------------------------
# collective-bytes extraction (not in cost_analysis — parse the HLO)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all tensors in an HLO shape string like
    'f32[128,256]' or '(bf16[8,16], bf16[8,16])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-shape and op name appear as:  %x = f32[..] all-gather(...)
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[\w\[\],]+))\s+([\w-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-start"):
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += _shape_bytes(m.group(1))
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def lower_cca_cell(shape_name: str, mesh, *, microbatch: int = 512,
                   int8_reduce: bool = False, reduce_buckets: int = 1,
                   reduce_dtype=None, chunk: int = 65_536):
    """Lower the paper's own workload: one distributed CCA data pass
    (power or final) at full Europarl scale — n=1.24M streamed rows per
    pass step, d_a = d_b = 2^19, k̃ = k+p = 2060.  Rows shard over
    (pod, data); Q/Y shard features over model (DESIGN.md §2).

    §Perf knobs: microbatch / int8_reduce / reduce_buckets."""
    import functools
    from repro.kernels.compat import shard_map
    from repro.configs.europarl_cca import config as cca_config
    from repro.core.rcca_dist import final_pass_local, power_pass_local

    wl = cca_config()
    kt = wl.rcca.sketch
    row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    col_axis = "model"
    data_spec = P(row_axes, col_axis)
    q_spec = P(col_axis, None)
    rep = P()
    kind = "power" if shape_name == "cca_power_pass" else "final"
    fn_local = power_pass_local if kind == "power" else final_pass_local

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(data_spec, data_spec, q_spec, q_spec),
        out_specs=(q_spec, q_spec, rep) if kind == "power" else (rep, rep, rep),
        check_rep=False,
    )
    def pass_step(a, b, Qa, Qb):
        kw = dict(row_axes=row_axes, col_axis=col_axis,
                  microbatch=microbatch, compute_dtype=jnp.bfloat16)
        if kind == "power":
            kw.update(int8_reduce=int8_reduce, reduce_buckets=reduce_buckets,
                      reduce_dtype=reduce_dtype)
        out = fn_local(a, b, Qa, Qb, **kw)
        if kind == "power":
            Ya, Yb, sa, sb, tra, trb, nn = out
            return Ya, Yb, nn
        Ca, Cb, F = out[0], out[1], out[2]
        return Ca, Cb, F

    a_sds = jax.ShapeDtypeStruct((chunk, wl.da), jnp.float32)
    b_sds = jax.ShapeDtypeStruct((chunk, wl.db), jnp.float32)
    q_a = jax.ShapeDtypeStruct((wl.da, kt), jnp.float32)
    q_b = jax.ShapeDtypeStruct((wl.db, kt), jnp.float32)
    ns = lambda s: NamedSharding(mesh, s)
    fn = jax.jit(pass_step,
                 in_shardings=(ns(data_spec), ns(data_spec), ns(q_spec), ns(q_spec)))
    with set_mesh(mesh):
        lowered = fn.lower(a_sds, b_sds, q_a, q_b)
    return lowered, {"kind": f"cca_{kind}"}


def lower_cell(arch: str, shape_name: str, mesh, *, remat: bool = True,
               loss_chunks: int = 8, policy: str = "2d", flash: bool = False,
               cca_opts: dict | None = None):
    """Lower one (arch × shape) cell on a mesh; returns (lowered, meta)."""
    if arch in ("europarl-cca", "europarl_cca"):
        return lower_cca_cell(shape_name, mesh, **(cca_opts or {}))
    cfg = get_config(arch)
    shape = S.SHAPES[shape_name]
    ok, reason = S.shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": reason}

    from repro.models.common import sharding_policy

    model = build_model(cfg)
    model.flash_attention = flash
    p_sharding, p_shape = S.param_shardings(model, mesh, policy=policy)

    if shape.kind == "train":
        n_params = sum(x.size for x in jax.tree.leaves(p_shape))
        # >300B params: bf16 moments (f32 mu/nu cannot fit 512×16GB HBM)
        opt_cfg = AdamWConfig(
            moment_dtype="bfloat16" if n_params > 3e11 else "float32"
        )
        opt_shape = jax.eval_shape(
            lambda p: adamw_init(p, jnp.dtype(opt_cfg.moment_dtype)), p_shape
        )
        o_sharding = S.opt_shardings(mesh, p_sharding)
        from repro.models.common import apply_policy_tree
        batch, batch_spec = S.input_specs(cfg, shape)
        with sharding_policy(policy):
            batch_spec = apply_policy_tree(batch_spec)
        b_sharding = S.fit_specs(batch_spec, batch, mesh)
        step = S.make_train_step(model, opt_cfg, loss_chunks=loss_chunks,
                                 remat=remat)
        fn = jax.jit(
            step,
            in_shardings=(p_sharding, o_sharding, b_sharding),
            out_shardings=(p_sharding, o_sharding, None),
            donate_argnums=(0, 1),
        )
        with set_mesh(mesh), sharding_policy(policy):
            lowered = fn.lower(p_shape, opt_shape, batch)
        return lowered, {"kind": "train"}

    if shape.kind == "prefill":
        batch, batch_spec = S.input_specs(cfg, shape)
        b_sharding = S.fit_specs(batch_spec, batch, mesh)
        cache, c_sharding = S.cache_specs_for(model, cfg, shape, mesh)
        step = S.make_prefill_step(model)
        if isinstance(model, EncDecModel):
            args = (p_shape, batch["frames"], batch["tokens"], cache)
            in_sh = (p_sharding, b_sharding["frames"], b_sharding["tokens"], c_sharding)
            donate = (3,)
        else:
            args = (p_shape, batch["tokens"], cache)
            in_sh = (p_sharding, b_sharding["tokens"], c_sharding)
            donate = (2,)
        fn = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(None, c_sharding), donate_argnums=donate)
        with set_mesh(mesh):
            lowered = fn.lower(*args)
        return lowered, {"kind": "prefill"}

    # decode
    batch, batch_spec = S.input_specs(cfg, shape)
    b_sharding = S.fit_specs(batch_spec, batch, mesh)
    cache, c_sharding = S.cache_specs_for(model, cfg, shape, mesh)
    step = S.make_decode_step(model)
    fn = jax.jit(
        step,
        in_shardings=(p_sharding, b_sharding["tokens"], c_sharding),
        out_shardings=(None, c_sharding),
        donate_argnums=(2,),
    )
    with set_mesh(mesh):
        lowered = fn.lower(p_shape, batch["tokens"], cache)
    return lowered, {"kind": "decode"}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, remat=True,
             loss_chunks=8, hlo_collectives=True, policy="2d",
             flash=False, cca_opts=None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, remat=remat,
                                   loss_chunks=loss_chunks, policy=policy,
                                   flash=flash, cca_opts=cca_opts)
    except Exception as e:  # lowering failure = bug, record it loudly
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "lower_error", "error": f"{type(e).__name__}: {e}",
        }
    if lowered is None:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped", "reason": meta["skipped"],
        }
    t_lower = time.time() - t0
    t0 = time.time()
    try:
        compiled = lowered.compile()
    except Exception as e:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "compile_error", "error": f"{type(e).__name__}: {e}",
            "lower_s": round(t_lower, 1),
        }
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "kind": meta["kind"], "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    if hlo_collectives:
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        out["collectives"] = collective_stats(hlo)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--loss-chunks", type=int, default=8)
    ap.add_argument("--policy", default="2d", choices=["2d", "dp"])
    ap.add_argument("--flash", action="store_true")
    args = ap.parse_args(argv)

    archs = model_archs() if args.arch == "all" else [args.arch]
    shapes = list(S.SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    ok = True
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached", flush=True)
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                res = run_cell(arch, shape, mesh_kind,
                               remat=not args.no_remat,
                               loss_chunks=args.loss_chunks,
                               policy=args.policy, flash=args.flash)
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                status = res["status"]
                extra = res.get("error", res.get("reason", ""))
                print(f"[dryrun] {tag}: {status} {extra}", flush=True)
                if status.endswith("error"):
                    ok = False
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
