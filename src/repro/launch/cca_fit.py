"""The paper's end-to-end driver: distributed RandomizedCCA fit.

    PYTHONPATH=src python -m repro.launch.cca_fit --smoke --ckpt-dir /tmp/cca

Streams a (synthetic-Europarl) paired-view corpus through Algorithm 1's
q+1 data passes.  Two execution modes:

- ``--mode dist``: all rows resident, shard_map over the host mesh —
  the production mode whose production-mesh lowering the dry-run checks;
- ``--mode stream``: out-of-core iterator with per-chunk jitted updates
  and mid-pass CHECKPOINTING (kill/resume fault tolerance for passes
  over data too large for memory).

Data source: synthetic generation by default, or an on-disk view store
(``repro.store``) via ``--data <store-path>`` — ``--ingest`` writes the
synthetic corpus there first.  Store-backed stream mode runs the async
prefetching PassRunner (``--prefetch`` depth, 0 = synchronous reads,
``auto`` = calibrated) and resumes a killed run from its pass cursor
with ``--resume``.  ``--workers N`` instead fans the store-backed fit
out over N worker PROCESSES through the ``repro.cluster`` coordinator
(``--cluster-dir`` for the shared coordination directory) — the result
is bit-identical to the single-process stream mode:

    python -m repro.launch.cca_fit --smoke --mode stream \
        --data /tmp/store --ingest --ckpt-dir /tmp/cca
    # kill it mid-pass, then:
    python -m repro.launch.cca_fit --smoke --mode stream \
        --data /tmp/store --ckpt-dir /tmp/cca --resume

``--topology {local,sharded,cluster,hybrid}`` is the unified spelling
of the execution layout (repro.exec): ``sharded`` folds merge groups
one-per-device over the local mesh, ``hybrid`` = cluster workers ×
per-worker device meshes (``--devices-per-worker``).  Every topology
is bit-identical on the same store.

Reports the paper's metrics: Σ canonical correlations (train objective),
feasibility residuals, and — at smoke scale — agreement with the exact
dense CCA oracle.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs.europarl_cca import config as europarl_config
from repro.configs.europarl_cca import smoke_config as europarl_smoke
from repro.core import exact_cca, feasibility_errors
from repro.core.rcca import DEFAULT_ENGINE, randomized_cca_iterator
from repro.core.rcca_dist import dist_randomized_cca
from repro.data import PlantedCCAData
from repro.launch.mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="dist", choices=["dist", "stream"])
    ap.add_argument("--engine", default=DEFAULT_ENGINE, choices=["kernels", "jnp"],
                    help="data-pass engine: fused Pallas kernels (default; "
                         "interpret-mode off-TPU) or the pure-jnp oracle path")
    ap.add_argument("--omega", default="materialized",
                    choices=["materialized", "seeded", "seeded-materialized"],
                    help="Gaussian-sketch provenance: 'seeded' runs the "
                         "first data pass from an 8-byte counter-PRNG seed "
                         "(kernels engine generates Omega tiles in-kernel; "
                         "cluster rounds ship the seed, not the (d, k~) "
                         "bases); 'seeded-materialized' materializes the "
                         "same tile-PRNG Omega up front — the bitwise "
                         "oracle of the seeded path")
    ap.add_argument("--autotune", action="store_true",
                    help="before fitting, sweep the fused powerpass/projgram "
                         "block+bucket sizes for this workload's chunk shape "
                         "and persist them to the autotune cache (run once "
                         "per shape on the target hardware)")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default=None, metavar="STORE",
                    help="path to an on-disk view store (repro.store); "
                         "stream mode prefetches from it, dist mode "
                         "materializes it onto the mesh")
    ap.add_argument("--ingest", action="store_true",
                    help="write the synthetic workload corpus into --data "
                         "first (chunked — never materializes n × d)")
    ap.add_argument("--prefetch", default="2",
                    help="store prefetch pipeline depth (0 = synchronous, "
                         "'auto' = calibrate from the read/compute ratio)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed store-backed run from the latest "
                         "pass cursor in --ckpt-dir")
    ap.add_argument("--workers", type=int, default=0,
                    help="run the store-backed fit across N worker "
                         "PROCESSES via the repro.cluster coordinator "
                         "(requires --data; bit-identical to the "
                         "single-process stream mode)")
    ap.add_argument("--cluster-dir", default=None,
                    help="shared coordination directory for --workers "
                         "(rounds/partials/cursors/heartbeats/logs; "
                         "default <store>.cluster)")
    ap.add_argument("--topology", default=None,
                    choices=["local", "sharded", "cluster", "hybrid"],
                    help="execution topology (repro.exec): local = "
                         "sequential stream, sharded = merge groups "
                         "one-per-device over the local mesh, cluster = "
                         "worker processes, hybrid = worker processes x "
                         "per-worker device meshes.  All topologies are "
                         "bit-identical on the same store (sharded/"
                         "cluster/hybrid need --data)")
    ap.add_argument("--devices-per-worker", type=int, default=4,
                    help="local devices each hybrid worker folds merge "
                         "groups over (spawned with the forced-host-"
                         "device XLA flag, so it works on CPU hosts)")
    ap.add_argument("--trace", default=None, metavar="DIR", nargs="?",
                    const="1",
                    help="record a repro.obs trace of the fit (spans + "
                         "roofline counters for every pass, worker, and "
                         "kernel; propagates to cluster workers) and "
                         "print the timeline/roofline report afterwards. "
                         "Optional DIR names the trace directory "
                         "(default rcca_trace/)")
    args = ap.parse_args(argv)
    args.prefetch = args.prefetch if args.prefetch == "auto" else int(args.prefetch)

    if args.trace:
        import os

        from repro import obs
        os.environ[obs.TRACE_ENV] = args.trace  # inherited by workers
        print(f"[cca] tracing -> {obs.trace_dir()}/ "
              "(timeline report after the fit)")

    wl = europarl_smoke() if args.smoke else europarl_config()
    rcca = wl.rcca
    if args.k is not None:
        import dataclasses
        rcca = dataclasses.replace(rcca, k=args.k)
    if args.p is not None:
        import dataclasses
        rcca = dataclasses.replace(rcca, p=args.p)
    if args.q is not None:
        import dataclasses
        rcca = dataclasses.replace(rcca, q=args.q)

    data = PlantedCCAData(n=wl.n, da=wl.da, db=wl.db, chunk=wl.chunk,
                          rank=max(rcca.k * 2, 16), seed=args.seed)
    key = jax.random.PRNGKey(args.seed)

    if args.topology is None and args.workers:
        args.topology = "cluster"
    if args.topology == "local":
        args.mode = "stream"  # Local IS the sequential streaming topology
    if args.topology in ("sharded", "cluster", "hybrid") and not args.data:
        raise SystemExit(f"--topology {args.topology} needs an on-disk "
                         "store: pass --data (these topologies cut a "
                         "view store into merge groups)")
    if args.workers and not args.data:
        raise SystemExit("--workers needs an on-disk store: pass --data "
                         "(the cluster coordinator shards a view store)")

    reader = None
    if args.data:
        from repro.store import ViewStoreReader, ingest_planted, store_exists

        if args.ingest or not store_exists(args.data):
            t_ing = time.time()
            reader = ingest_planted(args.data, data)
            print(f"[cca] ingested {reader.n} rows "
                  f"({reader.nbytes / 1e6:.1f} MB, {len(reader.shards)} shards) "
                  f"→ {args.data} in {time.time() - t_ing:.1f}s")
        else:
            reader = ViewStoreReader(args.data)
            print(f"[cca] view store {args.data}: n={reader.n} "
                  f"da={reader.da} db={reader.db} chunk={reader.chunk} "
                  f"({reader.nbytes / 1e6:.1f} MB on disk)")
        if (reader.n, reader.da, reader.db) != (wl.n, wl.da, wl.db):
            print(f"[cca] store geometry overrides workload: "
                  f"n={reader.n} da={reader.da} db={reader.db}")

    if args.autotune and args.engine == "kernels":
        # Sweep the chunk-shaped fused ops so the data passes pick up
        # tuned bucket sizes (caps bind at trace time — sweep BEFORE
        # the first pass compiles).  Zeros suffice: block timing is
        # data-independent.
        from repro.kernels import autotune as kernel_autotune
        c = min(wl.chunk, wl.n)
        kt = rcca.sketch
        a0 = jnp.zeros((c, wl.da), jnp.float32)
        b0 = jnp.zeros((c, wl.db), jnp.float32)
        qa0 = jnp.zeros((wl.da, kt), jnp.float32)
        qb0 = jnp.zeros((wl.db, kt), jnp.float32)
        # both view directions: the power pass calls (a,b,Qb) AND
        # (b,a,Qa), the final pass projgrams each view — asymmetric
        # da/db means four distinct cache keys
        pp = kernel_autotune.autotune_powerpass(a0, b0, qb0)
        pg = kernel_autotune.autotune_projgram(a0, qa0)
        if wl.da != wl.db:
            pp_b = kernel_autotune.autotune_powerpass(b0, a0, qa0)
            pg_b = kernel_autotune.autotune_projgram(b0, qb0)
        else:
            pp_b, pg_b = pp, pg  # same cache keys — one sweep covers both
        print(f"[cca] autotuned chunk ({c}, da={wl.da}, db={wl.db}, k~={kt}): "
              f"powerpass blocks a={pp} b={pp_b}, "
              f"projgram blocks a={pg} b={pg_b} "
              f"(cache: {kernel_autotune.cache_path()})")
        del a0, b0, qa0, qb0

    t0 = time.time()
    if args.topology in ("cluster", "hybrid"):
        from repro.cluster import ClusterCoordinator

        n_workers = args.workers or 2
        devices = args.devices_per_worker if args.topology == "hybrid" else 1
        cluster_dir = args.cluster_dir or args.data.rstrip("/") + ".cluster"
        if args.prefetch == "auto":
            print("[cca] --prefetch auto is per-process calibration; "
                  "cluster workers use a fixed depth 2 instead")
        coord = ClusterCoordinator(
            reader, rcca, cluster_dir, n_workers=n_workers,
            devices_per_worker=devices, engine=args.engine,
            omega=args.omega,
            prefetch=args.prefetch if args.prefetch != "auto" else 2)
        print(f"[cca] {args.topology} mode, engine={args.engine}, "
              f"omega={args.omega}, "
              f"workers={n_workers}x{devices}dev, groups={coord.n_groups}, "
              f"cluster_dir={cluster_dir}")
        res = coord.fit(key)
        print("[cca] cluster:", res.diagnostics["cluster"])
        A = B = None
        if reader.nbytes <= 2 << 30:
            A, B = reader.materialize()
    elif args.topology == "sharded":
        from repro.exec import PassEngine, Sharded

        eng = PassEngine(rcca, engine=args.engine, topology=Sharded(),
                         omega=args.omega)
        mesh = eng.topology.build_mesh()
        print(f"[cca] sharded mode, engine={args.engine}, omega={args.omega}, "
              f"devices={mesh.devices.size}, n={reader.n} "
              f"chunks={reader.n_chunks} (force more CPU devices with "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        res = eng.run_mesh(reader, key)
        print("[cca] topology:", res.diagnostics["topology"])
        A = B = None
        if reader.nbytes <= 2 << 30:
            A, B = reader.materialize()
    elif args.mode == "dist":
        if args.omega != "materialized":
            # the resident-mode shard_map driver has no streaming pass
            # to de-materialize — Ω lives on the mesh either way
            print(f"[cca] --omega {args.omega} only affects the streaming "
                  "topologies; dist mode keeps the materialized sketch")
        A, B = reader.materialize() if reader is not None else data.materialize()
        mesh = make_host_mesh()
        print(f"[cca] dist mode, engine={args.engine}, "
              f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}, "
              f"n={wl.n} da={wl.da} db={wl.db} k={rcca.k} p={rcca.p} q={rcca.q}")
        res = dist_randomized_cca(jnp.asarray(A), jnp.asarray(B), rcca, key, mesh,
                                  engine=args.engine)
    elif reader is not None:
        from repro.store import PassRunner

        runner = PassRunner(reader, rcca, engine=args.engine,
                            prefetch=args.prefetch, ckpt_dir=args.ckpt_dir,
                            omega=args.omega)
        print(f"[cca] stream mode (store-backed), engine={args.engine}, "
              f"omega={args.omega}, prefetch={args.prefetch}, "
              f"n={reader.n} chunks={reader.n_chunks}")
        res = runner.fit(key, resume=args.resume)
        print("[cca] io:", res.diagnostics["io"])
        # evaluation materializes — only do it for corpora that fit
        A = B = None
        if reader.nbytes <= 2 << 30:
            A, B = reader.materialize()
    else:
        mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
        state = {"count": 0}

        def on_chunk(pass_idx, chunk_idx, acc, Qa, Qb):
            state["count"] += 1
            if mgr and state["count"] % 16 == 0:
                mgr.save(
                    pass_idx * 10_000 + chunk_idx,
                    {"acc": acc.state(), "Qa": Qa, "Qb": Qb},
                    metadata={"pass_idx": pass_idx, "chunk_idx": chunk_idx},
                )

        print(f"[cca] stream mode, engine={args.engine}, omega={args.omega}, "
              f"n={wl.n} chunks={data.n_chunks}")
        res = randomized_cca_iterator(
            lambda: iter(data), wl.da, wl.db, rcca, key, on_pass_end=on_chunk,
            engine=args.engine, omega=args.omega,
        )
        A, B = data.materialize()  # for evaluation only

    dt = time.time() - t0
    rho = np.asarray(res.rho)
    print(f"[cca] done in {dt:.1f}s; sum rho = {rho.sum():.4f}; top-5 rho = {rho[:5]}")

    if args.trace:
        from repro import obs
        from repro.obs import report as obs_report
        print(obs_report.render(obs_report.analyze(obs.trace_dir())))

    if A is None:
        print("[cca] corpus larger than the eval budget — skipping "
              "materialized feasibility/oracle checks")
        return

    lam_a = float(res.diagnostics["lam_a"])
    lam_b = float(res.diagnostics["lam_b"])
    feas = feasibility_errors(jnp.asarray(A), jnp.asarray(B),
                              jnp.asarray(res.Xa), jnp.asarray(res.Xb), lam_a, lam_b)
    print("[cca] feasibility:", {k: float(v) for k, v in feas.items()})

    if args.smoke:
        ex = exact_cca(jnp.asarray(A), jnp.asarray(B), rcca.k, lam_a, lam_b)
        gap = float(np.sum(np.asarray(ex.rho)) - rho.sum())
        print(f"[cca] exact-oracle objective gap: {gap:.5f} "
              f"(exact {float(np.sum(np.asarray(ex.rho))):.4f})")


if __name__ == "__main__":
    main()
