"""Launch layer: production meshes, step builders, dry-run, drivers."""
