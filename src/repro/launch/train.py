"""End-to-end LM trainer (runs real steps on whatever devices exist).

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production use is identical: the same step function the dry-run lowered
for the 512-chip mesh runs here on the host mesh — only the mesh (and
therefore the fitted shardings) changes.  Checkpoint/restart: kill it
mid-run and relaunch with the same --ckpt-dir; it resumes from the
latest step, re-sharding to the current mesh (elastic).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticTokenStream
from repro.kernels.compat import set_mesh
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--loss-chunks", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = make_host_mesh()
    print(f"[train] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 10))
    step_fn = S.make_train_step(model, opt_cfg, loss_chunks=args.loss_chunks)

    p_sharding, p_shape = S.param_shardings(model, mesh)
    o_sharding = S.opt_shardings(mesh, p_sharding)

    with set_mesh(mesh):
        params = jax.jit(model.init, out_shardings=p_sharding)(jax.random.PRNGKey(0))
        opt_state = jax.jit(adamw_init, out_shardings=o_sharding)(params)

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        restored, meta = mgr.restore(
            {"params": params, "opt": opt_state},
            shardings={"params": p_sharding, "opt": o_sharding},
        )
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(meta["step"]) + 1
            print(f"[train] resumed from step {start_step - 1}")

    stream = SyntheticTokenStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    shape = S.ShapeSpec("cli", "train", args.seq, args.batch)
    batch_sds, batch_spec = S.input_specs(cfg, shape)
    b_sharding = S.fit_specs(batch_spec, batch_sds, mesh)

    jit_step = jax.jit(
        step_fn,
        in_shardings=(p_sharding, o_sharding, b_sharding),
        out_shardings=(p_sharding, o_sharding, None),
        donate_argnums=(0, 1),
    )

    rng = np.random.default_rng(0)
    for step in range(start_step, args.steps):
        batch = {"tokens": jnp.asarray(stream.get_batch(step))}
        if cfg.kind == "encdec":
            from repro.configs.whisper_small import N_FRAMES
            batch["frames"] = jnp.asarray(
                rng.standard_normal((args.batch, min(N_FRAMES, 64), cfg.d_model), np.float32)
            )
        elif cfg.frontend == "vision_patches":
            npz = 8
            batch["tokens"] = batch["tokens"][:, : args.seq + 1 - npz]
            batch["embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, npz, cfg.d_model), np.float32)
            )
        t0 = time.time()
        with set_mesh(mesh):
            params, opt_state, metrics = jit_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        print(f"[train] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)", flush=True)
        assert np.isfinite(loss), "loss diverged"
        if mgr and (step % args.ckpt_every == 0 or step == args.steps - 1):
            mgr.save(step, {"params": params, "opt": opt_state},
                     metadata={"loss": loss}, background=True)
    if mgr:
        mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
