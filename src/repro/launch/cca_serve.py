"""Serving-loop driver: fit → publish → serve → drift → delta-refit → swap.

    PYTHONPATH=src python -m repro.launch.cca_serve --smoke \
        --store /tmp/cca_store --registry /tmp/cca_registry

One process walks the whole production story of ``repro.serve``:

1. ingest the first tranche of a (synthetic) paired-view corpus into a
   view store and fit it with :func:`repro.exec.fit_with_state` — the
   fit that keeps its accumulator state for later delta-refits;
2. publish the model as **v1** of a :class:`repro.serve.ModelRegistry`
   entry (atomic, content-hashed) and persist the
   :class:`~repro.exec.FitState` next to it;
3. serve traffic through a :class:`repro.serve.BatchedProjector`
   (request coalescing, padded device batches) while a
   :class:`repro.serve.DriftMonitor` watches paired held-out rows;
4. inject a distribution shift (the held-out pairing breaks — the
   cheapest honest stand-in for an upstream pipeline change): the
   canonical correlation collapses and the monitor emits the
   refit-needed signal;
5. the signal triggers the incremental path: the second corpus tranche
   is APPENDED to the store (atomic manifest re-publish), and
   :func:`repro.exec.delta_refit` folds only the delta through pass 0
   (mode="exact": bitwise what a cold fit of the grown corpus computes);
6. publish **v2** and hot-swap the projector at a batch boundary —
   zero dropped requests — then re-baseline the monitor and show the
   held-out correlation recovered on healthy traffic.

Every stage traces through ``repro.obs`` (``--trace``), so the swap,
the batch occupancies and the drift counters land in the same timeline
as the fit's passes.
"""

from __future__ import annotations

import argparse
import itertools
import os
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

from repro.data import PlantedCCAData
from repro.core.rcca import DEFAULT_ENGINE, RCCAConfig
from repro.exec import FitState, Local, Sharded, delta_refit, fit_with_state
from repro.serve import (BatchedProjector, CorpusIndex, DriftMonitor,
                         ModelRegistry)
from repro.store import (ViewStoreReader, extend_chunks, ingest_chunks,
                         store_exists)


def _fitstate_dir(registry_root: str, name: str) -> str:
    return os.path.join(registry_root, name, "fitstate")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus (seconds on CPU) — the demo scale")
    ap.add_argument("--store", required=True,
                    help="view store path (created/extended here)")
    ap.add_argument("--registry", required=True,
                    help="model registry root (repro.serve.ModelRegistry)")
    ap.add_argument("--name", default="europarl-cca",
                    help="registry model name")
    ap.add_argument("--engine", default=DEFAULT_ENGINE,
                    choices=["kernels", "jnp"])
    ap.add_argument("--omega", default="materialized",
                    choices=["materialized", "seeded",
                             "seeded-materialized"])
    ap.add_argument("--topology", default="local",
                    choices=["local", "sharded"],
                    help="fit/refit topology (delta-refit over cluster "
                         "partials is a ROADMAP residual)")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--window", type=int, default=192,
                    help="drift-monitor window (held-out rows)")
    ap.add_argument("--threshold", type=float, default=0.8,
                    help="refit signal fires below this fraction of the "
                         "baseline correlation")
    ap.add_argument("--clients", type=int, default=16,
                    help="concurrent request threads during the swap")
    ap.add_argument("--prune-keep", type=int, default=None, metavar="N",
                    help="after the final publish, garbage-collect old "
                         "registry versions keeping the newest N (the "
                         "current version and its rollback chain are "
                         "always kept)")
    ap.add_argument("--trace", default=None, metavar="DIR", nargs="?",
                    const="1",
                    help="record a repro.obs trace (spans for fit + "
                         "serve batches, drift/swap/occupancy counters)")
    args = ap.parse_args(argv)

    if args.trace:
        from repro import obs
        os.environ[obs.TRACE_ENV] = args.trace
        print(f"[serve] tracing -> {obs.trace_dir()}/")

    # -- corpus geometry: two tranches + held-out traffic -----------------
    # the first tranche must end on a merge-group boundary (the
    # incremental-fit alignment contract: repro.exec.delta)
    if args.smoke:
        chunk, merge_group = 128, 2
        n0, n1, n_traffic = 1024, 1536, 1024
        cfg = RCCAConfig(k=4, p=8, q=1, nu=0.01, center=True)
    else:
        chunk, merge_group = 1024, 8
        n0, n1, n_traffic = 65536, 98304, 8192
        cfg = RCCAConfig(k=16, p=16, q=1, nu=0.01, center=True)
    if args.k is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, k=args.k)
    if args.q is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, q=args.q)
    da, db = (28, 20) if args.smoke else (160, 120)
    data = PlantedCCAData(n=n1 + n_traffic, da=da, db=db,
                          rank=max(cfg.k * 2, 8), noise=0.4,
                          seed=11 + args.seed, chunk=chunk)
    c0, c1 = n0 // chunk, n1 // chunk
    topology = Local() if args.topology == "local" else Sharded()
    key = jax.random.PRNGKey(args.seed)
    reg = ModelRegistry(args.registry)

    # -- 1+2: first tranche → stateful fit → publish v1 -------------------
    if not store_exists(args.store):
        ingest_chunks(args.store,
                      (data.get_chunk(i) for i in range(c0)), chunk=chunk)
    reader = ViewStoreReader(args.store)
    print(f"[serve] store {args.store}: n={reader.n} da={reader.da} "
          f"db={reader.db} ({reader.n_chunks} chunks)")
    t0 = time.time()
    res, state = fit_with_state(reader, cfg, key, topology=topology,
                                engine=args.engine, omega=args.omega,
                                merge_group=merge_group)
    v1 = reg.publish(args.name, res, fit_meta=state.meta)
    state.save(_fitstate_dir(args.registry, args.name))
    print(f"[serve] fit tranche 1 in {time.time() - t0:.1f}s; "
          f"published {args.name} v{v1} "
          f"(sum rho = {float(np.sum(np.asarray(res.rho))):.4f})")

    # -- 3: serve + monitor -----------------------------------------------
    model = reg.load(args.name)
    proj = BatchedProjector(model, max_batch=32)
    monitor = DriftMonitor(model, window=args.window,
                           threshold=args.threshold)
    index = CorpusIndex.from_store(model, reader, view="b")

    # held-out traffic: rows past every corpus tranche, enough to fill
    # the drift window
    parts = [data.get_chunk(i) for i in
             range(c1, c1 + -(-args.window // chunk))]
    xa_t = np.concatenate([a for a, _ in parts])
    xb_t = np.concatenate([b for _, b in parts])
    for lo in range(0, args.window, 64):
        monitor.observe(xa_t[lo:lo + 64], xb_t[lo:lo + 64])
    print(f"[serve] baseline held-out correlation: "
          f"{monitor.baseline:.4f} (window={args.window})")
    r = proj.project_a(xa_t[0])
    hits, _ = index.topk(r["emb"], k=5)
    print(f"[serve] sample request: v{r['version']} "
          f"top-5 cross-view rows {hits.tolist()}")

    # -- 4: inject shift → drift signal -----------------------------------
    perm = np.random.default_rng(7).permutation(xb_t.shape[0])
    shifted = xb_t[perm]  # pairing broken: upstream pipeline "change"
    mean = None
    for lo in range(0, args.window, 64):
        mean = monitor.observe(xa_t[lo:lo + 64], shifted[lo:lo + 64]) or mean
    print(f"[serve] injected shift: correlation {mean:.4f} "
          f"-> refit_needed={monitor.refit_needed}")
    if not monitor.refit_needed:
        raise SystemExit("drift monitor failed to flag the injected shift")

    # -- 5: append tranche 2 + delta-refit --------------------------------
    t0 = time.time()
    extend_chunks(args.store, (data.get_chunk(i) for i in range(c0, c1)))
    reader = ViewStoreReader(args.store)
    state = FitState.load(_fitstate_dir(args.registry, args.name))
    res2, state2 = delta_refit(state, reader, mode="exact",
                               topology=topology)
    d = res2.diagnostics["delta"]
    print(f"[serve] delta-refit in {time.time() - t0:.1f}s: "
          f"+{reader.n - n0} rows, delta_chunks={d['delta_chunks']}, "
          f"refolded={d['refolded_chunks']} "
          f"(sum rho = {float(np.sum(np.asarray(res2.rho))):.4f})")

    # -- 6: publish v2 + hot-swap under live traffic ----------------------
    v2 = reg.publish(args.name, res2, fit_meta=state2.meta, parent=v1)
    state2.save(_fitstate_dir(args.registry, args.name))
    model2 = reg.load(args.name)

    def client(i: int) -> int:
        return proj.project_a(xa_t[i % xa_t.shape[0]])["version"]

    with ThreadPoolExecutor(max_workers=8) as pool:
        futs = [pool.submit(client, i) for i in range(args.clients)]
        proj.swap(model2)
        futs += [pool.submit(client, i) for i in range(args.clients)]
        served = [f.result() for f in futs]
    versions = sorted(set(served))
    stats = proj.stats()
    print(f"[serve] hot-swap v{v1}->v{v2}: {len(served)} responses across "
          f"the flip (versions seen: {versions}, dropped: 0); "
          f"batches={stats['batches']} "
          f"mean_occupancy={stats['mean_occupancy']:.1f} "
          f"swaps={stats['swaps']}")

    # -- recovery: healthy traffic under the refreshed model --------------
    monitor.rebind(model2)
    recovered = None
    for lo in range(0, args.window, 64):
        recovered = monitor.observe(
            xa_t[lo:lo + 64], xb_t[lo:lo + 64]) or recovered
    print(f"[serve] post-swap held-out correlation: {recovered:.4f} "
          f"(refit_needed={monitor.refit_needed})")
    proj.close()

    if args.prune_keep is not None:
        pruned = reg.prune(args.name, keep=args.prune_keep)
        print(f"[serve] pruned versions {pruned} (keep={args.prune_keep})")

    if args.trace:
        from repro import obs
        from repro.obs import report as obs_report
        print(obs_report.render(obs_report.analyze(obs.trace_dir())))
    print(f"[serve] registry {args.registry}: {args.name} versions "
          f"{reg.versions(args.name)}, current v{reg.current_version(args.name)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
