"""Shared violation record + rendering for every repro.analysis checker.

Each checker (lint, kernel_check, protocol, sanitize comparator) emits
:class:`Violation` records under its own code range:

  RCCA0xx  architecture lint          (:mod:`repro.analysis.lint`)
  RCCA1xx  kernel contract checker    (:mod:`repro.analysis.kernel_check`)
  RCCA2xx  cluster-protocol detector  (:mod:`repro.analysis.protocol`)
  RCCA3xx  determinism sanitizer      (:mod:`repro.analysis.sanitize`)

The CLI (``python -m repro.analysis``) renders them one per line in the
conventional ``path:line: CODE message`` shape and exits nonzero when
any are present.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: a stable rule code, where, and why."""

    code: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.code} {self.message}"


def render_report(violations: Sequence[Violation], *, title: str) -> str:
    """Human-readable block: title, sorted findings, count line."""
    lines = [title]
    for v in sorted(violations, key=lambda v: (v.path, v.line, v.code)):
        lines.append("  " + v.render())
    n = len(violations)
    lines.append(f"  -> {n} violation{'s' if n != 1 else ''}"
                 if n else "  -> clean")
    return "\n".join(lines)
