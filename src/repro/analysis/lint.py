"""AST architecture lint for the repro tree (rules RCCA001–RCCA007).

The bitwise-reproducibility contract (DESIGN.md, README §Bitwise
reproducibility) survives only as long as a handful of architectural
disciplines hold.  Each rule here pins one of them:

  RCCA001  accumulator-fold loops live in ``repro/exec`` only.  Any
           loop outside it that calls the fold/merge primitives
           (``merge_stats``, ``merge_power_stats``, ``merge_final_stats``,
           ``push_group``, ``end_chunk``, ``flush_tail``, or a
           ``*update_fn``) is reimplementing accumulation order — the
           exact thing the canonical pairwise tree exists to own.
  RCCA002  version-sensitive jax APIs (``jax.experimental.pallas.tpu``
           a.k.a. ``pltpu``, ``jax.experimental.shard_map``) are used
           only through :mod:`repro.kernels.compat`.  Everywhere else
           imports the shim, so a jax upgrade is a one-file change.
  RCCA003  view-store shard files (``shard_*.a.npy`` / ``*.b.npy``)
           are read only by ``repro/store``.  Direct reads elsewhere
           bypass the manifest (fingerprint, row ranges, dtype) and
           break the store's atomic-publish guarantee.
  RCCA004  pass-path modules (``repro/exec``, ``repro/cluster``,
           ``repro/core/rcca.py``, ``repro/store/passes.py``) are
           deterministic: no wall-clock (``time.time``), no ``uuid``,
           no legacy global RNG (``random.*`` / ``np.random.*``
           module-level calls), no iteration over ``set()`` — set
           order is a hash-seed coin flip and merge-group iteration
           order is part of the contract.
  RCCA005  cluster/store file writes go through the atomic
           staging+rename helpers (``repro.ckpt.save_pytree``, the
           store writer's staging dir): no bare ``open(.., "w"/"wb")``
           or ``np.save`` outside them.  A torn write that a reader
           can observe is a protocol violation, not a perf bug.
  RCCA006  jax PRNG draws in the pass path happen only in
           ``repro/core/rcca.py`` (``init_Q`` / ``omega_seeds``).  A
           ``jax.random.*`` call anywhere else in the pass path is a
           second entropy source the seeded-Ω contract can't see:
           every execution mode must derive identical randomness from
           the one fit key (or the 8-byte Ω seed it produces).
  RCCA007  pass-path modules (plus ``repro/store/prefetch.py``) take
           timings through the :mod:`repro.obs` clocks
           (``obs.monotonic()`` / ``obs.wall()``), not raw
           ``time.monotonic`` / ``time.perf_counter``.  One clock home
           keeps spans, io counters, and diagnostics in a single
           comparable time domain — a bespoke clock is a second
           profiler the trace can't see.

Suppression: a trailing ``# rcca: noqa`` comment silences every rule
on that line; ``# rcca: noqa[RCCA004]`` (comma-separated codes)
silences only those rules.  Every suppression in the tree should carry
a justification comment — the lint is the contract's memory, noqa is
the documented exception.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Iterable, List, Optional, Sequence

from .report import Violation

# ---------------------------------------------------------------------------
# rule scoping
# ---------------------------------------------------------------------------

#: modules allowed to hold accumulator-fold loops (RCCA001)
FOLD_HOME = ("repro/exec/",)

#: the one module allowed to touch version-sensitive jax APIs (RCCA002)
COMPAT_HOME = ("repro/kernels/compat.py",)

#: modules allowed to read store shard files directly (RCCA003)
STORE_HOME = ("repro/store/",)

#: deterministic pass-path modules (RCCA004)
PASS_PATH = ("repro/exec/", "repro/cluster/", "repro/core/rcca.py",
             "repro/store/passes.py")

#: modules whose file writes must be staged+renamed (RCCA005).
#: ``repro/ckpt`` is the atomic helper itself and is out of scope.
ATOMIC_WRITE_SCOPE = ("repro/cluster/", "repro/store/")

#: the one pass-path module allowed to draw from the jax PRNG (RCCA006)
RNG_HOME = ("repro/core/rcca.py",)

#: modules whose timings must flow through the repro.obs clocks (RCCA007)
OBS_CLOCK_SCOPE = PASS_PATH + ("repro/store/prefetch.py",)

#: the module that implements the obs clocks (out of RCCA007 scope)
OBS_HOME = ("repro/obs/",)

#: fold/merge primitives whose looped use outside repro/exec trips RCCA001
FOLD_CALLS = frozenset({
    "merge_stats", "merge_power_stats", "merge_final_stats",
    "push_group", "end_chunk", "flush_tail", "reduce_group_partials",
})
FOLD_FN_RE = re.compile(r"^(jit_)?update_fn$")

#: version-sensitive jax modules (RCCA002) — prefix match on import path
VERSION_SENSITIVE = ("jax.experimental.shard_map",
                     "jax.experimental.pallas.tpu")

#: view-store shard data-file naming (RCCA003)
SHARD_FILE_RE = re.compile(r"\.(a|b)\.npy\b")

NOQA_RE = re.compile(r"#\s*rcca:\s*noqa(?:\[([A-Za-z0-9,\s]+)\])?")


def _in(relpath: str, prefixes: Sequence[str]) -> bool:
    return any(relpath == p or relpath.startswith(p) for p in prefixes)


# ---------------------------------------------------------------------------
# per-rule AST visitors
# ---------------------------------------------------------------------------


def _call_name(node: ast.Call) -> Optional[str]:
    """Trailing identifier of the callee: ``f(...)`` → f, ``o.m(...)`` → m."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute chain as a dotted string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


_LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.comprehension)


def _rule_001(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if _in(relpath, FOLD_HOME):
        return
    # collect line spans of loop bodies, then flag fold calls inside them
    loop_nodes: List[ast.AST] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            loop_nodes.append(node)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            loop_nodes.append(node)
    seen = set()
    for loop in loop_nodes:
        for node in ast.walk(loop):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name is None:
                continue
            if name in FOLD_CALLS or FOLD_FN_RE.match(name):
                key = (node.lineno, name)
                if key in seen:
                    continue
                seen.add(key)
                yield Violation(
                    "RCCA001", relpath, node.lineno,
                    f"accumulator-fold call `{name}` in a loop outside "
                    "repro/exec — fold order is owned by the canonical "
                    "pairwise tree (repro.exec.accumulate)")


def _rule_002(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if _in(relpath, COMPAT_HOME):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if any(alias.name.startswith(m) for m in VERSION_SENSITIVE):
                    yield Violation(
                        "RCCA002", relpath, node.lineno,
                        f"version-sensitive import `{alias.name}` outside "
                        "repro.kernels.compat — use the compat shim")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            hits = [mod] if any(mod.startswith(m) for m in VERSION_SENSITIVE) \
                else [f"{mod}.{a.name}" for a in node.names
                      if any(f"{mod}.{a.name}".startswith(m)
                             for m in VERSION_SENSITIVE)]
            for h in hits:
                yield Violation(
                    "RCCA002", relpath, node.lineno,
                    f"version-sensitive import `{h}` outside "
                    "repro.kernels.compat — use the compat shim")
        elif isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            if dotted and (dotted.startswith("pltpu.")
                           or any(dotted.startswith(m + ".")
                                  for m in VERSION_SENSITIVE)):
                yield Violation(
                    "RCCA002", relpath, node.lineno,
                    f"version-sensitive API use `{dotted}` outside "
                    "repro.kernels.compat — use the compat shim")


def _docstring_nodes(tree: ast.AST) -> set:
    """ids of string constants that are docstrings (documentation may
    legitimately name shard files; code must not)."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            body = node.body
            if body and isinstance(body[0], ast.Expr) \
                    and isinstance(body[0].value, ast.Constant) \
                    and isinstance(body[0].value.value, str):
                out.add(id(body[0].value))
    return out


def _rule_003(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if _in(relpath, STORE_HOME):
        return
    docstrings = _docstring_nodes(tree)
    # constants embedded in an f-string are reported via the JoinedStr,
    # not double-reported on their own
    embedded = {id(v) for node in ast.walk(tree)
                if isinstance(node, ast.JoinedStr) for v in node.values}
    for node in ast.walk(tree):
        if id(node) in docstrings or id(node) in embedded:
            continue
        text = None
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            text = node.value
        elif isinstance(node, ast.JoinedStr):
            text = "".join(v.value for v in node.values
                           if isinstance(v, ast.Constant)
                           and isinstance(v.value, str))
        if text and SHARD_FILE_RE.search(text):
            yield Violation(
                "RCCA003", relpath, node.lineno,
                "store shard data file referenced outside repro/store — "
                "read views through ViewStoreReader (manifest-checked, "
                "atomic-publish aware)")


#: module-level legacy RNG entry points (unseeded global state)
_RNG_CALLS = frozenset({
    "random.random", "random.randint", "random.randrange", "random.choice",
    "random.shuffle", "random.sample", "random.uniform",
    "np.random.rand", "np.random.randn", "np.random.randint",
    "np.random.random", "np.random.permutation", "np.random.shuffle",
    "np.random.choice", "numpy.random.rand", "numpy.random.randn",
    "numpy.random.randint", "numpy.random.random",
})
_CLOCK_CALLS = frozenset({"time.time", "time.time_ns"})


def _rule_004(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if not _in(relpath, PASS_PATH):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted in _CLOCK_CALLS:
                yield Violation(
                    "RCCA004", relpath, node.lineno,
                    f"wall-clock `{dotted}()` in a pass-path module — "
                    "pass results must not depend on when they ran")
            elif dotted in _RNG_CALLS:
                yield Violation(
                    "RCCA004", relpath, node.lineno,
                    f"unseeded global RNG `{dotted}()` in a pass-path "
                    "module — thread a seeded Generator / jax PRNG key")
            elif dotted and (dotted == "uuid.uuid4"
                             or dotted.startswith("uuid.uuid")):
                yield Violation(
                    "RCCA004", relpath, node.lineno,
                    f"`{dotted}()` in a pass-path module — identifiers in "
                    "the pass path must be derived, not random")
        if isinstance(node, (ast.For, ast.AsyncFor)):
            it = node.iter
            is_set = (isinstance(it, ast.Call)
                      and isinstance(it.func, ast.Name)
                      and it.func.id in ("set", "frozenset")) \
                or isinstance(it, ast.Set)
            if is_set:
                yield Violation(
                    "RCCA004", relpath, node.lineno,
                    "iteration over a set in a pass-path module — set "
                    "order is hash-seed dependent; use dict.fromkeys or "
                    "sorted() for a deterministic order")
        if isinstance(node, ast.comprehension):
            it = node.iter
            if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                    and it.func.id in ("set", "frozenset")):
                yield Violation(
                    "RCCA004", relpath, it.lineno,
                    "comprehension over a set in a pass-path module — set "
                    "order is hash-seed dependent; use dict.fromkeys or "
                    "sorted() for a deterministic order")


_SAVE_CALLS = frozenset({"np.save", "np.savez", "np.savez_compressed",
                         "numpy.save", "numpy.savez",
                         "numpy.savez_compressed"})


def _rule_005(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if not _in(relpath, ATOMIC_WRITE_SCOPE):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted in _SAVE_CALLS:
            yield Violation(
                "RCCA005", relpath, node.lineno,
                f"`{dotted}` in cluster/store scope — write through an "
                "atomic staging+rename helper (repro.ckpt.save_pytree / "
                "the store writer's staging dir)")
            continue
        callee = _call_name(node)
        if callee != "open":
            continue
        mode = None
        if len(node.args) >= 2:
            a = node.args[1]
            if isinstance(a, ast.Constant) and isinstance(a.value, str):
                mode = a.value
        for kw in node.keywords:
            if kw.arg == "mode" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                mode = kw.value.value
        if mode and mode[0] in ("w", "x"):
            yield Violation(
                "RCCA005", relpath, node.lineno,
                f"direct `open(.., {mode!r})` in cluster/store scope — "
                "publish through atomic staging+rename so readers never "
                "observe a torn file (appends are exempt)")


def _rule_006(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if not _in(relpath, PASS_PATH) or _in(relpath, RNG_HOME):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted and (dotted.startswith("jax.random.")
                       or dotted.startswith("jrandom.")
                       or dotted.startswith("random.PRNGKey")):
            yield Violation(
                "RCCA006", relpath, node.lineno,
                f"`{dotted}()` in a pass-path module outside rcca.py — "
                "Ω/seed derivation lives in repro.core.rcca (init_Q / "
                "omega_seeds); a second draw site breaks the seeded-Ω "
                "equivalence across engines and topologies")


_MONO_CALLS = frozenset({"time.monotonic", "time.monotonic_ns",
                         "time.perf_counter", "time.perf_counter_ns"})


def _rule_007(tree: ast.AST, relpath: str) -> Iterable[Violation]:
    if not _in(relpath, OBS_CLOCK_SCOPE) or _in(relpath, OBS_HOME):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted in _MONO_CALLS:
            yield Violation(
                "RCCA007", relpath, node.lineno,
                f"raw clock `{dotted}()` in pass-path scope — take "
                "timings via repro.obs (obs.monotonic() / obs.wall()) so "
                "spans, io counters, and diagnostics share one clock "
                "domain")


_RULES = (_rule_001, _rule_002, _rule_003, _rule_004, _rule_005, _rule_006,
          _rule_007)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _noqa_codes(line: str) -> Optional[frozenset]:
    """Suppressed codes on this source line: ``frozenset()`` means ALL
    rules (bare noqa), ``None`` means no suppression."""
    m = NOQA_RE.search(line)
    if not m:
        return None
    if not m.group(1):
        return frozenset()
    return frozenset(c.strip().upper() for c in m.group(1).split(","))


def lint_source(src: str, relpath: str) -> List[Violation]:
    """Lint one module's source.  ``relpath`` is the path relative to
    the ``src/`` root (e.g. ``repro/cluster/worker.py``) — rule scoping
    keys off it, which is also what makes fixture snippets testable
    under any synthetic path."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation("RCCA000", relpath, e.lineno or 0,
                          f"unparsable module: {e.msg}")]
    lines = src.splitlines()
    out: List[Violation] = []
    for rule in _RULES:
        for v in rule(tree, relpath):
            line = lines[v.line - 1] if 0 < v.line <= len(lines) else ""
            codes = _noqa_codes(line)
            if codes is not None and (not codes or v.code in codes):
                continue
            out.append(v)
    return out


def lint_file(path: str, src_root: str) -> List[Violation]:
    relpath = os.path.relpath(path, src_root).replace(os.sep, "/")
    with open(path) as f:
        return lint_source(f.read(), relpath)


def lint_tree(src_root: Optional[str] = None) -> List[Violation]:
    """Lint every ``repro`` module under ``src_root`` (default: the
    source root this package was imported from)."""
    if src_root is None:
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    out: List[Violation] = []
    pkg_root = os.path.join(src_root, "repro")
    for dirpath, dirnames, filenames in os.walk(pkg_root):
        dirnames.sort()
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                out.extend(lint_file(os.path.join(dirpath, fname), src_root))
    return out
