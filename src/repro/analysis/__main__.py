"""``python -m repro.analysis`` — the static-analysis gate.

Default (no subcommand) runs the full offline suite: architecture lint
over the tree, the kernel contract checker over every registered
kernel + the autotune cache, and the cluster-protocol small-model
interleaving exploration.  Exit status 0 iff everything is clean —
this is what ``make analyze``, ``scripts/verify.sh --analyze`` and the
CI ``analyze`` job call.

Subcommands::

    lint [paths...]          architecture lint (default: the whole tree)
    kernels                  kernel contracts + autotune cache
    protocol [--trace FILE]  trace invariants (FILE or
                             $RCCA_PROTOCOL_TRACE) + the interleaving
                             exploration
    sanitize-diff A B        compare two RCCA_SANITIZE_OUT traces and
                             name the first divergent merge boundary
"""

from __future__ import annotations

import argparse
import sys

from .report import render_report


def _run_lint(paths) -> int:
    from .lint import lint_file, lint_tree

    if paths:
        import os

        vs = []
        for p in paths:
            # resolve the src root so rule scoping sees repro/...
            ap = os.path.abspath(p)
            root = ap
            while os.path.basename(os.path.dirname(root)) and \
                    os.path.basename(root) != "repro":
                root = os.path.dirname(root)
            vs.extend(lint_file(ap, os.path.dirname(root)))
    else:
        vs = lint_tree()
    print(render_report(vs, title="architecture lint (RCCA0xx)"))
    return 1 if vs else 0


def _run_kernels() -> int:
    from .kernel_check import check_registry

    vs = check_registry()
    print(render_report(vs, title="kernel contracts (RCCA1xx)"))
    return 1 if vs else 0


def _run_protocol(trace: str | None) -> int:
    from .protocol import check_trace_file, explore_interleavings

    vs = list(check_trace_file(trace))
    report = explore_interleavings()
    vs.extend(report.violations())
    print(render_report(vs, title="cluster protocol (RCCA2xx)"))
    print(f"  model: {report.n_scenarios} crash scenarios, "
          f"{report.n_interleavings} interleavings explored")
    return 1 if vs else 0


def _run_sanitize_diff(a: str, b: str) -> int:
    from .sanitize import first_divergence, load

    div = first_divergence(load(a), load(b))
    if div is None:
        print("sanitize traces identical")
        return 0
    print(f"RCCA301 first divergence at record {div['index']} "
          f"({div['reason']}):")
    print(f"  a: {div['a']}")
    print(f"  b: {div['b']}")
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd")
    p_lint = sub.add_parser("lint", help="architecture lint")
    p_lint.add_argument("paths", nargs="*")
    sub.add_parser("kernels", help="kernel contracts + autotune cache")
    p_proto = sub.add_parser("protocol", help="protocol trace + model check")
    p_proto.add_argument("--trace", default=None)
    p_diff = sub.add_parser("sanitize-diff", help="compare sanitize traces")
    p_diff.add_argument("a")
    p_diff.add_argument("b")
    args = ap.parse_args(argv)

    if args.cmd == "lint":
        return _run_lint(args.paths)
    if args.cmd == "kernels":
        return _run_kernels()
    if args.cmd == "protocol":
        return _run_protocol(args.trace)
    if args.cmd == "sanitize-diff":
        return _run_sanitize_diff(args.a, args.b)
    # full gate
    rc = _run_lint([])
    rc |= _run_kernels()
    rc |= _run_protocol(None)
    print("ANALYZE: " + ("FAIL" if rc else "OK"))
    return rc


if __name__ == "__main__":
    sys.exit(main())
