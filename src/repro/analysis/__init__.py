"""Static analysis & sanitizers for the repro tree.

Four checkers, one CLI (``python -m repro.analysis``), one CI gate:

- :mod:`~repro.analysis.kernel_check` — verifies every registered
  Pallas kernel's launch plan (grid × block × index-map consistency,
  output coverage, VMEM budget, dtype rules, autotune-cache validity)
  by abstract evaluation, no device needed.
- :mod:`~repro.analysis.lint` — AST architecture lint (RCCA001–007)
  pinning the disciplines the bitwise-reproducibility contract rests
  on; ``# rcca: noqa[CODE]`` suppresses with justification.
- :mod:`~repro.analysis.protocol` — cluster-protocol race detector: an
  offline invariant checker over recorded publish/read/rename/merge
  traces, plus a small-model interleaving explorer that exhaustively
  permutes worker publish/crash orderings and model-checks the
  coordinator's merge against the canonical pairwise tree.
- :mod:`~repro.analysis.sanitize` — runtime determinism sanitizer
  (``RCCA_SANITIZE=1``): fingerprints accumulator state at every
  merge-group boundary; a comparator pinpoints the first divergent
  group between two runs.

Submodules import lazily — ``repro.analysis`` is imported by runtime
modules (accumulate's sanitizer hook) and must stay cycle-free and
cheap.
"""

from .report import Violation, render_report

_SUBMODULES = ("kernel_check", "lint", "protocol", "report", "sanitize")


def __getattr__(name):
    if name in _SUBMODULES:
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["Violation", "render_report", *_SUBMODULES]
