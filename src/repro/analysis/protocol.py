"""Cluster-protocol race detector (RCCA2xx).

Two complementary halves:

1. **Trace recording + offline invariant checking.**  The partial
   store (:mod:`repro.cluster.partials`) and the coordinator emit
   protocol events (stage/commit/twin_drop/stale_replace/read/merge)
   through :func:`trace_event` whenever ``$RCCA_PROTOCOL_TRACE`` names
   a JSONL file — the env var propagates to worker subprocesses, and
   single-line O_APPEND writes keep concurrent emitters intact.
   :func:`check_trace` then asserts the protocol invariants offline:

     RCCA201  no reader ever observes a staging path (``*.stage<pid>``
              must be invisible outside its writer).
     RCCA202  at-most-once merge per (fit, pass, group) — a group that
              enters the pairwise tree twice is double-counted data.
     RCCA203  every successful partial/round read is preceded by a
              commit of that path: a read with no commit means some
              writer bypassed the atomic staging+rename (exactly what
              a torn-write bug looks like in a trace).
     RCCA204  stale replacement only across bindings: replacing a
              partial whose binding already matches the writer's is a
              lost-update race, not staleness.

2. **Small-model interleaving exploration.**  :func:`explore_interleavings`
   model-checks the publish/crash protocol exhaustively for a small
   configuration (2 workers × ≤4 merge groups): every interleaving of
   the workers' publish sequences × every crash-after-prefix point,
   with the crashed worker's unpublished groups re-dispatched — and for
   every ordering, the coordinator's streamed group-order merge
   (:class:`repro.exec.accumulate.SegmentedAccumulator`) must agree
   BITWISE with the canonical
   :func:`repro.exec.accumulate.reduce_group_partials` on
   order-sensitive float32 payloads.  ``mutate`` injects protocol bugs
   (arrival-order merge, torn publish) so tests can prove the model
   checker actually detects them.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .report import Violation

TRACE_ENV = "RCCA_PROTOCOL_TRACE"


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------


def trace_event(op: str, path: str = "", **meta) -> None:
    """Append one protocol event to the trace file named by
    ``$RCCA_PROTOCOL_TRACE`` (no-op when unset).  One JSON object per
    line; single ``os.write`` with O_APPEND so concurrent workers
    interleave whole lines, never bytes.

    When the unified ``$RCCA_TRACE`` stream (:mod:`repro.obs`) is on,
    the same event is mirrored there as an ``ev="proto"`` record, so
    one obs trace serves both the profiler and this race detector —
    :func:`check_trace` keys on the top-level ``op`` field, which obs
    span/counter records lack (they fall through as ``"?"``)."""
    out = os.environ.get(TRACE_ENV)
    if not out and not os.environ.get("RCCA_TRACE"):
        return
    rec = {"op": op, "path": path, "pid": os.getpid()}
    if meta:
        rec["meta"] = meta
    from repro import obs
    obs.proto_event(rec)
    if not out:
        return
    line = json.dumps(rec, sort_keys=True, default=str) + "\n"
    fd = os.open(out, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, line.encode())
    finally:
        os.close(fd)


def read_trace(path: str) -> List[dict]:
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


# ---------------------------------------------------------------------------
# offline invariant checking
# ---------------------------------------------------------------------------


def check_trace(events: Sequence[dict], *, where: str = "trace") -> List[Violation]:
    """RCCA201–204 over a recorded event sequence (file order = the
    observable serialization on the shared FS)."""
    out: List[Violation] = []
    committed = set()
    merged: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        op = ev.get("op", "?")
        path = ev.get("path", "")
        meta = ev.get("meta", {})
        loc = f"{where}#{i}"
        if op == "commit":
            committed.add(path)
        elif op == "read":
            if ".stage" in os.path.basename(path):
                out.append(Violation(
                    "RCCA201", loc, i,
                    f"reader observed staging path {path!r} — staged tmp "
                    "must be invisible until the atomic rename"))
            elif path not in committed:
                out.append(Violation(
                    "RCCA203", loc, i,
                    f"read of {path!r} with no prior commit — a writer "
                    "bypassed the atomic staging+rename publish"))
        elif op == "merge":
            key = (meta.get("fit_id"), meta.get("pass_idx"),
                   meta.get("group"))
            if key in merged:
                out.append(Violation(
                    "RCCA202", loc, i,
                    f"merge group {key[2]} of pass {key[1]} entered the "
                    f"tree twice (first at event {merged[key]}) — "
                    "double-counted data"))
            else:
                merged[key] = i
        elif op == "stale_replace":
            if meta.get("old_binding") == meta.get("new_binding"):
                out.append(Violation(
                    "RCCA204", loc, i,
                    f"stale replacement of {path!r} with an IDENTICAL "
                    "binding — that is a lost-update race, not staleness"))
    return out


def check_trace_file(path: Optional[str] = None) -> List[Violation]:
    path = path or os.environ.get(TRACE_ENV)
    if not path or not os.path.exists(path):
        return []
    return check_trace(read_trace(path), where=path)


# ---------------------------------------------------------------------------
# small-model interleaving exploration
# ---------------------------------------------------------------------------


@dataclass
class ExplorationReport:
    n_groups: int
    n_workers: int
    n_scenarios: int = 0
    n_interleavings: int = 0
    mismatches: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.mismatches

    def violations(self) -> List[Violation]:
        return [Violation("RCCA205", "model", 0, m) for m in self.mismatches]


def _interleavings(seqs: List[List[int]]):
    """Every merge of the (order-preserving) per-worker sequences."""
    seqs = [s for s in seqs if s]
    if not seqs:
        yield []
        return
    for i in range(len(seqs)):
        head, rest = seqs[i][0], [list(s) for s in seqs]
        rest[i] = rest[i][1:]
        for tail in _interleavings(rest):
            yield [head] + tail


def _group_payload(g: int):
    """Order-sensitive fp32 stats for group ``g``: summands with wildly
    different magnitudes, so ANY deviation from the canonical reduction
    order changes the result bits."""
    import numpy as np

    # [1e8, -1e8, 3, 4]: pairing (1e8 + -1e8) preserves the small terms
    # (canonical tree → 7) while (1e8 + 3) absorbs them (any other
    # pairing → 0) — a one-position reorder flips result bits.
    magnitude = (1e8, -1e8, 3.0, 4.0)[g % 4]
    base = np.asarray(
        [magnitude, 1.0 + g, 1e8 * ((-1) ** g), 0.1 * (g + 1)],
        dtype=np.float32)
    return {"y": base, "n": np.float32(g + 1)}


def explore_interleavings(n_workers: int = 2, n_groups: int = 4, *,
                          mutate: Optional[str] = None) -> ExplorationReport:
    """Exhaustive small-model check of the publish/crash/merge protocol.

    Model: ``n_groups`` merge groups strided over ``n_workers`` workers
    (worker ``w`` owns groups ``g ≡ w (mod n_workers)``, matching the
    cluster's shard assignment); workers publish their groups in
    ascending order.  Scenarios: the fault-free run plus every single
    crash (any worker, after any prefix of its publishes), with the
    dead worker's unpublished groups re-dispatched to a repair worker.
    For each scenario × each interleaving of the surviving publish
    sequences, the coordinator merge is replayed as the streamed
    group-order :class:`~repro.exec.accumulate.SegmentedAccumulator`
    and compared BITWISE against the canonical
    :func:`~repro.exec.accumulate.reduce_group_partials`.

    ``mutate`` injects a protocol bug (for testing the checker):
      ``"arrival_order"`` — coordinator merges in publish order instead
      of group order; ``"torn_publish"`` — the crashed worker's last
      publish lands half-written and is NOT re-dispatched.
    """
    import numpy as np

    from repro.exec.accumulate import (SegmentedAccumulator,
                                       reduce_group_partials)

    if n_groups > 4 or n_workers != 2:
        raise ValueError("small-model explorer: 2 workers, ≤4 groups")

    def init_fn():
        return {"y": np.zeros(4, np.float32), "n": np.float32(0.0)}

    n_chunks = n_groups  # one chunk per group: geometry for the tree
    canonical = reduce_group_partials(
        {g: _group_payload(g) for g in range(n_groups)}, init_fn,
        n_chunks, group_chunks=1)

    owners = {w: [g for g in range(n_groups) if g % n_workers == w]
              for w in range(n_workers)}
    report = ExplorationReport(n_groups=n_groups, n_workers=n_workers)

    # scenario = (crashed worker or None, #publishes before the crash)
    scenarios = [(None, 0)]
    for w in range(n_workers):
        for k in range(len(owners[w])):
            scenarios.append((w, k))

    for crashed, k in scenarios:
        report.n_scenarios += 1
        pub: Dict[int, List[int]] = {w: list(owners[w])
                                     for w in range(n_workers)}
        redispatch: List[int] = []
        torn: Optional[int] = None
        if crashed is not None:
            alive = pub[crashed][:k]
            lost = pub[crashed][k:]
            if mutate == "torn_publish" and lost:
                # the crash tears the NEXT publish: it lands on disk
                # half-written and nobody re-dispatches it
                torn = lost[0]
                alive = alive + [torn]
                lost = lost[1:]
            pub[crashed] = alive
            redispatch = lost
        # repair worker appends the re-dispatched groups, in order
        seqs = [pub[w] for w in range(n_workers)] + \
               ([redispatch] if redispatch else [])

        for order in _interleavings([list(s) for s in seqs]):
            report.n_interleavings += 1
            disk = {}
            for g in order:  # last-write-wins publish serialization
                payload = _group_payload(g)
                if g == torn:
                    payload = {"y": payload["y"].copy(), "n": payload["n"]}
                    payload["y"][2:] = 0.0  # half-written partial
                disk[g] = payload
            merge_order = (sorted(disk) if mutate != "arrival_order"
                           else list(dict.fromkeys(order)))
            acc = SegmentedAccumulator(init_fn, n_chunks, group_chunks=1)
            try:
                for pos, g in enumerate(merge_order):
                    if mutate == "arrival_order":
                        # model the buggy coordinator faithfully: feed the
                        # tree by arrival position, not group id
                        acc.push_group(pos, disk[g])  # rcca: noqa[RCCA001] — the model checker replays (buggy) coordinators by design
                    else:
                        acc.push_group(g, disk[g])  # rcca: noqa[RCCA001] — model replay of the real coordinator merge
                got = acc.result()
            except ValueError as e:
                report.mismatches.append(
                    f"scenario crash={crashed}@{k} order={order}: "
                    f"merge rejected: {e}")
                continue
            same = all(
                np.asarray(got[f]).tobytes()
                == np.asarray(canonical[f]).tobytes()
                for f in ("y", "n"))
            if not same:
                report.mismatches.append(
                    f"scenario crash={crashed}@{k} order={order}: merged "
                    "result differs bitwise from the canonical pairwise "
                    "tree")
    return report
