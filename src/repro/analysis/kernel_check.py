"""Static contract checker for the registered Pallas kernels (RCCA1xx).

Every production kernel wrapper launches from a declarative
:class:`~repro.kernels.plan.KernelPlan` built by a pure ``plan_*``
function (see :mod:`repro.kernels.plan`); the registry in
``repro.kernels.KERNEL_REGISTRY`` maps each kernel to its plan builder
plus representative probe shapes.  Because the checker verifies the
*same plan object* the wrapper realizes via ``launch_args``, a passing
check is a statement about what actually runs — there is no duplicated
sizing logic to drift.

Checks, per probe (all pure Python + one ``jax.eval_shape`` trace — no
device, no kernel execution):

  RCCA101  grid/block consistency: block shapes tile the padded operand
           shapes exactly (every padded dim divisible by its block dim),
           ranks agree, grid dims positive.
  RCCA102  index-map validity: every grid position maps each operand to
           an in-range block coordinate (no OOB tile).
  RCCA103  output coverage: walking the full grid visits EVERY tile of
           every output — an uncovered tile is garbage VMEM contents
           silently published to HBM.
  RCCA104  VMEM residency: every block and scratch buffer fits the
           shared per-buffer budget
           (:data:`repro.kernels.matmul.VMEM_BLOCK_ELEMS`).
  RCCA105  dtype rules: scratch accumulators and declared accumulator
           outputs are f32; bf16 inputs never accumulate in bf16.
  RCCA106  abstract-eval agreement: ``jax.eval_shape`` of the live
           wrapper matches the plan's logical output shapes/dtypes.
  RCCA107  autotune-cache validity: every persisted cache entry parses,
           its shape key names padded (×128) dims, and re-planning the
           shape under the entry's block caps yields a plan that passes
           RCCA101–105 — a hand-edited or stale cache cannot smuggle an
           inconsistent launch into production.  Schedule entries
           (``powerpass-staged`` / ``projgram-staged``) must carry a
           ``"staged"|"recompute"`` value and both schedules' plans at
           that shape must still re-plan cleanly.
  RCCA108  PRNG-bearing plans: a ``*_seeded`` kernel draws its Ω tiles
           from a counter-based PRNG, so its ONLY source of randomness
           must be the seed plumbed as an SMEM scalar operand — exactly
           one scalar, integer dtype, a handful of words (a seed, never
           a data array smuggled around the blocked specs).
"""

from __future__ import annotations

import itertools
from typing import List, Optional

from .report import Violation


def _probe_tag(name: str, probe: dict) -> str:
    dims = "x".join(str(v) for k, v in probe.items() if k != "dtype")
    return f"{name}[{dims}|{probe.get('dtype', '?')}]"


def check_plan(plan, *, where: str = "", budget: Optional[int] = None) -> List[Violation]:
    """RCCA101–105 on one :class:`~repro.kernels.plan.KernelPlan`."""
    from repro.kernels.matmul import VMEM_BLOCK_ELEMS

    budget = VMEM_BLOCK_ELEMS if budget is None else budget
    where = where or plan.name
    out: List[Violation] = []

    def v(code: str, msg: str) -> None:
        out.append(Violation(code, where, 0, msg))

    # -- RCCA101: grid & tiling consistency -------------------------------
    if not plan.grid or any(g <= 0 for g in plan.grid):
        v("RCCA101", f"empty/non-positive grid {plan.grid}")
        return out
    specs = [("in", i, b) for i, b in enumerate(plan.in_specs)] + \
            [("out", i, b) for i, b in enumerate(plan.out_specs)]
    tiles = {}
    for kind, i, b in specs:
        tag = f"{kind}_specs[{i}]"
        if len(b.shape) != len(b.padded):
            v("RCCA101", f"{tag}: block rank {len(b.shape)} != padded rank "
              f"{len(b.padded)}")
            continue
        bad = [d for d in range(len(b.shape))
               if b.shape[d] <= 0 or b.padded[d] % b.shape[d] != 0]
        if bad:
            v("RCCA101", f"{tag}: block {b.shape} does not tile padded "
              f"{b.padded} (dims {bad})")
            continue
        tiles[(kind, i)] = tuple(p // s for p, s in zip(b.padded, b.shape))
    if len(plan.out_shape) != len(plan.out_specs):
        v("RCCA101", f"{len(plan.out_shape)} logical out shapes for "
          f"{len(plan.out_specs)} out specs")
    for i, (logical, b) in enumerate(zip(plan.out_shape, plan.out_specs)):
        if len(logical) == len(b.padded) and \
                any(lo > p for lo, p in zip(logical, b.padded)):
            v("RCCA101", f"out_specs[{i}]: logical shape {logical} exceeds "
              f"padded {b.padded}")

    # -- RCCA102 + RCCA103: walk the full grid ----------------------------
    coverage = {i: set() for i in range(len(plan.out_specs))}
    for idx in itertools.product(*(range(g) for g in plan.grid)):
        for kind, i, b in specs:
            if (kind, i) not in tiles:
                continue  # tiling already broken; skip the walk for it
            try:
                coord = tuple(b.index_map(*idx))
            except TypeError:
                v("RCCA102", f"{kind}_specs[{i}]: index map arity does not "
                  f"match grid rank {len(plan.grid)}")
                tiles.pop((kind, i))
                continue
            rng = tiles[(kind, i)]
            if len(coord) != len(rng) or any(
                    not (0 <= c < r) for c, r in zip(coord, rng)):
                v("RCCA102", f"{kind}_specs[{i}]: grid {idx} -> block "
                  f"coord {coord} outside tiling {rng}")
                tiles.pop((kind, i))
                continue
            if kind == "out":
                coverage[i].add(coord)
    for i, b in enumerate(plan.out_specs):
        if ("out", i) not in tiles:
            continue
        want = 1
        for t in tiles[("out", i)]:
            want *= t
        if len(coverage[i]) != want:
            v("RCCA103", f"out_specs[{i}]: grid visits {len(coverage[i])} of "
              f"{want} output tiles — uncovered tiles publish garbage")

    # -- RCCA104: VMEM budget ---------------------------------------------
    for kind, i, b in specs:
        if b.elems > budget:
            v("RCCA104", f"{kind}_specs[{i}]: block {b.shape} = {b.elems} "
              f"elems exceeds VMEM budget {budget}")
    for i, s in enumerate(plan.scratch):
        if s.elems > budget:
            v("RCCA104", f"scratch[{i}]: {s.shape} = {s.elems} elems "
              f"exceeds VMEM budget {budget}")

    # -- RCCA105: dtype rules ---------------------------------------------
    for i, s in enumerate(plan.scratch):
        if s.dtype != "float32":
            v("RCCA105", f"scratch[{i}]: accumulator dtype {s.dtype} != "
              "float32")
    for i in plan.accum_outputs:
        if i >= len(plan.out_specs):
            v("RCCA105", f"accum_outputs names out_specs[{i}] which does "
              "not exist")
        elif plan.out_specs[i].dtype != "float32":
            v("RCCA105", f"out_specs[{i}]: declared accumulator output has "
              f"dtype {plan.out_specs[i].dtype} != float32")
    if any(b.dtype == "bfloat16" for b in plan.in_specs) \
            and not plan.accum_outputs \
            and any(b.dtype == "bfloat16" for b in plan.out_specs):
        v("RCCA105", "bf16 inputs with bf16 outputs and no declared f32 "
          "accumulator output — bf16 accumulation loses the contract")

    # -- RCCA108: PRNG-bearing plans — the seed is the only entropy -------
    if plan.name.endswith("_seeded") and len(plan.scalars) != 1:
        v("RCCA108", f"seeded kernel declares {len(plan.scalars)} scalar "
          "operands — the counter-based PRNG contract is exactly one "
          "SMEM seed")
    for i, s in enumerate(plan.scalars):
        if s.dtype not in ("uint32", "int32", "uint64", "int64"):
            v("RCCA108", f"scalars[{i}]: dtype {s.dtype} — scalar operands "
              "are integer seeds/sizes")
        if s.elems > 8:
            v("RCCA108", f"scalars[{i}]: {s.shape} = {s.elems} elems — a "
              "scalar operand is a seed, not a data array routed around "
              "the blocked specs")
    return out


def check_kernel(kdef, *, abstract: bool = True) -> List[Violation]:
    """All probes of one registered kernel, plus the abstract-eval
    cross-check (RCCA106) of the live wrapper against the plan."""
    out: List[Violation] = []
    for probe in kdef.probes:
        where = _probe_tag(kdef.name, probe)
        try:
            plan = kdef.plan(dict(probe))
        except Exception as e:  # noqa: BLE001 — any plan crash is a finding
            out.append(Violation("RCCA101", where, 0,
                                 f"plan builder raised: {e!r}"))
            continue
        if plan is None:
            continue  # documented unfused-fallback shape
        out.extend(check_plan(plan, where=where))
        if not abstract:
            continue
        try:
            import jax

            fn, arg_structs = kdef.abstract(dict(probe))
            res = jax.eval_shape(fn, *arg_structs)
        except Exception as e:  # noqa: BLE001
            out.append(Violation("RCCA106", where, 0,
                                 f"abstract eval raised: {e!r}"))
            continue
        got = [res] if not isinstance(res, (tuple, list)) else list(res)
        if len(got) != len(plan.out_shape):
            out.append(Violation(
                "RCCA106", where, 0,
                f"wrapper returns {len(got)} outputs, plan declares "
                f"{len(plan.out_shape)}"))
            continue
        for i, (g, want) in enumerate(zip(got, plan.out_shape)):
            if tuple(g.shape) != tuple(want):
                out.append(Violation(
                    "RCCA106", where, 0,
                    f"output[{i}]: wrapper abstract shape {tuple(g.shape)} "
                    f"!= plan logical shape {tuple(want)}"))
    return out


# ---------------------------------------------------------------------------
# autotune-cache validation (RCCA107)
# ---------------------------------------------------------------------------


def _plan_from_cache_entry(op: str, dims: List[int], dtype: str, blocks):
    from repro.kernels.matmul import plan_matmul
    from repro.kernels.powerpass import plan_powerpass
    from repro.kernels.projgram import plan_projgram

    b0, b1, b2 = (int(b) for b in blocks)
    if op in ("matmul_nn", "matmul_tn"):
        M, K, N = dims
        return plan_matmul(M, K, N, dtype, transpose_lhs=(op == "matmul_tn"),
                           block_m=b0, block_n=b1, block_k=b2)
    if op == "powerpass":
        n, db, kt, da = dims
        return plan_powerpass(n, da, db, kt, dtype,
                              block_n=b0, block_db=b1, block_da=b2)
    if op == "projgram":
        n, d, kt = dims
        return plan_projgram(n, d, kt, dtype,
                             block_n=b0, block_d=b1, block_c=b2)
    return None


def _plans_from_schedule_entry(op: str, dims: List[int], dtype: str):
    """Every KernelPlan either schedule of a staged-vs-recompute cache
    entry would launch at this shape — the recompute base plus the
    stage/sweep pair — skipping schedules the planners decline."""
    from repro.kernels.powerpass import plan_powerpass, plan_powerpass_staged
    from repro.kernels.projgram import plan_projgram, plan_projgram_staged

    plans = []
    if op == "powerpass-staged":
        n, db, kt, da = dims
        plans.append(plan_powerpass(n, da, db, kt, dtype))
        staged = plan_powerpass_staged(n, da, db, kt, dtype)
        if staged is not None:
            plans.extend(staged)
    elif op == "projgram-staged":
        n, d, kt = dims
        plans.append(plan_projgram(n, d, kt, dtype))
        staged = plan_projgram_staged(n, d, kt, dtype)
        if staged is not None:
            plans.extend(staged)
    return [p for p in plans if p is not None]


def check_autotune_cache(path: Optional[str] = None) -> List[Violation]:
    """RCCA107 over every entry of the persisted autotune cache: shape
    keys must parse to padded dims, blocks must be usable caps, and the
    re-planned launch under those caps must itself pass RCCA101–105.
    A missing cache is clean (autotuning is optional by design)."""
    import json
    import os

    from repro.kernels import autotune

    path = path or autotune.cache_path()
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            cache = json.load(f)
    except (OSError, ValueError) as e:
        return [Violation("RCCA107", path, 0, f"unreadable cache: {e}")]
    if not isinstance(cache, dict):
        return [Violation("RCCA107", path, 0, "cache root is not an object")]

    known_ops = ("matmul_nn", "matmul_tn", "powerpass", "projgram",
                 "powerpass-staged", "projgram-staged")
    ndims = {"matmul_nn": 3, "matmul_tn": 3, "powerpass": 4, "projgram": 3,
             "powerpass-staged": 4, "projgram-staged": 3}
    schedule_ops = ("powerpass-staged", "projgram-staged")
    out: List[Violation] = []
    for key, ent in sorted(cache.items()):
        where = f"{path}[{key}]"
        parts = key.split("|")
        if len(parts) != 4:
            out.append(Violation("RCCA107", where, 0,
                                 "shape key is not backend|op|dtype|dims"))
            continue
        _backend, op, dtype, dim_s = parts
        if op not in known_ops:
            out.append(Violation("RCCA107", where, 0,
                                 f"unknown op {op!r} in shape key"))
            continue
        try:
            dims = [int(d) for d in dim_s.split("x")]
        except ValueError:
            out.append(Violation("RCCA107", where, 0,
                                 f"unparsable dims {dim_s!r}"))
            continue
        if len(dims) != ndims[op]:
            out.append(Violation("RCCA107", where, 0,
                                 f"{op} key carries {len(dims)} dims, "
                                 f"expected {ndims[op]}"))
            continue
        if any(d <= 0 or d % 128 for d in dims):
            out.append(Violation("RCCA107", where, 0,
                                 f"dims {dims} not padded to x128 — keys "
                                 "must name the padded problem"))
            continue
        if op in schedule_ops:
            # schedule entries record a measured staged-vs-recompute
            # winner, not block caps — validate the value and that both
            # schedules still re-plan to launches passing RCCA101–105
            sched = ent.get("schedule") if isinstance(ent, dict) else None
            if sched not in ("staged", "recompute"):
                out.append(Violation("RCCA107", where, 0,
                                     f"schedule entry value {sched!r} not "
                                     "'staged'|'recompute'"))
                continue
            for plan in _plans_from_schedule_entry(op, dims, dtype):
                for v in check_plan(plan, where=where):
                    out.append(Violation("RCCA107", v.path, v.line,
                                         f"schedule entry re-plan invalid: "
                                         f"[{v.code}] {v.message}"))
            continue
        blocks = ent.get("blocks") if isinstance(ent, dict) else None
        try:
            blocks = [int(b) for b in blocks]
            assert len(blocks) == 3 and all(b > 0 for b in blocks)
        except (TypeError, ValueError, AssertionError):
            out.append(Violation("RCCA107", where, 0,
                                 f"entry blocks {blocks!r} not three "
                                 "positive ints"))
            continue
        try:
            plan = _plan_from_cache_entry(op, dims, dtype, blocks)
        except Exception as e:  # noqa: BLE001
            out.append(Violation("RCCA107", where, 0,
                                 f"re-planning under cached blocks raised: "
                                 f"{e!r}"))
            continue
        if plan is not None:
            for v in check_plan(plan, where=where):
                out.append(Violation("RCCA107", v.path, v.line,
                                     f"cached blocks yield invalid plan: "
                                     f"[{v.code}] {v.message}"))
    return out


def check_registry(registry=None, *, abstract: bool = True,
                   cache: bool = True) -> List[Violation]:
    """The full kernel gate: every registered kernel's probes (RCCA101–
    106) plus the persisted autotune cache (RCCA107)."""
    if registry is None:
        from repro.kernels import KERNEL_REGISTRY as registry
    out: List[Violation] = []
    for name in sorted(registry):
        out.extend(check_kernel(registry[name], abstract=abstract))
    if cache:
        out.extend(check_autotune_cache())
    return out
