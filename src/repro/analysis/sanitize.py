"""Determinism sanitizer (RCCA301): merge-boundary fingerprints.

``RCCA_SANITIZE=1`` turns on a lightweight runtime recorder: the
canonical accumulator (:mod:`repro.exec.accumulate`) fingerprints every
merge-group sum at its boundary — the exact points the bitwise contract
quantifies over — and the pass engines mark pass start/end, so a run
leaves an ordered trace of ``(label, sha256-of-leaf-bytes)`` records in
its diagnostics (``diagnostics["sanitize"]``) and, when
``RCCA_SANITIZE_OUT`` names a file, as a JSON dump on disk.

Two runs that claim bit-identity must produce IDENTICAL traces;
:func:`first_divergence` compares them and names the first divergent
merge boundary — turning "the final correlations differ in ulp 3"
into "pass 2, merge group 17 already differs", which is the difference
between a day of bisection and a glance.

This module is a LEAF: nothing here imports repro (the accumulator
imports us), and jax/numpy load lazily inside :func:`observe` so the
disabled path costs one env lookup.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import List, Optional

_trace: List[dict] = []
_context: dict = {}


def enabled() -> bool:
    return os.environ.get("RCCA_SANITIZE") == "1"


def reset() -> None:
    """Start a fresh trace (runs own their traces; drivers call this at
    fit start)."""
    _trace.clear()
    _context.clear()


def set_context(**kv) -> None:
    """Attach ambient labels (pass index, kind, topology) to subsequent
    observations; ``None`` removes a key."""
    for k, v in kv.items():
        if v is None:
            _context.pop(k, None)
        else:
            _context[k] = v


def observe(label: str, tree) -> None:
    """Fingerprint one accumulator pytree at a merge boundary.  The
    digest covers every leaf's shape, dtype and exact bytes — two
    observations agree iff the accumulator states are bit-identical."""
    if not enabled():
        return
    import jax
    import numpy as np

    h = hashlib.sha256()
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    for keypath, leaf in leaves:  # canonical pytree order — deterministic
        arr = np.asarray(leaf)
        h.update(jax.tree_util.keystr(keypath).encode())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    full = dict(_context)
    full["label"] = label
    full["digest"] = h.hexdigest()
    _trace.append(full)


def snapshot() -> List[dict]:
    """The trace so far (copy — safe to stash in diagnostics)."""
    return [dict(r) for r in _trace]


def dump(path: Optional[str] = None) -> Optional[str]:
    """Write the trace as JSON to ``path`` (default:
    ``$RCCA_SANITIZE_OUT``); returns the path written, or None."""
    path = path or os.environ.get("RCCA_SANITIZE_OUT")
    if not path or not _trace:
        return None
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(_trace, f, indent=1)
    os.replace(tmp, path)
    return path


def load(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)


def _key(rec: dict) -> tuple:
    return tuple(sorted((k, v) for k, v in rec.items() if k != "digest"))


def first_divergence(a: List[dict], b: List[dict]) -> Optional[dict]:
    """First merge boundary where two traces disagree, or None when
    they are identical.  Returns a dict naming the index, the boundary
    label(s) and both digests — the bisection starting point."""
    for i, (ra, rb) in enumerate(zip(a, b)):
        if _key(ra) != _key(rb):
            return {"code": "RCCA301", "index": i, "reason": "label",
                    "a": ra, "b": rb}
        if ra.get("digest") != rb.get("digest"):
            return {"code": "RCCA301", "index": i, "reason": "digest",
                    "a": ra, "b": rb}
    if len(a) != len(b):
        i = min(len(a), len(b))
        return {"code": "RCCA301", "index": i, "reason": "length",
                "a": a[i] if i < len(a) else None,
                "b": b[i] if i < len(b) else None}
    return None
