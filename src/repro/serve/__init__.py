"""Model serving: versioned CCA artifacts, batched projection, drift.

The serving half of the ROADMAP's "millions of users" story, layered on
the incremental-fit path (:mod:`repro.exec.delta`):

- :class:`ModelRegistry` — versioned, atomically-published, content-
  hashed model artifacts (:mod:`repro.serve.registry`);
- :class:`BatchedProjector` — coalesces concurrent projection requests
  into padded device batches, with zero-drop hot-swap between batches
  (:mod:`repro.serve.projector`);
- :class:`CorpusIndex` — cross-view top-k retrieval against an indexed
  corpus of projected rows;
- :class:`DriftMonitor` — canonical-correlation decay on held-out
  traffic emits the refit-needed signal that feeds
  :func:`repro.exec.delta_refit` (:mod:`repro.serve.drift`).

``python -m repro.launch.cca_serve`` drives the full loop.
"""

from .drift import DriftMonitor
from .projector import BatchedProjector, CorpusIndex
from .registry import ModelRegistry, ServedModel

__all__ = [
    "BatchedProjector",
    "CorpusIndex",
    "DriftMonitor",
    "ModelRegistry",
    "ServedModel",
]
