"""Batched projection serving: request coalescing + zero-drop hot-swap.

:class:`BatchedProjector` is the traffic front-end: concurrent callers
submit single rows (or small row blocks) of either view; a daemon batch
thread coalesces whatever is queued into one padded device batch per
view, projects it (x ↦ Φᵃx / Φᵇx), and completes each request with its
embedding stamped with the model version that computed it.

Hot-swap contract: ``swap(new_model)`` takes effect at the next batch
boundary.  The in-flight batch completes under the old version; every
queued and future request is served by the new one; no request is ever
dropped or served by a half-installed model, because the batch thread
reads the model exactly once per batch under the queue lock.  The
version stamp on every response is what makes this testable: a response
claiming version v must equal ``x @ Xa(v)`` bitwise.

Padding: a batch of r requests is padded to the next power of two (≤
``max_batch``), so the jitted projection sees a handful of shapes
instead of one per occupancy — the standard serving trade of a few
wasted pad rows for a warm compile cache.

:class:`CorpusIndex` holds one view's projected corpus for cross-view
top-k retrieval: score(query, row) = Σ_k ρ_k·φ_k(query)·φ_k(row), the
correlation-weighted inner product in canonical space.

Everything traces through :mod:`repro.obs`: a ``serve_batch`` span per
batch (occupancy + version), ``serve_occupancy`` counters, and a
``serve_swap`` counter per version flip.
"""

from __future__ import annotations

import functools
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .registry import ServedModel


@functools.lru_cache(maxsize=64)
def _project_jit(dim: int, k: int, bucket: int):
    """One compiled projection per (input dim, k, padded batch) shape."""
    return jax.jit(lambda X, x: x @ X)


def _bucket(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, max(cap, n))


class _Ticket:
    """One in-flight request; completed by the batch thread."""

    __slots__ = ("view", "x", "_event", "emb", "version", "error")

    def __init__(self, view: str, x: np.ndarray):
        self.view = view
        self.x = x
        self._event = threading.Event()
        self.emb: Optional[np.ndarray] = None
        self.version: Optional[int] = None
        self.error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        """Block for the response: ``{"emb": (k,), "version": int}``."""
        if not self._event.wait(timeout):
            raise TimeoutError("projection request timed out")
        if self.error is not None:
            raise self.error
        return {"emb": self.emb, "version": self.version}


class BatchedProjector:
    """Coalesce concurrent projection requests into padded device
    batches, with hot-swap between batches (module docstring)."""

    def __init__(self, model: ServedModel, *, max_batch: int = 64,
                 max_wait_s: float = 0.002):
        if max_batch < 1:
            raise ValueError("max_batch must be positive")
        self._model = model
        self._pending_model: Optional[ServedModel] = None
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._cond = threading.Condition()
        self._queue: deque[_Ticket] = deque()
        self._stop = False
        self.batches = 0
        self.requests = 0
        self.swaps = 0
        self._occupancy_sum = 0
        self._thread = threading.Thread(
            target=self._loop, name="rcca-serve-batch", daemon=True)
        self._thread.start()

    # -- client side ------------------------------------------------------

    def submit(self, view: str, x) -> _Ticket:
        """Queue one row of ``view`` ("a" or "b") for projection;
        returns a ticket whose ``result()`` blocks for the response."""
        if view not in ("a", "b"):
            raise ValueError(f"view must be 'a' or 'b', got {view!r}")
        x = np.asarray(x, dtype=np.float32).reshape(-1)
        want = self._model.Xa.shape[0] if view == "a" \
            else self._model.Xb.shape[0]
        if x.shape[0] != want:
            raise ValueError(
                f"view {view} rows have {want} features, got {x.shape[0]}")
        t = _Ticket(view, x)
        with self._cond:
            if self._stop:
                raise RuntimeError("projector is shut down")
            self._queue.append(t)
            self._cond.notify_all()
        return t

    def project_a(self, x, timeout: Optional[float] = 30.0) -> Dict[str, Any]:
        return self.submit("a", x).result(timeout)

    def project_b(self, x, timeout: Optional[float] = 30.0) -> Dict[str, Any]:
        return self.submit("b", x).result(timeout)

    def swap(self, model: ServedModel) -> None:
        """Install ``model`` at the next batch boundary — the in-flight
        batch finishes on the old version; nothing is dropped."""
        with self._cond:
            self._pending_model = model
            self._cond.notify_all()

    @property
    def model(self) -> ServedModel:
        with self._cond:
            return self._pending_model or self._model

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            return {
                "batches": self.batches, "requests": self.requests,
                "swaps": self.swaps,
                "mean_occupancy": (self._occupancy_sum / self.batches
                                   if self.batches else 0.0),
            }

    def close(self, timeout: float = 30.0) -> None:
        """Drain the queue, then stop the batch thread."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join(timeout)

    def __enter__(self) -> "BatchedProjector":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- batch thread -----------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait(0.05)
                if not self._queue:
                    if self._stop:
                        return
                    continue
                if not self._stop and len(self._queue) < self.max_batch \
                        and self.max_wait_s > 0:
                    # brief coalescing window once traffic has started
                    deadline = obs.monotonic() + self.max_wait_s
                    while len(self._queue) < self.max_batch:
                        left = deadline - obs.monotonic()
                        if left <= 0 or self._stop:
                            break
                        self._cond.wait(left)
                if self._pending_model is not None:  # batch boundary
                    self._model = self._pending_model
                    self._pending_model = None
                    self.swaps += 1
                    obs.counter("serve_swap", version=self._model.version)
                batch = [self._queue.popleft()
                         for _ in range(min(len(self._queue),
                                            self.max_batch))]
                model = self._model
                self.batches += 1
                self.requests += len(batch)
                self._occupancy_sum += len(batch)
            self._run_batch(model, batch)

    def _run_batch(self, model: ServedModel, batch: List[_Ticket]) -> None:
        with obs.span("serve_batch", occupancy=len(batch),
                      version=model.version):
            for view in ("a", "b"):
                group = [t for t in batch if t.view == view]
                if not group:
                    continue
                X = model.Xa if view == "a" else model.Xb
                try:
                    rows = np.stack([t.x for t in group])
                    b = _bucket(len(group), self.max_batch)
                    if b > len(group):  # pad to the shape bucket
                        rows = np.concatenate(
                            [rows, np.zeros((b - len(group), rows.shape[1]),
                                            rows.dtype)])
                    fn = _project_jit(X.shape[0], X.shape[1], b)
                    emb = np.asarray(fn(X.astype(jnp.float32), rows))
                    for i, t in enumerate(group):
                        t.emb = emb[i]
                        t.version = model.version
                        t._event.set()
                except BaseException as e:  # complete, never strand
                    for t in group:
                        if not t.done():
                            t.error = e
                            t._event.set()
            obs.counter("serve_occupancy", occupancy=len(batch),
                        max_batch=self.max_batch, version=model.version)


class CorpusIndex:
    """One view's projected corpus, indexed for cross-view top-k.

    Rows are projected once at build time (chunk-streamed from a view
    store — the corpus never materializes beyond its embeddings);
    ``topk`` scores a query embedding from the *other* view with the
    correlation-weighted inner product and returns the best rows.
    """

    def __init__(self, model: ServedModel, view: str, emb: np.ndarray):
        if view not in ("a", "b"):
            raise ValueError(f"view must be 'a' or 'b', got {view!r}")
        self.model = model
        self.view = view
        self.emb = np.asarray(emb, dtype=np.float32)  # (n, k)
        if self.emb.ndim != 2 or self.emb.shape[1] != model.k:
            raise ValueError(
                f"embeddings must be (n, k={model.k}), got {self.emb.shape}")

    @classmethod
    def from_store(cls, model: ServedModel, store, view: str = "b",
                   *, max_rows: Optional[int] = None) -> "CorpusIndex":
        """Project one view of a store chunk-by-chunk into an index."""
        from repro.store import ViewStoreReader

        reader = store if isinstance(store, ViewStoreReader) \
            else ViewStoreReader(store)
        X = model.Xa if view == "a" else model.Xb
        parts, rows = [], 0
        with obs.span("index_build", view=view, n=reader.n):
            for a, b in reader.iter_chunks():
                block = a if view == "a" else b
                parts.append(np.asarray(
                    jnp.asarray(block, dtype=jnp.float32) @ X))
                rows += block.shape[0]
                if max_rows is not None and rows >= max_rows:
                    break
        emb = np.concatenate(parts)
        return cls(model, view, emb if max_rows is None else emb[:max_rows])

    def topk(self, query_emb, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k corpus rows for a query embedding from the other view:
        returns ``(indices, scores)``, scores descending."""
        q = np.asarray(query_emb, dtype=np.float32).reshape(-1)
        weighted = q * np.asarray(self.model.rho, dtype=np.float32)
        scores = self.emb @ weighted
        k = min(k, scores.shape[0])
        idx = np.argpartition(-scores, k - 1)[:k]
        order = np.argsort(-scores[idx], kind="stable")
        idx = idx[order]
        return idx, scores[idx]
