"""Versioned CCA model registry: atomic publish, content hashes.

Registry layout (same staging+rename discipline as ``repro.store`` —
a reader can never observe a torn artifact)::

    registry/
      <name>/
        v00001/                # one save_pytree dir per version
          manifest.json        #   Xa/Xb/rho/Qa/Qb leaves + metadata
          Xa.npy ...
        v00002/
        current.json           # atomically-replaced version pointer

Each version directory is written by ``repro.ckpt.save_pytree`` (tmp +
rename) and is immutable once published; ``current.json`` is the only
mutable file and flips via ``os.replace``.  Version metadata carries a
content hash (sha256 over the projection leaves), the store
fingerprint + algo binding inherited from the fit, and the parent
version — the provenance chain a drift investigation walks.

``prune(name, keep=N)`` is the garbage collector: it removes old
versions while never touching the current version, its recorded parent
(the rollback target), or the newest N — and deletes via
rename-then-rmtree so a concurrent reader can never open a half-deleted
artifact.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ckpt import load_flat, load_metadata, save_pytree

_LEAVES = ("Xa", "Xb", "rho", "Qa", "Qb")
_VDIR_RE = re.compile(r"^v(\d{5})$")


def _content_hash(arrays: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for name in _LEAVES:
        arr = np.ascontiguousarray(np.asarray(arrays[name]))
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class ServedModel:
    """One immutable published model version, loaded for serving."""

    name: str
    version: int
    Xa: jnp.ndarray  # (da, k) view-A projection
    Xb: jnp.ndarray  # (db, k)
    rho: jnp.ndarray  # (k,) canonical correlations
    Qa: jnp.ndarray
    Qb: jnp.ndarray
    meta: Dict[str, Any]

    @property
    def k(self) -> int:
        return int(self.Xa.shape[1])

    def project_a(self, x) -> jnp.ndarray:
        """x ↦ Φᵃx: rows of view A into the canonical space."""
        return jnp.asarray(x) @ self.Xa

    def project_b(self, x) -> jnp.ndarray:
        return jnp.asarray(x) @ self.Xb

    def score(self, ea, eb) -> jnp.ndarray:
        """Correlation score of paired embeddings: Σ_k ρ_k·φᵃ_k·φᵇ_k
        (rows of ``ea``/``eb`` are already-projected pairs)."""
        return jnp.sum(jnp.asarray(ea) * jnp.asarray(eb) * self.rho, axis=-1)


class ModelRegistry:
    """Versioned model artifacts with atomic publish + flip.

    ``publish`` writes the next version directory (atomic via
    save_pytree's staging rename), then flips ``current.json`` with
    ``os.replace`` — readers either see the old current or the new one,
    never a half-published artifact.  Versions are immutable; rollback
    is ``set_current(name, older_version)``.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # -- paths ------------------------------------------------------------

    def _model_dir(self, name: str) -> str:
        if not name or "/" in name or name.startswith("."):
            raise ValueError(f"bad model name {name!r}")
        return os.path.join(self.root, name)

    def _version_dir(self, name: str, version: int) -> str:
        return os.path.join(self._model_dir(name), f"v{version:05d}")

    # -- enumeration ------------------------------------------------------

    def models(self) -> List[str]:
        return sorted(d for d in os.listdir(self.root)
                      if os.path.isdir(os.path.join(self.root, d)))

    def versions(self, name: str) -> List[int]:
        d = self._model_dir(name)
        if not os.path.isdir(d):
            return []
        out = []
        for entry in os.listdir(d):
            m = _VDIR_RE.match(entry)
            if m and os.path.exists(os.path.join(d, entry, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def current_version(self, name: str) -> Optional[int]:
        path = os.path.join(self._model_dir(name), "current.json")
        try:
            with open(path) as f:
                return int(json.load(f)["version"])
        except (FileNotFoundError, KeyError, ValueError):
            return None

    # -- publish ----------------------------------------------------------

    def publish(self, name: str, result, *, fit_meta: Optional[dict] = None,
                parent: Optional[int] = None,
                make_current: bool = True) -> int:
        """Publish an ``RCCAResult`` (or anything with Xa/Xb/rho/Qa/Qb
        attributes) as the next version of ``name``; returns it.

        ``fit_meta`` is the binding/provenance to record (a FitState's
        ``meta`` — store fingerprint, algo, engine); ``parent`` the
        version this one refitted from (defaults to the current one).
        """
        arrays = {leaf: np.asarray(jax.device_get(getattr(result, leaf)))
                  for leaf in _LEAVES}
        versions = self.versions(name)
        version = (versions[-1] + 1) if versions else 1
        if parent is None:
            parent = self.current_version(name)
        meta = {
            "name": name, "version": version, "parent": parent,
            "content_sha256": _content_hash(arrays),
            "k": int(arrays["Xa"].shape[1]),
            "da": int(arrays["Xa"].shape[0]),
            "db": int(arrays["Xb"].shape[0]),
        }
        if fit_meta:
            meta["fit"] = {k: v for k, v in fit_meta.items()
                           if k in ("engine", "omega", "merge_group",
                                    "algo", "fingerprint", "n")}
        vdir = self._version_dir(name, version)
        os.makedirs(self._model_dir(name), exist_ok=True)
        save_pytree(arrays, vdir, metadata=meta)  # atomic (tmp + rename)
        obs.counter("registry_publish", model=name, version=version)
        if make_current:
            self.set_current(name, version)
        return version

    def set_current(self, name: str, version: int) -> None:
        """Atomically flip the served-version pointer."""
        if version not in self.versions(name):
            raise ValueError(f"{name!r} has no published version {version}")
        d = self._model_dir(name)
        tmp = os.path.join(d, f".current.{os.getpid()}.tmp")
        with open(tmp, "w") as f:
            json.dump({"version": version}, f)
        os.replace(tmp, os.path.join(d, "current.json"))

    # -- load -------------------------------------------------------------

    def load(self, name: str, version: Optional[int] = None) -> ServedModel:
        """Load a version (default: current) for serving, verifying the
        content hash — a corrupted artifact fails here, not in traffic."""
        if version is None:
            version = self.current_version(name)
            if version is None:
                versions = self.versions(name)
                if not versions:
                    raise FileNotFoundError(
                        f"no published versions of {name!r} under "
                        f"{self.root!r}")
                version = versions[-1]
        vdir = self._version_dir(name, version)
        flat, meta = load_flat(vdir)
        got = _content_hash(flat)
        if got != meta.get("content_sha256"):
            raise ValueError(
                f"{name} v{version} content hash mismatch: artifact "
                f"corrupted ({got[:12]}… != "
                f"{str(meta.get('content_sha256'))[:12]}…)")
        return ServedModel(
            name=name, version=version,
            Xa=jnp.asarray(flat["Xa"]), Xb=jnp.asarray(flat["Xb"]),
            rho=jnp.asarray(flat["rho"]), Qa=jnp.asarray(flat["Qa"]),
            Qb=jnp.asarray(flat["Qb"]), meta=meta)

    def meta(self, name: str, version: int) -> dict:
        return load_metadata(self._version_dir(name, version))

    # -- garbage collection ----------------------------------------------

    def prune(self, name: str, *, keep: int) -> List[int]:
        """Delete old versions of ``name``, keeping the newest ``keep``
        plus everything a rollback could land on; returns the versions
        removed (ascending).

        Protected, never pruned: the current version, its recorded
        ``parent`` (the rollback target ``set_current`` lands on when a
        swap goes bad), and the newest ``keep`` versions.  Deletion is
        reader-safe: a version directory is first renamed out of the
        registry namespace (atomic, so :meth:`versions` / :meth:`load`
        never see a half-deleted artifact — a concurrent ``load`` either
        opened the manifest before the rename and reads the moved inode,
        or misses the version entirely) and only then removed.
        """
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        versions = self.versions(name)
        protected = set(versions[-keep:])
        cur = self.current_version(name)
        if cur is not None:
            protected.add(cur)
            try:
                parent = self.meta(name, cur).get("parent")
            except (OSError, ValueError):
                parent = None
            if parent is not None:
                protected.add(int(parent))
        pruned: List[int] = []
        d = self._model_dir(name)
        for version in versions:
            if version in protected:
                continue
            vdir = self._version_dir(name, version)
            trash = os.path.join(d, f".trash.v{version:05d}.{os.getpid()}")
            try:
                os.rename(vdir, trash)
            except FileNotFoundError:
                continue  # concurrent prune got it first
            shutil.rmtree(trash, ignore_errors=True)
            pruned.append(version)
        if pruned:
            obs.counter("registry_prune", model=name, n=len(pruned),
                        kept=len(versions) - len(pruned))
        return pruned
