"""Drift monitoring: canonical-correlation decay on held-out traffic.

A served CCA model claims its projections correlate across views: on
paired traffic (xa, xb), the per-component Pearson correlation of
φᵃ(xa) and φᵇ(xb) should track the fitted canonical correlations.
When the traffic distribution moves, that empirical correlation decays
— the cheapest honest health signal a CCA model has, computable from a
small held-out sample with no labels.

:class:`DriftMonitor` keeps a sliding window of paired held-out rows.
The first full window under a model version becomes the baseline;
every subsequent full window's mean top-k correlation is compared
against it, and a relative decay below ``threshold`` emits the
refit-needed signal (a flag + optional callback) that the serving loop
feeds into :func:`repro.exec.delta_refit`.  ``rebind(model)`` after a
hot-swap re-baselines on fresh traffic.

Everything is observable: a ``drift`` counter per evaluated window
(mean correlation, baseline, ratio) and a ``drift_signal`` counter
when the refit signal fires.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Optional, Tuple

import numpy as np

from repro import obs

from .registry import ServedModel


def paired_correlation(model: ServedModel, xa: np.ndarray,
                       xb: np.ndarray) -> np.ndarray:
    """Per-component Pearson correlation of the two views' projections
    over a sample of paired rows — the empirical counterpart of the
    fitted canonical correlations ρ."""
    ea = np.asarray(xa, dtype=np.float32) @ np.asarray(model.Xa, np.float32)
    eb = np.asarray(xb, dtype=np.float32) @ np.asarray(model.Xb, np.float32)
    ea = ea - ea.mean(axis=0)
    eb = eb - eb.mean(axis=0)
    denom = np.sqrt((ea * ea).sum(axis=0) * (eb * eb).sum(axis=0))
    denom = np.where(denom == 0, 1.0, denom)
    return (ea * eb).sum(axis=0) / denom


class DriftMonitor:
    """Sliding-window correlation-decay detector (module docstring).

    ``observe(xa, xb)`` feeds paired held-out rows (single rows or
    blocks); every time the window holds ``window`` rows, the monitor
    evaluates and slides.  ``refit_needed`` latches True once the mean
    correlation falls below ``threshold × baseline``; ``rebind``
    clears it for a refreshed model.
    """

    def __init__(self, model: ServedModel, *, window: int = 256,
                 threshold: float = 0.8, top: Optional[int] = None,
                 on_refit_needed: Optional[Callable[["DriftMonitor"],
                                                    None]] = None):
        if not 0.0 < threshold <= 1.0:
            raise ValueError("threshold is a relative-decay fraction in (0, 1]")
        self.model = model
        self.window = int(window)
        self.threshold = float(threshold)
        self.top = top  # components tracked (default: all k)
        self.on_refit_needed = on_refit_needed
        self.baseline: Optional[float] = None
        self.last_mean: Optional[float] = None
        self.windows_evaluated = 0
        self.refit_needed = False
        self._rows_a: Deque[np.ndarray] = deque()
        self._rows_b: Deque[np.ndarray] = deque()

    # -- traffic ----------------------------------------------------------

    def observe(self, xa, xb) -> Optional[float]:
        """Feed paired held-out rows; returns the window's mean
        correlation when a window completed, else None."""
        xa = np.atleast_2d(np.asarray(xa, dtype=np.float32))
        xb = np.atleast_2d(np.asarray(xb, dtype=np.float32))
        if xa.shape[0] != xb.shape[0]:
            raise ValueError("held-out rows must stay paired")
        for i in range(xa.shape[0]):
            self._rows_a.append(xa[i])
            self._rows_b.append(xb[i])
        if len(self._rows_a) < self.window:
            return None
        return self._evaluate()

    def _evaluate(self) -> float:
        A = np.stack(self._rows_a)
        B = np.stack(self._rows_b)
        self._rows_a.clear()
        self._rows_b.clear()
        corr = paired_correlation(self.model, A, B)
        top = self.top if self.top is not None else corr.shape[0]
        mean = float(np.mean(corr[:top]))
        self.last_mean = mean
        self.windows_evaluated += 1
        if self.baseline is None:
            self.baseline = mean
            obs.counter("drift", version=self.model.version, mean=mean,
                        baseline=mean, ratio=1.0)
            return mean
        ratio = mean / self.baseline if self.baseline > 0 else 1.0
        obs.counter("drift", version=self.model.version, mean=mean,
                    baseline=self.baseline, ratio=ratio)
        if ratio < self.threshold and not self.refit_needed:
            self.refit_needed = True
            obs.counter("drift_signal", version=self.model.version,
                        mean=mean, baseline=self.baseline, ratio=ratio)
            if self.on_refit_needed is not None:
                self.on_refit_needed(self)
        return mean

    # -- lifecycle --------------------------------------------------------

    def rebind(self, model: ServedModel, *, keep_baseline: bool = False):
        """Point the monitor at a refreshed model (post hot-swap): the
        signal clears and — unless ``keep_baseline`` — the next full
        window under the new version re-baselines."""
        self.model = model
        self.refit_needed = False
        self._rows_a.clear()
        self._rows_b.clear()
        if not keep_baseline:
            self.baseline = None

    def status(self) -> dict:
        return {
            "version": self.model.version, "baseline": self.baseline,
            "last_mean": self.last_mean, "refit_needed": self.refit_needed,
            "windows": self.windows_evaluated,
            "buffered": len(self._rows_a),
        }
