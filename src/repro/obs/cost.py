"""KernelPlan-derived flop/byte cost model for the roofline report.

A :class:`~repro.kernels.plan.KernelPlan` is the single source of truth
for launch geometry, so it is also the single source of truth for the
cost model.  HBM traffic follows Pallas's residency rule: a block stays
VMEM-resident while its ``index_map`` value is unchanged between
*consecutive* grid steps (last grid axis innermost), so an operand is
fetched once per run — ``Π grid[:j+1]`` fetches, where ``j`` is the
innermost grid axis its index map depends on, and exactly one fetch for
a grid-invariant map.  Dependence is detected by probing each axis at
its unit vector (the index maps in this codebase are affine in the grid
coordinates), which stays O(axes) at any grid size — including the
Europarl chunk's ~10^8-step grids, far beyond what enumeration could
count.  The same rule charges output blocks one writeback per run.

MXU flops follow the per-kernel formulas documented in the kernel
modules: the recompute schedules' honest ``n_buckets·proj + acc``
accounting, and the staged schedules' bucket-count-independent
``proj`` / ``acc`` split across the ``proj_stage`` /
``powerpass_sweep`` / ``gram_sweep`` plans — which is how the roofline
counters stop charging the recompute once a launch goes staged.

:func:`chunk_cost_fn` is the instrumentation entry point: given the
pass kind and engine it returns a cheap ``(a, b) -> cost`` closure (or
``None`` when tracing is off) that the fold loops attach to their chunk
spans; the underlying per-shape model is cached in
:func:`repro.kernels.ops.chunk_cost`.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.kernels.plan import BlockDef, KernelPlan


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= int(x)
    return n


def _block_runs(block: BlockDef, grid) -> int:
    """Number of HBM fetches (or writebacks) of this operand across one
    launch: one per consecutive run of its index-map value over the
    lexicographic grid walk.  The innermost grid axis the map depends
    on — found by probing unit vectors, valid for the affine maps the
    plans use — bounds the run length: every step of an axis at or
    outside it starts a new run."""
    zero = (0,) * len(grid)
    base = tuple(block.index_map(*zero))
    jmax = -1
    for ax, g in enumerate(grid):
        if g <= 1:
            continue
        probe = list(zero)
        probe[ax] = 1
        if tuple(block.index_map(*probe)) != base:
            jmax = ax
    if jmax < 0:
        return 1
    return _prod(grid[:jmax + 1])


def plan_bytes(plan: KernelPlan) -> int:
    """Modelled HBM traffic of one launch: every input block read once
    per residency run, every output block written once per run, plus
    the SMEM scalars."""
    total = 0
    for block in (*plan.in_specs, *plan.out_specs):
        n_fetches = _block_runs(block, plan.grid)
        total += n_fetches * block.elems * np.dtype(block.dtype).itemsize
    for sc in plan.scalars:
        total += sc.elems * np.dtype(sc.dtype).itemsize
    return total


def plan_flops(plan: KernelPlan) -> int:
    """Modelled MXU flops of one launch, from the plan geometry.

    Seeded variants count the same matmul flops as their materialized
    twins — the in-kernel Ω generation is VPU work the model keeps out
    of the MXU roofline (its effect shows up as the missing Q bytes).
    """
    name = plan.name
    if name in ("matmul_nn", "matmul_tn"):
        mp, np_out = plan.out_specs[0].padded
        kp = plan.in_specs[1].padded[0]
        return 2 * mp * kp * np_out
    if name in ("powerpass", "powerpass_seeded"):
        n_rows, dap = plan.in_specs[0].padded
        dbp = plan.in_specs[1].padded[1]
        ktp = plan.out_specs[0].padded[1]
        # projection P = B Q re-accumulated once per output bucket
        # (grid[0]), plus the single ΔY += AᵀP accumulation
        return plan.grid[0] * 2 * n_rows * dbp * ktp + 2 * n_rows * dap * ktp
    if name in ("projgram", "projgram_seeded"):
        n_rows, dp = plan.in_specs[0].padded
        ktp = plan.out_specs[0].padded[1]
        # P = X Q re-accumulated once per C-column bucket (grid[0]);
        # the gram C = PᵀP is computed bc columns at a time, summing
        # to one full (k̃p, k̃p) product
        return plan.grid[0] * 2 * n_rows * dp * ktp + 2 * n_rows * ktp * ktp
    if name in ("proj_stage", "proj_stage_seeded"):
        # staged phase 1: the projection happens exactly once —
        # no bucket factor, which is the point of the schedule
        n_rows, dp = plan.in_specs[0].padded
        ktp = plan.out_specs[0].padded[1]
        return 2 * n_rows * dp * ktp
    if name == "powerpass_sweep":
        n_rows, dap = plan.in_specs[0].padded
        ktp = plan.out_specs[0].padded[1]
        return 2 * n_rows * dap * ktp
    if name == "gram_sweep":
        n_rows, ktp = plan.in_specs[0].padded
        return 2 * n_rows * ktp * ktp
    raise ValueError(f"no cost formula for kernel plan {name!r}")


def plan_cost(plan: KernelPlan) -> Dict[str, Any]:
    return {"kernel": plan.name, "calls": 1,
            "flops": plan_flops(plan), "bytes": plan_bytes(plan)}


def merge_kernel_costs(parts: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Sum per-kernel cost entries by kernel name (stable order)."""
    out: Dict[str, Dict[str, Any]] = {}
    for p in parts:
        t = out.setdefault(p["kernel"], {"kernel": p["kernel"], "calls": 0,
                                         "flops": 0, "bytes": 0})
        t["calls"] += p.get("calls", 1)
        t["flops"] += p["flops"]
        t["bytes"] += p["bytes"]
    return list(out.values())


def chunk_cost_fn(kind: str, engine: str, kt: int, dtype: Any,
                  seeded: bool = False) -> Optional[Callable]:
    """``(a, b) -> {"flops", "bytes", "kernels", "schedule"}`` for one
    chunk update of the given pass kind, or ``None`` when tracing is
    disabled.

    The closure only reads shapes; the model itself is memoized per
    shape in :func:`repro.kernels.ops.chunk_cost`, so the per-chunk
    overhead under tracing is a cache lookup.  ``schedule`` reports the
    staged-vs-recompute choice the kernels resolve for this shape (None
    for the jnp engine), so the timeline shows the schedule per launch.
    """
    from repro import obs
    if not obs.enabled():
        return None
    from repro.kernels import ops as kernel_ops
    dtype_name = str(np.dtype(dtype))

    def fn(a: Any, b: Any) -> Dict[str, Any]:
        return kernel_ops.chunk_cost(
            kind, int(a.shape[0]), int(a.shape[1]), int(b.shape[1]),
            int(kt), dtype_name, engine=engine, seeded=seeded)

    return fn
