"""KernelPlan-derived flop/byte cost model for the roofline report.

A :class:`~repro.kernels.plan.KernelPlan` is the single source of truth
for launch geometry, so it is also the single source of truth for the
cost model: HBM traffic is counted by enumerating each operand's
distinct ``index_map`` blocks over the grid (a block with an index map
constant in some grid axis is loaded once, not once per step — exactly
the VMEM-residency the plans encode), and MXU flops follow the
per-kernel formulas documented in the kernel modules (the powerpass /
projgram docstrings' honest ``n_buckets·proj + acc`` accounting).

:func:`chunk_cost_fn` is the instrumentation entry point: given the
pass kind and engine it returns a cheap ``(a, b) -> cost`` closure (or
``None`` when tracing is off) that the fold loops attach to their chunk
spans; the underlying per-shape model is cached in
:func:`repro.kernels.ops.chunk_cost`.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.kernels.plan import BlockDef, KernelPlan

#: grids larger than this are not enumerated; traffic falls back to
#: one full sweep of the padded operand (chunk-scale grids are tiny)
_ENUM_CAP = 1 << 16


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= int(x)
    return n


def _distinct_blocks(block: BlockDef, grid) -> int:
    if _prod(grid) <= _ENUM_CAP:
        seen = {
            tuple(block.index_map(*idx))
            for idx in itertools.product(*(range(g) for g in grid))
        }
        return len(seen)
    return max(1, _prod(block.padded) // block.elems)


def plan_bytes(plan: KernelPlan) -> int:
    """Modelled HBM traffic of one launch: every distinct input block
    read once, every distinct output block written once, plus the SMEM
    scalars."""
    total = 0
    for block in (*plan.in_specs, *plan.out_specs):
        n_blocks = _distinct_blocks(block, plan.grid)
        total += n_blocks * block.elems * np.dtype(block.dtype).itemsize
    for sc in plan.scalars:
        total += sc.elems * np.dtype(sc.dtype).itemsize
    return total


def plan_flops(plan: KernelPlan) -> int:
    """Modelled MXU flops of one launch, from the plan geometry.

    Seeded variants count the same matmul flops as their materialized
    twins — the in-kernel Ω generation is VPU work the model keeps out
    of the MXU roofline (its effect shows up as the missing Q bytes).
    """
    name = plan.name
    if name in ("matmul_nn", "matmul_tn"):
        mp, np_out = plan.out_specs[0].padded
        kp = plan.in_specs[1].padded[0]
        return 2 * mp * kp * np_out
    if name in ("powerpass", "powerpass_seeded"):
        n_rows, dap = plan.in_specs[0].padded
        dbp = plan.in_specs[1].padded[1]
        ktp = plan.out_specs[0].padded[1]
        # projection P = B Q re-accumulated once per output bucket
        # (grid[0]), plus the single ΔY += AᵀP accumulation
        return plan.grid[0] * 2 * n_rows * dbp * ktp + 2 * n_rows * dap * ktp
    if name in ("projgram", "projgram_seeded"):
        n_rows, dp = plan.in_specs[0].padded
        ktp = plan.out_specs[0].padded[1]
        # P = X Q re-accumulated once per C-column bucket (grid[0]);
        # the gram C = PᵀP is computed bc columns at a time, summing
        # to one full (k̃p, k̃p) product
        return plan.grid[0] * 2 * n_rows * dp * ktp + 2 * n_rows * ktp * ktp
    raise ValueError(f"no cost formula for kernel plan {name!r}")


def plan_cost(plan: KernelPlan) -> Dict[str, Any]:
    return {"kernel": plan.name, "calls": 1,
            "flops": plan_flops(plan), "bytes": plan_bytes(plan)}


def merge_kernel_costs(parts: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Sum per-kernel cost entries by kernel name (stable order)."""
    out: Dict[str, Dict[str, Any]] = {}
    for p in parts:
        t = out.setdefault(p["kernel"], {"kernel": p["kernel"], "calls": 0,
                                         "flops": 0, "bytes": 0})
        t["calls"] += p.get("calls", 1)
        t["flops"] += p["flops"]
        t["bytes"] += p["bytes"]
    return list(out.values())


def chunk_cost_fn(kind: str, engine: str, kt: int, dtype: Any,
                  seeded: bool = False) -> Optional[Callable]:
    """``(a, b) -> {"flops", "bytes", "kernels"}`` for one chunk update
    of the given pass kind, or ``None`` when tracing is disabled.

    The closure only reads shapes; the model itself is memoized per
    shape in :func:`repro.kernels.ops.chunk_cost`, so the per-chunk
    overhead under tracing is a cache lookup.
    """
    from repro import obs
    if not obs.enabled():
        return None
    from repro.kernels import ops as kernel_ops
    dtype_name = str(np.dtype(dtype))

    def fn(a: Any, b: Any) -> Dict[str, Any]:
        return kernel_ops.chunk_cost(
            kind, int(a.shape[0]), int(a.shape[1]), int(b.shape[1]),
            int(kt), dtype_name, engine=engine, seeded=seeded)

    return fn
