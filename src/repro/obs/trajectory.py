"""Fold every ``results/BENCH_*.json`` into one comparable trajectory.

Each benchmark writes its own BENCH artifact (through
:func:`benchmarks.common.write_bench`, which stamps schema + commit
metadata).  This module folds all of them into a single
schema-versioned ``results/TRAJECTORY.json`` so the per-PR perf record
is one file with one shape — and computes regression deltas against the
previous trajectory's entry for the same bench, so a perf cliff shows
up as a number in the diff, not as archaeology across artifacts.

    python -m repro.obs trajectory [--results results] [--check]

``--check`` validates an existing trajectory file (the CI obs job fails
on a malformed one) without rewriting it.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1

#: metric keys that are identifiers/config, not comparable measurements
_NON_METRICS = frozenset({"schema", "shape", "prefetch_depth", "buckets"})


def _numeric(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def extract_metrics(bench: Dict[str, Any]) -> Dict[str, float]:
    """Flatten a BENCH artifact's comparable numbers: top-level numeric
    scalars plus the numeric fields of each named ``results`` row
    (keyed ``<row_name>.<field>``)."""
    out: Dict[str, float] = {}
    for k, v in bench.items():
        if k in _NON_METRICS:
            continue
        if _numeric(v):
            out[k] = float(v)
    for row in bench.get("results") or []:
        if not isinstance(row, dict):
            continue
        name = row.get("name")
        if not name:
            continue
        for k, v in row.items():
            if k in _NON_METRICS or not _numeric(v):
                continue
            out[f"{name}.{k}"] = float(v)
    return out


def _entry(path: str, results_dir: str) -> Dict[str, Any]:
    with open(path) as f:
        bench = json.load(f)
    return {
        "bench": bench.get("bench", os.path.basename(path)),
        "file": os.path.relpath(path, results_dir),
        # legacy artifacts predate write_bench and carry no meta stamp
        "meta": bench.get("meta"),
        "metrics": extract_metrics(bench),
    }


def _deltas(cur: Dict[str, float],
            prev: Optional[Dict[str, float]]) -> Dict[str, Dict[str, float]]:
    """Per-metric {prev, cur, rel} against the previous trajectory's
    entry for the same bench (rel = cur/prev - 1; prev == 0 is skipped)."""
    if not prev:
        return {}
    out: Dict[str, Dict[str, float]] = {}
    for k, v in cur.items():
        p = prev.get(k)
        if p is None or p == 0:
            continue
        if p != v:
            out[k] = {"prev": p, "cur": v, "rel": round(v / p - 1.0, 6)}
    return out


def build(results_dir: str = "results",
          meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Trajectory dict over every ``BENCH_*.json`` under ``results_dir``,
    with deltas vs. the previous ``TRAJECTORY.json`` if one exists."""
    paths = sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json")))
    previous: Dict[str, Dict[str, float]] = {}
    prev_path = os.path.join(results_dir, "TRAJECTORY.json")
    if os.path.exists(prev_path):
        try:
            with open(prev_path) as f:
                prev = json.load(f)
            previous = {e["bench"]: e.get("metrics", {})
                        for e in prev.get("entries", [])}
        except (json.JSONDecodeError, KeyError, TypeError):
            previous = {}
    entries: List[Dict[str, Any]] = []
    for path in paths:
        e = _entry(path, results_dir)
        e["deltas"] = _deltas(e["metrics"], previous.get(e["bench"]))
        entries.append(e)
    traj: Dict[str, Any] = {"schema": SCHEMA_VERSION, "entries": entries}
    if meta:
        traj["meta"] = meta
    return traj


def write(results_dir: str = "results",
          meta: Optional[Dict[str, Any]] = None) -> str:
    traj = build(results_dir, meta=meta)
    out = os.path.join(results_dir, "TRAJECTORY.json")
    with open(out, "w") as f:
        json.dump(traj, f, indent=2, sort_keys=True)
        f.write("\n")
    return out


def validate(traj: Any) -> List[str]:
    """Schema errors in a trajectory dict (empty list = valid)."""
    errs: List[str] = []
    if not isinstance(traj, dict):
        return ["trajectory is not a JSON object"]
    if traj.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema != {SCHEMA_VERSION}: {traj.get('schema')!r}")
    entries = traj.get("entries")
    if not isinstance(entries, list):
        return errs + ["entries is not a list"]
    for i, e in enumerate(entries):
        where = f"entries[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where} is not an object")
            continue
        for key in ("bench", "file", "metrics"):
            if key not in e:
                errs.append(f"{where} missing {key!r}")
        metrics = e.get("metrics")
        if not isinstance(metrics, dict) or not all(
                _numeric(v) for v in metrics.values()):
            errs.append(f"{where}.metrics is not a numeric mapping")
    return errs


def validate_file(path: str) -> List[str]:
    if not os.path.exists(path):
        return [f"{path} does not exist"]
    try:
        with open(path) as f:
            traj = json.load(f)
    except json.JSONDecodeError as e:
        return [f"{path} is not valid JSON: {e}"]
    return validate(traj)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs trajectory", description=__doc__)
    ap.add_argument("--results", default="results",
                    help="directory holding BENCH_*.json artifacts")
    ap.add_argument("--check", action="store_true",
                    help="validate the existing TRAJECTORY.json, don't write")
    args = ap.parse_args(argv)
    traj_path = os.path.join(args.results, "TRAJECTORY.json")
    if args.check:
        errs = validate_file(traj_path)
        for e in errs:
            print(f"TRAJECTORY: {e}")
        print(f"TRAJECTORY: {'OK' if not errs else 'MALFORMED'} {traj_path}")
        return 1 if errs else 0
    out = write(args.results)
    with open(out) as f:
        n = len(json.load(f)["entries"])
    print(f"TRAJECTORY: wrote {out} ({n} benches)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
