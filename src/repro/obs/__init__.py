"""repro.obs — unified tracing, metrics, and roofline accounting.

One trace stream serves every layer of the pass stack:

* :mod:`repro.obs.trace` — structured spans + counters as O_APPEND JSONL
  per process, enabled by the ``RCCA_TRACE`` env var (zero overhead when
  unset), plus the sanctioned pass-path clocks ``monotonic()``/``wall()``
  (analysis rule RCCA007).
* :mod:`repro.obs.cost` — KernelPlan-derived flop/byte cost model shared
  by the per-chunk counters and the roofline report.
* :mod:`repro.obs.report` — ``python -m repro.obs report <trace>``:
  per-pass timeline, roofline table, prefetch overlap, merge share.
* :mod:`repro.obs.trajectory` — folds every ``results/BENCH_*.json``
  into one schema-versioned ``results/TRAJECTORY.json`` with regression
  deltas vs. the previous entry.

The trace API is re-exported here so instrumented modules just do
``from repro import obs`` and call ``obs.span`` / ``obs.counter`` /
``obs.monotonic``.  Submodules with heavier imports (cost pulls in the
kernel plans) load lazily on first attribute access.
"""
from __future__ import annotations

import importlib

from repro.obs.trace import (  # noqa: F401
    DEFAULT_DIR,
    TRACE_ENV,
    counter,
    enabled,
    iter_events,
    load_events,
    monotonic,
    proto_event,
    set_context,
    span,
    trace_dir,
    wall,
)

_SUBMODULES = ("trace", "cost", "report", "trajectory")


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


__all__ = [
    "TRACE_ENV", "DEFAULT_DIR", "span", "counter", "enabled", "trace_dir",
    "set_context", "proto_event", "monotonic", "wall",
    "iter_events", "load_events", *_SUBMODULES,
]
