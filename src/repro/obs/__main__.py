"""CLI: ``python -m repro.obs {report,trajectory,export-trace} ...``."""
from __future__ import annotations

import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.obs {report,trajectory,export-trace}"
              " [args...]\n"
              "  report        timeline + roofline from an RCCA_TRACE dir\n"
              "  trajectory    fold results/BENCH_*.json into TRAJECTORY.json\n"
              "  export-trace  RCCA_TRACE dir -> chrome://tracing JSON")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "report":
        from repro.obs.report import main as run
    elif cmd == "trajectory":
        from repro.obs.trajectory import main as run
    elif cmd == "export-trace":
        from repro.obs.chrometrace import main as run
    else:
        print(f"unknown subcommand {cmd!r} "
              "(expected report, trajectory or export-trace)")
        return 2
    return run(rest)


if __name__ == "__main__":
    raise SystemExit(main())
