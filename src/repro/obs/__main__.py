"""CLI: ``python -m repro.obs {report,trajectory} ...``."""
from __future__ import annotations

import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.obs {report,trajectory} [args...]\n"
              "  report      timeline + roofline from an RCCA_TRACE dir\n"
              "  trajectory  fold results/BENCH_*.json into TRAJECTORY.json")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "report":
        from repro.obs.report import main as run
    elif cmd == "trajectory":
        from repro.obs.trajectory import main as run
    else:
        print(f"unknown subcommand {cmd!r} (expected report or trajectory)")
        return 2
    return run(rest)


if __name__ == "__main__":
    raise SystemExit(main())
