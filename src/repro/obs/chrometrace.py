"""Export an ``RCCA_TRACE`` directory as Chrome trace-event JSON.

    python -m repro.obs export-trace rcca_trace -o trace.json

The output loads directly in ``chrome://tracing`` or Perfetto
(https://ui.perfetto.dev) and shows the same data ``repro.obs report``
aggregates — but on a zoomable timeline: one track per process
(coordinator / workers / driver), nested spans as complete ("X")
events, counters as counter ("C") tracks, and cluster-protocol events
as instants ("i").

Mapping from the obs JSONL stream (:mod:`repro.obs.trace`):

======================  =============================================
obs record              trace-event record
======================  =============================================
``ev: span``            ``ph: "X"`` complete event (ts + dur, µs);
                        nesting recovered by Chrome from overlap, the
                        span tree's parent links ride in ``args``
``ev: ctr``             ``ph: "C"`` counter sample for numeric fields
                        (strings ride in a parallel instant's args)
``ev: proto``           ``ph: "i"`` instant (op + path in args)
process ``ctx.role``    ``ph: "M" process_name`` metadata
======================  =============================================

Timestamps: obs records carry epoch-seconds wall clocks shared across
processes; the exporter rebases to the earliest record so Perfetto's
timeline starts at zero.  Spans are placed on the recording thread's
track: obs records carry the OS thread id (``tid``), so each thread of
a process — the engine's prefetch I/O threads next to its fold loop —
renders as its own Perfetto track (older traces without ``tid`` fall
back to one track per process).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs.trace import load_events


def _numeric(fields: Dict[str, Any]) -> Dict[str, float]:
    out = {}
    for k, v in fields.items():
        if isinstance(v, bool):
            out[k] = float(v)
        elif isinstance(v, (int, float)):
            out[k] = float(v)
    return out


def convert(events: List[dict]) -> Dict[str, Any]:
    """Obs records → ``{"traceEvents": [...], ...}`` (JSON Object
    Format, so Perfetto accepts metadata alongside the array)."""
    spans = [ev for ev in events if ev.get("ev") == "span"]
    ctrs = [ev for ev in events if ev.get("ev") == "ctr"]
    protos = [ev for ev in events if ev.get("ev") == "proto"]
    t0 = min((float(ev["t"]) for ev in events if "t" in ev), default=0.0)

    def us(t: float) -> float:
        return (t - t0) * 1e6

    out: List[Dict[str, Any]] = []
    roles: Dict[int, str] = {}
    for ev in spans:
        ctx = ev.get("ctx") or {}
        pid = int(ev.get("pid", 0))
        if "role" in ctx:
            roles.setdefault(pid, str(ctx["role"]))
        args = dict(ev.get("attrs") or {})
        args["sid"] = ev.get("sid")
        if ev.get("parent") is not None:
            args["parent_sid"] = ev["parent"]
        out.append({
            "ph": "X", "name": str(ev.get("name", "?")),
            "pid": pid, "tid": int(ev.get("tid", pid)),
            "ts": us(float(ev["t"])),
            "dur": float(ev.get("dur", 0.0)) * 1e6,
            "cat": "span", "args": args,
        })
    for ev in ctrs:
        pid = int(ev.get("pid", 0))
        fields = ev.get("fields") or {}
        nums = _numeric(fields)
        name = str(ev.get("name", "?"))
        # counters keyed by a string field (kernel=..., site=...) split
        # into one counter track per key value, so the series don't mix
        tags = [f"{k}={v}" for k, v in sorted(fields.items())
                if isinstance(v, str)]
        track = name if not tags else f"{name}[{','.join(tags)}]"
        if nums:
            out.append({
                "ph": "C", "name": track, "pid": pid,
                "ts": us(float(ev.get("t", t0))), "cat": "ctr",
                "args": nums,
            })
        else:  # nothing numeric to plot: keep it visible as an instant
            out.append({
                "ph": "i", "name": track, "pid": pid,
                "tid": int(ev.get("tid", pid)),
                "ts": us(float(ev.get("t", t0))), "s": "p",
                "cat": "ctr", "args": dict(fields),
            })
    for ev in protos:
        pid = int(ev.get("pid", 0))
        out.append({
            "ph": "i", "name": f"proto:{ev.get('op', '?')}",
            "pid": pid, "tid": int(ev.get("tid", pid)),
            "ts": us(float(ev.get("t", t0))), "s": "p",
            "cat": "proto",
            "args": {"path": ev.get("path"), **(ev.get("meta") or {})},
        })
    for pid, role in sorted(roles.items()):
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "args": {"name": f"{role} (pid {pid})"}})
    out.sort(key=lambda e: e.get("ts", 0.0))
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs export-trace",
                          "t0_epoch_s": t0}}


def export(trace_path: str, out_path: str) -> Dict[str, int]:
    """Read a trace file/dir, write Chrome JSON; returns event counts."""
    events = load_events(trace_path)
    doc = convert(events)
    with open(out_path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return {"events_in": len(events), "events_out": len(doc["traceEvents"])}


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs export-trace", description=__doc__)
    ap.add_argument("trace", help="trace file or directory (RCCA_TRACE dir)")
    ap.add_argument("-o", "--out", default="trace.json",
                    help="output path (default: trace.json)")
    args = ap.parse_args(argv)
    n = export(args.trace, args.out)
    print(f"{args.out}: {n['events_out']} trace events "
          f"(from {n['events_in']} obs records) — open in chrome://tracing "
          "or https://ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
