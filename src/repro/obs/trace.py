"""Structured spans and counters — the one trace stream for the pass stack.

Every process in a fit (driver, coordinator, cluster workers) appends
JSONL records to its own file under the directory named by the
``RCCA_TRACE`` environment variable (the value ``1`` selects the default
directory ``rcca_trace/``).  Records are written with a single
``os.write`` on an ``O_APPEND`` descriptor, so concurrent threads and
processes interleave whole lines and a killed worker leaves at worst one
torn final line — which the reader skips.

Record shapes (all carry ``ev``, ``t`` = epoch seconds, ``pid``, ``tid``
= the recording OS thread id, and the process ``ctx`` dict set via
:func:`set_context`; ``tid`` is what lets the Perfetto exporter give
each thread — e.g. the engine's I/O prefetchers next to the fold loop —
its own track):

* ``{"ev": "span", "name": ..., "t": t0, "dur": seconds, "sid": n,
  "parent": m | None, "attrs": {...}}`` — one record per completed
  ``with span(...)`` block, emitted at exit.  ``sid`` is unique per
  process; ``parent`` is the enclosing span's sid on the same thread.
* ``{"ev": "ctr", "name": ..., "parent": m | None, "fields": {...}}`` —
  a named bundle of numeric (or short string, for grouping) fields.
* ``{"ev": "proto", "op": ..., "path": ..., "meta": {...}}`` — a cluster
  protocol event mirrored from :mod:`repro.analysis.protocol`; the
  top-level ``op``/``path``/``meta`` keys keep ``check_trace`` working
  directly on an obs trace file.

When ``RCCA_TRACE`` is unset every entry point is a no-op: ``span``
returns a shared null context manager and ``counter`` returns before
building the record, so the traced code path costs one environment
lookup.  Instrumented call sites that loop per chunk should additionally
branch on :func:`enabled` and keep their original loop byte-for-byte.

This module is also the sanctioned clock home for pass-path code
(analysis rule RCCA007): take timings via :func:`monotonic` /
:func:`wall` so spans, counters, and diagnostics share one clock domain.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

TRACE_ENV = "RCCA_TRACE"
DEFAULT_DIR = "rcca_trace"

# RCCA007 exemption: this module *implements* the obs clocks.
monotonic = time.perf_counter
wall = time.time  # rcca: noqa[RCCA004]


def trace_dir() -> Optional[str]:
    """Resolved trace directory, or None when tracing is disabled."""
    val = os.environ.get(TRACE_ENV)
    if not val:
        return None
    return DEFAULT_DIR if val == "1" else val


def enabled() -> bool:
    return bool(os.environ.get(TRACE_ENV))


_CTX: Dict[str, Any] = {}
_FDS: Dict[str, int] = {}
_SIDS = itertools.count(1)
_TLS = threading.local()


def set_context(**attrs: Any) -> None:
    """Stamp process-wide attributes (fit_id, role, shard) on every record."""
    for k, v in attrs.items():
        if v is None:
            _CTX.pop(k, None)
        else:
            _CTX[k] = v


def _fd(dir_: str) -> int:
    path = os.path.join(dir_, f"trace-{os.getpid()}.jsonl")
    fd = _FDS.get(path)
    if fd is None:
        os.makedirs(dir_, exist_ok=True)
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        _FDS[path] = fd
    return fd


def _emit(rec: Dict[str, Any]) -> None:
    dir_ = trace_dir()
    if dir_ is None:
        return
    rec["pid"] = os.getpid()
    rec["tid"] = threading.get_native_id()
    if _CTX:
        rec["ctx"] = dict(_CTX)
    line = json.dumps(rec, sort_keys=True, default=str) + "\n"
    os.write(_fd(dir_), line.encode())


def _stack() -> List[int]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class _Span:
    """Context manager recording one span on exit (even when unwinding)."""

    __slots__ = ("name", "attrs", "sid", "parent", "_t0", "_w0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        st = _stack()
        self.parent = st[-1] if st else None
        self.sid = next(_SIDS)
        st.append(self.sid)
        self._w0 = wall()
        self._t0 = monotonic()
        return self

    def __exit__(self, *exc: Any) -> None:
        dur = monotonic() - self._t0
        st = _stack()
        if st and st[-1] == self.sid:
            st.pop()
        rec: Dict[str, Any] = {
            "ev": "span",
            "name": self.name,
            "t": self._w0,
            "dur": dur,
            "sid": self.sid,
            "parent": self.parent,
        }
        if self.attrs:
            rec["attrs"] = self.attrs
        _emit(rec)


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None


_NULL = _NullSpan()


def span(name: str, **attrs: Any) -> Any:
    """``with span("pass", pass_idx=0):`` — no-op when tracing is off."""
    if not os.environ.get(TRACE_ENV):
        return _NULL
    return _Span(name, attrs)


def counter(name: str, **fields: Any) -> None:
    """Record a named bundle of numeric fields (strings allowed as keys
    for grouping, e.g. ``kernel="powerpass"`` or ``site="prefetch"``)."""
    if not os.environ.get(TRACE_ENV):
        return
    st = _stack()
    _emit({
        "ev": "ctr",
        "name": name,
        "t": wall(),
        "parent": st[-1] if st else None,
        "fields": fields,
    })


def proto_event(rec: Dict[str, Any]) -> None:
    """Mirror a cluster-protocol event into the obs stream (op/path/meta
    stay top-level so the protocol race detector reads obs files)."""
    if not os.environ.get(TRACE_ENV):
        return
    out = dict(rec)
    out["ev"] = "proto"
    out["t"] = wall()
    _emit(out)


def iter_events(path: str) -> Iterator[Dict[str, Any]]:
    """Yield records from a trace file or directory of ``*.jsonl`` files.

    Tolerates a torn final line (a killed writer) by skipping anything
    that does not parse as JSON.
    """
    if os.path.isdir(path):
        files = sorted(
            os.path.join(path, f) for f in os.listdir(path)
            if f.endswith(".jsonl")
        )
    else:
        files = [path]
    for fp in files:
        with open(fp, "r", encoding="utf-8", errors="replace") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(rec, dict):
                    yield rec


def load_events(path: str) -> List[Dict[str, Any]]:
    return list(iter_events(path))
