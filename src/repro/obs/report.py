"""Offline analysis of an ``RCCA_TRACE`` directory: timeline + roofline.

    python -m repro.obs report rcca_trace [--json out.json]

Reads every per-process ``trace-*.jsonl`` file and reconstructs:

* **timeline** — per process (coordinator / workers / driver), the
  top-level span tree with per-span self-time (duration minus child
  spans), so the wall-clock of a fit decomposes into named phases:
  pass > chunk / io_wait / gather / mesh_fold / publish / barrier /
  merge.
* **coverage** — the fraction of each process's traced window that
  falls inside top-level spans.  The acceptance bar for the
  instrumentation is ≥ 0.95: less means some phase of the fit runs
  outside any span and the profile is lying by omission.
* **roofline** — per-kernel cost-model totals (flops / bytes / calls,
  from the same :class:`~repro.kernels.plan.KernelPlan` geometry the
  launches use, via the ``kernel_cost`` counters) joined with the
  measured fold time (``chunk`` + ``mesh_fold`` spans carrying
  cost-model attrs), giving achieved model-flops/s and arithmetic
  intensity per pass kind and engine.
* **io overlap** — per prefetch site, the fraction of read time hidden
  behind compute: ``(read_s - io_stall_s) / read_s`` from the ``io``
  counters the prefetcher emits on close.
* **merge share** — merge-tree seconds as a fraction of the
  coordinator's fit wall, the scaling number the cluster benchmarks
  track.
* **protocol** — RCCA2xx race-detector verdict over the mirrored
  ``proto`` records (one trace serves both the profiler and the
  checker).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs.trace import load_events

#: span names whose time is a leaf phase (no further decomposition)
_FOLD_SPANS = ("chunk", "mesh_fold")


def _spans_by_pid(events: List[dict]) -> Dict[int, List[dict]]:
    out: Dict[int, List[dict]] = {}
    for ev in events:
        if ev.get("ev") == "span":
            out.setdefault(int(ev.get("pid", 0)), []).append(ev)
    return out


def _self_times(spans: List[dict]) -> None:
    """Annotate each span dict with ``self`` = dur − Σ direct-child durs
    (clamped at 0 — children overlapping their parent's edges are a
    clock artifact, not negative work)."""
    child_sum: Dict[Any, float] = {}
    for sp in spans:
        if sp.get("parent") is not None:
            child_sum[sp["parent"]] = (child_sum.get(sp["parent"], 0.0)
                                       + float(sp.get("dur", 0.0)))
    for sp in spans:
        sp["self"] = max(0.0, float(sp.get("dur", 0.0))
                         - child_sum.get(sp.get("sid"), 0.0))


def _role(spans: List[dict]) -> str:
    for sp in spans:
        ctx = sp.get("ctx") or {}
        if "role" in ctx:
            return str(ctx["role"])
    return "proc"


def _coverage(spans: List[dict]) -> Dict[str, float]:
    """Top-level span seconds vs. the process's traced window."""
    t0 = min(float(sp["t"]) for sp in spans)
    t1 = max(float(sp["t"]) + float(sp.get("dur", 0.0)) for sp in spans)
    top = [sp for sp in spans if sp.get("parent") is None]
    covered = sum(float(sp.get("dur", 0.0)) for sp in top)
    window = max(t1 - t0, 1e-12)
    return {"window_s": window, "covered_s": covered,
            "fraction": min(1.0, covered / window)}


def analyze(path: str) -> Dict[str, Any]:
    """Full report dict for a trace file or directory."""
    events = load_events(path)
    by_pid = _spans_by_pid(events)
    report: Dict[str, Any] = {"trace": path, "n_events": len(events)}

    # -- timeline + coverage ------------------------------------------
    procs: Dict[str, Any] = {}
    trace_t0 = min((float(sp["t"]) for sps in by_pid.values() for sp in sps),
                   default=0.0)
    for pid, spans in sorted(by_pid.items()):
        _self_times(spans)
        phases: Dict[str, Dict[str, float]] = {}
        for sp in spans:
            ph = phases.setdefault(sp["name"], {"n": 0, "s": 0.0,
                                                "self_s": 0.0})
            ph["n"] += 1
            ph["s"] += float(sp.get("dur", 0.0))
            ph["self_s"] += float(sp["self"])
        top = [
            {"name": sp["name"], "t": round(float(sp["t"]) - trace_t0, 4),
             "dur": round(float(sp.get("dur", 0.0)), 4),
             "attrs": sp.get("attrs", {})}
            for sp in sorted((s for s in spans if s.get("parent") is None),
                             key=lambda s: float(s["t"]))
        ]
        procs[str(pid)] = {
            "role": _role(spans),
            "top_spans": top,
            "phases": {k: {"n": v["n"], "s": round(v["s"], 4),
                           "self_s": round(v["self_s"], 4)}
                       for k, v in sorted(phases.items())},
            "coverage": {k: round(v, 4) if isinstance(v, float) else v
                         for k, v in _coverage(spans).items()},
        }
    report["processes"] = procs
    fracs = [p["coverage"]["fraction"] for p in procs.values()]
    report["coverage"] = round(min(fracs), 4) if fracs else 0.0

    # -- roofline -----------------------------------------------------
    kernels: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ev") == "ctr" and ev.get("name") == "kernel_cost":
            f = ev.get("fields", {})
            k = kernels.setdefault(str(f.get("kernel", "?")),
                                   {"calls": 0, "flops": 0, "bytes": 0})
            k["calls"] += int(f.get("calls", 0))
            k["flops"] += int(f.get("flops", 0))
            k["bytes"] += int(f.get("bytes", 0))
    folds: Dict[Any, Dict[str, float]] = {}
    for spans in by_pid.values():
        for sp in spans:
            if sp["name"] not in _FOLD_SPANS:
                continue
            a = sp.get("attrs", {})
            if "flops" not in a:
                continue
            key = (str(a.get("kind", "?")), str(a.get("engine", "?")))
            fd = folds.setdefault(key, {"s": 0.0, "flops": 0, "bytes": 0,
                                        "n": 0})
            fd["s"] += float(sp.get("dur", 0.0))
            fd["flops"] += int(a["flops"])
            fd["bytes"] += int(a.get("bytes", 0))
            fd["n"] += 1
    report["roofline"] = {
        "kernels": {
            k: dict(v, intensity=round(v["flops"] / v["bytes"], 3)
                    if v["bytes"] else None)
            for k, v in sorted(kernels.items())
        },
        "folds": {
            f"{kind}/{engine}": {
                "n": fd["n"], "s": round(fd["s"], 4),
                "flops": fd["flops"], "bytes": fd["bytes"],
                "model_gflops_per_s": round(fd["flops"] / fd["s"] / 1e9, 3)
                if fd["s"] else None,
            }
            for (kind, engine), fd in sorted(folds.items())
        },
    }

    # -- io overlap ---------------------------------------------------
    io: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ev") == "ctr" and ev.get("name") == "io":
            f = ev.get("fields", {})
            s = io.setdefault(str(f.get("site", "?")),
                              {"chunks": 0, "bytes": 0,
                               "read_s": 0.0, "io_stall_s": 0.0})
            s["chunks"] += int(f.get("chunks", 0))
            s["bytes"] += int(f.get("bytes", 0))
            s["read_s"] += float(f.get("read_s", 0.0))
            s["io_stall_s"] += float(f.get("io_stall_s", 0.0))
    report["io"] = {
        site: dict(v, read_s=round(v["read_s"], 4),
                   io_stall_s=round(v["io_stall_s"], 4),
                   overlap=round((v["read_s"] - v["io_stall_s"])
                                 / v["read_s"], 4) if v["read_s"] else None)
        for site, v in sorted(io.items())
    }

    # -- merge share --------------------------------------------------
    merge_s = fit_s = 0.0
    for spans in by_pid.values():
        for sp in spans:
            if sp["name"] == "merge":
                merge_s += float(sp.get("dur", 0.0))
            elif sp["name"] == "fit" and (sp.get("attrs", {}).get("site")
                                          == "coordinator"):
                fit_s += float(sp.get("dur", 0.0))
    report["merge"] = {"merge_s": round(merge_s, 4),
                       "fit_s": round(fit_s, 4),
                       "share": round(merge_s / fit_s, 4) if fit_s else None}

    # -- worker liveness ----------------------------------------------
    # heartbeat-age samples the coordinator's barrier loop emits (~1 Hz
    # per live worker): per-shard max/last age sits next to the compute
    # spans, so a stale-but-alive worker is visible in the same report
    # that shows where the time went
    beats: Dict[int, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("ev") == "ctr" and ev.get("name") == "heartbeat":
            f = ev.get("fields", {})
            s = beats.setdefault(int(f.get("shard", -1)),
                                 {"samples": 0, "max_age_s": 0.0,
                                  "last_age_s": 0.0, "passes": set()})
            age = float(f.get("age_s", 0.0))
            s["samples"] += 1
            s["max_age_s"] = max(s["max_age_s"], age)
            s["last_age_s"] = age
            s["passes"].add(int(f.get("pass_idx", -1)))
    report["liveness"] = {
        str(shard): {"samples": v["samples"],
                     "max_age_s": round(v["max_age_s"], 3),
                     "last_age_s": round(v["last_age_s"], 3),
                     "passes": sorted(v["passes"])}
        for shard, v in sorted(beats.items())
    }

    # -- redispatches + protocol verdict ------------------------------
    report["redispatches"] = sum(
        int(ev.get("fields", {}).get("groups", 0)) for ev in events
        if ev.get("ev") == "ctr" and ev.get("name") == "redispatch")
    proto = [ev for ev in events if ev.get("ev") == "proto"]
    if proto:
        from repro.analysis.protocol import check_trace
        # per-process trace files concatenate in filename order; the
        # wall timestamp recovers the cross-process serialization the
        # invariants are stated over (the single-file
        # RCCA_PROTOCOL_TRACE stream stays the canonical witness)
        proto.sort(key=lambda ev: float(ev.get("t", 0.0)))
        violations = check_trace(proto, where=path)
        report["protocol"] = {"events": len(proto),
                              "violations": [str(v) for v in violations]}
    return report


def render(report: Dict[str, Any]) -> str:
    """Human-readable multi-section text of an :func:`analyze` dict."""
    out: List[str] = []
    out.append(f"trace: {report['trace']}  ({report['n_events']} events, "
               f"{len(report['processes'])} processes)")
    out.append("")
    out.append("timeline")
    for pid, proc in report["processes"].items():
        cov = proc["coverage"]
        out.append(f"  [{proc['role']} pid={pid}]  window "
                   f"{cov['window_s']:.3f}s, coverage {cov['fraction']:.1%}")
        for sp in proc["top_spans"]:
            attrs = sp["attrs"]
            tag = " ".join(f"{k}={attrs[k]}" for k in sorted(attrs)
                           if k in ("site", "pass_idx", "kind", "engine",
                                    "schedule"))
            out.append(f"    +{sp['t']:8.3f}s  {sp['name']:<12} "
                       f"{sp['dur']:8.3f}s  {tag}")
        for name, ph in proc["phases"].items():
            out.append(f"      {name:<12} n={ph['n']:<5d} "
                       f"sum={ph['s']:9.3f}s  self={ph['self_s']:9.3f}s")
    out.append("")
    out.append(f"span coverage (min over processes): "
               f"{report['coverage']:.1%}")
    out.append("")
    out.append("roofline — cost-model kernel totals")
    out.append(f"  {'kernel':<20} {'calls':>7} {'flops':>14} {'bytes':>14} "
               f"{'flops/byte':>10}")
    for k, v in report["roofline"]["kernels"].items():
        inten = f"{v['intensity']:.2f}" if v["intensity"] else "-"
        out.append(f"  {k:<20} {v['calls']:>7d} {v['flops']:>14d} "
                   f"{v['bytes']:>14d} {inten:>10}")
    out.append("  fold spans (measured wall over cost-model work):")
    for key, fd in report["roofline"]["folds"].items():
        gf = (f"{fd['model_gflops_per_s']:.3f} model-GFLOP/s"
              if fd["model_gflops_per_s"] is not None else "-")
        out.append(f"    {key:<16} n={fd['n']:<5d} {fd['s']:8.3f}s  {gf}")
    out.append("")
    out.append("io overlap")
    for site, v in report["io"].items():
        ov = f"{v['overlap']:.1%}" if v["overlap"] is not None else "-"
        out.append(f"  {site:<14} chunks={v['chunks']:<6d} "
                   f"read={v['read_s']:.3f}s stall={v['io_stall_s']:.3f}s "
                   f"overlap={ov}")
    m = report["merge"]
    share = f"{m['share']:.1%}" if m["share"] is not None else "-"
    out.append("")
    out.append(f"merge tree: {m['merge_s']:.3f}s of {m['fit_s']:.3f}s "
               f"coordinator fit wall ({share})")
    if report.get("liveness"):
        out.append("")
        out.append("worker liveness (heartbeat ages seen at the barrier)")
        for shard, v in report["liveness"].items():
            passes = ",".join(str(p) for p in v["passes"])
            out.append(f"  shard {shard:>3}  samples={v['samples']:<5d} "
                       f"max_age={v['max_age_s']:.3f}s "
                       f"last_age={v['last_age_s']:.3f}s  passes=[{passes}]")
    if report["redispatches"]:
        out.append(f"redispatched groups: {report['redispatches']}")
    if "protocol" in report:
        p = report["protocol"]
        verdict = "OK" if not p["violations"] else "VIOLATIONS"
        out.append(f"protocol: {p['events']} events -> {verdict}")
        for v in p["violations"]:
            out.append(f"  {v}")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs report", description=__doc__)
    ap.add_argument("trace", help="trace file or directory (RCCA_TRACE dir)")
    ap.add_argument("--json", default=None,
                    help="also write the full report dict to this path")
    args = ap.parse_args(argv)
    report = analyze(args.trace)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    print(render(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
