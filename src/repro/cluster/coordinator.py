"""Two-pass map/combine/reduce coordinator — the cluster's driver.

Hadoop-shaped execution of Algorithm 1 (the paper's "suitable for
distributed processing frameworks in which iteration is expensive"
claim as a subsystem): for each of the q+1 data passes the coordinator

1. publishes the pass ROUND (Qa/Qb bases + binding metadata) under the
   cluster directory,
2. spawns one worker process per shard (``python -m
   repro.cluster.worker`` — any external scheduler could do the same);
   with ``devices_per_worker > 1`` each worker folds its merge groups
   one-per-device over a local mesh (the HYBRID topology — the spawner
   forces ``--xla_force_host_platform_device_count`` into the worker
   environment so the layout works on accelerator-less hosts too),
3. runs the BARRIER: polls for per-merge-group partials, re-dispatching
   the merge groups of dead, stale-heartbeat or straggling workers to
   fresh repair workers (at-most-once per group id — duplicates are
   byte-identical and ignored),
4. STREAMS the deterministic fixed-order pairwise tree directly from
   the on-disk partials (``SegmentedAccumulator.push_group`` in group
   order — only O(log G) group partials are ever resident, so huge
   k̃·d partial sets merge in bounded memory) and either rotates the
   bases (``power_update_Q``) or finishes (``finalize_result``).

Because workers fold whole merge groups with the same per-chunk updates
through the one canonical fold (``repro.exec``), and the merge tree is
the same fixed structure the single-process drivers use, the
coordinator's result is BIT-IDENTICAL to ``randomized_cca_streaming``
on the same store for any worker count AND any devices-per-worker
layout (tests/test_cluster.py, tests/test_exec_topologies.py), under
injected worker kills (tests/test_cluster_failures.py) and injected
worker hangs caught by the heartbeat monitor.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time
import uuid
from typing import Dict, List, Optional

import jax

from repro import obs
from repro.analysis import sanitize
from repro.analysis.protocol import trace_event
from repro.core.rcca import (
    DEFAULT_ENGINE,
    RCCAConfig,
    RCCAResult,
    algo_meta,
    finalize_result,
    init_Q,
    omega_seeds,
    power_update_Q,
    resolve_engine,
    resolve_omega,
    stats_init_fn,
)
from repro.exec import MERGE_GROUP_CHUNKS, SegmentedAccumulator
from repro.store import ViewStoreReader

from . import partials as pt


class ClusterCoordinator:
    """Drive a multi-worker two-pass fit over a view store.

    Parameters
    ----------
    store:          view store path/URI, or an open ``ViewStoreReader``.
    cfg:            :class:`RCCAConfig` hyper-parameters.
    cluster_dir:    shared directory for rounds/partials/cursors/
                    heartbeats/logs — on a real cluster this lives on
                    the DFS all workers mount; kill/resume state never
                    leaves it.
    n_workers:      worker processes per pass.
    devices_per_worker: local devices each worker folds merge groups
                    over (>1 = the Hybrid topology; workers are spawned
                    with the forced-host-device XLA flag so the layout
                    runs on any host).  Results are bitwise invariant
                    to this knob.
    engine:         data-pass engine, binding for every partial.
    merge_group:    chunks per merge group (the partial granularity).
                    MUST equal the single-process driver's value for
                    bit-identical results (default: the shared
                    ``repro.exec.MERGE_GROUP_CHUNKS``).
    combine_groups: combiner-on-the-way-out span (power of two): each
                    worker pre-merges runs of this many consecutive
                    merge groups through its own pairwise stack and
                    publishes ONE span partial per run, shrinking the
                    coordinator's merge fan-in (and the partials
                    directory) by that factor.  Results are bitwise
                    invariant to this knob — a combined span is exactly
                    one subtree of the canonical reduction.  1 (the
                    default) is the historical per-group protocol.
    omega:          Ω provenance (``rcca.OMEGA_MODES``), binding for
                    every round and partial.  ``"seeded"`` publishes
                    the pass-0 round with the per-view (2,)-uint32
                    seeds in the Qa/Qb slots — an 8-byte broadcast
                    instead of the ``(d, k̃)`` bases; workers re-derive
                    (jnp) or in-kernel generate (kernels) Ω from it.
    prefetch:       per-worker chunk prefetch depth.
    worker_timeout: seconds a pass may run before live workers are
                    declared stragglers, killed and their missing
                    groups re-dispatched.
    heartbeat_timeout: seconds a worker's heartbeat beacon may go
                    stale before the worker is declared stuck and
                    killed (re-dispatch happens through the normal
                    dead-worker path).  ``None`` disables the monitor
                    and leaves only the wall-clock ``worker_timeout``.
                    Set it comfortably above per-group fold time (the
                    beacon beats at start and every group/cursor save).
    max_redispatch: repair rounds per pass before giving up.
    env_overrides:  {shard: {env}} merged into that shard's initial
                    worker process — the failure-injection hook
                    (repair workers never inherit it).
    """

    def __init__(self, store, cfg: RCCAConfig, cluster_dir: str, *,
                 n_workers: int = 2, devices_per_worker: int = 1,
                 engine: str = DEFAULT_ENGINE,
                 merge_group: int = MERGE_GROUP_CHUNKS,
                 combine_groups: int = 1,
                 omega: str = "materialized", prefetch: int = 2,
                 ckpt_every: int = 4, worker_timeout: float = 600.0,
                 heartbeat_timeout: Optional[float] = None,
                 max_redispatch: int = 3,
                 env_overrides: Optional[Dict[int, dict]] = None,
                 python: str = sys.executable):
        if isinstance(store, ViewStoreReader):
            self.reader, self.store_path = store, store.path
        else:
            self.reader, self.store_path = ViewStoreReader(store), store
        self.cfg = cfg
        self.cluster_dir = cluster_dir
        self.n_workers = int(n_workers)
        self.devices_per_worker = int(devices_per_worker)
        self.engine = resolve_engine(engine)
        self.merge_group = int(merge_group)
        self.combine_groups = int(combine_groups)
        self.omega = resolve_omega(omega)
        self.prefetch = int(prefetch)
        self.ckpt_every = int(ckpt_every)
        self.worker_timeout = worker_timeout
        self.heartbeat_timeout = heartbeat_timeout
        self.max_redispatch = int(max_redispatch)
        self.env_overrides = env_overrides or {}
        self.python = python
        if self.n_workers < 1:
            raise ValueError("need at least one worker")
        if self.devices_per_worker < 1:
            raise ValueError("need at least one device per worker")
        if self.combine_groups < 1 or \
                self.combine_groups & (self.combine_groups - 1):
            raise ValueError(
                f"combine_groups must be a power of two (a combined span "
                f"must be one subtree of the canonical pairwise "
                f"reduction), got {self.combine_groups}")
        os.makedirs(os.path.join(cluster_dir, "logs"), exist_ok=True)
        # (pass_idx, group) → error for stale-partial removals that
        # failed — surfaced in diagnostics, retried at every pass sweep
        self._clean_pending: Dict[tuple, str] = {}

    # -- process management -----------------------------------------------

    @property
    def n_groups(self) -> int:
        return -(-self.reader.n_chunks // self.merge_group)

    def _spawn(self, shard: int, pass_idx: int, *, groups=None,
               extra_env: Optional[dict] = None) -> subprocess.Popen:
        cmd = [self.python, "-m", "repro.cluster.worker",
               "--store", self.store_path,
               "--cluster-dir", self.cluster_dir,
               "--shard", str(shard),
               "--n-shards", str(self.n_workers),
               "--pass-idx", str(pass_idx),
               "--prefetch", str(self.prefetch),
               "--ckpt-every", str(self.ckpt_every)]
        if self.devices_per_worker > 1:
            cmd += ["--devices", str(self.devices_per_worker)]
        if groups is not None:
            cmd += ["--groups", ",".join(str(g) for g in groups)]
        env = dict(os.environ)
        # workers must import repro wherever the scheduler runs them
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        if self.devices_per_worker > 1:
            # hybrid workers need their device mesh before jax wakes up;
            # on accelerator hosts the flag is inert (it only forces the
            # HOST platform's device count)
            flag = ("--xla_force_host_platform_device_count="
                    f"{self.devices_per_worker}")
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
        if extra_env:
            env.update(extra_env)
        log = open(os.path.join(self.cluster_dir, "logs",
                                f"w{shard:03d}_p{pass_idx:05d}.log"), "ab")
        try:
            return subprocess.Popen(cmd, env=env, stdout=log,
                                    stderr=subprocess.STDOUT)
        finally:
            log.close()  # the child holds its own descriptor

    def _owned(self, shard: int) -> List[int]:
        return [g for g in range(self.n_groups)
                if (g // self.combine_groups) % self.n_workers == shard]

    # -- one pass ---------------------------------------------------------

    def _kill_stale(self, procs: Dict[int, subprocess.Popen], pass_idx: int,
                    spawned_at: Dict[int, float]) -> List[int]:
        """Heartbeat monitor: kill live workers whose beacon (or, if
        they never beat, whose spawn) is older than the staleness
        threshold.  The kill turns them into ordinary dead workers, so
        the existing re-dispatch path picks their groups up — long
        before the wall-clock pass timeout fires."""
        if self.heartbeat_timeout is None:
            return []
        stale = []
        now = obs.monotonic()
        for shard, p in procs.items():
            if p.poll() is not None:
                continue
            # age is bounded by time-since-spawn: a beacon left behind by
            # an earlier fit in the same cluster_dir (same shard/pass key)
            # must never condemn a freshly spawned worker that hasn't had
            # time to beat yet
            since_spawn = now - spawned_at.get(shard, now)
            age = pt.heartbeat_age(self.cluster_dir, shard, pass_idx)
            age = since_spawn if age is None else min(age, since_spawn)
            if age > self.heartbeat_timeout:
                p.kill()
                stale.append(shard)
        return stale

    def _run_pass(self, pass_idx: int, kind: str, Qa, Qb,
                  expect: dict) -> tuple:
        """Spawn → barrier → streamed tree merge (+ per-pass diagnostics)."""
        t0 = obs.monotonic()
        # stale-partial hygiene BEFORE the barrier polls: retry removals
        # that failed in earlier passes, then sweep this pass's group
        # range for leftovers of other fits.  Failures are never
        # swallowed — they land in diagnostics and stay queued.
        for p_old, g_old in list(self._clean_pending):
            if pt.clear_stale_partial(self.cluster_dir, p_old, g_old) is None:
                del self._clean_pending[(p_old, g_old)]
        for g, err in pt.sweep_stale_partials(
                self.cluster_dir, pass_idx, self.n_groups, expect).items():
            self._clean_pending[(pass_idx, g)] = err
        with obs.span("publish", pass_idx=int(pass_idx), kind=kind):
            pt.write_round(self.cluster_dir, pass_idx, Qa, Qb,
                           {**expect, "n_shards": self.n_workers,
                            "combine": self.combine_groups})
            procs = {s: self._spawn(s, pass_idx,
                                    extra_env=self.env_overrides.get(s))
                     for s in range(self.n_workers) if self._owned(s)}
        spawned_at = {s: obs.monotonic() for s in procs}
        n_spawned = len(procs)
        redispatched: List[int] = []
        stale_shards: List[int] = []
        attempts = 0
        deadline = (obs.monotonic() + self.worker_timeout
                    if self.worker_timeout else None)
        barrier = obs.span("barrier", pass_idx=int(pass_idx), kind=kind)
        barrier.__enter__()
        last_liveness = -1.0
        while True:
            plan, missing = pt.collect_coverage(self.cluster_dir, pass_idx,
                                                self.n_groups, expect)
            if not missing:
                break
            # liveness telemetry (~1 Hz): heartbeat ages of the live
            # workers, so `repro.obs report` can show per-shard health
            # next to the compute spans
            now = obs.monotonic()
            if now - last_liveness >= 1.0:
                last_liveness = now
                for shard, p in procs.items():
                    if p.poll() is not None:
                        continue
                    age = pt.heartbeat_age(self.cluster_dir, shard, pass_idx)
                    since = now - spawned_at.get(shard, now)
                    age = since if age is None else min(age, since)
                    obs.counter("heartbeat", shard=int(shard),
                                age_s=round(age, 3), pass_idx=int(pass_idx),
                                missing_groups=len(missing))
            stale_shards.extend(self._kill_stale(procs, pass_idx, spawned_at))
            timed_out = deadline is not None and obs.monotonic() > deadline
            if timed_out:
                for p in procs.values():  # stragglers: kill, then re-dispatch
                    if p.poll() is None:
                        p.kill()
            all_done = all(p.poll() is not None for p in procs.values())
            if all_done or timed_out:
                attempts += 1
                if attempts > self.max_redispatch:
                    raise RuntimeError(
                        f"pass {pass_idx}: merge groups {missing} still "
                        f"missing after {self.max_redispatch} re-dispatch "
                        f"round(s) — see {self.cluster_dir}/logs")
                # re-dispatch the dead/stale shards' groups to a fresh
                # repair worker (a "survivor" process; its shard id is
                # outside the strided range so cursors never collide)
                redispatched.extend(missing)
                obs.counter("redispatch", pass_idx=int(pass_idx),
                            groups=len(missing), attempt=attempts)
                repair = self.n_workers + attempts - 1
                procs = {repair: self._spawn(repair, pass_idx, groups=missing)}
                spawned_at = {repair: obs.monotonic()}
                n_spawned += 1
                deadline = (obs.monotonic() + self.worker_timeout
                            if self.worker_timeout else None)
            time.sleep(0.05)
        barrier.__exit__(None, None, None)
        for p in procs.values():
            p.poll()
        t_merge = obs.monotonic()
        r = self.reader
        # Streamed reduce: push each on-disk partial straight into the
        # fixed pairwise tree in group order and drop it — O(log G)
        # stats pytrees resident no matter how many groups the pass has
        # (the binding is re-validated per partial at merge time, the
        # at-most-once guard against a racing stale publisher).
        sanitize.set_context(pass_idx=int(pass_idx), kind=kind,
                             site="coordinator_merge")
        acc = SegmentedAccumulator(
            stats_init_fn(kind, r.da, r.db, self.cfg.sketch),
            r.n_chunks, self.merge_group)
        merge_span = obs.span("merge", pass_idx=int(pass_idx), kind=kind,
                              groups=self.n_groups, partials=len(plan))
        merge_span.__enter__()
        g = 0
        while g < self.n_groups:
            span, _ = plan[g]
            loaded = pt.read_partial(self.cluster_dir, pass_idx, g, span)
            assert loaded is not None, g
            stats, meta = loaded
            if not pt.binding_matches(meta, expect):  # at-most-once guard
                raise RuntimeError(f"stale partial for group {g} at merge time")
            trace_event("merge",
                        pt.partial_path(self.cluster_dir, pass_idx, g, span),
                        fit_id=expect["fit_id"], pass_idx=int(pass_idx),
                        group=int(g), span=int(span))
            # the sanctioned entry into the canonical tree: spans in
            # ascending group order, fold order owned by the accumulator
            # (a combined span is one subtree — bitwise identical to its
            # groups pushed individually)
            acc.push_group_span(g, stats, span)  # rcca: noqa[RCCA001]
            g += span
        merged = acc.result()
        merge_span.__exit__(None, None, None)
        sanitize.observe("pass_end", merged)
        now = obs.monotonic()
        obs.counter("workers", pass_idx=int(pass_idx), spawned=n_spawned)
        diag = {"wall_s": round(now - t0, 4),
                "merge_s": round(now - t_merge, 4),
                "merge_fan_in": len(plan),
                "workers_spawned": n_spawned,
                "redispatched_groups": sorted(set(redispatched)),
                "stale_heartbeat_shards": sorted(set(stale_shards)),
                "stale_clean_failures": {
                    f"p{p:05d}_g{g:05d}": e
                    for (p, g), e in sorted(self._clean_pending.items())}}
        return merged, diag

    # -- driving ----------------------------------------------------------

    def _materialize_omega(self, seed_a, seed_b):
        """(2,)-uint32 seeds → the tile-PRNG Ω bases, at a pass
        boundary where the coordinator itself needs the arrays
        (centering corrections, q = 0 finalize)."""
        from repro.kernels import rand as krand

        r, cfg = self.reader, self.cfg
        return (krand.dense_omega(seed_a, r.da, cfg.sketch, cfg.dtype),
                krand.dense_omega(seed_b, r.db, cfg.sketch, cfg.dtype))

    def fit(self, key: jax.Array) -> RCCAResult:
        """All q+1 passes across ``n_workers`` processes →
        :class:`RCCAResult`, bit-identical to the single-process
        drivers on the same store."""
        # fit identity only (binds partials to THIS fit across worker
        # respawns); never reaches the arithmetic or the merge order
        fit_id = uuid.uuid4().hex  # rcca: noqa[RCCA004]
        obs.set_context(fit_id=fit_id, role="coordinator")
        with obs.span("fit", site="coordinator", engine=self.engine,
                      n_workers=self.n_workers,
                      devices_per_worker=self.devices_per_worker):
            return self._fit(key, fit_id)

    def _fit(self, key: jax.Array, fit_id: str) -> RCCAResult:
        r, cfg = self.reader, self.cfg
        sanitize.reset()
        seeded = self.omega == "seeded"
        if seeded:
            # pass-0 rounds ship the 8-byte seeds in the Qa/Qb slots;
            # workers re-derive (jnp) or in-kernel generate (kernels) Ω
            Qa, Qb = omega_seeds(key)
        else:
            Qa, Qb = init_Q(key, r.da, r.db, cfg, omega=self.omega)
        passes = []
        for pass_idx in range(cfg.q + 1):
            kind = "final" if pass_idx == cfg.q else "power"
            expect = pt.binding_meta(
                fit_id=fit_id, pass_idx=pass_idx, kind=kind,
                engine=self.engine, fingerprint=r.fingerprint(),
                merge_group=self.merge_group, algo=algo_meta(cfg),
                omega=self.omega)
            with obs.span("pass", pass_idx=pass_idx, kind=kind,
                          site="coordinator"):
                stats, diag = self._run_pass(pass_idx, kind, Qa, Qb, expect)
                passes.append(diag)
                # n is an f32 accumulator: allow its rounding at huge row
                # counts while still catching whole wrong/duplicate chunks
                if abs(float(stats.n) - r.n) > max(1.0, 1e-6 * r.n):
                    raise RuntimeError(
                        f"pass {pass_idx} merged {float(stats.n):.0f} rows, "
                        f"store has {r.n} — a merge group folded the wrong "
                        "chunks")
                if kind == "power":
                    if seeded and pass_idx == 0 and cfg.center:
                        Qa, Qb = self._materialize_omega(Qa, Qb)
                    Qa, Qb = power_update_Q(stats, Qa, Qb, cfg)
        if seeded and cfg.q == 0:  # finalize needs the actual Ω
            Qa, Qb = self._materialize_omega(Qa, Qb)
        res = finalize_result(stats, Qa, Qb, cfg, r.da, r.db)
        res.diagnostics["cluster"] = {
            "n_workers": self.n_workers,
            "devices_per_worker": self.devices_per_worker,
            "topology": "hybrid" if self.devices_per_worker > 1 else "cluster",
            "n_groups": self.n_groups,
            "merge_group": self.merge_group,
            "combine_groups": self.combine_groups,
            "omega": self.omega,
            "fit_id": fit_id,
            "passes": passes,
        }
        if sanitize.enabled():
            res.diagnostics["sanitize"] = sanitize.snapshot()
            sanitize.dump()
        return res
