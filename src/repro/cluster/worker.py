"""One shard of one data pass — the cluster's map task.

    python -m repro.cluster.worker --store /data/corpus \
        --cluster-dir /data/cluster --shard 3 --n-shards 8 --pass-idx 0

Runnable under any external scheduler (the coordinator's subprocess
spawn is just one such scheduler): everything a worker needs beyond its
shard identity comes from the store manifest and the pass ROUND the
coordinator published (Qa/Qb bases, engine, merge-group size, binding
metadata).  The worker streams its merge groups — strided whole-group
assignment via ``ViewStoreReader.row_shard(group=...)``, prefetched
through :class:`~repro.store.prefetch.ChunkPrefetcher` — folds each
group's chunks with the same jitted update the single-process drivers
use, and atomically publishes one partial per group.

Fault tolerance:

- a per-worker CURSOR (current group fold + next chunk) is checkpointed
  through ``repro.ckpt`` every ``ckpt_every`` chunks, so a killed
  worker re-run with the same shard id resumes MID-SHARD: published
  groups are skipped, the in-flight group continues from the cursor,
  and ``row_shard(start=...)`` seeks the store so the folded prefix is
  never re-read;
- partials already published (by a previous incarnation or by a repair
  worker that took over this shard) are detected by their binding
  metadata and skipped — publishing is idempotent and merge-safe
  because partial content is a deterministic function of (store,
  round, group).

``RCCA_CLUSTER_KILL_AT=<pass>:<chunk>`` simulates a hard crash right
after folding that chunk (tests/test_cluster_failures.py) — the CLI
dies with ``os._exit``, skipping every cleanup path, exactly like a
lost machine.
"""

from __future__ import annotations

import argparse
import os
from typing import Optional, Sequence

import jax

from repro.ckpt import CheckpointManager
from repro.core.rcca import SegmentedAccumulator, jit_update_fn, stats_init_fn
from repro.store import ViewStoreReader, prefetched, shard_chunks

from . import partials as pt

KILL_ENV = "RCCA_CLUSTER_KILL_AT"


class WorkerKilled(RuntimeError):
    """Injected crash (see :data:`KILL_ENV`)."""


def _parse_kill(pass_idx: int) -> Optional[int]:
    spec = os.environ.get(KILL_ENV)
    if not spec:
        return None
    p, _, c = spec.partition(":")
    return int(c) if int(p) == pass_idx else None


def run_worker(store: str, cluster_dir: str, shard: int, n_shards: int,
               pass_idx: int, *, groups: Optional[Sequence[int]] = None,
               prefetch: int = 2, ckpt_every: int = 4,
               round_wait_s: float = 30.0,
               kill_at_chunk: Optional[int] = None) -> int:
    """Process one shard of one pass; returns the number of partials
    this invocation published.  ``groups`` overrides the strided
    assignment (the coordinator's re-dispatch path)."""
    reader = ViewStoreReader(store)
    Qa, Qb, meta = pt.read_round(cluster_dir, pass_idx, wait_s=round_wait_s)
    if meta["fingerprint"] != reader.fingerprint():
        raise ValueError(
            f"round for pass {pass_idx} was published against a different "
            f"store (fingerprint {meta['fingerprint'][:12]}… != "
            f"{reader.fingerprint()[:12]}…)")
    if kill_at_chunk is None:
        kill_at_chunk = _parse_kill(pass_idx)

    kind, engine = meta["kind"], meta["engine"]
    G = int(meta["merge_group"])
    n_chunks = reader.n_chunks
    n_groups = -(-n_chunks // G)
    kt = Qa.shape[1]
    init_fn = stats_init_fn(kind, reader.da, reader.db, kt)
    upd = jit_update_fn(kind, engine)
    Qa, Qb = jax.device_put(Qa), jax.device_put(Qb)

    expect = {k: meta.get(k) for k in pt.BINDING_KEYS}
    if groups is None:
        owned = [g for g in range(shard, n_groups, n_shards)]
    else:
        owned = sorted(int(g) for g in groups)

    def group_done(g: int) -> bool:
        return pt.binding_matches(
            pt.partial_meta(cluster_dir, pass_idx, g), expect)

    # -- resume position --------------------------------------------------
    mgr = CheckpointManager(pt.worker_cursor_dir(cluster_dir, shard, pass_idx),
                            keep=2)
    todo = [g for g in owned if not group_done(g)]
    published = 0
    if not todo:
        return 0
    start_chunk = todo[0] * G
    current = init_fn()
    cur_meta = mgr.metadata(mgr.latest_step())
    if pt.binding_matches(cur_meta, expect) and cur_meta.get("shard") == shard:
        nxt, g0 = int(cur_meta["next_chunk"]), int(cur_meta["group"])
        # the cursor only helps if it sits mid-way through the FIRST
        # group still missing its partial — anything else (stale cursor,
        # a hole left by a repair worker) is redone from group start
        if todo[0] == g0 and g0 * G < nxt < min(n_chunks, (g0 + 1) * G):
            tree, _ = mgr.restore({"current": init_fn()})
            current = tree["current"]
            start_chunk = nxt

    # -- stream ----------------------------------------------------------
    if groups is None:
        idxs = list(shard_chunks(shard, n_shards, n_chunks,
                                 start=start_chunk, group=G))
        src = reader.row_shard(shard, n_shards, start=start_chunk, group=G)
    else:
        idxs = [c for g in todo for c in range(g * G, min(n_chunks, (g + 1) * G))
                if c >= start_chunk]
        src = (reader.get_chunk(i) for i in iter(idxs))
    src = prefetched(src, depth=prefetch)
    try:
        done_since_cursor = 0
        for chunk_idx, (a, b) in zip(idxs, src):
            g = chunk_idx // G
            if g not in todo:  # published by a previous incarnation
                continue
            current = upd(current, a, b, Qa, Qb)
            done_since_cursor += 1
            end_of_group = (chunk_idx + 1) % G == 0 or chunk_idx + 1 == n_chunks
            if end_of_group:
                jax.block_until_ready(current)
                if not group_done(g):  # idempotent re-publication guard
                    pt.write_partial(cluster_dir, pass_idx, g, current,
                                     expect, shard=shard, n_shards=n_shards)
                published += 1
                current = init_fn()
            if done_since_cursor % ckpt_every == 0 or end_of_group:
                mgr.save(chunk_idx, {"current": current},
                         metadata={**expect, "next_chunk": chunk_idx + 1,
                                   "group": (chunk_idx + 1) // G,
                                   "shard": shard})
            if kill_at_chunk is not None and chunk_idx >= kill_at_chunk:
                raise WorkerKilled(f"injected kill at chunk {chunk_idx}")
    finally:
        src.close()
    return published


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", required=True,
                    help="view store path or URI (repro.store)")
    ap.add_argument("--cluster-dir", required=True)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--n-shards", type=int, required=True)
    ap.add_argument("--pass-idx", type=int, required=True)
    ap.add_argument("--groups", default=None,
                    help="comma-separated merge-group ids overriding the "
                         "strided assignment (coordinator re-dispatch)")
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--round-wait-s", type=float, default=30.0)
    args = ap.parse_args(argv)
    groups = None
    if args.groups:
        groups = [int(g) for g in args.groups.split(",")]
    try:
        n = run_worker(args.store, args.cluster_dir, args.shard, args.n_shards,
                       args.pass_idx, groups=groups, prefetch=args.prefetch,
                       ckpt_every=args.ckpt_every,
                       round_wait_s=args.round_wait_s)
    except WorkerKilled as e:
        print(f"[worker {args.shard}] {e}", flush=True)
        os._exit(3)  # hard death: no cleanup, like a lost machine
    print(f"[worker {args.shard}] pass {args.pass_idx}: "
          f"published {n} partial(s)", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
