"""One shard of one data pass — the cluster's map task.

    python -m repro.cluster.worker --store /data/corpus \
        --cluster-dir /data/cluster --shard 3 --n-shards 8 --pass-idx 0

Runnable under any external scheduler (the coordinator's subprocess
spawn is just one such scheduler): everything a worker needs beyond its
shard identity comes from the store manifest and the pass ROUND the
coordinator published (Qa/Qb bases, engine, merge-group size, binding
metadata).  Under ``omega="seeded"`` the pass-0 round's Qa/Qb slots
hold the per-view (2,)-uint32 Ω seeds instead of bases: the kernels
engine generates Ω tiles inside the fused kernels (never materializing
the ``(d, k̃)`` array), the jnp engine re-derives Ω locally — either
way the worker stays stateless and the broadcast is 8 bytes per view.  The worker streams its merge groups — strided whole-group
assignment via ``ViewStoreReader.row_shard(group=...)``, prefetched
through :class:`~repro.store.prefetch.ChunkPrefetcher` — folds each
group's chunks through the ONE canonical fold loop
(``repro.exec.run_fold`` feeding a sink-mode
``SegmentedAccumulator``), and atomically publishes one partial per
group.

With ``--devices N > 1`` the worker is a HYBRID worker: it builds a
1-D mesh over its local devices and folds whole merge groups
one-per-device under shard_map (``repro.exec.fold_groups_on_mesh``) —
each group's left-fold runs on a single device with the exact
per-chunk update arithmetic, so the published partials are bitwise
identical to the sequential worker's and the coordinator's tree merge
(and the final result) cannot tell the layouts apart.  On hosts
without accelerators the coordinator forces
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` into the
worker's environment, so the layout is exercisable anywhere.

Fault tolerance:

- a per-worker CURSOR (current group fold + next chunk) is checkpointed
  through ``repro.ckpt`` every ``ckpt_every`` chunks, so a killed
  worker re-run with the same shard id resumes MID-SHARD: published
  groups are skipped, the in-flight group continues from the cursor,
  and ``row_shard(start=...)`` seeks the store so the folded prefix is
  never re-read.  (Device-parallel workers publish whole groups and
  resume at group granularity — published groups are skipped, the rest
  are redone.)
- partials already published (by a previous incarnation or by a repair
  worker that took over this shard) are detected by their binding
  metadata and skipped — publishing is idempotent and merge-safe
  because partial content is a deterministic function of (store,
  round, group);
- a per-shard HEARTBEAT beacon is touched at start and at every
  merge-group boundary / cursor save; the coordinator re-dispatches
  shards whose beacon goes stale (a stuck-but-alive worker) without
  waiting for the wall-clock pass timeout.

``RCCA_CLUSTER_KILL_AT=<pass>:<chunk>`` simulates a hard crash right
after folding that chunk (tests/test_cluster_failures.py) — the CLI
dies with ``os._exit``, skipping every cleanup path, exactly like a
lost machine.  ``RCCA_CLUSTER_HANG_AT=<pass>:<chunk>`` instead wedges
the worker in a sleep loop at that chunk (heartbeat goes stale, the
process stays alive) — the stuck-worker case only heartbeats detect.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Optional, Sequence

import numpy as np

import jax

from repro import obs
from repro.ckpt import CheckpointManager
from repro.core.rcca import (jit_seeded_update_fn, jit_update_fn,
                             seeded_update_fn, stats_init_fn, update_fn)
from repro.exec import (SegmentedAccumulator, SpanCombiner,
                        fold_groups_on_mesh, n_full_chunks, run_fold)
from repro.store import ViewStoreReader, prefetched, shard_chunks

from . import partials as pt

KILL_ENV = "RCCA_CLUSTER_KILL_AT"
HANG_ENV = "RCCA_CLUSTER_HANG_AT"


class WorkerKilled(RuntimeError):
    """Injected crash (see :data:`KILL_ENV`)."""


def _parse_injection(env: str, pass_idx: int) -> Optional[int]:
    spec = os.environ.get(env)
    if not spec:
        return None
    p, _, c = spec.partition(":")
    return int(c) if int(p) == pass_idx else None


def _cost_fn(kind: str, engine: str, kt: int, q_dtype, seeded: bool):
    if not obs.enabled():
        return None
    from repro.obs.cost import chunk_cost_fn

    return chunk_cost_fn(kind, engine, kt, q_dtype, seeded=seeded)


def _hang_forever(shard: int, chunk_idx: int) -> None:
    print(f"[worker {shard}] injected hang at chunk {chunk_idx}", flush=True)
    while True:  # stuck-but-alive: no beats, no exit
        time.sleep(0.5)


def run_worker(store: str, cluster_dir: str, shard: int, n_shards: int,
               pass_idx: int, *, groups: Optional[Sequence[int]] = None,
               prefetch: int = 2, ckpt_every: int = 4,
               round_wait_s: float = 30.0,
               kill_at_chunk: Optional[int] = None,
               hang_at_chunk: Optional[int] = None,
               devices: int = 1) -> int:
    """Process one shard of one pass; returns the number of partials
    this invocation published.  ``groups`` overrides the strided
    assignment (the coordinator's re-dispatch path); ``devices > 1``
    folds merge groups one-per-device over the local mesh (the Hybrid
    topology's worker side)."""
    reader = ViewStoreReader(store)
    Qa, Qb, meta = pt.read_round(cluster_dir, pass_idx, wait_s=round_wait_s)
    if meta["fingerprint"] != reader.fingerprint():
        raise ValueError(
            f"round for pass {pass_idx} was published against a different "
            f"store (fingerprint {meta['fingerprint'][:12]}… != "
            f"{reader.fingerprint()[:12]}…)")
    if kill_at_chunk is None:
        kill_at_chunk = _parse_injection(KILL_ENV, pass_idx)
    if hang_at_chunk is None:
        hang_at_chunk = _parse_injection(HANG_ENV, pass_idx)

    kind, engine = meta["kind"], meta["engine"]
    obs.set_context(fit_id=meta.get("fit_id"), role=f"worker{shard:03d}",
                    shard=shard)
    G = int(meta["merge_group"])
    n_chunks = reader.n_chunks
    n_groups = -(-n_chunks // G)
    # k̃ comes from the binding metadata, not the payload shape: a
    # seeded pass-0 round's Qa/Qb slots hold (2,)-uint32 seeds
    algo = meta["algo"]
    kt = int(algo["k"]) + int(algo["p"])
    q_dtype = np.dtype(algo["dtype"])
    seeds = meta.get("omega", "materialized") == "seeded" and pass_idx == 0
    if seeds and engine != "kernels":
        # jnp engine: re-derive Ω locally from the 8-byte seed (still
        # stateless — nothing but the round was read), then run the
        # standard update path
        from repro.kernels import rand as krand

        Qa = krand.dense_omega(Qa, reader.da, kt, q_dtype)
        Qb = krand.dense_omega(Qb, reader.db, kt, q_dtype)
        seeds = False
    init_fn = stats_init_fn(kind, reader.da, reader.db, kt)
    if seeds:  # kernels engine: Ω tiles generated inside the kernels
        upd = jit_seeded_update_fn(kind, kt, q_dtype)
        upd_raw = seeded_update_fn(kind, kt, q_dtype)
    else:
        upd = jit_update_fn(kind, engine)
        upd_raw = update_fn(kind, engine)
    Qa, Qb = jax.device_put(Qa), jax.device_put(Qb)
    pt.touch_heartbeat(cluster_dir, shard, pass_idx)

    expect = {k: meta.get(k) for k in pt.BINDING_KEYS}
    # combiner-on-the-way-out: pre-merge runs of `combine` consecutive
    # groups into one span partial before publishing (shrinks the
    # coordinator's merge fan-in by that factor); 1 = off, the
    # historical per-group protocol
    combine = int(meta.get("combine", 1))
    if groups is None:
        owned = [g for g in range(n_groups)
                 if (g // combine) % n_shards == shard]
    else:
        owned = sorted(int(g) for g in groups)

    def group_done(g: int) -> bool:
        """Published already — individually or inside a combined span
        (check every aligned span that could contain g)."""
        s = 1
        while s <= combine:
            if pt.binding_matches(
                    pt.partial_meta(cluster_dir, pass_idx, g - g % s, s),
                    expect):
                return True
            s <<= 1
        return False

    todo = [g for g in owned if not group_done(g)]
    if not todo:
        return 0
    state = {"published": 0}

    def publish_span(g: int, span: int, stats) -> None:
        """The (combined) group sink: beat, publish-if-new, count."""
        with obs.span("publish", group=int(g), span=int(span)):
            jax.block_until_ready(stats)
            if not pt.binding_matches(  # idempotent re-publication guard
                    pt.partial_meta(cluster_dir, pass_idx, g, span), expect):
                pt.write_partial(cluster_dir, pass_idx, g, stats, expect,
                                 shard=shard, n_shards=n_shards, span=span)
            state["published"] += 1
            pt.touch_heartbeat(cluster_dir, shard, pass_idx)

    combiner = SpanCombiner(combine, publish_span)

    def publish(g: int, stats) -> None:
        if combine > 1:
            combiner.emit(g, stats)
        else:
            publish_span(g, 1, stats)

    # -- device-parallel (hybrid) shard ----------------------------------
    if devices > 1:
        n_dev = len(jax.devices())
        if n_dev < devices:
            raise RuntimeError(
                f"worker asked for {devices} devices but only {n_dev} "
                "visible — the spawner must set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={devices} (or "
                "provide real accelerators) before jax initializes")
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:devices]), ("dev",))

        def emit(g: int, stats) -> None:
            publish(g, stats)
            # failure injection at group granularity: the device fold
            # publishes whole groups, so "after chunk c" means "after
            # the group containing c"
            last_chunk = min(n_chunks, (g + 1) * G) - 1
            if hang_at_chunk is not None and last_chunk >= hang_at_chunk:
                _hang_forever(shard, last_chunk)
            if kill_at_chunk is not None and last_chunk >= kill_at_chunk:
                raise WorkerKilled(
                    f"injected kill after group {g} (chunk {last_chunk})")

        with obs.span("worker_pass", pass_idx=int(pass_idx), kind=kind,
                      shard=shard, site="hybrid"):
            fold_groups_on_mesh(
                lambda i: reader.get_chunk(i), todo, upd_raw,
                upd, init_fn, Qa, Qb, mesh=mesh, merge_group=G,
                n_chunks=n_chunks, full_chunks=n_full_chunks(reader),
                emit=emit, prefetch=prefetch,
                span_attrs={"kind": kind, "engine": engine,
                            "pass_idx": int(pass_idx)},
                cost_fn=_cost_fn(kind, engine, kt, q_dtype, seeds))
            combiner.flush()  # trailing short run (end of stream)
        return state["published"]

    # -- sequential shard --------------------------------------------------

    # resume position
    mgr = CheckpointManager(pt.worker_cursor_dir(cluster_dir, shard, pass_idx),
                            keep=2)
    start_chunk = todo[0] * G
    current = init_fn()
    cur_meta = mgr.metadata(mgr.latest_step())
    if pt.binding_matches(cur_meta, expect) and cur_meta.get("shard") == shard:
        nxt, g0 = int(cur_meta["next_chunk"]), int(cur_meta["group"])
        # the cursor only helps if it sits mid-way through the FIRST
        # group still missing its partial — anything else (stale cursor,
        # a hole left by a repair worker) is redone from group start
        if todo[0] == g0 and g0 * G < nxt < min(n_chunks, (g0 + 1) * G):
            tree, _ = mgr.restore({"current": init_fn()})
            current = tree["current"]
            start_chunk = nxt

    # stream (striping in G*combine-chunk runs keeps whole combine-runs
    # on one worker, so the combiner sees unbroken aligned runs)
    if groups is None:
        idxs = list(shard_chunks(shard, n_shards, n_chunks,
                                 start=start_chunk, group=G * combine))
        src = reader.row_shard(shard, n_shards, start=start_chunk,
                               group=G * combine)
    else:
        idxs = [c for g in todo for c in range(g * G, min(n_chunks, (g + 1) * G))
                if c >= start_chunk]
        src = (reader.get_chunk(i) for i in iter(idxs))
    src = prefetched(src, depth=prefetch)

    todo_set = set(todo)
    counters = {"since_cursor": 0}

    def cb(chunk_idx: int, acc: SegmentedAccumulator) -> None:
        counters["since_cursor"] += 1
        end_of_group = (chunk_idx + 1) % G == 0 or chunk_idx + 1 == n_chunks
        if counters["since_cursor"] % ckpt_every == 0 or end_of_group:
            mgr.save(chunk_idx, {"current": acc.current},
                     metadata={**expect, "next_chunk": chunk_idx + 1,
                               "group": (chunk_idx + 1) // G,
                               "shard": shard})
            pt.touch_heartbeat(cluster_dir, shard, pass_idx)
        if hang_at_chunk is not None and chunk_idx >= hang_at_chunk:
            _hang_forever(shard, chunk_idx)
        if kill_at_chunk is not None and chunk_idx >= kill_at_chunk:
            raise WorkerKilled(f"injected kill at chunk {chunk_idx}")

    acc = SegmentedAccumulator(init_fn, n_chunks, G, sink=publish)
    acc.current = current
    with obs.span("worker_pass", pass_idx=int(pass_idx), kind=kind,
                  shard=shard, site="worker"):
        try:
            # published-by-someone-else groups are read-and-dropped, not
            # folded (the stream already carries them; folding them would
            # double-publish and corrupt the cursor's group accounting)
            run_fold(((i, ab) for i, ab in zip(idxs, src)
                      if i // G in todo_set),
                     upd, acc, Qa, Qb, on_chunk=cb,
                     span_attrs={"kind": kind, "engine": engine,
                                 "pass_idx": int(pass_idx)},
                     cost_fn=_cost_fn(kind, engine, kt, q_dtype, seeds))
            combiner.flush()  # trailing short run (end of stream)
        finally:
            src.close()
    return state["published"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", required=True,
                    help="view store path or URI (repro.store)")
    ap.add_argument("--cluster-dir", required=True)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--n-shards", type=int, required=True)
    ap.add_argument("--pass-idx", type=int, required=True)
    ap.add_argument("--groups", default=None,
                    help="comma-separated merge-group ids overriding the "
                         "strided assignment (coordinator re-dispatch)")
    ap.add_argument("--devices", type=int, default=1,
                    help="local devices to fold merge groups over "
                         "(>1 = the Hybrid topology's device-parallel "
                         "worker; needs that many visible jax devices)")
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--round-wait-s", type=float, default=30.0)
    args = ap.parse_args(argv)
    groups = None
    if args.groups:
        groups = [int(g) for g in args.groups.split(",")]
    try:
        n = run_worker(args.store, args.cluster_dir, args.shard, args.n_shards,
                       args.pass_idx, groups=groups, prefetch=args.prefetch,
                       ckpt_every=args.ckpt_every,
                       round_wait_s=args.round_wait_s, devices=args.devices)
    except WorkerKilled as e:
        print(f"[worker {args.shard}] {e}", flush=True)
        os._exit(3)  # hard death: no cleanup, like a lost machine
    print(f"[worker {args.shard}] pass {args.pass_idx}: "
          f"published {n} partial(s)", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
