"""On-disk partial sufficient statistics — the cluster's merge currency.

The map/combine/reduce contract of the two-pass algorithm (Algorithm 1
is a sum of per-chunk updates, so per-worker statistics merge exactly —
the shape Scalable-CCA frames for Hadoop-style execution):

- a PARTIAL is the sum of one merge group's chunk updates (``rcca.
  MERGE_GROUP_CHUNKS`` chunks), written atomically through
  ``repro.ckpt`` as a versioned checkpoint directory whose metadata
  binds it to everything that must match for the merge to be valid:
  the fit id, pass index, store fingerprint, engine, algorithm
  hyper-parameters, merge-group size and the shard that produced it;
- a ROUND is the coordinator's per-pass broadcast: the ``Qa``/``Qb``
  bases every worker of that pass projects against, under the same
  binding metadata.  Under ``omega="seeded"`` the first pass's round
  carries the per-view ``(2,)``-uint32 Ω seeds in the Qa/Qb slots
  instead of the ``(d, k̃)`` bases — workers are stateless for pass 0
  (kernels engine: Ω tiles generated in-kernel; jnp engine: Ω
  re-derived locally from the seed).  Workers read the round, stream
  their merge groups, and publish one partial per group;
- the coordinator merges partials with ``rcca.reduce_group_partials``
  — the fixed pairwise tree over group indices — so the result is
  bit-identical to the single-process drivers for ANY worker count and
  ANY completion order, and each group id enters the reduction at most
  once no matter how many workers raced to produce it (partial content
  is deterministic, so duplicate publications are byte-identical and
  last-write-wins is safe).

Layout under a cluster directory::

    cluster/
      rounds/pass_00000/          # Qa, Qb + round metadata (repro.ckpt)
      partials/p00000_g00003/     # one merge group's stats + metadata
      workers/shard_000/pass_00000/   # per-worker resume cursors
      heartbeats/shard_000_p00000 # liveness beacons (mtime = last beat)
      logs/w000_p00000.log        # captured worker stdout/stderr

Heartbeats are the coordinator's liveness signal beyond process exit
codes: a worker touches its per-shard beacon at start and at every
merge-group boundary / cursor save, so a stuck (but alive) worker goes
stale long before the wall-clock ``worker_timeout`` — the first
scheduler signal of the ROADMAP's speculative-re-dispatch follow-up.
"""

from __future__ import annotations

import os
import re
import shutil
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro import obs
from repro.analysis.protocol import trace_event
from repro.ckpt import load_flat, load_metadata, save_pytree
from repro.core.rcca import FinalStats, PowerStats

PARTIAL_VERSION = 1

#: Metadata keys that must agree between a round and every partial
#: merged under it — the at-most-once / staleness guard.  ``omega`` is
#: binding because Ω provenance changes what a pass-0 round's Qa/Qb
#: payload even IS (seeded rounds ship (2,)-uint32 seeds, not bases).
BINDING_KEYS = ("version", "fit_id", "pass_idx", "kind", "engine",
                "fingerprint", "merge_group", "algo", "omega")


def round_dir(cluster_dir: str, pass_idx: int) -> str:
    return os.path.join(cluster_dir, "rounds", f"pass_{pass_idx:05d}")


def partial_path(cluster_dir: str, pass_idx: int, group: int,
                 span: int = 1) -> str:
    """Partial directory for ``span`` consecutive merge groups starting
    at ``group``.  ``span == 1`` keeps the historical per-group path, so
    combined (``x{span}``) and individual partials never collide — a
    repair worker re-publishing group ``g`` individually cannot race a
    combined span that happens to start there."""
    name = f"p{pass_idx:05d}_g{group:05d}"
    if span > 1:
        name += f"x{span}"
    return os.path.join(cluster_dir, "partials", name)


#: partial directory names: p<pass>_g<group>[x<span>] (staging suffixes
#: ``.stage<pid>`` intentionally do not match)
_PARTIAL_RE = re.compile(r"^p(\d{5})_g(\d{5})(?:x(\d+))?$")


def scan_partials(cluster_dir: str, pass_idx: int) -> List[Tuple[int, int]]:
    """All ``(group, span)`` partials of a pass present on disk —
    published or torn; validity is the caller's check."""
    d = os.path.join(cluster_dir, "partials")
    try:
        entries = os.listdir(d)
    except FileNotFoundError:
        return []
    out = []
    for name in entries:
        m = _PARTIAL_RE.match(name)
        if m and int(m.group(1)) == pass_idx:
            out.append((int(m.group(2)), int(m.group(3) or 1)))
    return sorted(out)


def worker_cursor_dir(cluster_dir: str, shard: int, pass_idx: int) -> str:
    return os.path.join(cluster_dir, "workers", f"shard_{shard:03d}",
                        f"pass_{pass_idx:05d}")


def heartbeat_path(cluster_dir: str, shard: int, pass_idx: int) -> str:
    return os.path.join(cluster_dir, "heartbeats",
                        f"shard_{shard:03d}_p{pass_idx:05d}")


def touch_heartbeat(cluster_dir: str, shard: int, pass_idx: int) -> None:
    """Beat once: create/refresh the beacon's mtime (cheap — an utime
    on the shared FS; workers beat at start and at every merge-group
    boundary and cursor save)."""
    path = heartbeat_path(cluster_dir, shard, pass_idx)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a"):
        pass
    os.utime(path, None)


def heartbeat_age(cluster_dir: str, shard: int, pass_idx: int) -> Optional[float]:
    """Seconds since the shard last beat, or None if it never has —
    the coordinator compares this against its staleness threshold."""
    try:
        # liveness wall-clock: feeds only the staleness policy (whether
        # to re-dispatch), never the pass arithmetic
        return max(0.0, obs.wall() - os.path.getmtime(
            heartbeat_path(cluster_dir, shard, pass_idx)))
    except OSError:
        return None


def binding_meta(*, fit_id: str, pass_idx: int, kind: str, engine: str,
                 fingerprint: str, merge_group: int, algo: dict,
                 omega: str = "materialized") -> dict:
    return {"version": PARTIAL_VERSION, "fit_id": fit_id,
            "pass_idx": int(pass_idx), "kind": kind, "engine": engine,
            "fingerprint": fingerprint, "merge_group": int(merge_group),
            "algo": algo, "omega": omega}


def binding_matches(meta: Optional[dict], expect: dict) -> bool:
    """True when a round/partial's binding metadata matches ``expect``
    on every :data:`BINDING_KEYS` entry — anything else is stale (an
    earlier fit, another store, another engine...) and must not merge."""
    if meta is None:
        return False
    return all(meta.get(k) == expect.get(k) for k in BINDING_KEYS)


# -- rounds (coordinator → workers) ---------------------------------------


def write_round(cluster_dir: str, pass_idx: int, Qa, Qb, meta: dict) -> None:
    d = round_dir(cluster_dir, pass_idx)
    save_pytree({"Qa": Qa, "Qb": Qb}, d, metadata=meta)
    trace_event("commit", d, pass_idx=int(pass_idx))


def read_round(cluster_dir: str, pass_idx: int, *,
               wait_s: float = 0.0) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Load a pass round, optionally waiting for the coordinator to
    publish it (a worker under an external scheduler may start first)."""
    d = round_dir(cluster_dir, pass_idx)
    deadline = obs.monotonic() + wait_s
    while not os.path.exists(os.path.join(d, "manifest.json")):
        if obs.monotonic() >= deadline:
            raise FileNotFoundError(
                f"no round published for pass {pass_idx} under {cluster_dir!r}")
        time.sleep(0.05)
    flat, meta = load_flat(d)
    trace_event("read", d, pass_idx=int(pass_idx))
    return jnp.asarray(flat["Qa"]), jnp.asarray(flat["Qb"]), meta


# -- partials (workers → coordinator) -------------------------------------


def _stats_from_flat(flat: dict, kind: str):
    cls = PowerStats if kind == "power" else FinalStats
    return cls(**{f: jnp.asarray(flat[f]) for f in cls._fields})


def write_partial(cluster_dir: str, pass_idx: int, group: int, stats,
                  meta: dict, *, shard: int, n_shards: int,
                  span: int = 1) -> None:
    """Atomically publish the statistics of ``span`` consecutive merge
    groups starting at ``group`` (``span == 1``: one plain per-group
    partial; ``span > 1``: a worker-combined aligned dyadic span — see
    ``repro.exec.SpanCombiner``).

    Concurrent publication of the same group id (a re-dispatched shard
    racing its presumed-dead owner) is harmless: content is
    deterministic, the staging rename is atomic, and the loser's copy
    is discarded.
    """
    final = partial_path(cluster_dir, pass_idx, group, span)
    os.makedirs(os.path.dirname(final), exist_ok=True)
    staging = f"{final}.stage{os.getpid()}"
    save_pytree(stats._asdict(), staging,
                metadata={**meta, "group": int(group), "span": int(span),
                          "shard": int(shard), "n_shards": int(n_shards)})
    trace_event("stage_write", staging, group=int(group), shard=int(shard))
    try:
        os.rename(staging, final)
        trace_event("commit", final, group=int(group), shard=int(shard))
    except OSError:
        existing = partial_meta(cluster_dir, pass_idx, group, span)
        if binding_matches(existing, meta):
            shutil.rmtree(staging, ignore_errors=True)  # a twin won the race
            trace_event("twin_drop", final, group=int(group),
                        shard=int(shard))
        else:  # stale leftover from an earlier fit — replace it
            shutil.rmtree(final, ignore_errors=True)
            os.rename(staging, final)
            trace_event("stale_replace", final, group=int(group),
                        shard=int(shard),
                        old_binding={k: existing.get(k) for k in BINDING_KEYS}
                        if existing else None,
                        new_binding={k: meta.get(k) for k in BINDING_KEYS})
            trace_event("commit", final, group=int(group), shard=int(shard))


def read_partial(cluster_dir: str, pass_idx: int, group: int,
                 span: int = 1) -> Optional[Tuple[object, dict]]:
    d = partial_path(cluster_dir, pass_idx, group, span)
    if not os.path.exists(os.path.join(d, "manifest.json")):
        return None
    flat, meta = load_flat(d)
    trace_event("read", d, group=int(group))
    return _stats_from_flat(flat, meta["kind"]), meta


def partial_meta(cluster_dir: str, pass_idx: int, group: int,
                 span: int = 1) -> Optional[dict]:
    """Metadata only — cheap validity polling for the barrier loop."""
    d = partial_path(cluster_dir, pass_idx, group, span)
    try:
        return load_metadata(d)
    except (FileNotFoundError, KeyError, ValueError):
        return None


def clear_stale_partial(cluster_dir: str, pass_idx: int,
                        group: int, span: int = 1) -> Optional[str]:
    """Remove a stale partial directory; returns an error string on
    failure, None on success (including already-gone).

    A failed removal is never silently swallowed: staleness is decided
    by binding metadata, so a leftover directory cannot corrupt a
    merge, but an undeletable one means the shared FS is misbehaving —
    the coordinator surfaces it in diagnostics and retries at the next
    sweep, and the protocol trace records both outcomes.
    """
    path = partial_path(cluster_dir, pass_idx, group, span)
    if not os.path.lexists(path):
        return None
    try:
        shutil.rmtree(path)
    except OSError as e:
        trace_event("clean_fail", path, group=int(group), error=str(e))
        return f"{path}: {e}"
    trace_event("clean", path, group=int(group))
    return None


def sweep_stale_partials(cluster_dir: str, pass_idx: int, n_groups: int,
                         expect: dict) -> Dict[int, str]:
    """Delete every published partial of a pass whose binding does NOT
    match ``expect`` (leftovers of an earlier fit in a reused
    cluster_dir).  Returns {group: error} for removals that FAILED —
    empty when the directory is clean."""
    failures: Dict[int, str] = {}
    for g, span in scan_partials(cluster_dir, pass_idx):
        if g >= n_groups:
            continue
        meta = partial_meta(cluster_dir, pass_idx, g, span)
        if meta is None or binding_matches(meta, expect):
            continue
        err = clear_stale_partial(cluster_dir, pass_idx, g, span)
        if err is not None:
            failures[g] = err
    return failures


def collect_partials(cluster_dir: str, pass_idx: int, n_groups: int,
                     expect: dict) -> Dict[int, dict]:
    """Group id → metadata for every VALID published per-group
    (span-1) partial of a pass (stale ones are ignored — and thus
    re-dispatched by the barrier).  Combined spans are the coverage
    collector's job (:func:`collect_coverage`)."""
    out = {}
    for g in range(n_groups):
        meta = partial_meta(cluster_dir, pass_idx, g)
        if binding_matches(meta, expect):
            out[g] = meta
    return out


def collect_coverage(
        cluster_dir: str, pass_idx: int, n_groups: int, expect: dict,
) -> Tuple[Dict[int, Tuple[int, dict]], List[int]]:
    """Greedy span-aware coverage of a pass's merge groups.

    Returns ``(plan, missing)``: ``plan`` maps a start group to the
    ``(span, meta)`` of the valid partial chosen to cover
    ``[start, start + span)`` — walking it in ascending start order
    visits every covered group exactly once — and ``missing`` lists the
    groups no valid partial covers (the barrier's re-dispatch set).  At
    each uncovered group the widest valid aligned span wins (fewest
    reads); overlapping alternatives are byte-identical subtrees of the
    same canonical reduction, so the choice cannot change the merge.
    """
    candidates: Dict[int, Dict[int, dict]] = {}
    for g, span in scan_partials(cluster_dir, pass_idx):
        if span & (span - 1) or g % span or g + span > n_groups:
            continue  # never written by a correct worker: unusable
        meta = partial_meta(cluster_dir, pass_idx, g, span)
        if binding_matches(meta, expect) and int(meta.get("span", 1)) == span:
            candidates.setdefault(g, {})[span] = meta
    plan: Dict[int, Tuple[int, dict]] = {}
    missing: List[int] = []
    g = 0
    while g < n_groups:
        spans = candidates.get(g)
        if not spans:
            missing.append(g)
            g += 1
            continue
        span = max(spans)
        plan[g] = (span, spans[span])
        g += span
    return plan, missing
