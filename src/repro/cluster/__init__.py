"""Multi-worker map/combine/reduce execution of the two-pass algorithm.

The paper's Hadoop-suitability claim as a process-level subsystem:

- :mod:`repro.cluster.partials` — mergeable sufficient statistics as a
  versioned on-disk format (the map output / combine input);
- :mod:`repro.cluster.worker` — one shard of one pass, resumable
  mid-shard, runnable under any external scheduler;
- :mod:`repro.cluster.coordinator` — spawns workers, runs the per-pass
  barrier with straggler/failure re-dispatch, and merges partials with
  a deterministic fixed-order pairwise tree that reproduces the
  single-process drivers BIT-IDENTICALLY for any worker count.
"""

from .coordinator import ClusterCoordinator, algo_meta
from .worker import WorkerKilled, run_worker

__all__ = ["ClusterCoordinator", "WorkerKilled", "algo_meta", "run_worker"]
