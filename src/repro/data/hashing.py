"""Feature hashing (Weinberger et al., 2009) — the paper's featurizer.

Bag-of-words composed with inner-product-preserving hashing: token t
maps to slot h(t) mod d with sign s(t) ∈ {±1}.  The paper uses 2^19
slots per language view on Europarl.
"""

from __future__ import annotations

import numpy as np


def _mix(x: np.ndarray, seed: int) -> np.ndarray:
    """Cheap splitmix64-style integer hash (vectorized, deterministic)."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint64) + np.uint64((seed * 0x9E3779B97F4A7C15) % 2**64)
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return x


class HashingFeaturizer:
    """Maps integer token-id bags to dense hashed feature rows."""

    def __init__(self, n_slots: int, seed: int = 0):
        self.n_slots = n_slots
        self.seed = seed

    def slots(self, token_ids: np.ndarray) -> np.ndarray:
        return (_mix(token_ids, self.seed) % np.uint64(self.n_slots)).astype(np.int64)

    def signs(self, token_ids: np.ndarray) -> np.ndarray:
        return np.where(_mix(token_ids, self.seed + 1) & np.uint64(1), 1.0, -1.0).astype(np.float32)

    def featurize(self, docs: list[np.ndarray]) -> np.ndarray:
        """docs: list of integer token-id arrays → (len(docs), n_slots)."""
        out = np.zeros((len(docs), self.n_slots), np.float32)
        for i, doc in enumerate(docs):
            if len(doc) == 0:
                continue
            s = self.slots(doc)
            np.add.at(out[i], s, self.signs(doc))
        return out

    def featurize_batch(self, token_mat: np.ndarray) -> np.ndarray:
        """token_mat: (n, L) padded token ids (0 = pad) → (n, n_slots)."""
        n, L = token_mat.shape
        out = np.zeros((n, self.n_slots), np.float32)
        valid = token_mat > 0
        rows = np.repeat(np.arange(n), L)[valid.ravel()]
        toks = token_mat.ravel()[valid.ravel()]
        np.add.at(out, (rows, self.slots(toks)), self.signs(toks))
        return out
