"""Data substrate: feature hashing, synthetic paired-view corpora
(Europarl stand-in with planted correlations), and LM token pipelines."""

from .hashing import HashingFeaturizer
from .synthetic import PlantedCCAData, SyntheticTokenStream, planted_views

__all__ = [
    "HashingFeaturizer",
    "PlantedCCAData",
    "SyntheticTokenStream",
    "planted_views",
]
