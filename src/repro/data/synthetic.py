"""Synthetic corpora.

PlantedCCAData — a Europarl stand-in: two views generated from a shared
latent with a known, power-law canonical-correlation spectrum, so every
benchmark curve (Fig 1/2a/3) has a checkable ground truth.  Generation
is chunked and deterministic per chunk index → the stream can be
replayed from any point (fault-tolerant data passes) and sharded by
row-range across workers without materializing n × d in memory.

SyntheticTokenStream — deterministic LM token batches for train steps.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class PlantedCCAData:
    """Two views A (n×da), B (n×db) with planted correlations.

    A = Z Wa + σa Ea,  B = Z Wb + σb Eb,  Z ~ N(0, I_r): the canonical
    correlations decay like a power law via per-component latent scales
    s_i = (i+1)^{-decay} — mimicking the paper's Fig-1 spectrum.
    """

    n: int
    da: int
    db: int
    rank: int = 64
    decay: float = 0.7
    noise: float = 0.5
    seed: int = 0
    chunk: int = 1024

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        scales = (np.arange(1, self.rank + 1, dtype=np.float32)) ** (-self.decay)
        self.scales = scales
        self.Wa = rng.standard_normal((self.rank, self.da), np.float32) / np.sqrt(self.da)
        self.Wb = rng.standard_normal((self.rank, self.db), np.float32) / np.sqrt(self.db)

    @property
    def n_chunks(self) -> int:
        return (self.n + self.chunk - 1) // self.chunk

    def get_chunk(self, idx: int) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic chunk — replayable from any index."""
        lo = idx * self.chunk
        hi = min(lo + self.chunk, self.n)
        m = hi - lo
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + idx)
        Z = rng.standard_normal((m, self.rank)).astype(np.float32) * self.scales
        Ea = rng.standard_normal((m, self.da)).astype(np.float32)
        Eb = rng.standard_normal((m, self.db)).astype(np.float32)
        A = Z @ self.Wa + self.noise * Ea / np.sqrt(self.da)
        B = Z @ self.Wb + self.noise * Eb / np.sqrt(self.db)
        return A, B

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for i in range(self.n_chunks):
            yield self.get_chunk(i)

    def materialize(self) -> Tuple[np.ndarray, np.ndarray]:
        """Small-scale only: stack all chunks (tests/benchmarks)."""
        As, Bs = zip(*list(self))
        return np.concatenate(As), np.concatenate(Bs)

    def row_shard(self, shard: int, n_shards: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Deterministic chunk assignment for distributed workers:
        worker w streams chunks w, w+n_shards, w+2·n_shards, ..."""
        for i in range(shard, self.n_chunks, n_shards):
            yield self.get_chunk(i)


@dataclasses.dataclass
class SyntheticTokenStream:
    """Deterministic (B, S) int32 token batches."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def get_batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7_919 + step)
        return rng.integers(0, self.vocab, (self.batch, self.seq + 1), dtype=np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1


def planted_views(key_seed: int, n: int, da: int, db: int, rank: int = 8,
                  noise: float = 0.5, decay: float = 0.7):
    """Convenience: materialized planted views as numpy arrays."""
    d = PlantedCCAData(n=n, da=da, db=db, rank=rank, decay=decay, noise=noise,
                       seed=key_seed, chunk=max(256, n // 8))
    return d.materialize()
