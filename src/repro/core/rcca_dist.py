"""Multi-pod distributed RandomizedCCA (shard_map over (pod, data, model)).

Sharding contract (see DESIGN.md §2):

- rows (n)   → mesh axes ``row_axes``  (default ("pod", "data"))
- features   → mesh axis  ``col_axis`` (default "model"); Qa/Qb/Ya/Yb are
  row-sharded over the same axis, so no da/db-sized tensor is ever
  replicated — the paper's binding constraint ("utility of storing Q, Y
  in main memory") becomes a per-device HBM constraint of d·k̃/|model|.

Per microbatch the only collectives are two psums of (mb × k̃) projected
activations over ``col_axis`` (~MBs); with ``engine="kernels"`` they
fold into the staged kernel pipeline at the phase boundary — the
``proj_stage`` kernel emits the local shard's partial P, the psum sums
it globally, and the sweep kernels consume the result (optionally
int8+error-feedback compressed via ``collective="fused-int8ef"``).  The
d-sized accumulators are psummed ONCE per pass over ``row_axes``.
Accumulation is bucketed so the large end-of-pass psum is split into
column buckets that overlap with the next microbatch's compute (XLA
async collectives) — the distributed-optimization trick from DESIGN.md
§5.

``orth`` is CholeskyQR2 with k̃×k̃ psum'd Grams (TPU-native; DESIGN §3).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.kernels.compat import shard_map

from .linalg import sym, tri_solve_right
from .rcca import DEFAULT_ENGINE, RCCAConfig, RCCAResult, finish, resolve_engine
from repro.exec.engine import pass_schedule


# --------------------------------------------------------------------------
# collective helpers
# --------------------------------------------------------------------------


def _psum(x, axes):
    if isinstance(axes, str):
        axes = (axes,)
    return jax.lax.psum(x, tuple(axes))


def dist_orth(Y: jax.Array, col_axis: Optional[str]):
    """Orthonormalize a row-sharded tall matrix: eigh-whitened first
    round + CholeskyQR cleanup (see linalg.orth); Grams psum over
    col_axis.  All collectives are k̃×k̃."""

    def gram(M):
        G = M.astype(jnp.float32).T @ M.astype(jnp.float32)
        if col_axis is not None:
            G = _psum(G, col_axis)
        return sym(G)

    from .linalg import eigh_whiten

    Q = eigh_whiten(Y, gram(Y))
    L2 = jnp.linalg.cholesky(gram(Q))
    return tri_solve_right(Q, L2).astype(Y.dtype)


# --------------------------------------------------------------------------
# data passes (run inside shard_map; a/b are LOCAL row×feature shards)
# --------------------------------------------------------------------------


def _microbatches(a: jax.Array, mb: Optional[int]):
    n_loc = a.shape[0]
    if mb is None or mb >= n_loc:
        return 1, n_loc
    assert n_loc % mb == 0, f"local rows {n_loc} not divisible by microbatch {mb}"
    return n_loc // mb, mb


def power_pass_local(a, b, Qa, Qb, *, row_axes, col_axis, microbatch=None,
                     compute_dtype=jnp.bfloat16, int8_reduce=False,
                     reduce_buckets=1, reduce_dtype=None, engine="jnp",
                     collective="fused"):
    """One range-finder pass over the local shard → global (Ya, Yb, stats).

    Returns Ya/Yb sharded like Qa/Qb (features over col_axis, replicated
    over rows) plus centering/λ statistics.

    ``engine="kernels"`` runs the per-microbatch matmuls as Pallas
    kernels on the local shards: fully fused project+accumulate when
    features are unsharded (col_axis None — P stays in VMEM), and the
    collective-fused staged pair when col_axis genuinely shards the
    features: the ``proj_stage`` kernel emits the *partial* P of the
    local feature shard, the (mb × k̃) psum happens at the phase
    boundary, and the ``powerpass_sweep`` kernel accumulates the
    globally-summed P — no unfused matmul pair around a full-width
    psum.  Both fused forms bucket the accumulator output columns, so
    they hold for ANY local feature width da_l·k̃ (the driver collapses
    a size-1 col_axis to None so trivial model axes take the
    single-kernel path).

    ``collective`` picks the sharded phase-boundary reduction:
    ``"fused"`` (exact f32 psum), ``"fused-int8ef"`` (blockwise-int8
    psum with error-feedback residuals carried across microbatches —
    ~4× fewer wire bytes on the cross-pod hop; see
    :func:`repro.distributed.psum_int8_ef`), or ``"unfused"`` (legacy
    project → psum → accumulate_tn matmul pair, kept as the parity
    oracle for the fused path).

    §Perf knobs: ``int8_reduce`` — compress the end-of-pass Y psum with
    blockwise int8 (4× fewer bytes on the row axes; randomized range
    finding tolerates the quantization noise — it's another random
    perturbation of the sketch, see EXPERIMENTS.md §Perf);
    ``reduce_buckets`` — split the Y psum into column buckets issued
    independently so XLA's async collectives overlap them with compute.
    """
    if collective not in ("fused", "fused-int8ef", "unfused"):
        raise ValueError(f"unknown collective mode {collective!r}")
    nb, mb = _microbatches(a, microbatch)
    da_l, kt = Qa.shape
    db_l = Qb.shape[0]
    f32 = jnp.float32
    cd = compute_dtype
    kernels = engine == "kernels"
    if kernels:
        from repro.kernels import ops as kops
    fused_col = kernels and col_axis is not None and collective != "unfused"
    use_ef = fused_col and collective == "fused-int8ef"
    if use_ef:
        from repro.distributed import psum_int8_ef

    a_r = a.reshape(nb, mb, da_l)
    b_r = b.reshape(nb, mb, db_l)
    Qa_c, Qb_c = Qa.astype(cd), Qb.astype(cd)

    def body(carry, ab):
        Ya, Yb, sa, sb, tra, trb, n, ea, eb = carry
        am, bm = ab
        am_c, bm_c = am.astype(cd), bm.astype(cd)
        if kernels and col_axis is None:
            # features unsharded → the fused chunk update applies as-is
            dYa, dYb = kops.power_pass_chunk(am_c, bm_c, Qa_c, Qb_c)
            Ya, Yb = Ya + dYa, Yb + dYb
        elif fused_col:
            # collective-fused staged pair: partial-P stage on the local
            # feature shard, psum at the phase boundary, sweep of the
            # global P — the psum is folded between the two kernel
            # phases instead of bracketing an unfused matmul pair.
            pb = kops.stage_project(bm_c, Qb_c).astype(cd)
            pa = kops.stage_project(am_c, Qa_c).astype(cd)
            if use_ef:
                pb, eb = psum_int8_ef(pb, col_axis, eb)
                pa, ea = psum_int8_ef(pa, col_axis, ea)
            else:
                pb = _psum(pb, col_axis)
                pa = _psum(pa, col_axis)
            Ya = Ya + kops.sweep_accumulate(am_c, pb)
            Yb = Yb + kops.sweep_accumulate(bm_c, pa)
        else:
            # projected activations: the ONLY per-microbatch collectives
            if kernels:
                pb = kops.project(bm_c, Qb_c).astype(cd)
                pa = kops.project(am_c, Qa_c).astype(cd)
            else:
                pb = bm_c @ Qb_c
                pa = am_c @ Qa_c
            if col_axis is not None:
                pb = _psum(pb, col_axis)
                pa = _psum(pa, col_axis)
            if kernels:
                Ya = Ya + kops.accumulate_tn(am_c, pb)
                Yb = Yb + kops.accumulate_tn(bm_c, pa)
            else:
                Ya = Ya + jnp.einsum("md,mk->dk", am_c, pb, preferred_element_type=f32)
                Yb = Yb + jnp.einsum("md,mk->dk", bm_c, pa, preferred_element_type=f32)
        sa = sa + jnp.sum(am, axis=0, dtype=f32)
        sb = sb + jnp.sum(bm, axis=0, dtype=f32)
        tra = tra + jnp.sum(am.astype(f32) ** 2)
        trb = trb + jnp.sum(bm.astype(f32) ** 2)
        return (Ya, Yb, sa, sb, tra, trb, n + mb, ea, eb), None

    z = jnp.zeros
    # error-feedback residuals ride the scan carry (zero-size when the
    # int8 collective is off, so the carry structure stays uniform)
    e_shape = (mb, kt) if use_ef else (0,)
    init = (
        z((da_l, kt), f32), z((db_l, kt), f32),
        z((da_l,), f32), z((db_l,), f32), z((), f32), z((), f32), z((), f32),
        z(e_shape, f32), z(e_shape, f32),
    )
    (Ya, Yb, sa, sb, tra, trb, n, _, _), _ = jax.lax.scan(body, init, (a_r, b_r))

    # one d-sized psum per pass, over the row axes only
    def reduce_Y(Y):
        if reduce_dtype is not None:
            # compressed-payload reduction: the sketch tolerates the
            # low-precision sum (it's one more random perturbation).
            # The optimization barrier stops XLA's convert-reassociation
            # pass from hoisting the cast past the all-reduce (which
            # would silently restore the f32 wire format).
            Y = jax.lax.optimization_barrier(Y.astype(reduce_dtype))
        if int8_reduce:
            # NOTE §Perf: refuted optimization kept for the record — XLA
            # must carry the int8 sum in int32 on the wire, so bytes do
            # NOT drop; see EXPERIMENTS.md §Perf iteration log.
            from repro.distributed import psum_int8_ef

            axes = (row_axes,) if isinstance(row_axes, str) else row_axes
            out = Y
            for ax in axes:
                out, _ = psum_int8_ef(out, ax)
            return out.astype(jnp.float32)
        if reduce_buckets > 1:
            from repro.distributed import bucketed_accumulate

            return bucketed_accumulate(Y, row_axes, reduce_buckets).astype(jnp.float32)
        return _psum(Y, row_axes).astype(jnp.float32)

    Ya, Yb = reduce_Y(Ya), reduce_Y(Yb)
    sa, sb = (_psum(t, row_axes) for t in (sa, sb))
    tra, trb, n = (_psum(t, row_axes) for t in (tra, trb, n))
    return Ya, Yb, sa, sb, tra, trb, n


def final_pass_local(a, b, Qa, Qb, *, row_axes, col_axis, microbatch=None,
                     compute_dtype=jnp.bfloat16, engine="jnp",
                     collective="fused"):
    """Final pass: projected covariances Ca, Cb, F (paper lines 14-18).

    ``engine="kernels"``: with unsharded features the fused
    project+gram kernel reads each local shard from HBM once per
    C-column bucket per microbatch (C-column bucketing keeps this
    fused for sketches past k̃p = 1024; single bucket ⇒ one read);
    with a genuinely sharded col_axis the collective-fused staged pair
    runs — ``proj_stage`` emits the local shard's partial P, the psum
    folds at the phase boundary, and ``gram_sweep`` /
    ``powerpass_sweep`` build Ca/Cb/F from the global P.  ``collective``
    as in :func:`power_pass_local` (``"fused-int8ef"`` compresses the
    phase-boundary psum with error feedback; ``"unfused"`` is the
    legacy matmul-pair parity oracle)."""
    if collective not in ("fused", "fused-int8ef", "unfused"):
        raise ValueError(f"unknown collective mode {collective!r}")
    nb, mb = _microbatches(a, microbatch)
    da_l, kt = Qa.shape
    db_l = Qb.shape[0]
    f32 = jnp.float32
    cd = compute_dtype
    kernels = engine == "kernels"
    if kernels:
        from repro.kernels import ops as kops
    fused_col = kernels and col_axis is not None and collective != "unfused"
    use_ef = fused_col and collective == "fused-int8ef"
    if use_ef:
        from repro.distributed import psum_int8_ef
    a_r = a.reshape(nb, mb, da_l)
    b_r = b.reshape(nb, mb, db_l)
    Qa_c, Qb_c = Qa.astype(cd), Qb.astype(cd)

    def body(carry, ab):
        Ca, Cb, F, sa, sb, tra, trb, n, ea, eb = carry
        am, bm = ab
        am_c, bm_c = am.astype(cd), bm.astype(cd)
        if kernels and col_axis is None:
            dCa, dCb, dF = kops.final_pass_chunk(am_c, bm_c, Qa_c, Qb_c)
            Ca, Cb, F = Ca + dCa, Cb + dCb, F + dF
        elif fused_col:
            pa = kops.stage_project(am_c, Qa_c).astype(cd)
            pb = kops.stage_project(bm_c, Qb_c).astype(cd)
            if use_ef:
                pa, ea = psum_int8_ef(pa, col_axis, ea)
                pb, eb = psum_int8_ef(pb, col_axis, eb)
            else:
                pa = _psum(pa, col_axis)
                pb = _psum(pb, col_axis)
            Ca = Ca + kops.gram_accumulate(pa)
            Cb = Cb + kops.gram_accumulate(pb)
            # F = PaᵀPb is the sweep contraction with Pa as the operand
            F = F + kops.sweep_accumulate(pa, pb)
        else:
            if kernels:
                pa = kops.project(am_c, Qa_c).astype(cd)
                pb = kops.project(bm_c, Qb_c).astype(cd)
            else:
                pa = am_c @ Qa_c
                pb = bm_c @ Qb_c
            if col_axis is not None:
                pa = _psum(pa, col_axis)
                pb = _psum(pb, col_axis)
            if kernels:
                Ca = Ca + kops.accumulate_tn(pa, pa)
                Cb = Cb + kops.accumulate_tn(pb, pb)
                F = F + kops.accumulate_tn(pa, pb)
            else:
                Ca = Ca + jnp.einsum("mi,mj->ij", pa, pa, preferred_element_type=f32)
                Cb = Cb + jnp.einsum("mi,mj->ij", pb, pb, preferred_element_type=f32)
                F = F + jnp.einsum("mi,mj->ij", pa, pb, preferred_element_type=f32)
        sa = sa + jnp.sum(am, axis=0, dtype=f32)
        sb = sb + jnp.sum(bm, axis=0, dtype=f32)
        tra = tra + jnp.sum(am.astype(f32) ** 2)
        trb = trb + jnp.sum(bm.astype(f32) ** 2)
        return (Ca, Cb, F, sa, sb, tra, trb, n + mb, ea, eb), None

    z = jnp.zeros
    e_shape = (mb, kt) if use_ef else (0,)
    init = (
        z((kt, kt), f32), z((kt, kt), f32), z((kt, kt), f32),
        z((da_l,), f32), z((db_l,), f32), z((), f32), z((), f32), z((), f32),
        z(e_shape, f32), z(e_shape, f32),
    )
    (Ca, Cb, F, sa, sb, tra, trb, n, _, _), _ = jax.lax.scan(body, init, (a_r, b_r))
    # Ca/Cb/F are identical within a model group (pa/pb already psummed
    # over col_axis) — reduce over rows only.
    Ca, Cb, F = (_psum(t, row_axes) for t in (Ca, Cb, F))
    sa, sb = (_psum(t, row_axes) for t in (sa, sb))
    tra, trb, n = (_psum(t, row_axes) for t in (tra, trb, n))
    return Ca, Cb, F, sa, sb, tra, trb, n


# --------------------------------------------------------------------------
# full distributed solve
# --------------------------------------------------------------------------


def dist_randomized_cca(
    A: jax.Array,
    B: jax.Array,
    cfg: RCCAConfig,
    key: jax.Array,
    mesh: Optional[Mesh] = None,
    *,
    row_axes: Sequence[str] = ("pod", "data"),
    col_axis: Optional[str] = "model",
    microbatch: Optional[int] = None,
    compute_dtype=jnp.float32,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
    topology=None,
    collective: str = "fused",
) -> RCCAResult:
    """Run Algorithm 1 on row+feature-sharded A (n×da), B (n×db).

    This is the RESIDENT-mode form of the ``repro.exec.Sharded``
    topology: with a non-None ``col_axis`` no da/db-sized tensor is
    ever replicated, at the cost of the bitwise-streaming contract (the
    per-microbatch feature psums reassociate the row sums).  Passing a
    ``repro.exec.Sharded`` value as ``topology`` supplies ``mesh`` and
    ``col_axis`` in one argument.  A/B must be shardable as
    P(row_axes, col_axis).  All q+1 data passes execute as shard_map
    programs on the schedule shared with the streaming engine; the
    finish (lines 19-25) is computed redundantly on every device
    (replicated, no host round-trip).  ``engine`` selects the
    per-microbatch update implementation inside the shard_map bodies
    (see rcca.randomized_cca_streaming); with ``engine="kernels"`` and
    a genuinely sharded ``col_axis``, ``collective`` picks the sharded
    kernel path — ``"fused"`` (default: staged kernels with the
    partial-P psum folded at the phase boundary), ``"fused-int8ef"``
    (same, int8+error-feedback compressed psum for the cross-pod hop),
    or ``"unfused"`` (legacy matmul pair around a full-width psum).
    """
    engine = resolve_engine(engine, use_kernels)
    if topology is not None:
        if topology.mesh is None and mesh is None:
            raise ValueError(
                "resident-mode Sharded topology needs an explicit mesh "
                "(its axis names define the row/feature sharding)")
        mesh = topology.mesh if mesh is None else mesh
        col_axis = topology.col_axis
    if mesh is None:
        raise ValueError("dist_randomized_cca needs a mesh (or a topology)")
    row_axes = tuple(ax for ax in row_axes if ax in mesh.axis_names)
    if col_axis is not None and col_axis not in mesh.axis_names:
        col_axis = None
    if col_axis is not None and mesh.shape[col_axis] == 1:
        # a trivial model axis shards nothing: drop it so the local
        # passes take the fused bucketed kernels (no mid-update psum)
        # instead of the unfused pair around a no-op collective.
        col_axis = None
    n, da = A.shape
    db = B.shape[1]
    kt = cfg.sketch

    data_spec = P(row_axes, col_axis)
    q_spec = P(col_axis, None)
    rep = P()

    ka, kb = jax.random.split(key)
    # Q init: generated under jit with sharded output (distributed randn)
    Qa = jax.jit(
        lambda k: jax.random.normal(k, (da, kt), cfg.dtype),
        out_shardings=NamedSharding(mesh, q_spec),
    )(ka)
    Qb = jax.jit(
        lambda k: jax.random.normal(k, (db, kt), cfg.dtype),
        out_shardings=NamedSharding(mesh, q_spec),
    )(kb)

    A = jax.device_put(A, NamedSharding(mesh, data_spec))
    B = jax.device_put(B, NamedSharding(mesh, data_spec))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(data_spec, data_spec, q_spec, q_spec),
        out_specs=(q_spec, q_spec, rep, rep, rep),
        check_rep=False,
    )
    def power_step(a, b, Qa, Qb):
        Ya, Yb, sa, sb, tra, trb, nn = power_pass_local(
            a, b, Qa, Qb, row_axes=row_axes, col_axis=col_axis,
            microbatch=microbatch, compute_dtype=compute_dtype, engine=engine,
            collective=collective,
        )
        if cfg.center:
            mu_bQ = (sb / nn) @ Qb.astype(jnp.float32)
            mu_aQ = (sa / nn) @ Qa.astype(jnp.float32)
            if col_axis is not None:
                mu_bQ = _psum(mu_bQ, col_axis)
                mu_aQ = _psum(mu_aQ, col_axis)
            Ya = Ya - nn * jnp.outer(sa / nn, mu_bQ)
            Yb = Yb - nn * jnp.outer(sb / nn, mu_aQ)
        Qa_new = dist_orth(Ya.astype(cfg.dtype), col_axis)
        Qb_new = dist_orth(Yb.astype(cfg.dtype), col_axis)
        return Qa_new, Qb_new, tra, trb, nn

    for _pass_idx, kind in pass_schedule(cfg.q):
        if kind != "power":
            break  # the final pass runs below, after final_step is built
        Qa, Qb, _, _, _ = jax.jit(power_step)(A, B, Qa, Qb)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(data_spec, data_spec, q_spec, q_spec),
        out_specs=(q_spec, q_spec, rep, rep, rep),
        check_rep=False,
    )
    def final_step(a, b, Qa, Qb):
        Ca, Cb, F, sa, sb, tra, trb, nn = final_pass_local(
            a, b, Qa, Qb, row_axes=row_axes, col_axis=col_axis,
            microbatch=microbatch, compute_dtype=compute_dtype, engine=engine,
            collective=collective,
        )
        Qa32 = Qa.astype(jnp.float32)
        Qb32 = Qb.astype(jnp.float32)
        if cfg.center:
            qa = Qa32.T @ (sa / nn)
            qb = Qb32.T @ (sb / nn)
            if col_axis is not None:
                qa = _psum(qa, col_axis)
                qb = _psum(qb, col_axis)
            Ca = Ca - nn * jnp.outer(qa, qa)
            Cb = Cb - nn * jnp.outer(qb, qb)
            F = F - nn * jnp.outer(qa, qb)
        QtQa = sym(Qa32.T @ Qa32)
        QtQb = sym(Qb32.T @ Qb32)
        if col_axis is not None:
            QtQa = _psum(QtQa, col_axis)
            QtQb = _psum(QtQb, col_axis)
        if cfg.nu is not None:
            lam_a = cfg.nu * tra / da
            lam_b = cfg.nu * trb / db
        else:
            lam_a = jnp.asarray(cfg.lam_a, jnp.float32)
            lam_b = jnp.asarray(cfg.lam_b, jnp.float32)
        # finish (paper lines 19-25) — replicated small math, local Q matmul
        Xa, Xb, S, _, _ = finish(
            Ca, Cb, F, QtQa, QtQb, Qa32, Qb32, nn, lam_a, lam_b, cfg.k
        )
        return Xa, Xb, S, lam_a, lam_b

    Xa, Xb, S, lam_a, lam_b = jax.jit(final_step)(A, B, Qa, Qb)
    return RCCAResult(
        Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb,
        diagnostics={"lam_a": lam_a, "lam_b": lam_b, "n": n},
    )
