"""Core contribution of the paper: RandomizedCCA and its baselines."""

from .exact import CCASolution, cca_objective, exact_cca, feasibility_errors
from .horst import HorstConfig, HorstResult, horst_cca
from .rcca import (
    RCCAConfig,
    RCCAResult,
    randomized_cca,
    randomized_cca_iterator,
    randomized_cca_streaming,
)

__all__ = [
    "CCASolution",
    "cca_objective",
    "exact_cca",
    "feasibility_errors",
    "HorstConfig",
    "HorstResult",
    "horst_cca",
    "RCCAConfig",
    "RCCAResult",
    "randomized_cca",
    "randomized_cca_iterator",
    "randomized_cca_streaming",
]
