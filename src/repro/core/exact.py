"""Exact (dense) regularized CCA — test oracle.

Solves the paper's eq. (1)-(2) directly via whitening + SVD:

    maximize Tr(Xaᵀ AᵀB Xb)
    s.t. Xaᵀ (AᵀA + λa I) Xa = n I,   Xbᵀ (BᵀB + λb I) Xb = n I

Cost O(n·d² + d³); only usable at test scale.  The framework's
RandomizedCCA is validated against this oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .linalg import inv_sqrt_psd, sym, topk_svd


class CCASolution(NamedTuple):
    Xa: jax.Array  # (da, k)
    Xb: jax.Array  # (db, k)
    rho: jax.Array  # (k,) canonical correlations (singular values of whitened cross-cov)


def center(M: jax.Array) -> jax.Array:
    return M - jnp.mean(M, axis=0, keepdims=True)


def exact_cca(
    A: jax.Array,
    B: jax.Array,
    k: int,
    lam_a: float = 0.0,
    lam_b: float = 0.0,
    *,
    do_center: bool = False,
) -> CCASolution:
    n = A.shape[0]
    if do_center:
        A = center(A)
        B = center(B)
    da, db = A.shape[1], B.shape[1]
    Ca = sym(A.T @ A) + lam_a * jnp.eye(da, dtype=A.dtype)
    Cb = sym(B.T @ B) + lam_b * jnp.eye(db, dtype=B.dtype)
    Cab = A.T @ B
    Wa = inv_sqrt_psd(Ca)
    Wb = inv_sqrt_psd(Cb)
    T = Wa @ Cab @ Wb
    U, S, V = topk_svd(T, k)
    Xa = jnp.sqrt(n) * (Wa @ U)
    Xb = jnp.sqrt(n) * (Wb @ V)
    # With constraints Xᵀ(C+λI)X = nI the singular values of the whitened
    # cross-covariance ARE the canonical correlations: (1/n)Tr(XaᵀCabXb) = ΣSᵢ.
    return CCASolution(Xa=Xa, Xb=Xb, rho=S)


def cca_objective(A: jax.Array, B: jax.Array, Xa: jax.Array, Xb: jax.Array) -> jax.Array:
    """(1/n) Tr(Xaᵀ AᵀB Xb) — the quantity in paper Fig. 2a / Table 2b."""
    n = A.shape[0]
    PA = A @ Xa
    PB = B @ Xb
    return jnp.trace(PA.T @ PB) / n


def feasibility_errors(
    A: jax.Array,
    B: jax.Array,
    Xa: jax.Array,
    Xb: jax.Array,
    lam_a: float = 0.0,
    lam_b: float = 0.0,
) -> dict[str, jax.Array]:
    """Constraint residuals: paper reports solutions feasible to machine
    precision — (regularized) identity covariance & diagonal cross-cov."""
    n = A.shape[0]
    k = Xa.shape[1]
    Ia = Xa.T @ (A.T @ (A @ Xa)) + lam_a * (Xa.T @ Xa)
    Ib = Xb.T @ (B.T @ (B @ Xb)) + lam_b * (Xb.T @ Xb)
    C = Xa.T @ (A.T @ (B @ Xb)) / n
    eye = jnp.eye(k, dtype=Xa.dtype)
    offdiag = C - jnp.diag(jnp.diagonal(C))
    return {
        "cov_a": jnp.max(jnp.abs(Ia / n - eye)),
        "cov_b": jnp.max(jnp.abs(Ib / n - eye)),
        "crosscov_offdiag": jnp.max(jnp.abs(offdiag)),
    }
