"""RandomizedCCA — Algorithm 1 of Mineiro & Karampatziakis (2014).

Three entry points, sharing one "finish" (paper lines 19-25):

- :func:`randomized_cca` — paper-faithful in-memory version (the ref).
- :func:`randomized_cca_streaming` — out-of-core semantics: each data
  pass is a ``lax.scan`` over row chunks; pass statistics are an
  explicit, checkpointable pytree (:class:`PassStats`) so a killed pass
  resumes mid-stream (see repro.ckpt).
- the multi-device version lives in :mod:`repro.core.rcca_dist`
  (shard_map over a (pod, data, model) mesh).

Mean-centering is the paper's §3 rank-one update: column sums are
accumulated alongside each pass (O(da+db) extra state, no extra pass)
and products are corrected as  Āᵀ B̄ = AᵀB − n μa μbᵀ.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Iterable, Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .exact import CCASolution
from .linalg import orth, sym, topk_svd, tri_solve_right
from jax.scipy.linalg import solve_triangular


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

#: Production default of the data-pass engine.  "kernels" = Pallas
#: (Mosaic on TPU, interpret mode elsewhere); "jnp" = the pure-jnp
#: oracle path the kernels are validated against.
DEFAULT_ENGINE = "kernels"


def resolve_engine(engine: str, use_kernels: Optional[bool] = None) -> str:
    """Normalize the engine knob; ``use_kernels`` is the legacy boolean
    spelling and wins when passed explicitly."""
    if use_kernels is not None:
        engine = "kernels" if use_kernels else "jnp"
    if engine not in ("kernels", "jnp"):
        raise ValueError(f"unknown engine {engine!r}; expected 'kernels' or 'jnp'")
    return engine


@dataclasses.dataclass(frozen=True)
class RCCAConfig:
    """Hyper-parameters of Algorithm 1.

    k:       target embedding dimension.
    p:       oversampling (paper uses 910-2000 for k=60).
    q:       number of power-iteration data passes (0 = pure sketch).
    lam_a/b: explicit ridge regularizers; if ``nu`` is set they are
             derived scale-free as λ = ν·Tr(XᵀX)/d (paper §4).
    center:  mean-shift both views via the rank-one update.
    """

    k: int
    p: int = 100
    q: int = 1
    lam_a: float = 0.0
    lam_b: float = 0.0
    nu: Optional[float] = None
    center: bool = False
    dtype: jnp.dtype = jnp.float32

    @property
    def sketch(self) -> int:  # k̃ = k + p
        return self.k + self.p


class RCCAResult(NamedTuple):
    Xa: jax.Array
    Xb: jax.Array
    rho: jax.Array  # top-k canonical correlations (Σ of paper line 22)
    Qa: jax.Array  # final range bases — useful to warm-start / analyze
    Qb: jax.Array
    diagnostics: dict


# --------------------------------------------------------------------------
# pass statistics (checkpointable)
# --------------------------------------------------------------------------


class PowerStats(NamedTuple):
    """Accumulators of one range-finder pass (paper lines 6-9)."""

    Ya: jax.Array  # AᵀB Qb   (da, k̃)
    Yb: jax.Array  # BᵀA Qa   (db, k̃)
    sa: jax.Array  # Aᵀ1      (da,)
    sb: jax.Array  # Bᵀ1      (db,)
    n: jax.Array  # row count ()
    tr_a: jax.Array  # ‖A‖_F²  () — for scale-free λ
    tr_b: jax.Array  # ‖B‖_F²  ()


class FinalStats(NamedTuple):
    """Accumulators of the final pass (paper lines 14-18)."""

    Ca: jax.Array  # Qaᵀ AᵀA Qa  (k̃, k̃)
    Cb: jax.Array  # Qbᵀ BᵀB Qb  (k̃, k̃)
    F: jax.Array  # Qaᵀ AᵀB Qb  (k̃, k̃)
    sa: jax.Array
    sb: jax.Array
    n: jax.Array
    tr_a: jax.Array
    tr_b: jax.Array


def init_power_stats(da: int, db: int, sketch: int, dtype) -> PowerStats:
    z = jnp.zeros
    return PowerStats(
        Ya=z((da, sketch), dtype),
        Yb=z((db, sketch), dtype),
        sa=z((da,), dtype),
        sb=z((db,), dtype),
        n=z((), dtype),
        tr_a=z((), dtype),
        tr_b=z((), dtype),
    )


def init_final_stats(sketch: int, da: int, db: int, dtype) -> FinalStats:
    z = jnp.zeros
    return FinalStats(
        Ca=z((sketch, sketch), dtype),
        Cb=z((sketch, sketch), dtype),
        F=z((sketch, sketch), dtype),
        sa=z((da,), dtype),
        sb=z((db,), dtype),
        n=z((), dtype),
        tr_a=z((), dtype),
        tr_b=z((), dtype),
    )


def update_power_stats(
    s: PowerStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> PowerStats:
    """Fold one row chunk into the range-finder accumulators.

    The two rank-k̃ products are the data-pass hot spot; the Pallas
    kernel (repro.kernels.ccapass) implements exactly this update with
    fused VMEM tiling — this jnp form is its oracle.
    """
    f32 = jnp.float32
    pb = b @ Qb  # (c, k̃)
    pa = a @ Qa
    return PowerStats(
        Ya=s.Ya + (a.T @ pb).astype(s.Ya.dtype),
        Yb=s.Yb + (b.T @ pa).astype(s.Yb.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_power_stats_kernel(
    s: PowerStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> PowerStats:
    """Pallas-kernel-backed version of :func:`update_power_stats`
    (fused MXU matmuls; interpret-mode on CPU).  The fused kernels
    bucket their output columns over a third grid axis, so this path
    holds at any feature width — Europarl's da = db = 2^19 included —
    rather than silently degrading to the unfused matmul pair."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    dYa, dYb = kops.power_pass_chunk(a, b, Qa, Qb)
    return s._replace(
        Ya=s.Ya + dYa.astype(s.Ya.dtype),
        Yb=s.Yb + dYb.astype(s.Yb.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_final_stats_kernel(
    s: FinalStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> FinalStats:
    """Pallas-kernel-backed version of :func:`update_final_stats`
    (projgram fusion: each view read from HBM once per chunk)."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    dCa, dCb, dF = kops.final_pass_chunk(a, b, Qa, Qb)
    return s._replace(
        Ca=s.Ca + dCa.astype(s.Ca.dtype),
        Cb=s.Cb + dCb.astype(s.Cb.dtype),
        F=s.F + dF.astype(s.F.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_final_stats(
    s: FinalStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> FinalStats:
    pa = a @ Qa  # (c, k̃)
    pb = b @ Qb
    f32 = jnp.float32
    return FinalStats(
        Ca=s.Ca + (pa.T @ pa).astype(s.Ca.dtype),
        Cb=s.Cb + (pb.T @ pb).astype(s.Cb.dtype),
        F=s.F + (pa.T @ pb).astype(s.F.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


# --------------------------------------------------------------------------
# centering corrections (rank-one updates, paper §3)
# --------------------------------------------------------------------------


def centered_Y(s: PowerStats, Qa, Qb, center: bool):
    if not center:
        return s.Ya, s.Yb
    n = jnp.maximum(s.n, 1.0)
    mu_a = s.sa / n
    mu_b = s.sb / n
    Ya = s.Ya - n * jnp.outer(mu_a, mu_b @ Qb)  # ĀᵀB̄Qb = AᵀBQb − n μa(μbᵀQb)
    Yb = s.Yb - n * jnp.outer(mu_b, mu_a @ Qa)
    return Ya, Yb


def centered_CF(s: FinalStats, Qa, Qb, center: bool):
    if not center:
        return s.Ca, s.Cb, s.F
    n = jnp.maximum(s.n, 1.0)
    qa = Qa.T @ (s.sa / n)  # (k̃,) = Qaᵀ μa
    qb = Qb.T @ (s.sb / n)
    Ca = s.Ca - n * jnp.outer(qa, qa)
    Cb = s.Cb - n * jnp.outer(qb, qb)
    F = s.F - n * jnp.outer(qa, qb)
    return Ca, Cb, F


def resolve_lambdas(cfg: RCCAConfig, tr_a, tr_b, da: int, db: int):
    if cfg.nu is None:
        return jnp.asarray(cfg.lam_a, jnp.float32), jnp.asarray(cfg.lam_b, jnp.float32)
    return cfg.nu * tr_a / da, cfg.nu * tr_b / db


# --------------------------------------------------------------------------
# finish: paper lines 19-25 (host-scale, (k̃)³)
# --------------------------------------------------------------------------


def finish(
    Ca: jax.Array,
    Cb: jax.Array,
    F: jax.Array,
    QtQa: jax.Array,
    QtQb: jax.Array,
    Qa: jax.Array,
    Qb: jax.Array,
    n: jax.Array,
    lam_a,
    lam_b,
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Lines 19-25: whiten F in the Q bases, SVD, map back to X.

    NOTE on conventions: the paper's ``chol`` is Matlab's (upper R,
    RᵀR = C) so it writes F ← La⁻ᵀ F Lb⁻¹ and Xa = √n Qa La⁻¹ U.  With
    jnp's lower factor (L Lᵀ = C) the equivalent is F ← La⁻¹ F Lb⁻ᵀ and
    Xa = √n Qa La⁻ᵀ U.  (Both give Q̃ᵀ(QᵀMQ)Q̃ = I for Q̃ = Q·W.)
    """
    La = jnp.linalg.cholesky(sym(Ca + lam_a * QtQa))
    Lb = jnp.linalg.cholesky(sym(Cb + lam_b * QtQb))
    Fw = solve_triangular(La, F, lower=True)  # La⁻¹ F
    Fw = tri_solve_right(Fw, Lb, trans=True)  # ... Lb⁻ᵀ
    U, S, V = topk_svd(Fw, k)
    sqn = jnp.sqrt(n.astype(Fw.dtype))
    Xa = sqn * (Qa @ solve_triangular(La.T, U, lower=False))  # √n Qa La⁻ᵀ U
    Xb = sqn * (Qb @ solve_triangular(Lb.T, V, lower=False))
    return Xa, Xb, S, La, Lb


# --------------------------------------------------------------------------
# in-memory, paper-faithful
# --------------------------------------------------------------------------


def randomized_cca(
    A: jax.Array, B: jax.Array, cfg: RCCAConfig, key: jax.Array
) -> RCCAResult:
    """Algorithm 1, verbatim, for in-memory A, B (the reference)."""
    n, da = A.shape
    db = B.shape[1]
    kt = cfg.sketch
    ka, kb = jax.random.split(key)
    dt = cfg.dtype
    Qa = jax.random.normal(ka, (da, kt), dt)
    Qb = jax.random.normal(kb, (db, kt), dt)

    if cfg.center:
        A = A - jnp.mean(A, axis=0, keepdims=True)
        B = B - jnp.mean(B, axis=0, keepdims=True)

    for _ in range(cfg.q):  # lines 5-12
        Ya = A.T @ (B @ Qb)
        Yb = B.T @ (A @ Qa)
        Qa = orth(Ya)
        Qb = orth(Yb)

    Pa = A @ Qa  # lines 14-18 (final pass)
    Pb = B @ Qb
    Ca = sym(Pa.T @ Pa)
    Cb = sym(Pb.T @ Pb)
    F = Pa.T @ Pb

    tr_a = jnp.sum(A.astype(jnp.float32) ** 2)
    tr_b = jnp.sum(B.astype(jnp.float32) ** 2)
    lam_a, lam_b = resolve_lambdas(cfg, tr_a, tr_b, da, db)

    QtQa = sym(Qa.T @ Qa)
    QtQb = sym(Qb.T @ Qb)
    Xa, Xb, S, La, Lb = finish(
        Ca, Cb, F, QtQa, QtQb, Qa, Qb, jnp.asarray(n, jnp.float32), lam_a, lam_b, cfg.k
    )
    diag = {"lam_a": lam_a, "lam_b": lam_b, "n": n}
    return RCCAResult(Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb, diagnostics=diag)


# --------------------------------------------------------------------------
# streaming / out-of-core
# --------------------------------------------------------------------------


def _scan_pass(update_fn, stats, A_chunks: jax.Array, B_chunks: jax.Array, Qa, Qb):
    """One data pass as a lax.scan over stacked row chunks."""

    def body(s, ab):
        a, b = ab
        return update_fn(s, a, b, Qa, Qb), None

    stats, _ = jax.lax.scan(body, stats, (A_chunks, B_chunks))
    return stats


def randomized_cca_streaming(
    A_chunks: jax.Array,  # (nc, c, da) — out-of-core rows, chunked
    B_chunks: jax.Array,  # (nc, c, db)
    cfg: RCCAConfig,
    key: jax.Array,
    *,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
) -> RCCAResult:
    """Algorithm 1 where every data pass is a scan over row chunks.

    This is the single-device form of the production data pass: the
    distributed version (rcca_dist) wraps the same updates in shard_map
    and psums the accumulators.  ``engine`` selects the per-chunk update
    implementation: ``"kernels"`` (default) runs the fused Pallas data
    passes (interpret mode off-TPU), ``"jnp"`` the pure-jnp oracle.
    ``use_kernels`` is the legacy boolean spelling of the same knob.
    """
    engine = resolve_engine(engine, use_kernels)
    nc, c, da = A_chunks.shape
    db = B_chunks.shape[-1]
    kt = cfg.sketch
    dt = cfg.dtype
    ka, kb = jax.random.split(key)
    Qa = jax.random.normal(ka, (da, kt), dt)
    Qb = jax.random.normal(kb, (db, kt), dt)

    kernels = engine == "kernels"
    upd_pow = update_power_stats_kernel if kernels else update_power_stats
    upd_fin = update_final_stats_kernel if kernels else update_final_stats

    for _ in range(cfg.q):
        stats = init_power_stats(da, db, kt, jnp.float32)
        stats = _scan_pass(upd_pow, stats, A_chunks, B_chunks, Qa, Qb)
        Ya, Yb = centered_Y(stats, Qa, Qb, cfg.center)
        Qa = orth(Ya.astype(dt))
        Qb = orth(Yb.astype(dt))

    fstats = init_final_stats(kt, da, db, jnp.float32)
    fstats = _scan_pass(upd_fin, fstats, A_chunks, B_chunks, Qa, Qb)
    Ca, Cb, F = centered_CF(fstats, Qa, Qb, cfg.center)
    lam_a, lam_b = resolve_lambdas(cfg, fstats.tr_a, fstats.tr_b, da, db)
    QtQa = sym((Qa.T @ Qa).astype(jnp.float32))
    QtQb = sym((Qb.T @ Qb).astype(jnp.float32))
    Xa, Xb, S, _, _ = finish(
        Ca, Cb, F, QtQa, QtQb, Qa.astype(jnp.float32), Qb.astype(jnp.float32),
        fstats.n, lam_a, lam_b, cfg.k,
    )
    diag = {"lam_a": lam_a, "lam_b": lam_b, "n": fstats.n}
    return RCCAResult(Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb, diagnostics=diag)


def _open_source(source_factory, start_chunk: int):
    """Instantiate the chunk source for one pass.

    Seek-aware factories opt in by naming their first positional
    parameter ``start`` (e.g. ``repro.store.PassRunner._source``); they
    are asked to begin at ``start_chunk`` directly, so a resumed pass
    never reads the skipped prefix from disk.  Anything else keeps the
    legacy contract: ``source_factory()`` yields from chunk 0 and the
    driver filters.  (Opt-in is by name, not arity — a factory that
    merely happens to take a defaulted positional must not silently
    receive a chunk index.)
    """
    try:
        params = list(inspect.signature(source_factory).parameters.values())
        seekable = bool(params) and params[0].name == "start" and \
            params[0].kind in (params[0].POSITIONAL_ONLY,
                               params[0].POSITIONAL_OR_KEYWORD)
    except (TypeError, ValueError):
        seekable = False
    if seekable:
        return source_factory(start_chunk), start_chunk
    return source_factory(), 0


def randomized_cca_iterator(
    source_factory,
    da: int,
    db: int,
    cfg: RCCAConfig,
    key: jax.Array,
    *,
    resume_state: Optional[dict] = None,
    on_pass_end=None,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
) -> RCCAResult:
    """True out-of-core driver: ``source_factory()`` yields (a, b) row
    chunks (e.g. from disk / a distributed FS).  Per-chunk updates are
    jitted; pass state is a plain pytree so the caller can checkpoint it
    between chunks (fault tolerance: resume a killed pass mid-stream via
    ``resume_state`` = {"pass_idx", "chunk_idx", "stats", "Qa", "Qb"}).
    A factory taking a positional ``start`` argument is seekable: each
    pass opens it at its first needed chunk, so a resume never re-reads
    the already-folded prefix (``repro.store`` readers/prefetchers use
    this).  ``engine`` selects the per-chunk update implementation (see
    :func:`randomized_cca_streaming`).
    """
    engine = resolve_engine(engine, use_kernels)
    kt = cfg.sketch
    dt = cfg.dtype
    ka, kb = jax.random.split(key)
    Qa = jax.random.normal(ka, (da, kt), dt)
    Qb = jax.random.normal(kb, (db, kt), dt)

    kernels = engine == "kernels"
    upd_pow = jax.jit(update_power_stats_kernel if kernels else update_power_stats)
    upd_fin = jax.jit(update_final_stats_kernel if kernels else update_final_stats)

    start_pass, start_chunk, stats0 = 0, 0, None
    if resume_state is not None:
        start_pass = int(resume_state["pass_idx"])
        start_chunk = int(resume_state["chunk_idx"])
        stats0 = resume_state["stats"]
        Qa, Qb = resume_state["Qa"], resume_state["Qb"]

    total_passes = cfg.q + 1  # q power passes + final pass
    for pass_idx in range(start_pass, total_passes):
        is_final = pass_idx == cfg.q
        if stats0 is not None:
            stats = stats0
            stats0 = None
        else:
            stats = (
                init_final_stats(kt, da, db, jnp.float32)
                if is_final
                else init_power_stats(da, db, kt, jnp.float32)
            )
        upd = upd_fin if is_final else upd_pow
        source, offset = _open_source(source_factory, start_chunk)
        for chunk_idx, (a, b) in enumerate(source, start=offset):
            if chunk_idx < start_chunk:
                continue
            stats = upd(stats, a, b, Qa, Qb)
            if on_pass_end is not None:
                on_pass_end(pass_idx, chunk_idx, stats, Qa, Qb)
        start_chunk = 0
        if not is_final:
            Ya, Yb = centered_Y(stats, Qa, Qb, cfg.center)
            Qa = orth(Ya.astype(dt))
            Qb = orth(Yb.astype(dt))

    Ca, Cb, F = centered_CF(stats, Qa, Qb, cfg.center)
    lam_a, lam_b = resolve_lambdas(cfg, stats.tr_a, stats.tr_b, da, db)
    QtQa = sym((Qa.T @ Qa).astype(jnp.float32))
    QtQb = sym((Qb.T @ Qb).astype(jnp.float32))
    Xa, Xb, S, _, _ = finish(
        Ca, Cb, F, QtQa, QtQb, Qa.astype(jnp.float32), Qb.astype(jnp.float32),
        stats.n, lam_a, lam_b, cfg.k,
    )
    return RCCAResult(
        Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb,
        diagnostics={"lam_a": lam_a, "lam_b": lam_b, "n": stats.n},
    )
