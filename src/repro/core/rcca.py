"""RandomizedCCA — Algorithm 1 of Mineiro & Karampatziakis (2014).

Three entry points, sharing one "finish" (paper lines 19-25):

- :func:`randomized_cca` — paper-faithful in-memory version (the ref).
- :func:`randomized_cca_streaming` — out-of-core semantics: each data
  pass is a scan over row chunks; pass statistics are an explicit,
  checkpointable accumulator (:class:`SegmentedAccumulator`) so a
  killed pass resumes mid-stream (see repro.ckpt).
- the multi-device version lives in :mod:`repro.core.rcca_dist`
  (shard_map over a (pod, data, model) mesh); the multi-PROCESS
  version in :mod:`repro.cluster` (map/combine/reduce over a store).

Every execution mode accumulates in the same CANONICAL ORDER — chunks
left-fold into fixed-size merge groups, group sums reduce through a
fixed pairwise tree (:class:`PairwiseStack`) — so their results agree
bitwise: the cluster coordinator's merge of per-worker partials
(:func:`merge_power_stats` / :func:`merge_final_stats` are exact
combiners — every accumulator field is a plain sum over rows) is
bit-identical to a single-process pass for any worker count.

Mean-centering is the paper's §3 rank-one update: column sums are
accumulated alongside each pass (O(da+db) extra state, no extra pass)
and products are corrected as  Āᵀ B̄ = AᵀB − n μa μbᵀ.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Iterable, Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .exact import CCASolution
from .linalg import orth, sym, topk_svd, tri_solve_right
from jax.scipy.linalg import solve_triangular


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

#: Production default of the data-pass engine.  "kernels" = Pallas
#: (Mosaic on TPU, interpret mode elsewhere); "jnp" = the pure-jnp
#: oracle path the kernels are validated against.
DEFAULT_ENGINE = "kernels"


def resolve_engine(engine: str, use_kernels: Optional[bool] = None) -> str:
    """Normalize the engine knob; ``use_kernels`` is the legacy boolean
    spelling and wins when passed explicitly."""
    if use_kernels is not None:
        engine = "kernels" if use_kernels else "jnp"
    if engine not in ("kernels", "jnp"):
        raise ValueError(f"unknown engine {engine!r}; expected 'kernels' or 'jnp'")
    return engine


@dataclasses.dataclass(frozen=True)
class RCCAConfig:
    """Hyper-parameters of Algorithm 1.

    k:       target embedding dimension.
    p:       oversampling (paper uses 910-2000 for k=60).
    q:       number of power-iteration data passes (0 = pure sketch).
    lam_a/b: explicit ridge regularizers; if ``nu`` is set they are
             derived scale-free as λ = ν·Tr(XᵀX)/d (paper §4).
    center:  mean-shift both views via the rank-one update.
    """

    k: int
    p: int = 100
    q: int = 1
    lam_a: float = 0.0
    lam_b: float = 0.0
    nu: Optional[float] = None
    center: bool = False
    dtype: jnp.dtype = jnp.float32

    @property
    def sketch(self) -> int:  # k̃ = k + p
        return self.k + self.p


def algo_meta(cfg: RCCAConfig) -> dict:
    """The hyper-parameter identity that binds persisted pass state —
    PassRunner cursors and cluster rounds/partials both embed and
    validate exactly this dict, so they can never drift apart."""
    return {"k": cfg.k, "p": cfg.p, "q": cfg.q, "center": cfg.center,
            "nu": cfg.nu, "lam_a": cfg.lam_a, "lam_b": cfg.lam_b,
            "dtype": str(jnp.dtype(cfg.dtype))}


class RCCAResult(NamedTuple):
    Xa: jax.Array
    Xb: jax.Array
    rho: jax.Array  # top-k canonical correlations (Σ of paper line 22)
    Qa: jax.Array  # final range bases — useful to warm-start / analyze
    Qb: jax.Array
    diagnostics: dict


# --------------------------------------------------------------------------
# pass statistics (checkpointable)
# --------------------------------------------------------------------------


class PowerStats(NamedTuple):
    """Accumulators of one range-finder pass (paper lines 6-9)."""

    Ya: jax.Array  # AᵀB Qb   (da, k̃)
    Yb: jax.Array  # BᵀA Qa   (db, k̃)
    sa: jax.Array  # Aᵀ1      (da,)
    sb: jax.Array  # Bᵀ1      (db,)
    n: jax.Array  # row count ()
    tr_a: jax.Array  # ‖A‖_F²  () — for scale-free λ
    tr_b: jax.Array  # ‖B‖_F²  ()


class FinalStats(NamedTuple):
    """Accumulators of the final pass (paper lines 14-18)."""

    Ca: jax.Array  # Qaᵀ AᵀA Qa  (k̃, k̃)
    Cb: jax.Array  # Qbᵀ BᵀB Qb  (k̃, k̃)
    F: jax.Array  # Qaᵀ AᵀB Qb  (k̃, k̃)
    sa: jax.Array
    sb: jax.Array
    n: jax.Array
    tr_a: jax.Array
    tr_b: jax.Array


def init_power_stats(da: int, db: int, sketch: int, dtype) -> PowerStats:
    z = jnp.zeros
    return PowerStats(
        Ya=z((da, sketch), dtype),
        Yb=z((db, sketch), dtype),
        sa=z((da,), dtype),
        sb=z((db,), dtype),
        n=z((), dtype),
        tr_a=z((), dtype),
        tr_b=z((), dtype),
    )


def init_final_stats(sketch: int, da: int, db: int, dtype) -> FinalStats:
    z = jnp.zeros
    return FinalStats(
        Ca=z((sketch, sketch), dtype),
        Cb=z((sketch, sketch), dtype),
        F=z((sketch, sketch), dtype),
        sa=z((da,), dtype),
        sb=z((db,), dtype),
        n=z((), dtype),
        tr_a=z((), dtype),
        tr_b=z((), dtype),
    )


def update_power_stats(
    s: PowerStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> PowerStats:
    """Fold one row chunk into the range-finder accumulators.

    The two rank-k̃ products are the data-pass hot spot; the Pallas
    kernel (repro.kernels.ccapass) implements exactly this update with
    fused VMEM tiling — this jnp form is its oracle.
    """
    f32 = jnp.float32
    pb = b @ Qb  # (c, k̃)
    pa = a @ Qa
    return PowerStats(
        Ya=s.Ya + (a.T @ pb).astype(s.Ya.dtype),
        Yb=s.Yb + (b.T @ pa).astype(s.Yb.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_power_stats_kernel(
    s: PowerStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> PowerStats:
    """Pallas-kernel-backed version of :func:`update_power_stats`
    (fused MXU matmuls; interpret-mode on CPU).  The fused kernels
    bucket their output columns over a third grid axis, so this path
    holds at any feature width — Europarl's da = db = 2^19 included —
    rather than silently degrading to the unfused matmul pair."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    dYa, dYb = kops.power_pass_chunk(a, b, Qa, Qb)
    return s._replace(
        Ya=s.Ya + dYa.astype(s.Ya.dtype),
        Yb=s.Yb + dYb.astype(s.Yb.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_final_stats_kernel(
    s: FinalStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> FinalStats:
    """Pallas-kernel-backed version of :func:`update_final_stats`
    (projgram fusion: each view read from HBM once per chunk)."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    dCa, dCb, dF = kops.final_pass_chunk(a, b, Qa, Qb)
    return s._replace(
        Ca=s.Ca + dCa.astype(s.Ca.dtype),
        Cb=s.Cb + dCb.astype(s.Cb.dtype),
        F=s.F + dF.astype(s.F.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_final_stats(
    s: FinalStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> FinalStats:
    pa = a @ Qa  # (c, k̃)
    pb = b @ Qb
    f32 = jnp.float32
    return FinalStats(
        Ca=s.Ca + (pa.T @ pa).astype(s.Ca.dtype),
        Cb=s.Cb + (pb.T @ pb).astype(s.Cb.dtype),
        F=s.F + (pa.T @ pb).astype(s.F.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


# --------------------------------------------------------------------------
# mergeable sufficient statistics (repro.cluster's map/combine contract)
# --------------------------------------------------------------------------

#: Chunks per merge group — the granularity of the canonical reduction
#: below and therefore of cluster partials.  A store-pass constant, NOT
#: a function of the worker count: bit-reproducibility across worker
#: counts holds exactly because the grouping never moves.
MERGE_GROUP_CHUNKS = 8


def merge_power_stats(x: PowerStats, y: PowerStats) -> PowerStats:
    """Combine two range-finder accumulators over disjoint row sets.

    Every field is a plain sum over rows, so the merge is the exact
    map/reduce combiner of Algorithm 1: stats(S₁ ∪ S₂) = stats(S₁) ⊕
    stats(S₂) with ⊕ = elementwise +.  (Exact as algebra; the fp ADD
    still rounds — which is why the reduction ORDER below is canonical.)
    """
    return PowerStats(*(a + b for a, b in zip(x, y)))


def merge_final_stats(x: FinalStats, y: FinalStats) -> FinalStats:
    """Combine two final-pass accumulators — same contract as
    :func:`merge_power_stats`."""
    return FinalStats(*(a + b for a, b in zip(x, y)))


def merge_stats(x, y):
    """Dispatch on the stats flavor (both are fieldwise sums)."""
    if isinstance(x, PowerStats):
        return merge_power_stats(x, y)
    return merge_final_stats(x, y)


class PairwiseStack:
    """Fixed-structure pairwise reduction over a sequence of partials.

    The binary-counter scheme of pairwise summation: pushing partial
    ``m`` merges stack tops of equal weight, so after ``m`` pushes the
    stack mirrors the binary digits of ``m`` and the reduction tree is a
    function of the partial INDEX alone — not of who computed each
    partial or when it arrived.  This is what makes the cluster merge
    bit-reproducible: any assignment of whole merge groups to workers,
    merged in group order, reproduces the single-process reduction
    bitwise.  Live memory is O(log #groups) stats pytrees.
    """

    def __init__(self, stack=None, counts=None):
        self.stack = list(stack) if stack is not None else []
        self.counts = list(counts) if counts is not None else []

    @staticmethod
    def depth_after(m: int) -> int:
        """Stack depth after ``m`` pushes (= popcount(m)) — lets a
        checkpoint restore rebuild the like-tree from a chunk index."""
        return bin(m).count("1")

    def push(self, s) -> None:
        self.stack.append(s)
        self.counts.append(1)
        while len(self.counts) >= 2 and self.counts[-1] == self.counts[-2]:
            hi = self.stack.pop()
            self.stack[-1] = merge_stats(self.stack[-1], hi)
            self.counts[-1] += self.counts.pop()

    def result(self):
        """Fold the leftover unequal-weight entries newest→oldest (the
        deterministic completion of the tree)."""
        if not self.stack:
            return None
        res = self.stack[-1]
        for s in reversed(self.stack[:-1]):
            res = merge_stats(s, res)
        return res


class SegmentedAccumulator:
    """Canonical accumulation of one data pass: chunks left-fold into
    the current ``group`` accumulator; each completed group (every
    ``group_chunks`` chunks, plus the ragged tail) enters a
    :class:`PairwiseStack`.  Single-process drivers, cluster workers and
    the coordinator merge all share this structure, which is the whole
    bit-reproducibility argument of ``repro.cluster``.
    """

    def __init__(self, init_fn, n_chunks: Optional[int],
                 group_chunks: int = MERGE_GROUP_CHUNKS):
        if group_chunks <= 0:
            raise ValueError("merge group size must be positive")
        self.init_fn = init_fn
        self.n_chunks = None if n_chunks is None else int(n_chunks)
        self.group_chunks = int(group_chunks)
        self.current = init_fn()
        self._tree = PairwiseStack()
        self.groups_done = 0
        self._in_group = 0  # chunks folded into ``current`` so far

    # -- geometry ---------------------------------------------------------

    @property
    def n_groups(self) -> int:
        return -(-self.n_chunks // self.group_chunks)

    @staticmethod
    def groups_completed(next_chunk: int, n_chunks: Optional[int],
                         group_chunks: int) -> int:
        """Merge groups fully folded once chunks [0, next_chunk) are in
        — with a known length, the ragged tail group completes with the
        last chunk."""
        if n_chunks is not None and next_chunk >= n_chunks:
            return -(-n_chunks // group_chunks)
        return next_chunk // group_chunks

    # -- folding ----------------------------------------------------------

    def update(self, chunk_idx: int, update_fn, a, b, Qa, Qb) -> None:
        """Fold one chunk, closing the merge group at its boundary."""
        self.current = update_fn(self.current, a, b, Qa, Qb)
        self.end_chunk(chunk_idx)

    def end_chunk(self, chunk_idx: int) -> None:
        self._in_group += 1
        nxt = chunk_idx + 1
        if nxt % self.group_chunks == 0 or nxt == self.n_chunks:
            self._push_current()

    def flush_tail(self) -> None:
        """Close a ragged tail group at end of stream — for sources of
        unknown length (a known ``n_chunks`` closes it in end_chunk)."""
        if self._in_group:
            self._push_current()

    def _push_current(self) -> None:
        self._tree.push(self.current)
        self.current = self.init_fn()
        self.groups_done += 1
        self._in_group = 0

    def push_group(self, group_idx: int, stats) -> None:
        """Feed a pre-computed merge-group sum (a cluster partial) —
        MUST be called in ascending group order with no gaps."""
        if group_idx != self.groups_done:
            raise ValueError(
                f"merge groups must arrive in order: got {group_idx}, "
                f"expected {self.groups_done}")
        self._tree.push(stats)
        self.groups_done += 1

    def result(self):
        r = self._tree.result()
        return self.init_fn() if r is None else r

    # -- checkpointing ----------------------------------------------------

    def state(self) -> dict:
        """Checkpointable pytree snapshot (jax arrays are immutable, so
        no copies are needed — only the containers are frozen)."""
        return {"current": self.current, "stack": tuple(self._tree.stack)}

    def load_state(self, state: dict) -> None:
        self.current = state["current"]
        self._tree.stack = list(state["stack"])
        # counts are implied by groups_done's binary digits (descending)
        m = self.groups_done
        self._tree.counts = [1 << i for i in reversed(range(m.bit_length()))
                             if m >> i & 1]
        if len(self._tree.counts) != len(self._tree.stack):
            raise ValueError(
                f"accumulator state has {len(self._tree.stack)} stack "
                f"entries; {self.groups_done} completed groups imply "
                f"{len(self._tree.counts)}")

    @classmethod
    def structure(cls, init_fn, n_chunks: Optional[int], group_chunks: int,
                  next_chunk: int) -> "SegmentedAccumulator":
        """Zero-filled accumulator with the stack shape implied by a
        resume position — the like-tree for repro.ckpt restores."""
        acc = cls(init_fn, n_chunks, group_chunks)
        acc.groups_done = cls.groups_completed(next_chunk, n_chunks, group_chunks)
        acc._in_group = max(0, next_chunk - acc.groups_done * group_chunks)
        depth = PairwiseStack.depth_after(acc.groups_done)
        acc.load_state({"current": init_fn(),
                        "stack": tuple(init_fn() for _ in range(depth))})
        return acc


def reduce_group_partials(partials, init_fn, n_chunks: int,
                          group_chunks: int = MERGE_GROUP_CHUNKS):
    """Deterministic fixed-order tree-reduce of per-group partials:
    ``partials`` maps group index → stats and must cover every group.
    Reproduces the single-process segmented accumulation bitwise
    regardless of which worker computed which group or in what order
    they completed."""
    acc = SegmentedAccumulator(init_fn, n_chunks, group_chunks)
    for g in range(acc.n_groups):
        if g not in partials:
            raise ValueError(f"merge group {g} missing from partial set")
        acc.push_group(g, partials[g])
    return acc.result()


# --------------------------------------------------------------------------
# centering corrections (rank-one updates, paper §3)
# --------------------------------------------------------------------------


def centered_Y(s: PowerStats, Qa, Qb, center: bool):
    if not center:
        return s.Ya, s.Yb
    n = jnp.maximum(s.n, 1.0)
    mu_a = s.sa / n
    mu_b = s.sb / n
    Ya = s.Ya - n * jnp.outer(mu_a, mu_b @ Qb)  # ĀᵀB̄Qb = AᵀBQb − n μa(μbᵀQb)
    Yb = s.Yb - n * jnp.outer(mu_b, mu_a @ Qa)
    return Ya, Yb


def centered_CF(s: FinalStats, Qa, Qb, center: bool):
    if not center:
        return s.Ca, s.Cb, s.F
    n = jnp.maximum(s.n, 1.0)
    qa = Qa.T @ (s.sa / n)  # (k̃,) = Qaᵀ μa
    qb = Qb.T @ (s.sb / n)
    Ca = s.Ca - n * jnp.outer(qa, qa)
    Cb = s.Cb - n * jnp.outer(qb, qb)
    F = s.F - n * jnp.outer(qa, qb)
    return Ca, Cb, F


def resolve_lambdas(cfg: RCCAConfig, tr_a, tr_b, da: int, db: int):
    if cfg.nu is None:
        return jnp.asarray(cfg.lam_a, jnp.float32), jnp.asarray(cfg.lam_b, jnp.float32)
    return cfg.nu * tr_a / da, cfg.nu * tr_b / db


# --------------------------------------------------------------------------
# shared per-pass transitions — every driver (streaming scan, iterator,
# cluster coordinator) runs EXACTLY these, which is what makes their
# outputs comparable bit-for-bit
# --------------------------------------------------------------------------


def init_Q(key: jax.Array, da: int, db: int, cfg: RCCAConfig):
    """Line 1-2: the Gaussian sketch bases, identically derived from the
    PRNG key by every execution mode."""
    ka, kb = jax.random.split(key)
    Qa = jax.random.normal(ka, (da, cfg.sketch), cfg.dtype)
    Qb = jax.random.normal(kb, (db, cfg.sketch), cfg.dtype)
    return Qa, Qb


def power_update_Q(stats: PowerStats, Qa, Qb, cfg: RCCAConfig):
    """Lines 10-11: close one range-finder pass (center + orth)."""
    Ya, Yb = centered_Y(stats, Qa, Qb, cfg.center)
    return orth(Ya.astype(cfg.dtype)), orth(Yb.astype(cfg.dtype))


def finalize_result(fstats: FinalStats, Qa, Qb, cfg: RCCAConfig,
                    da: int, db: int) -> RCCAResult:
    """Lines 19-25 from merged final-pass statistics."""
    Ca, Cb, F = centered_CF(fstats, Qa, Qb, cfg.center)
    lam_a, lam_b = resolve_lambdas(cfg, fstats.tr_a, fstats.tr_b, da, db)
    QtQa = sym((Qa.T @ Qa).astype(jnp.float32))
    QtQb = sym((Qb.T @ Qb).astype(jnp.float32))
    Xa, Xb, S, _, _ = finish(
        Ca, Cb, F, QtQa, QtQb, Qa.astype(jnp.float32), Qb.astype(jnp.float32),
        fstats.n, lam_a, lam_b, cfg.k,
    )
    return RCCAResult(
        Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb,
        diagnostics={"lam_a": lam_a, "lam_b": lam_b, "n": fstats.n},
    )


# --------------------------------------------------------------------------
# finish: paper lines 19-25 (host-scale, (k̃)³)
# --------------------------------------------------------------------------


def finish(
    Ca: jax.Array,
    Cb: jax.Array,
    F: jax.Array,
    QtQa: jax.Array,
    QtQb: jax.Array,
    Qa: jax.Array,
    Qb: jax.Array,
    n: jax.Array,
    lam_a,
    lam_b,
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Lines 19-25: whiten F in the Q bases, SVD, map back to X.

    NOTE on conventions: the paper's ``chol`` is Matlab's (upper R,
    RᵀR = C) so it writes F ← La⁻ᵀ F Lb⁻¹ and Xa = √n Qa La⁻¹ U.  With
    jnp's lower factor (L Lᵀ = C) the equivalent is F ← La⁻¹ F Lb⁻ᵀ and
    Xa = √n Qa La⁻ᵀ U.  (Both give Q̃ᵀ(QᵀMQ)Q̃ = I for Q̃ = Q·W.)
    """
    La = jnp.linalg.cholesky(sym(Ca + lam_a * QtQa))
    Lb = jnp.linalg.cholesky(sym(Cb + lam_b * QtQb))
    Fw = solve_triangular(La, F, lower=True)  # La⁻¹ F
    Fw = tri_solve_right(Fw, Lb, trans=True)  # ... Lb⁻ᵀ
    U, S, V = topk_svd(Fw, k)
    sqn = jnp.sqrt(n.astype(Fw.dtype))
    Xa = sqn * (Qa @ solve_triangular(La.T, U, lower=False))  # √n Qa La⁻ᵀ U
    Xb = sqn * (Qb @ solve_triangular(Lb.T, V, lower=False))
    return Xa, Xb, S, La, Lb


# --------------------------------------------------------------------------
# in-memory, paper-faithful
# --------------------------------------------------------------------------


def randomized_cca(
    A: jax.Array, B: jax.Array, cfg: RCCAConfig, key: jax.Array
) -> RCCAResult:
    """Algorithm 1, verbatim, for in-memory A, B (the reference)."""
    n, da = A.shape
    db = B.shape[1]
    kt = cfg.sketch
    ka, kb = jax.random.split(key)
    dt = cfg.dtype
    Qa = jax.random.normal(ka, (da, kt), dt)
    Qb = jax.random.normal(kb, (db, kt), dt)

    if cfg.center:
        A = A - jnp.mean(A, axis=0, keepdims=True)
        B = B - jnp.mean(B, axis=0, keepdims=True)

    for _ in range(cfg.q):  # lines 5-12
        Ya = A.T @ (B @ Qb)
        Yb = B.T @ (A @ Qa)
        Qa = orth(Ya)
        Qb = orth(Yb)

    Pa = A @ Qa  # lines 14-18 (final pass)
    Pb = B @ Qb
    Ca = sym(Pa.T @ Pa)
    Cb = sym(Pb.T @ Pb)
    F = Pa.T @ Pb

    tr_a = jnp.sum(A.astype(jnp.float32) ** 2)
    tr_b = jnp.sum(B.astype(jnp.float32) ** 2)
    lam_a, lam_b = resolve_lambdas(cfg, tr_a, tr_b, da, db)

    QtQa = sym(Qa.T @ Qa)
    QtQb = sym(Qb.T @ Qb)
    Xa, Xb, S, La, Lb = finish(
        Ca, Cb, F, QtQa, QtQb, Qa, Qb, jnp.asarray(n, jnp.float32), lam_a, lam_b, cfg.k
    )
    diag = {"lam_a": lam_a, "lam_b": lam_b, "n": n}
    return RCCAResult(Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb, diagnostics=diag)


# --------------------------------------------------------------------------
# streaming / out-of-core
# --------------------------------------------------------------------------


def _scan_pass(update_fn, init_fn, A_chunks: jax.Array, B_chunks: jax.Array,
               Qa, Qb, merge_group: int = MERGE_GROUP_CHUNKS):
    """One data pass over stacked row chunks, in canonical merge order:
    a lax.scan left-folds each ``merge_group``-chunk group, group sums
    reduce through the fixed pairwise tree.  (The scan body and an
    eagerly jitted per-chunk update compile to bitwise-identical
    arithmetic, so this matches the iterator/cluster paths exactly.)"""

    def body(s, ab):
        a, b = ab
        return update_fn(s, a, b, Qa, Qb), None

    nc = A_chunks.shape[0]
    acc = SegmentedAccumulator(init_fn, nc, merge_group)
    for lo in range(0, nc, merge_group):
        hi = min(nc, lo + merge_group)
        stats, _ = jax.lax.scan(body, init_fn(), (A_chunks[lo:hi], B_chunks[lo:hi]))
        acc.push_group(lo // merge_group, stats)
    return acc.result()


def randomized_cca_streaming(
    A_chunks: jax.Array,  # (nc, c, da) — out-of-core rows, chunked
    B_chunks: jax.Array,  # (nc, c, db)
    cfg: RCCAConfig,
    key: jax.Array,
    *,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
    merge_group: int = MERGE_GROUP_CHUNKS,
) -> RCCAResult:
    """Algorithm 1 where every data pass is a scan over row chunks.

    This is the single-device form of the production data pass: the
    distributed version (rcca_dist) wraps the same updates in shard_map
    and psums the accumulators.  ``engine`` selects the per-chunk update
    implementation: ``"kernels"`` (default) runs the fused Pallas data
    passes (interpret mode off-TPU), ``"jnp"`` the pure-jnp oracle.
    ``use_kernels`` is the legacy boolean spelling of the same knob.
    ``merge_group`` is the canonical merge-group size; a
    ``repro.cluster`` coordinator run with the same value is
    bit-identical to this driver for ANY worker count.
    """
    engine = resolve_engine(engine, use_kernels)
    nc, c, da = A_chunks.shape
    db = B_chunks.shape[-1]
    kt = cfg.sketch
    Qa, Qb = init_Q(key, da, db, cfg)

    kernels = engine == "kernels"
    upd_pow = update_power_stats_kernel if kernels else update_power_stats
    upd_fin = update_final_stats_kernel if kernels else update_final_stats
    init_pow = lambda: init_power_stats(da, db, kt, jnp.float32)
    init_fin = lambda: init_final_stats(kt, da, db, jnp.float32)

    for _ in range(cfg.q):
        stats = _scan_pass(upd_pow, init_pow, A_chunks, B_chunks, Qa, Qb,
                           merge_group)
        Qa, Qb = power_update_Q(stats, Qa, Qb, cfg)

    fstats = _scan_pass(upd_fin, init_fin, A_chunks, B_chunks, Qa, Qb,
                        merge_group)
    return finalize_result(fstats, Qa, Qb, cfg, da, db)


def _open_source(source_factory, start_chunk: int):
    """Instantiate the chunk source for one pass.

    Seek-aware factories opt in by naming their first positional
    parameter ``start`` (e.g. ``repro.store.PassRunner._source``); they
    are asked to begin at ``start_chunk`` directly, so a resumed pass
    never reads the skipped prefix from disk.  Anything else keeps the
    legacy contract: ``source_factory()`` yields from chunk 0 and the
    driver filters.  (Opt-in is by name, not arity — a factory that
    merely happens to take a defaulted positional must not silently
    receive a chunk index.)
    """
    try:
        params = list(inspect.signature(source_factory).parameters.values())
        seekable = bool(params) and params[0].name == "start" and \
            params[0].kind in (params[0].POSITIONAL_ONLY,
                               params[0].POSITIONAL_OR_KEYWORD)
    except (TypeError, ValueError):
        seekable = False
    if seekable:
        return source_factory(start_chunk), start_chunk
    return source_factory(), 0


def jit_update_fn(kind: str, engine: str):
    """The jitted per-chunk update for one pass flavor — the exact
    function cluster workers and the iterator driver share."""
    kernels = resolve_engine(engine) == "kernels"
    if kind == "power":
        return jax.jit(update_power_stats_kernel if kernels else update_power_stats)
    if kind == "final":
        return jax.jit(update_final_stats_kernel if kernels else update_final_stats)
    raise ValueError(f"unknown pass kind {kind!r}")


def stats_init_fn(kind: str, da: int, db: int, sketch: int):
    """Zero accumulators for one pass flavor (f32 — the accumulator
    precision every execution mode shares)."""
    if kind == "power":
        return lambda: init_power_stats(da, db, sketch, jnp.float32)
    if kind == "final":
        return lambda: init_final_stats(sketch, da, db, jnp.float32)
    raise ValueError(f"unknown pass kind {kind!r}")


def randomized_cca_iterator(
    source_factory,
    da: int,
    db: int,
    cfg: RCCAConfig,
    key: jax.Array,
    *,
    resume_state: Optional[dict] = None,
    on_pass_end=None,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
    merge_group: int = MERGE_GROUP_CHUNKS,
    n_chunks: Optional[int] = None,
) -> RCCAResult:
    """True out-of-core driver: ``source_factory()`` yields (a, b) row
    chunks (e.g. from disk / a distributed FS).  Per-chunk updates are
    jitted; pass state is a :class:`SegmentedAccumulator` whose
    ``state()`` pytree the caller can checkpoint between chunks (fault
    tolerance: resume a killed pass mid-stream via ``resume_state`` =
    {"pass_idx", "chunk_idx", "acc", "Qa", "Qb"} with ``acc`` a state
    pytree captured from the ``on_pass_end(pass_idx, chunk_idx, acc,
    Qa, Qb)`` callback's accumulator).  A factory taking a positional
    ``start`` argument is seekable: each pass opens it at its first
    needed chunk, so a resume never re-reads the already-folded prefix
    (``repro.store`` readers/prefetchers use this).  ``engine`` selects
    the per-chunk update implementation and ``merge_group`` the
    canonical merge-group size (see :func:`randomized_cca_streaming`);
    ``n_chunks``, when known, lets a cursor saved at the very last
    chunk of a pass restore correctly (``repro.store.PassRunner``
    passes it).
    """
    engine = resolve_engine(engine, use_kernels)
    kt = cfg.sketch
    Qa, Qb = init_Q(key, da, db, cfg)

    upd_pow = jit_update_fn("power", engine)
    upd_fin = jit_update_fn("final", engine)

    start_pass, start_chunk, acc_state = 0, 0, None
    if resume_state is not None:
        start_pass = int(resume_state["pass_idx"])
        start_chunk = int(resume_state["chunk_idx"])
        acc_state = resume_state["acc"]
        Qa, Qb = resume_state["Qa"], resume_state["Qb"]

    total_passes = cfg.q + 1  # q power passes + final pass
    for pass_idx in range(start_pass, total_passes):
        is_final = pass_idx == cfg.q
        kind = "final" if is_final else "power"
        upd = upd_fin if is_final else upd_pow
        acc = SegmentedAccumulator.structure(
            stats_init_fn(kind, da, db, kt), n_chunks, merge_group, start_chunk)
        if acc_state is not None:
            acc.load_state(acc_state)
            acc_state = None
        source, offset = _open_source(source_factory, start_chunk)
        for chunk_idx, (a, b) in enumerate(source, start=offset):
            if chunk_idx < start_chunk:
                continue
            acc.update(chunk_idx, upd, a, b, Qa, Qb)
            if on_pass_end is not None:
                on_pass_end(pass_idx, chunk_idx, acc, Qa, Qb)
        acc.flush_tail()
        start_chunk = 0
        if not is_final:
            Qa, Qb = power_update_Q(acc.result(), Qa, Qb, cfg)

    return finalize_result(acc.result(), Qa, Qb, cfg, da, db)
