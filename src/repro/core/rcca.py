"""RandomizedCCA — Algorithm 1 of Mineiro & Karampatziakis (2014).

Three entry points, sharing one "finish" (paper lines 19-25):

- :func:`randomized_cca` — paper-faithful in-memory version (the ref).
- :func:`randomized_cca_streaming` / :func:`randomized_cca_iterator` —
  out-of-core semantics: each data pass is a fold over row chunks with
  explicit, checkpointable accumulator state.  Both are shells over
  the ONE pass engine in :mod:`repro.exec`, which also runs the same
  passes device-parallel (``Sharded``), multi-process (``Cluster``)
  and both at once (``Hybrid``).
- the feature-sharded resident-mode version lives in
  :mod:`repro.core.rcca_dist` (shard_map over a (pod, data, model)
  mesh, psums inside the pass).

Every execution topology accumulates in the same CANONICAL ORDER —
chunks left-fold into fixed-size merge groups, group sums reduce
through a fixed pairwise tree (see :mod:`repro.exec.accumulate`) — so
their results agree bitwise: the cluster coordinator's merge of
per-worker partials (:func:`merge_power_stats` /
:func:`merge_final_stats` are exact combiners — every accumulator
field is a plain sum over rows) is bit-identical to a single-process
pass for any worker count and any devices-per-worker layout.

Mean-centering is the paper's §3 rank-one update: column sums are
accumulated alongside each pass (O(da+db) extra state, no extra pass)
and products are corrected as  Āᵀ B̄ = AᵀB − n μa μbᵀ.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .linalg import orth, sym, topk_svd, tri_solve_right
from jax.scipy.linalg import solve_triangular


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

#: Production default of the data-pass engine.  "kernels" = Pallas
#: (Mosaic on TPU, interpret mode elsewhere); "jnp" = the pure-jnp
#: oracle path the kernels are validated against.
DEFAULT_ENGINE = "kernels"


def resolve_engine(engine: str, use_kernels: Optional[bool] = None) -> str:
    """Normalize the engine knob; ``use_kernels`` is the legacy boolean
    spelling and wins when passed explicitly."""
    if use_kernels is not None:
        engine = "kernels" if use_kernels else "jnp"
    if engine not in ("kernels", "jnp"):
        raise ValueError(f"unknown engine {engine!r}; expected 'kernels' or 'jnp'")
    return engine


@dataclasses.dataclass(frozen=True)
class RCCAConfig:
    """Hyper-parameters of Algorithm 1.

    k:       target embedding dimension.
    p:       oversampling (paper uses 910-2000 for k=60).
    q:       number of power-iteration data passes (0 = pure sketch).
    lam_a/b: explicit ridge regularizers; if ``nu`` is set they are
             derived scale-free as λ = ν·Tr(XᵀX)/d (paper §4).
    center:  mean-shift both views via the rank-one update.
    """

    k: int
    p: int = 100
    q: int = 1
    lam_a: float = 0.0
    lam_b: float = 0.0
    nu: Optional[float] = None
    center: bool = False
    dtype: jnp.dtype = jnp.float32

    @property
    def sketch(self) -> int:  # k̃ = k + p
        return self.k + self.p


def algo_meta(cfg: RCCAConfig) -> dict:
    """The hyper-parameter identity that binds persisted pass state —
    PassRunner cursors and cluster rounds/partials both embed and
    validate exactly this dict, so they can never drift apart."""
    return {"k": cfg.k, "p": cfg.p, "q": cfg.q, "center": cfg.center,
            "nu": cfg.nu, "lam_a": cfg.lam_a, "lam_b": cfg.lam_b,
            "dtype": str(jnp.dtype(cfg.dtype))}


class RCCAResult(NamedTuple):
    Xa: jax.Array
    Xb: jax.Array
    rho: jax.Array  # top-k canonical correlations (Σ of paper line 22)
    Qa: jax.Array  # final range bases — useful to warm-start / analyze
    Qb: jax.Array
    diagnostics: dict


# --------------------------------------------------------------------------
# pass statistics (checkpointable)
# --------------------------------------------------------------------------


class PowerStats(NamedTuple):
    """Accumulators of one range-finder pass (paper lines 6-9)."""

    Ya: jax.Array  # AᵀB Qb   (da, k̃)
    Yb: jax.Array  # BᵀA Qa   (db, k̃)
    sa: jax.Array  # Aᵀ1      (da,)
    sb: jax.Array  # Bᵀ1      (db,)
    n: jax.Array  # row count ()
    tr_a: jax.Array  # ‖A‖_F²  () — for scale-free λ
    tr_b: jax.Array  # ‖B‖_F²  ()


class FinalStats(NamedTuple):
    """Accumulators of the final pass (paper lines 14-18)."""

    Ca: jax.Array  # Qaᵀ AᵀA Qa  (k̃, k̃)
    Cb: jax.Array  # Qbᵀ BᵀB Qb  (k̃, k̃)
    F: jax.Array  # Qaᵀ AᵀB Qb  (k̃, k̃)
    sa: jax.Array
    sb: jax.Array
    n: jax.Array
    tr_a: jax.Array
    tr_b: jax.Array


def init_power_stats(da: int, db: int, sketch: int, dtype) -> PowerStats:
    z = jnp.zeros
    return PowerStats(
        Ya=z((da, sketch), dtype),
        Yb=z((db, sketch), dtype),
        sa=z((da,), dtype),
        sb=z((db,), dtype),
        n=z((), dtype),
        tr_a=z((), dtype),
        tr_b=z((), dtype),
    )


def init_final_stats(sketch: int, da: int, db: int, dtype) -> FinalStats:
    z = jnp.zeros
    return FinalStats(
        Ca=z((sketch, sketch), dtype),
        Cb=z((sketch, sketch), dtype),
        F=z((sketch, sketch), dtype),
        sa=z((da,), dtype),
        sb=z((db,), dtype),
        n=z((), dtype),
        tr_a=z((), dtype),
        tr_b=z((), dtype),
    )


def update_power_stats(
    s: PowerStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> PowerStats:
    """Fold one row chunk into the range-finder accumulators.

    The two rank-k̃ products are the data-pass hot spot; the Pallas
    kernel (repro.kernels.ccapass) implements exactly this update with
    fused VMEM tiling — this jnp form is its oracle.
    """
    f32 = jnp.float32
    pb = b @ Qb  # (c, k̃)
    pa = a @ Qa
    return PowerStats(
        Ya=s.Ya + (a.T @ pb).astype(s.Ya.dtype),
        Yb=s.Yb + (b.T @ pa).astype(s.Yb.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_power_stats_kernel(
    s: PowerStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> PowerStats:
    """Pallas-kernel-backed version of :func:`update_power_stats`
    (fused MXU matmuls; interpret-mode on CPU).  The fused kernels
    bucket their output columns over a third grid axis, so this path
    holds at any feature width — Europarl's da = db = 2^19 included —
    rather than silently degrading to the unfused matmul pair."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    dYa, dYb = kops.power_pass_chunk(a, b, Qa, Qb)
    return s._replace(
        Ya=s.Ya + dYa.astype(s.Ya.dtype),
        Yb=s.Yb + dYb.astype(s.Yb.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_final_stats_kernel(
    s: FinalStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> FinalStats:
    """Pallas-kernel-backed version of :func:`update_final_stats`
    (projgram fusion: each view read from HBM once per chunk)."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    dCa, dCb, dF = kops.final_pass_chunk(a, b, Qa, Qb)
    return s._replace(
        Ca=s.Ca + dCa.astype(s.Ca.dtype),
        Cb=s.Cb + dCb.astype(s.Cb.dtype),
        F=s.F + dF.astype(s.F.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


def update_final_stats(
    s: FinalStats, a: jax.Array, b: jax.Array, Qa: jax.Array, Qb: jax.Array
) -> FinalStats:
    pa = a @ Qa  # (c, k̃)
    pb = b @ Qb
    f32 = jnp.float32
    return FinalStats(
        Ca=s.Ca + (pa.T @ pa).astype(s.Ca.dtype),
        Cb=s.Cb + (pb.T @ pb).astype(s.Cb.dtype),
        F=s.F + (pa.T @ pb).astype(s.F.dtype),
        sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
        sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
        n=s.n + a.shape[0],
        tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
        tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
    )


# --------------------------------------------------------------------------
# mergeable sufficient statistics (repro.cluster's map/combine contract)
#
# The canonical accumulation machinery (merge groups, pairwise tree,
# segmented accumulator) lives in repro.exec.accumulate — the one
# implementation every execution topology shares.  It is re-exported
# here because these names are part of this module's long-standing API.
# --------------------------------------------------------------------------

from repro.exec.accumulate import (  # noqa: E402, F401  (re-exports)
    MERGE_GROUP_CHUNKS,
    PairwiseStack,
    SegmentedAccumulator,
    merge_stats,
    reduce_group_partials,
)


def merge_power_stats(x: PowerStats, y: PowerStats) -> PowerStats:
    """Combine two range-finder accumulators over disjoint row sets.

    Every field is a plain sum over rows, so the merge is the exact
    map/reduce combiner of Algorithm 1: stats(S₁ ∪ S₂) = stats(S₁) ⊕
    stats(S₂) with ⊕ = elementwise +.  (Exact as algebra; the fp ADD
    still rounds — which is why the canonical reduction ORDER of
    ``repro.exec.accumulate`` exists.)
    """
    return merge_stats(x, y)


def merge_final_stats(x: FinalStats, y: FinalStats) -> FinalStats:
    """Combine two final-pass accumulators — same contract as
    :func:`merge_power_stats`."""
    return merge_stats(x, y)


# --------------------------------------------------------------------------
# centering corrections (rank-one updates, paper §3)
# --------------------------------------------------------------------------


def centered_Y(s: PowerStats, Qa, Qb, center: bool):
    if not center:
        return s.Ya, s.Yb
    n = jnp.maximum(s.n, 1.0)
    mu_a = s.sa / n
    mu_b = s.sb / n
    Ya = s.Ya - n * jnp.outer(mu_a, mu_b @ Qb)  # ĀᵀB̄Qb = AᵀBQb − n μa(μbᵀQb)
    Yb = s.Yb - n * jnp.outer(mu_b, mu_a @ Qa)
    return Ya, Yb


def centered_CF(s: FinalStats, Qa, Qb, center: bool):
    if not center:
        return s.Ca, s.Cb, s.F
    n = jnp.maximum(s.n, 1.0)
    qa = Qa.T @ (s.sa / n)  # (k̃,) = Qaᵀ μa
    qb = Qb.T @ (s.sb / n)
    Ca = s.Ca - n * jnp.outer(qa, qa)
    Cb = s.Cb - n * jnp.outer(qb, qb)
    F = s.F - n * jnp.outer(qa, qb)
    return Ca, Cb, F


def resolve_lambdas(cfg: RCCAConfig, tr_a, tr_b, da: int, db: int):
    if cfg.nu is None:
        return jnp.asarray(cfg.lam_a, jnp.float32), jnp.asarray(cfg.lam_b, jnp.float32)
    return cfg.nu * tr_a / da, cfg.nu * tr_b / db


# --------------------------------------------------------------------------
# shared per-pass transitions — every driver (streaming scan, iterator,
# cluster coordinator) runs EXACTLY these, which is what makes their
# outputs comparable bit-for-bit
# --------------------------------------------------------------------------


#: The Ω-provenance knob of the seeded-sketch path:
#: - ``"materialized"``   — classic ``jax.random.normal`` draw, array
#:   threaded everywhere (the default; pre-existing behavior).
#: - ``"seeded"``         — Ω is a pure function of a (2,)-uint32 seed
#:   (:mod:`repro.kernels.rand`); the first data pass generates its
#:   tiles inside the Pallas kernels and never materializes the
#:   ``(d, k̃)`` array, and cluster rounds ship the seed, not the array.
#: - ``"seeded-materialized"`` — the same tile-PRNG Ω, but materialized
#:   up front and run through the standard update path: the bitwise
#:   oracle ``omega="seeded"`` is validated against.
OMEGA_MODES = ("materialized", "seeded", "seeded-materialized")


def resolve_omega(omega: str) -> str:
    """Normalize/validate the Ω-provenance knob."""
    if omega not in OMEGA_MODES:
        raise ValueError(
            f"unknown omega {omega!r}; expected one of {OMEGA_MODES}")
    return omega


def omega_seeds(key: jax.Array):
    """Per-view (2,)-uint32 Ω seeds for the seeded modes — the 64-bit
    payload that replaces the (d, k̃) broadcast, identically derived
    from the PRNG key by every execution mode."""
    from repro.kernels import rand as krand

    return krand.seeds_from_key(key)


def init_Q(key: jax.Array, da: int, db: int, cfg: RCCAConfig,
           omega: str = "materialized"):
    """Line 1-2: the Gaussian sketch bases, identically derived from the
    PRNG key by every execution mode.

    Always generated in f32 with a single cast to ``cfg.dtype`` —
    drawing directly in bf16 would quantize the underlying uniforms
    and lose entropy, and it would diverge from the seeded kernels'
    generate-in-f32-then-cast semantics.  The seeded modes materialize
    the tile-PRNG Ω (the cross-engine oracle of the in-kernel path).
    """
    from repro.kernels import rand as krand

    if resolve_omega(omega) == "materialized":
        ka, kb = jax.random.split(key)
        Qa = jax.random.normal(ka, (da, cfg.sketch), jnp.float32)
        Qb = jax.random.normal(kb, (db, cfg.sketch), jnp.float32)
        return Qa.astype(cfg.dtype), Qb.astype(cfg.dtype)
    seed_a, seed_b = omega_seeds(key)
    return (krand.dense_omega(seed_a, da, cfg.sketch, cfg.dtype),
            krand.dense_omega(seed_b, db, cfg.sketch, cfg.dtype))


def power_update_Q(stats: PowerStats, Qa, Qb, cfg: RCCAConfig):
    """Lines 10-11: close one range-finder pass (center + orth)."""
    Ya, Yb = centered_Y(stats, Qa, Qb, cfg.center)
    return orth(Ya.astype(cfg.dtype)), orth(Yb.astype(cfg.dtype))


def finalize_result(fstats: FinalStats, Qa, Qb, cfg: RCCAConfig,
                    da: int, db: int) -> RCCAResult:
    """Lines 19-25 from merged final-pass statistics."""
    Ca, Cb, F = centered_CF(fstats, Qa, Qb, cfg.center)
    lam_a, lam_b = resolve_lambdas(cfg, fstats.tr_a, fstats.tr_b, da, db)
    QtQa = sym((Qa.T @ Qa).astype(jnp.float32))
    QtQb = sym((Qb.T @ Qb).astype(jnp.float32))
    Xa, Xb, S, _, _ = finish(
        Ca, Cb, F, QtQa, QtQb, Qa.astype(jnp.float32), Qb.astype(jnp.float32),
        fstats.n, lam_a, lam_b, cfg.k,
    )
    return RCCAResult(
        Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb,
        diagnostics={"lam_a": lam_a, "lam_b": lam_b, "n": fstats.n},
    )


# --------------------------------------------------------------------------
# finish: paper lines 19-25 (host-scale, (k̃)³)
# --------------------------------------------------------------------------


def finish(
    Ca: jax.Array,
    Cb: jax.Array,
    F: jax.Array,
    QtQa: jax.Array,
    QtQb: jax.Array,
    Qa: jax.Array,
    Qb: jax.Array,
    n: jax.Array,
    lam_a,
    lam_b,
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Lines 19-25: whiten F in the Q bases, SVD, map back to X.

    NOTE on conventions: the paper's ``chol`` is Matlab's (upper R,
    RᵀR = C) so it writes F ← La⁻ᵀ F Lb⁻¹ and Xa = √n Qa La⁻¹ U.  With
    jnp's lower factor (L Lᵀ = C) the equivalent is F ← La⁻¹ F Lb⁻ᵀ and
    Xa = √n Qa La⁻ᵀ U.  (Both give Q̃ᵀ(QᵀMQ)Q̃ = I for Q̃ = Q·W.)
    """
    La = jnp.linalg.cholesky(sym(Ca + lam_a * QtQa))
    Lb = jnp.linalg.cholesky(sym(Cb + lam_b * QtQb))
    Fw = solve_triangular(La, F, lower=True)  # La⁻¹ F
    Fw = tri_solve_right(Fw, Lb, trans=True)  # ... Lb⁻ᵀ
    U, S, V = topk_svd(Fw, k)
    sqn = jnp.sqrt(n.astype(Fw.dtype))
    Xa = sqn * (Qa @ solve_triangular(La.T, U, lower=False))  # √n Qa La⁻ᵀ U
    Xb = sqn * (Qb @ solve_triangular(Lb.T, V, lower=False))
    return Xa, Xb, S, La, Lb


# --------------------------------------------------------------------------
# in-memory, paper-faithful
# --------------------------------------------------------------------------


def randomized_cca(
    A: jax.Array, B: jax.Array, cfg: RCCAConfig, key: jax.Array
) -> RCCAResult:
    """Algorithm 1, verbatim, for in-memory A, B (the reference)."""
    n, da = A.shape
    db = B.shape[1]
    kt = cfg.sketch
    ka, kb = jax.random.split(key)
    dt = cfg.dtype
    # f32 generation + single cast — same entropy semantics as init_Q
    Qa = jax.random.normal(ka, (da, kt), jnp.float32).astype(dt)
    Qb = jax.random.normal(kb, (db, kt), jnp.float32).astype(dt)

    if cfg.center:
        A = A - jnp.mean(A, axis=0, keepdims=True)
        B = B - jnp.mean(B, axis=0, keepdims=True)

    for _ in range(cfg.q):  # lines 5-12
        Ya = A.T @ (B @ Qb)
        Yb = B.T @ (A @ Qa)
        Qa = orth(Ya)
        Qb = orth(Yb)

    Pa = A @ Qa  # lines 14-18 (final pass)
    Pb = B @ Qb
    Ca = sym(Pa.T @ Pa)
    Cb = sym(Pb.T @ Pb)
    F = Pa.T @ Pb

    tr_a = jnp.sum(A.astype(jnp.float32) ** 2)
    tr_b = jnp.sum(B.astype(jnp.float32) ** 2)
    lam_a, lam_b = resolve_lambdas(cfg, tr_a, tr_b, da, db)

    QtQa = sym(Qa.T @ Qa)
    QtQb = sym(Qb.T @ Qb)
    Xa, Xb, S, La, Lb = finish(
        Ca, Cb, F, QtQa, QtQb, Qa, Qb, jnp.asarray(n, jnp.float32), lam_a, lam_b, cfg.k
    )
    diag = {"lam_a": lam_a, "lam_b": lam_b, "n": n}
    return RCCAResult(Xa=Xa, Xb=Xb, rho=S, Qa=Qa, Qb=Qb, diagnostics=diag)


# --------------------------------------------------------------------------
# streaming / out-of-core — shells over the repro.exec pass engine
# --------------------------------------------------------------------------


def randomized_cca_streaming(
    A_chunks: jax.Array,  # (nc, c, da) — out-of-core rows, chunked
    B_chunks: jax.Array,  # (nc, c, db)
    cfg: RCCAConfig,
    key: jax.Array,
    *,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
    merge_group: int = MERGE_GROUP_CHUNKS,
    topology=None,
) -> RCCAResult:
    """Algorithm 1 where every data pass is a fold over row chunks.

    A shell over ``repro.exec.PassEngine`` — the canonical chunk →
    merge-group → pairwise-tree accumulation every execution topology
    shares.  ``engine`` selects the per-chunk update implementation:
    ``"kernels"`` (default) runs the fused Pallas data passes
    (interpret mode off-TPU), ``"jnp"`` the pure-jnp oracle.
    ``use_kernels`` is the legacy boolean spelling of the same knob.
    ``merge_group`` is the canonical merge-group size; a
    ``repro.cluster`` coordinator run with the same value is
    bit-identical to this driver for ANY worker count.  ``topology``
    optionally selects ``repro.exec.Sharded()`` to fold merge groups
    one-per-device over the local mesh (bitwise the same result); the
    default is sequential ``Local`` execution.
    """
    from repro.exec import Local, PassEngine, StackedChunks

    engine = resolve_engine(engine, use_kernels)
    eng = PassEngine(cfg, engine=engine, merge_group=merge_group,
                     topology=Local() if topology is None else topology)
    return eng.run(StackedChunks(A_chunks, B_chunks), key)


def jit_update_fn(kind: str, engine: str):
    """The jitted per-chunk update for one pass flavor — the exact
    function cluster workers and the iterator driver share."""
    return jax.jit(update_fn(kind, engine))


def update_fn(kind: str, engine: str):
    """The raw (unjitted) per-chunk update for one pass flavor — what
    the device-parallel group fold scans inside shard_map (jitting is
    the caller's concern there)."""
    kernels = resolve_engine(engine) == "kernels"
    if kind == "power":
        return update_power_stats_kernel if kernels else update_power_stats
    if kind == "final":
        return update_final_stats_kernel if kernels else update_final_stats
    raise ValueError(f"unknown pass kind {kind!r}")


def seeded_update_fn(kind: str, kt: int, q_dtype):
    """The raw per-chunk update for a seeded-Ω pass (kernels engine):
    Ω tiles are generated inside the fused Pallas kernels, so the Qa/Qb
    operand slots carry the (2,)-uint32 seeds instead of (d, k̃) arrays
    — same arity as :func:`update_fn`'s result, which is what lets the
    fold loop, shard_map specs, cursors and cluster rounds stay
    structurally unchanged.  Bitwise identical to the materialized
    update fed ``rand.dense_omega(seed, d, kt, q_dtype)``."""
    from repro.kernels import ops as kops

    f32 = jnp.float32
    if kind == "power":
        def upd(s: PowerStats, a, b, seed_a, seed_b) -> PowerStats:
            dYa, dYb = kops.power_pass_chunk_seeded(a, b, seed_a, seed_b,
                                                    kt=kt, q_dtype=q_dtype)
            return s._replace(
                Ya=s.Ya + dYa.astype(s.Ya.dtype),
                Yb=s.Yb + dYb.astype(s.Yb.dtype),
                sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
                sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
                n=s.n + a.shape[0],
                tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
                tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
            )
        return upd
    if kind == "final":
        def upd(s: FinalStats, a, b, seed_a, seed_b) -> FinalStats:
            dCa, dCb, dF = kops.final_pass_chunk_seeded(a, b, seed_a, seed_b,
                                                        kt=kt, q_dtype=q_dtype)
            return s._replace(
                Ca=s.Ca + dCa.astype(s.Ca.dtype),
                Cb=s.Cb + dCb.astype(s.Cb.dtype),
                F=s.F + dF.astype(s.F.dtype),
                sa=s.sa + jnp.sum(a, axis=0, dtype=f32).astype(s.sa.dtype),
                sb=s.sb + jnp.sum(b, axis=0, dtype=f32).astype(s.sb.dtype),
                n=s.n + a.shape[0],
                tr_a=s.tr_a + jnp.sum(a.astype(f32) ** 2),
                tr_b=s.tr_b + jnp.sum(b.astype(f32) ** 2),
            )
        return upd
    raise ValueError(f"unknown pass kind {kind!r}")


def jit_seeded_update_fn(kind: str, kt: int, q_dtype):
    """Jitted :func:`seeded_update_fn` — what streaming drivers and
    cluster workers run for a seeded pass."""
    return jax.jit(seeded_update_fn(kind, kt, q_dtype))


def stats_init_fn(kind: str, da: int, db: int, sketch: int):
    """Zero accumulators for one pass flavor (f32 — the accumulator
    precision every execution mode shares)."""
    if kind == "power":
        return lambda: init_power_stats(da, db, sketch, jnp.float32)
    if kind == "final":
        return lambda: init_final_stats(sketch, da, db, jnp.float32)
    raise ValueError(f"unknown pass kind {kind!r}")


def randomized_cca_iterator(
    source_factory,
    da: int,
    db: int,
    cfg: RCCAConfig,
    key: jax.Array,
    *,
    resume_state: Optional[dict] = None,
    on_pass_end=None,
    engine: str = DEFAULT_ENGINE,
    use_kernels: Optional[bool] = None,
    merge_group: int = MERGE_GROUP_CHUNKS,
    omega: str = "materialized",
    n_chunks: Optional[int] = None,
) -> RCCAResult:
    """True out-of-core driver: ``source_factory()`` yields (a, b) row
    chunks (e.g. from disk / a distributed FS).  Per-chunk updates are
    jitted; pass state is a :class:`SegmentedAccumulator` whose
    ``state()`` pytree the caller can checkpoint between chunks (fault
    tolerance: resume a killed pass mid-stream via ``resume_state`` =
    {"pass_idx", "chunk_idx", "acc", "Qa", "Qb"} with ``acc`` a state
    pytree captured from the ``on_pass_end(pass_idx, chunk_idx, acc,
    Qa, Qb)`` callback's accumulator).  A factory taking a positional
    ``start`` argument is seekable: each pass opens it at its first
    needed chunk, so a resume never re-reads the already-folded prefix
    (``repro.store`` readers/prefetchers use this).  ``engine`` selects
    the per-chunk update implementation and ``merge_group`` the
    canonical merge-group size (see :func:`randomized_cca_streaming`);
    ``n_chunks``, when known, lets a cursor saved at the very last
    chunk of a pass restore correctly (``repro.store.PassRunner``
    passes it).  A shell over ``repro.exec.PassEngine.run_stream`` —
    the engine owns the fold loop, source seeking and resume-state
    restoration.
    """
    from repro.exec import PassEngine

    eng = PassEngine(cfg, engine=resolve_engine(engine, use_kernels),
                     merge_group=merge_group, omega=omega)
    return eng.run_stream(source_factory, da, db, key, n_chunks=n_chunks,
                          resume_state=resume_state, on_pass_end=on_pass_end)
