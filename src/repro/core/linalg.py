"""Matmul-friendly linear algebra helpers used across the CCA core.

Everything here is deliberately expressed as dense matmuls + small
(k̃ × k̃) host-scale factorizations so it maps onto the TPU MXU: no
Householder QR, no pivoting.  ``k̃ = k + p`` is a few hundred to a few
thousand, so all square factorizations below are "small" in the paper's
sense (§3: feasible on one commodity machine for k+p ≲ 10000).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular


def sym(M: jax.Array) -> jax.Array:
    """Symmetrize (guards eigh/cholesky against matmul round-off skew)."""
    return 0.5 * (M + M.T)


def chol_psd(M: jax.Array, jitter: float = 0.0) -> jax.Array:
    """Cholesky of a (nearly) PSD matrix with optional diagonal jitter."""
    d = M.shape[-1]
    if jitter:
        M = M + jitter * jnp.eye(d, dtype=M.dtype)
    return jnp.linalg.cholesky(sym(M))


def tri_solve_right(Y: jax.Array, L: jax.Array, *, trans: bool = False) -> jax.Array:
    """Compute ``Y @ inv(L)`` (or ``Y @ inv(L).T``) via triangular solve.

    L is lower triangular.  Used for CholeskyQR and the paper's line 21
    ``F ← La^{-T} F Lb^{-1}`` without forming explicit inverses.
    """
    # Y L^{-1} = (L^{-T} Y^T)^T ; solve L^T Z = Y^T  (upper system)
    if not trans:
        return solve_triangular(L.T, Y.T, lower=False).T
    # Y L^{-T} = (L^{-1} Y^T)^T ; solve L Z = Y^T (lower system)
    return solve_triangular(L, Y.T, lower=True).T


def cholesky_qr(Y: jax.Array, jitter: float = 0.0) -> tuple[jax.Array, jax.Array]:
    """One round of CholeskyQR: Q = Y L^{-T} with L = chol(YᵀY).

    Returns (Q, R) with R = Lᵀ upper-triangular so that Q R = Y.
    All-matmul: the only non-matmul op is a k̃×k̃ Cholesky.
    """
    G = sym(Y.T @ Y)
    L = chol_psd(G, jitter)
    Q = tri_solve_right(Y, L, trans=False)
    return Q, L.T


def cholesky_qr2(Y: jax.Array, jitter: float = 0.0) -> jax.Array:
    """CholeskyQR2: two rounds ⇒ orthogonality error O(ε) instead of
    O(ε·κ²).  This is the TPU-native replacement for Matlab ``orth`` in
    Algorithm 1 lines 10-11 (see DESIGN.md §3)."""
    Q, _ = cholesky_qr(Y, jitter)
    Q, _ = cholesky_qr(Q, 0.0)
    return Q


def eigh_whiten(Y: jax.Array, G: jax.Array, rel_eps: float = 1e-12) -> jax.Array:
    """First-round orthonormalization robust to arbitrary κ(Y):
    Q = Y · V · w^{-1/2} from the eigendecomposition of the Gram.
    Power iteration squares the condition number every pass, which
    overwhelms plain CholeskyQR in f32 — eigh does not care."""
    w, V = jnp.linalg.eigh(sym(G).astype(jnp.float32))
    w = jnp.maximum(w, rel_eps * jnp.max(w))
    return (Y.astype(jnp.float32) @ V) * (1.0 / jnp.sqrt(w))


def orth(Y: jax.Array) -> jax.Array:
    """Paper's ``orth``: orthonormal basis for range(Y).

    eigh-whitened first round (rank/κ robust) + one CholeskyQR cleanup
    round (restores orthogonality to O(ε)).  Both factorizations are
    k̃×k̃ — "small" in the paper's sense — so this stays matmul-dominated.
    """
    dt = Y.dtype
    Q = eigh_whiten(Y, Y.T @ Y)
    Q, _ = cholesky_qr(Q, 0.0)
    return Q.astype(dt)


def inv_sqrt_psd(M: jax.Array, eps: float = 0.0) -> jax.Array:
    """Symmetric inverse square root via eigh (small matrices only)."""
    w, V = jnp.linalg.eigh(sym(M))
    w = jnp.maximum(w, 0.0) + eps
    return (V * (1.0 / jnp.sqrt(w))) @ V.T


def topk_svd(F: jax.Array, k: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k SVD of a small dense matrix (paper line 22)."""
    U, S, Vt = jnp.linalg.svd(F, full_matrices=False)
    return U[:, :k], S[:k], Vt[:k, :].T
