"""Horst iteration for CCA — the paper's baseline (§2, Table 2b).

Gauss-Seidel variant of the Horst/orthogonal power method for the
multivariate eigenvalue problem (Chu & Watterson 1993; Zhang & Chu
2011): alternate regularized least-squares solves with block
normalization in the covariance metric.  One Horst iteration costs two
data passes (one per view); the paper budget is 120 passes.

Also implements ``Horst+rcca`` — initializing from a RandomizedCCA
solution — which the paper shows cuts 120 passes to ~34.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .linalg import inv_sqrt_psd, sym


@dataclasses.dataclass(frozen=True)
class HorstConfig:
    k: int
    iters: int = 60  # each iteration = 2 data passes
    lam_a: float = 0.0
    lam_b: float = 0.0
    nu: Optional[float] = None
    solver: str = "chol"  # "chol" (exact, d³) | "cg" (approximate LS, paper fn.5)
    cg_iters: int = 10


class HorstResult(NamedTuple):
    Xa: jax.Array
    Xb: jax.Array
    rho: jax.Array
    objective_history: jax.Array  # (iters,) train objective per iteration


def _metric_normalize(W: jax.Array, M_mul, n: float) -> jax.Array:
    """X ← √n · W (Wᵀ M W)^{-1/2} so that Xᵀ M X = n I."""
    G = sym(W.T @ M_mul(W))
    return jnp.sqrt(n) * (W @ inv_sqrt_psd(G, eps=1e-12))


def _cg_solve(M_mul, RHS: jax.Array, iters: int) -> jax.Array:
    """Block conjugate gradient for M X = RHS (approximate LS, paper's
    footnote 5: solves need only be approximate for convergence)."""

    def body(carry, _):
        X, R, P, rs = carry
        MP = M_mul(P)
        alpha = rs / jnp.maximum(jnp.sum(P * MP, axis=0), 1e-30)
        X = X + P * alpha
        R = R - MP * alpha
        rs_new = jnp.sum(R * R, axis=0)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        P = R + P * beta
        return (X, R, P, rs_new), None

    X0 = jnp.zeros_like(RHS)
    R0 = RHS
    (X, _, _, _), _ = jax.lax.scan(
        body, (X0, R0, R0, jnp.sum(R0 * R0, axis=0)), None, length=iters
    )
    return X


def horst_cca(
    A: jax.Array,
    B: jax.Array,
    cfg: HorstConfig,
    key: Optional[jax.Array] = None,
    init_Xb: Optional[jax.Array] = None,
) -> HorstResult:
    """Dense Horst iteration.  ``init_Xb`` warm-starts (Horst+rcca).

    At test scale we precompute the Gram matrices once; on a cluster the
    same recurrence runs as data passes (each matmul against A/B is a
    streamed shard_map pass exactly like rcca's — see rcca_dist).
    """
    n, da = A.shape
    db = B.shape[1]
    if cfg.nu is not None:
        lam_a = cfg.nu * jnp.sum(A.astype(jnp.float32) ** 2) / da
        lam_b = cfg.nu * jnp.sum(B.astype(jnp.float32) ** 2) / db
    else:
        lam_a, lam_b = cfg.lam_a, cfg.lam_b

    Caa = sym(A.T @ A)
    Cbb = sym(B.T @ B)
    Cab = A.T @ B

    Ma = lambda X: Caa @ X + lam_a * X
    Mb = lambda X: Cbb @ X + lam_b * X

    if cfg.solver == "chol":
        La = jnp.linalg.cholesky(Caa + lam_a * jnp.eye(da, dtype=A.dtype))
        Lb = jnp.linalg.cholesky(Cbb + lam_b * jnp.eye(db, dtype=B.dtype))
        solve_a = lambda R: jax.scipy.linalg.cho_solve((La, True), R)
        solve_b = lambda R: jax.scipy.linalg.cho_solve((Lb, True), R)
    else:
        solve_a = lambda R: _cg_solve(Ma, R, cfg.cg_iters)
        solve_b = lambda R: _cg_solve(Mb, R, cfg.cg_iters)

    if init_Xb is None:
        assert key is not None, "need a PRNG key for random init"
        Xb = jax.random.normal(key, (db, cfg.k), A.dtype)  # paper fn.5: Gaussian init
    else:
        Xb = init_Xb
    Xb = _metric_normalize(Xb, Mb, n)

    def step(Xb, _):
        Wa = solve_a(Cab @ Xb)  # LS solve: argmin ‖A Xa − B Xb‖² + λ‖Xa‖²
        Xa = _metric_normalize(Wa, Ma, n)
        Wb = solve_b(Cab.T @ Xa)  # Gauss-Seidel: uses fresh Xa
        Xb = _metric_normalize(Wb, Mb, n)
        obj = jnp.trace(Xa.T @ Cab @ Xb) / n
        return Xb, (Xa, obj)

    Xb, (Xas, objs) = jax.lax.scan(step, Xb, None, length=cfg.iters)
    Xa = Xas[-1]

    # rotate into canonical (diagonal cross-cov) coordinates
    T = Xa.T @ Cab @ Xb / n
    U, S, Vt = jnp.linalg.svd(T)
    Xa = Xa @ U
    Xb = Xb @ Vt.T
    return HorstResult(Xa=Xa, Xb=Xb, rho=S, objective_history=objs)


# ---------------------------------------------------------------------------
# streaming / out-of-core Horst (the paper's actual large-scale regime)
# ---------------------------------------------------------------------------


class StreamingGrams:
    """Gram-vector products as streamed data passes, with an explicit
    pass counter — the currency of the paper's Table 2b.  Never
    materializes AᵀA (O(d·k) state only)."""

    def __init__(self, source_factory):
        self.source_factory = source_factory
        self.passes = 0
        self.n = None

    def cross(self, Xa, Xb):
        """One pass → (AᵀB·Xb, BᵀA·Xa)."""
        self.passes += 1
        Ra = Rb = None
        n = 0
        for a, b in self.source_factory():
            ua, ub = a.T @ (b @ Xb), b.T @ (a @ Xa)
            Ra = ua if Ra is None else Ra + ua
            Rb = ub if Rb is None else Rb + ub
            n += a.shape[0]
        self.n = n
        return Ra, Rb

    def gram(self, Va, Vb):
        """One pass → (AᵀA·Va, BᵀB·Vb) — the CG matvec for both views."""
        self.passes += 1
        Ga = Gb = None
        for a, b in self.source_factory():
            ua, ub = a.T @ (a @ Va), b.T @ (b @ Vb)
            Ga = ua if Ga is None else Ga + ua
            Gb = ub if Gb is None else Gb + ub
        return Ga, Gb


def horst_cca_streaming(
    source_factory,
    da: int,
    db: int,
    cfg: HorstConfig,
    key: Optional[jax.Array] = None,
    init_Xb: Optional[jax.Array] = None,
    init_Xa: Optional[jax.Array] = None,
    lam_a: float = 0.0,
    lam_b: float = 0.0,
) -> HorstResult:
    """Horst iteration with every matrix product a streamed data pass
    (paper §2: the multiplication step runs directly in the X coordinate
    system; AᵀA is never materialized).  The regularized LS solves use a
    few CG iterations whose matvecs are shared data passes — the paper's
    footnote-5 regime (approximate solves still converge).

    Pass cost per Horst iteration: 1 (cross products) + cg_iters (CG
    matvecs, both views jointly) + 1 (metric normalization).  The total
    is in ``result.passes`` terms via the StreamingGrams counter; use
    ``init_Xb`` from RandomizedCCA for the Horst+rcca warm start and
    compare pass counts with Alg. 1's q+1 (Table 2b).
    """
    k = cfg.k
    if init_Xb is None:
        assert key is not None
        ka, kb = jax.random.split(key)
        Xb = jax.random.normal(kb, (db, k), jnp.float32)
        Xa = jax.random.normal(ka, (da, k), jnp.float32)
    else:
        Xb = jnp.asarray(init_Xb, jnp.float32)
        Xa = (jnp.asarray(init_Xa, jnp.float32) if init_Xa is not None
              else jax.random.normal(jax.random.PRNGKey(0), (da, k), jnp.float32))
    grams = StreamingGrams(source_factory)
    eye = jnp.eye(k)
    objs = []

    def cg_joint(Ra, Rb, Wa0, Wb0):
        """CG on (Ca+λa)Wa=Ra and (Cb+λb)Wb=Rb with shared passes."""
        Wa, Wb = Wa0, Wb0
        Ga0, Gb0 = grams.gram(Wa, Wb)
        ra = Ra - (Ga0 + lam_a * Wa)
        rb = Rb - (Gb0 + lam_b * Wb)
        pa, pb = ra, rb
        rs_a = jnp.sum(ra * ra, 0)
        rs_b = jnp.sum(rb * rb, 0)
        for _ in range(cfg.cg_iters):
            Gpa, Gpb = grams.gram(pa, pb)
            Gpa = Gpa + lam_a * pa
            Gpb = Gpb + lam_b * pb
            aa = rs_a / jnp.maximum(jnp.sum(pa * Gpa, 0), 1e-30)
            ab = rs_b / jnp.maximum(jnp.sum(pb * Gpb, 0), 1e-30)
            Wa, Wb = Wa + pa * aa, Wb + pb * ab
            ra, rb = ra - Gpa * aa, rb - Gpb * ab
            rs_a2 = jnp.sum(ra * ra, 0)
            rs_b2 = jnp.sum(rb * rb, 0)
            pa = ra + pa * (rs_a2 / jnp.maximum(rs_a, 1e-30))
            pb = rb + pb * (rs_b2 / jnp.maximum(rs_b, 1e-30))
            rs_a, rs_b = rs_a2, rs_b2
        return Wa, Wb

    Wa_prev = jnp.zeros((da, k), jnp.float32)
    Wb_prev = Xb * 0.0
    for _ in range(cfg.iters):
        Ra, Rb = grams.cross(Xa if jnp.any(Xa != 0) else jnp.zeros_like(Xa), Xb)
        n = grams.n
        Wa, Wb = cg_joint(Ra, Rb, jnp.zeros((da, k), jnp.float32),
                          jnp.zeros((db, k), jnp.float32))
        # exact metric normalization (one pass)
        GaW, GbW = grams.gram(Wa, Wb)
        Ma = sym(Wa.T @ GaW) + lam_a * sym(Wa.T @ Wa)
        Mb = sym(Wb.T @ GbW) + lam_b * sym(Wb.T @ Wb)
        Xa = jnp.sqrt(n) * (Wa @ inv_sqrt_psd(Ma, eps=1e-12))
        Xb = jnp.sqrt(n) * (Wb @ inv_sqrt_psd(Mb, eps=1e-12))
        objs.append(float(jnp.trace(Xa.T @ Ra @ jnp.linalg.inv(
            sym(Wb.T @ Wb) + 1e-30 * eye)) ) if False else 0.0)

    # canonical rotation + objective from one final cross pass
    Ra, Rb = grams.cross(Xa, Xb)
    n = grams.n
    F = Xa.T @ Ra / n  # = Xaᵀ AᵀB Xb / n  (both sides already normalized)

    # wait: Ra = AᵀB·Xb ⇒ Xaᵀ·Ra = Xaᵀ AᵀB Xb  ✓
    U, S, Vt = jnp.linalg.svd(F)
    return HorstResult(Xa=Xa @ U, Xb=Xb @ Vt.T, rho=S,
                       objective_history=jnp.asarray([grams.passes], jnp.float32))
