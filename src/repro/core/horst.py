"""Horst iteration for CCA — the paper's baseline (§2, Table 2b).

Gauss-Seidel variant of the Horst/orthogonal power method for the
multivariate eigenvalue problem (Chu & Watterson 1993; Zhang & Chu
2011): alternate regularized least-squares solves with block
normalization in the covariance metric.  One Horst iteration costs two
data passes (one per view); the paper budget is 120 passes.

Also implements ``Horst+rcca`` — initializing from a RandomizedCCA
solution — which the paper shows cuts 120 passes to ~34.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .linalg import inv_sqrt_psd, sym


@dataclasses.dataclass(frozen=True)
class HorstConfig:
    k: int
    iters: int = 60  # each iteration = 2 data passes
    lam_a: float = 0.0
    lam_b: float = 0.0
    nu: Optional[float] = None
    solver: str = "chol"  # "chol" (exact, d³) | "cg" (approximate LS, paper fn.5)
    cg_iters: int = 10


class HorstResult(NamedTuple):
    Xa: jax.Array
    Xb: jax.Array
    rho: jax.Array
    objective_history: jax.Array  # (iters,) train objective per iteration


def _metric_normalize(W: jax.Array, M_mul, n: float) -> jax.Array:
    """X ← √n · W (Wᵀ M W)^{-1/2} so that Xᵀ M X = n I."""
    G = sym(W.T @ M_mul(W))
    return jnp.sqrt(n) * (W @ inv_sqrt_psd(G, eps=1e-12))


def _cg_solve(M_mul, RHS: jax.Array, iters: int) -> jax.Array:
    """Block conjugate gradient for M X = RHS (approximate LS, paper's
    footnote 5: solves need only be approximate for convergence)."""

    def body(carry, _):
        X, R, P, rs = carry
        MP = M_mul(P)
        alpha = rs / jnp.maximum(jnp.sum(P * MP, axis=0), 1e-30)
        X = X + P * alpha
        R = R - MP * alpha
        rs_new = jnp.sum(R * R, axis=0)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        P = R + P * beta
        return (X, R, P, rs_new), None

    X0 = jnp.zeros_like(RHS)
    R0 = RHS
    (X, _, _, _), _ = jax.lax.scan(
        body, (X0, R0, R0, jnp.sum(R0 * R0, axis=0)), None, length=iters
    )
    return X


def horst_cca(
    A: jax.Array,
    B: jax.Array,
    cfg: HorstConfig,
    key: Optional[jax.Array] = None,
    init_Xb: Optional[jax.Array] = None,
) -> HorstResult:
    """Dense Horst iteration.  ``init_Xb`` warm-starts (Horst+rcca).

    At test scale we precompute the Gram matrices once; on a cluster the
    same recurrence runs as data passes (each matmul against A/B is a
    streamed shard_map pass exactly like rcca's — see rcca_dist).
    """
    n, da = A.shape
    db = B.shape[1]
    if cfg.nu is not None:
        lam_a = cfg.nu * jnp.sum(A.astype(jnp.float32) ** 2) / da
        lam_b = cfg.nu * jnp.sum(B.astype(jnp.float32) ** 2) / db
    else:
        lam_a, lam_b = cfg.lam_a, cfg.lam_b

    Caa = sym(A.T @ A)
    Cbb = sym(B.T @ B)
    Cab = A.T @ B

    Ma = lambda X: Caa @ X + lam_a * X
    Mb = lambda X: Cbb @ X + lam_b * X

    if cfg.solver == "chol":
        La = jnp.linalg.cholesky(Caa + lam_a * jnp.eye(da, dtype=A.dtype))
        Lb = jnp.linalg.cholesky(Cbb + lam_b * jnp.eye(db, dtype=B.dtype))
        solve_a = lambda R: jax.scipy.linalg.cho_solve((La, True), R)
        solve_b = lambda R: jax.scipy.linalg.cho_solve((Lb, True), R)
    else:
        solve_a = lambda R: _cg_solve(Ma, R, cfg.cg_iters)
        solve_b = lambda R: _cg_solve(Mb, R, cfg.cg_iters)

    if init_Xb is None:
        assert key is not None, "need a PRNG key for random init"
        Xb = jax.random.normal(key, (db, cfg.k), A.dtype)  # paper fn.5: Gaussian init
    else:
        Xb = init_Xb
    Xb = _metric_normalize(Xb, Mb, n)

    def step(Xb, _):
        Wa = solve_a(Cab @ Xb)  # LS solve: argmin ‖A Xa − B Xb‖² + λ‖Xa‖²
        Xa = _metric_normalize(Wa, Ma, n)
        Wb = solve_b(Cab.T @ Xa)  # Gauss-Seidel: uses fresh Xa
        Xb = _metric_normalize(Wb, Mb, n)
        obj = jnp.trace(Xa.T @ Cab @ Xb) / n
        return Xb, (Xa, obj)

    Xb, (Xas, objs) = jax.lax.scan(step, Xb, None, length=cfg.iters)
    Xa = Xas[-1]

    # rotate into canonical (diagonal cross-cov) coordinates
    T = Xa.T @ Cab @ Xb / n
    U, S, Vt = jnp.linalg.svd(T)
    Xa = Xa @ U
    Xb = Xb @ Vt.T
    return HorstResult(Xa=Xa, Xb=Xb, rho=S, objective_history=objs)


# ---------------------------------------------------------------------------
# streaming / out-of-core Horst (the paper's actual large-scale regime)
# ---------------------------------------------------------------------------


class StreamingGrams:
    """Gram-vector products as streamed data passes, with an explicit
    pass counter — the currency of the paper's Table 2b.  Never
    materializes AᵀA (O(d·k) state only)."""

    def __init__(self, source_factory):
        self.source_factory = source_factory
        self.passes = 0
        self.n = None

    def gram_a(self, V):
        """One pass → AᵀA·V (the view-A CG matvec)."""
        self.passes += 1
        G = None
        for a, _ in self.source_factory():
            u = a.T @ (a @ V)
            G = u if G is None else G + u
        return G

    def gram_b(self, V):
        """One pass → BᵀB·V."""
        self.passes += 1
        G = None
        for _, b in self.source_factory():
            u = b.T @ (b @ V)
            G = u if G is None else G + u
        return G

    def norm_cross_a(self, Wa):
        """One pass → (AᵀA·Wa, BᵀA·Wa): everything the A-side metric
        normalization AND the follow-up B-side cross product need —
        both are linear in Wa, so one pass serves both."""
        self.passes += 1
        U = V = None
        n = 0
        for a, b in self.source_factory():
            p = a @ Wa
            u, v = a.T @ p, b.T @ p
            U = u if U is None else U + u
            V = v if V is None else V + v
            n += a.shape[0]
        self.n = n
        return U, V

    def norm_cross_b(self, Wb):
        """One pass → (BᵀB·Wb, AᵀB·Wb)."""
        self.passes += 1
        U = V = None
        n = 0
        for a, b in self.source_factory():
            p = b @ Wb
            u, v = b.T @ p, a.T @ p
            U = u if U is None else U + u
            V = v if V is None else V + v
            n += a.shape[0]
        self.n = n
        return U, V


def horst_cca_streaming(
    source_factory,
    da: int,
    db: int,
    cfg: HorstConfig,
    key: Optional[jax.Array] = None,
    init_Xb: Optional[jax.Array] = None,
    init_Xa: Optional[jax.Array] = None,
    lam_a: float = 0.0,
    lam_b: float = 0.0,
) -> HorstResult:
    """Horst iteration with every matrix product a streamed data pass
    (paper §2: the multiplication step runs directly in the X coordinate
    system; AᵀA is never materialized).  The regularized LS solves use a
    few CG iterations whose matvecs are data passes — the paper's
    footnote-5 regime (approximate solves still converge).

    The update order is Gauss-Seidel, matching :func:`horst_cca`: the
    B-side solve uses the FRESH Xa.  (A simultaneous/Jacobi update of
    both views is not monotone for the Horst iteration and stalls in a
    limit cycle well below the optimum.)  Passes are shared where the
    dependency structure allows: each view's metric normalization and
    the other view's next cross product are both linear in the solved W,
    so one combined pass (norm_cross_*) serves both.  CG solves warm-
    start from the previous iteration's W.

    Pass cost per Horst iteration: 2·(cg_iters + warm-start residual)
    CG matvecs + 2 combined normalize+cross passes.  The total is in
    ``objective_history[0]`` via the StreamingGrams counter; use
    ``init_Xb`` from RandomizedCCA for the Horst+rcca warm start and
    compare pass counts with Alg. 1's q+1 (Table 2b).
    """
    k = cfg.k
    if init_Xb is None:
        assert key is not None
        Xb = jax.random.normal(jax.random.split(key)[1], (db, k), jnp.float32)
    else:
        Xb = jnp.asarray(init_Xb, jnp.float32)
    grams = StreamingGrams(source_factory)

    def cg_view(gram_fn, lam, R, W0):
        """CG on (G + λ)W = R; W0=None starts from zero (saves the
        warm-start residual pass)."""
        if W0 is None:
            W, r = jnp.zeros_like(R), R
        else:
            W = W0
            r = R - (gram_fn(W0) + lam * W0)
        p, rs = r, jnp.sum(r * r, 0)
        for _ in range(cfg.cg_iters):
            Gp = gram_fn(p) + lam * p
            alpha = rs / jnp.maximum(jnp.sum(p * Gp, 0), 1e-30)
            W = W + p * alpha
            r = r - Gp * alpha
            rs2 = jnp.sum(r * r, 0)
            p = r + p * (rs2 / jnp.maximum(rs, 1e-30))
            rs = rs2
        return W

    # bootstrap: normalize the initial Xb in the B metric and produce the
    # first A-side RHS Ra = AᵀB·Xb — one combined pass
    Ub, Va = grams.norm_cross_b(Xb)
    n = grams.n
    Tb = inv_sqrt_psd(sym(Xb.T @ Ub) + lam_b * sym(Xb.T @ Xb), eps=1e-12)
    Xb = jnp.sqrt(n) * (Xb @ Tb)
    Ra = jnp.sqrt(n) * (Va @ Tb)

    Wa = jnp.asarray(init_Xa, jnp.float32) if init_Xa is not None else None
    Wb = None
    # iters=0 (warm-start evaluation only): the loop never assigns Xa
    Xa = Wa if Wa is not None else jax.random.normal(
        key if key is not None else jax.random.PRNGKey(0), (da, k), jnp.float32)
    for _ in range(cfg.iters):
        # view A: LS solve, then one pass for (normalization, B-side RHS)
        Wa = cg_view(grams.gram_a, lam_a, Ra, Wa)
        Ua, Vb = grams.norm_cross_a(Wa)
        Ta = inv_sqrt_psd(sym(Wa.T @ Ua) + lam_a * sym(Wa.T @ Wa), eps=1e-12)
        Xa = jnp.sqrt(n) * (Wa @ Ta)
        Rb = jnp.sqrt(n) * (Vb @ Ta)  # = BᵀA·Xa — Gauss-Seidel: fresh Xa
        # view B likewise; its combined pass yields the next Ra
        Wb = cg_view(grams.gram_b, lam_b, Rb, Wb)
        Ub, Va = grams.norm_cross_b(Wb)
        Tb = inv_sqrt_psd(sym(Wb.T @ Ub) + lam_b * sym(Wb.T @ Wb), eps=1e-12)
        Xb = jnp.sqrt(n) * (Wb @ Tb)
        Ra = jnp.sqrt(n) * (Va @ Tb)

    # canonical rotation + objective: Ra is already AᵀB·Xb for the final Xb
    F = Xa.T @ Ra / n
    U, S, Vt = jnp.linalg.svd(F)
    return HorstResult(Xa=Xa @ U, Xb=Xb @ Vt.T, rho=S,
                       objective_history=jnp.asarray([grams.passes], jnp.float32))
