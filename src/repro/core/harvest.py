"""Activation harvesting: the bridge between the model zoo and the
paper's technique.

RandomizedCCA consumes paired views (n × d matrices).  This module
turns any zoo model into a view provider: run the backbone over a token
stream and emit per-position hidden states as CCA rows.  This is the
modern form of the paper's own application (multilingual embedding
alignment on Europarl): align two LMs over paired text, Whisper audio
frames ↔ transcripts, Qwen2-VL patches ↔ captions, or two layers of
one model (SVCCA-style diagnostics).

For cluster-scale harvesting the stream is row-sharded exactly like the
CCA data pass, and states feed repro.core.rcca_dist without leaving the
device: harvest → pass accumulators is a single jit program per chunk.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def activation_views(model, params, batch: Dict[str, jax.Array],
                     *, normalize: bool = True) -> jax.Array:
    """Final-layer hidden states flattened to CCA rows (B·S, D).

    ``normalize`` applies per-row unit-variance scaling, the analogue of
    the paper's per-view scale-free regularization setup.
    """
    hidden, _ = model.forward_hidden(params, batch, remat=False)
    B, S, D = hidden.shape
    rows = hidden.reshape(B * S, D).astype(jnp.float32)
    if normalize:
        rows = rows / (jnp.std(rows, axis=1, keepdims=True) + 1e-6)
    return rows


def paired_activation_stream(model_a, params_a, model_b, params_b,
                             token_batches, *, batch_key_a="tokens",
                             batch_key_b="tokens"):
    """Iterator of (A_chunk, B_chunk) view pairs for the streaming CCA
    driver — one chunk per token batch, computed lazily (out-of-core)."""
    for batch in token_batches:
        yield (
            activation_views(model_a, params_a, {batch_key_a: batch[batch_key_a]}),
            activation_views(model_b, params_b, {batch_key_b: batch[batch_key_b]}),
        )


def layer_views(model, params, batch: Dict[str, jax.Array], layer_frac: float):
    """SVCCA-style: hidden states at a fractional depth.  Implemented by
    truncating the stacked layer params before the forward pass."""

    cfg = model.cfg
    if model.family != "attn":
        raise NotImplementedError("layer_views supports the attn family")
    L = cfg.n_layers
    keep = max(1, int(L * layer_frac))
    p2 = dict(params)
    p2["layers"] = jax.tree.map(lambda x: x[:keep], params["layers"])
    import dataclasses

    cfg2 = dataclasses.replace(cfg, n_layers=keep,
                               layer_pattern=cfg.pattern()[:keep])
    m2 = type(model)(cfg2)
    return activation_views(m2, p2, batch)
