"""Async chunk prefetching: overlap disk IO + H2D transfer with compute.

A data pass over a store is a producer/consumer pipeline: the producer
(daemon thread) reads the next chunk from the memory-mapped shards and
stages it onto the device (``jax.device_put``) while the consumer runs
the current chunk's fused Pallas update.  A bounded queue of depth
``depth`` gives double (or deeper) buffering; depth 2 is the classic
two-slot pipeline — one chunk in flight, one being consumed.

The prefetcher also meters the pipeline: producer read seconds, consumer
stall seconds (time the pass sat waiting on IO), rows and bytes moved —
the numbers ``benchmarks/io_bench.py`` turns into the prefetch-on/off
rows/s comparison and ``PassRunner`` surfaces as per-pass diagnostics.
An IO-bound pass shows ``stall_s`` ≈ wall − compute; a compute-bound
pass shows ``stall_s`` ≈ 0 (IO fully hidden).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional, Tuple

from repro import obs

_SENTINEL = object()


class ChunkPrefetcher:
    """Iterate ``chunks`` with a background read+transfer thread.

    ``device_put=True`` stages each chunk's arrays on the default jax
    device inside the producer thread (numpy mmap reads and the H2D
    copy both release the GIL, so they genuinely overlap compute).
    Exceptions in the producer propagate to the consumer at the point
    of the failing chunk; an error the consumer never reached (it
    closed the pipeline first) is re-raised by ``close()`` — a failed
    read is never silently discarded.  ``close()`` (or exhausting the
    iterator) shuts the thread down; the prefetcher is single-use.
    """

    def __init__(self, chunks: Iterable[Tuple], *, depth: int = 2,
                 device_put: bool = True,
                 transform: Optional[Callable] = None,
                 site: str = "prefetch"):
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.site = site
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._src = iter(chunks)
        self._device_put = device_put
        self._transform = transform
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None  # producer failure
        self._delivered = False  # error already raised in __next__
        self.read_s = 0.0  # producer: disk read + H2D staging
        self.stall_s = 0.0  # consumer: time blocked on the queue
        self.chunks = 0
        self.rows = 0
        self.bytes = 0
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _stage(self, item: Tuple) -> Tuple:
        if self._transform is not None:
            item = self._transform(item)
        if self._device_put:
            import jax

            item = tuple(jax.device_put(x) for x in item)
        return item

    def _put(self, item) -> None:
        """Bounded put, polling the stop flag so ``close()`` never
        deadlocks the producer against a full queue."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def _produce(self) -> None:
        try:
            while True:
                if self._stop.is_set():
                    return
                t0 = obs.monotonic()
                try:
                    item = next(self._src)  # disk read happens here
                except StopIteration:
                    break
                a, b = self._stage(item)
                self.read_s += obs.monotonic() - t0
                self.rows += int(a.shape[0])
                self.bytes += int(a.nbytes) + int(b.nbytes)
                self._put((a, b))
            self._put(_SENTINEL)
        except BaseException as e:  # surface in the consumer
            # record FIRST: if close() drains the queue before (or
            # while) the put lands, the error still reaches the caller
            # through close() instead of vanishing with the drain
            self._error = e
            self._put(e)

    def __iter__(self) -> Iterator[Tuple]:
        return self

    def __next__(self) -> Tuple:
        t0 = obs.monotonic()
        item = self._q.get()
        self.stall_s += obs.monotonic() - t0
        if item is _SENTINEL:
            raise StopIteration
        if isinstance(item, BaseException):
            self._delivered = True
            raise item
        self.chunks += 1
        return item

    def close(self) -> None:
        self._stop.set()
        # drain so a blocked producer can observe the stop flag (a
        # queued copy of the error may be discarded here — self._error
        # still holds it)
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)
        self._emit_io()
        if self._error is not None and not self._delivered:
            # the producer failed but the consumer never reached the
            # queued exception — re-raise rather than swallow the loss
            self._delivered = True
            raise self._error

    def _emit_io(self) -> None:
        if (self.chunks or self.read_s) and not getattr(self, "_counted", False):
            self._counted = True
            obs.counter("io", site=self.site, **self.stats())

    def stats(self) -> dict:
        return {
            "chunks": self.chunks,
            "rows": self.rows,
            "bytes": self.bytes,
            "read_s": round(self.read_s, 4),
            "io_stall_s": round(self.stall_s, 4),
        }


class SyncChunkMeter:
    """Prefetch-off baseline with the same metering surface as
    :class:`ChunkPrefetcher`: reads happen inline on the consumer
    thread, so ``io_stall_s`` IS the read time — nothing is hidden."""

    def __init__(self, chunks: Iterable[Tuple], *, device_put: bool = True,
                 site: str = "sync"):
        self._src = iter(chunks)
        self._device_put = device_put
        self.site = site
        self.read_s = 0.0
        self.chunks = 0
        self.rows = 0
        self.bytes = 0

    def __iter__(self) -> Iterator[Tuple]:
        return self

    def __next__(self) -> Tuple:
        t0 = obs.monotonic()
        a, b = next(self._src)
        if self._device_put:
            import jax

            a, b = jax.device_put(a), jax.device_put(b)
        self.read_s += obs.monotonic() - t0
        self.chunks += 1
        self.rows += int(a.shape[0])
        self.bytes += int(a.nbytes) + int(b.nbytes)
        return a, b

    def close(self) -> None:
        if (self.chunks or self.read_s) and not getattr(self, "_counted", False):
            self._counted = True
            obs.counter("io", site=self.site, **self.stats())

    def stats(self) -> dict:
        return {
            "chunks": self.chunks,
            "rows": self.rows,
            "bytes": self.bytes,
            "read_s": round(self.read_s, 4),
            "io_stall_s": round(self.read_s, 4),  # inline reads all stall
        }


def prefetched(chunks: Iterable[Tuple], *, depth: int = 2,
               device_put: bool = True,
               site: str = "prefetch") -> Iterable[Tuple]:
    """``depth == 0`` → synchronous metered reads (prefetch off);
    otherwise a :class:`ChunkPrefetcher`.  The uniform spelling lets
    callers thread a ``--prefetch N`` knob straight through.  ``site``
    labels the pipeline's ``io`` trace counter (emitted at close under
    ``RCCA_TRACE``)."""
    if depth == 0:
        return SyncChunkMeter(chunks, device_put=device_put, site=site)
    return ChunkPrefetcher(chunks, depth=depth, device_put=device_put,
                           site=site)
