"""Out-of-core paired-view store + async prefetching pass pipeline.

The paper's "suitable for large datasets stored out of core" claim as
a subsystem: a sharded mmap-.npy on-disk format with a JSON manifest
(:class:`ViewStoreWriter` / :class:`ViewStoreReader`), double-buffered
async prefetch overlapping shard reads + H2D transfer with the fused
Pallas updates (:class:`ChunkPrefetcher`), and a pass orchestrator with
a checkpointed resume cursor (:class:`PassRunner`).
"""

from .format import (
    ShardInfo,
    ViewStoreReader,
    ViewStoreWriter,
    extend_chunks,
    ingest_chunks,
    ingest_planted,
    shard_chunks,
    store_exists,
)
from .passes import PassRunner, choose_pipeline
from .prefetch import ChunkPrefetcher, prefetched
from .uri import FsspecFS, LocalFS, StoreFS, register_scheme

__all__ = [
    "ChunkPrefetcher",
    "FsspecFS",
    "LocalFS",
    "PassRunner",
    "ShardInfo",
    "StoreFS",
    "ViewStoreReader",
    "ViewStoreWriter",
    "choose_pipeline",
    "extend_chunks",
    "ingest_chunks",
    "ingest_planted",
    "prefetched",
    "register_scheme",
    "shard_chunks",
    "store_exists",
]
