"""URI-scheme dispatch for view-store IO.

The store manifest/shard format is path-string-keyed, so pointing
``ViewStoreReader`` at a distributed filesystem only needs the IO layer
swapped: a pluggable opener registry keyed by URL scheme.  Bare paths
and ``file://`` resolve to the local filesystem; a ``gs://`` / ``s3://``
/ ``hdfs://`` backend registers a :class:`StoreFS` implementation once
and every reader, worker and coordinator path works unchanged::

    from repro.store.uri import StoreFS, register_scheme

    class GcsFS(StoreFS):
        def open(self, path, mode="rb"): ...
        def exists(self, path): ...

    register_scheme("gs", GcsFS())
    reader = ViewStoreReader("gs://bucket/corpus")

Remote backends only need ``open``/``exists``: the base class reads
whole objects and decodes ``.npy`` in memory (a remote read is a
network transfer either way; mmap is a local-FS optimization).

When `fsspec <https://filesystem-spec.readthedocs.io>`_ is importable,
the common remote schemes (``gs``, ``s3``, ``memory``, ...) are
auto-registered through :class:`FsspecFS` — a lazy adapter that only
instantiates the backend filesystem (and thus imports its SDK: gcsfs,
s3fs, ...) on first IO, so a missing SDK fails at first use with the
backend's own install hint rather than at import time.  Without fsspec
nothing changes: unregistered schemes keep raising the explicit
``register_scheme`` hint.
"""

from __future__ import annotations

import io
import os
import posixpath
from typing import BinaryIO, Dict, Tuple
from urllib.parse import urlsplit

import numpy as np


class StoreFS:
    """Minimal filesystem surface a view-store reader needs."""

    #: Whether :meth:`load_array` can honor ``mmap_mode`` (local files).
    #: A remote backend materializes arrays in memory regardless, so
    #: the reader must evict its shard cache instead of holding every
    #: shard it ever touched.
    supports_mmap = False

    def open(self, path: str, mode: str = "rb") -> BinaryIO:
        raise NotImplementedError

    def exists(self, path: str) -> bool:
        raise NotImplementedError

    def join(self, base: str, *parts: str) -> str:
        """URI path join (POSIX semantics keep the scheme prefix intact)."""
        return posixpath.join(base, *parts)

    def load_array(self, path: str, *, mmap_mode=None) -> np.ndarray:
        """Default for remote schemes: fetch the object and decode in
        memory (``mmap_mode`` is a local-FS optimization and ignored)."""
        with self.open(path) as f:
            return np.load(io.BytesIO(f.read()))


class LocalFS(StoreFS):
    """Bare paths and ``file://`` — the default backend."""

    supports_mmap = True

    def open(self, path: str, mode: str = "rb") -> BinaryIO:
        return open(path, mode)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def join(self, base: str, *parts: str) -> str:
        return os.path.join(base, *parts)

    def load_array(self, path: str, *, mmap_mode=None) -> np.ndarray:
        return np.load(path, mmap_mode=mmap_mode)


class FsspecFS(StoreFS):
    """``fsspec``-backed opener for remote object stores.

    Lazy on purpose: the adapter is registered for a scheme without
    touching fsspec's backend registry, and ``fsspec.filesystem`` (which
    imports the scheme's SDK — gcsfs for ``gs``, s3fs for ``s3``) runs
    only on first IO.  ``storage_options`` are forwarded verbatim
    (credentials, endpoints, anonymous access, ...).
    """

    supports_mmap = False

    def __init__(self, scheme: str, **storage_options):
        self.scheme = scheme.lower()
        self._options = dict(storage_options)
        self._fs = None

    @property
    def fs(self):
        if self._fs is None:
            try:
                import fsspec
            except ImportError as e:  # registered eagerly by a caller
                raise ImportError(
                    f"scheme {self.scheme!r} is backed by fsspec, which is "
                    "not installed") from e
            self._fs = fsspec.filesystem(self.scheme, **self._options)
        return self._fs

    def open(self, path: str, mode: str = "rb") -> BinaryIO:
        return self.fs.open(path, mode)

    def exists(self, path: str) -> bool:
        return self.fs.exists(path)


#: Remote schemes resolved through fsspec when it is importable —
#: the DFS backends the store was designed for plus fsspec's in-memory
#: filesystem (the test double).  Lazy: a scheme's SDK is imported on
#: first IO, so listing it here costs nothing when it's absent.
FSSPEC_SCHEMES = ("gs", "gcs", "s3", "s3a", "az", "abfs", "hdfs", "memory")


def _fsspec_available() -> bool:
    import importlib.util

    return importlib.util.find_spec("fsspec") is not None


_LOCAL = LocalFS()
_REGISTRY: Dict[str, StoreFS] = {}


def register_scheme(scheme: str, fs: StoreFS) -> None:
    """Make ``scheme://...`` store paths resolve through ``fs``
    (overrides any fsspec auto-registration for that scheme)."""
    _REGISTRY[scheme.lower()] = fs


def registered_schemes() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_store_path(path: str) -> Tuple[StoreFS, str]:
    """Split a store path into (filesystem, backend-native path).

    Bare paths, ``file://`` URIs and one-letter "schemes" (Windows
    drives) map to :class:`LocalFS`.  Explicitly registered schemes win;
    otherwise the common remote schemes fall through to a lazily
    constructed :class:`FsspecFS` when fsspec is installed.  Anything
    else must be :func:`register_scheme`-d.
    """
    parts = urlsplit(path)
    scheme = parts.scheme.lower()
    if scheme in ("", "file") or len(scheme) == 1:
        return _LOCAL, parts.path if scheme == "file" else path
    fs = _REGISTRY.get(scheme)
    if fs is None and scheme in FSSPEC_SCHEMES and _fsspec_available():
        fs = _REGISTRY[scheme] = FsspecFS(scheme)
    if fs is None:
        hint = (f" (fsspec would resolve it — pip install fsspec)"
                if scheme in FSSPEC_SCHEMES else "")
        raise KeyError(
            f"no opener registered for scheme {scheme!r} (store path "
            f"{path!r}); call repro.store.uri.register_scheme({scheme!r}, fs) "
            f"with a StoreFS implementation{hint}. Registered: "
            f"{registered_schemes() or '(none)'}")
    return fs, path
