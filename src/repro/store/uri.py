"""URI-scheme dispatch for view-store IO.

The store manifest/shard format is path-string-keyed, so pointing
``ViewStoreReader`` at a distributed filesystem only needs the IO layer
swapped: a pluggable opener registry keyed by URL scheme.  Bare paths
and ``file://`` resolve to the local filesystem; a ``gs://`` / ``s3://``
/ ``hdfs://`` backend registers a :class:`StoreFS` implementation once
and every reader, worker and coordinator path works unchanged::

    from repro.store.uri import StoreFS, register_scheme

    class GcsFS(StoreFS):
        def open(self, path, mode="rb"): ...
        def exists(self, path): ...

    register_scheme("gs", GcsFS())
    reader = ViewStoreReader("gs://bucket/corpus")

Remote backends only need ``open``/``exists``: the base class reads
whole objects and decodes ``.npy`` in memory (a remote read is a
network transfer either way; mmap is a local-FS optimization).
"""

from __future__ import annotations

import io
import os
import posixpath
from typing import BinaryIO, Dict, Tuple
from urllib.parse import urlsplit

import numpy as np


class StoreFS:
    """Minimal filesystem surface a view-store reader needs."""

    #: Whether :meth:`load_array` can honor ``mmap_mode`` (local files).
    #: A remote backend materializes arrays in memory regardless, so
    #: the reader must evict its shard cache instead of holding every
    #: shard it ever touched.
    supports_mmap = False

    def open(self, path: str, mode: str = "rb") -> BinaryIO:
        raise NotImplementedError

    def exists(self, path: str) -> bool:
        raise NotImplementedError

    def join(self, base: str, *parts: str) -> str:
        """URI path join (POSIX semantics keep the scheme prefix intact)."""
        return posixpath.join(base, *parts)

    def load_array(self, path: str, *, mmap_mode=None) -> np.ndarray:
        """Default for remote schemes: fetch the object and decode in
        memory (``mmap_mode`` is a local-FS optimization and ignored)."""
        with self.open(path) as f:
            return np.load(io.BytesIO(f.read()))


class LocalFS(StoreFS):
    """Bare paths and ``file://`` — the default backend."""

    supports_mmap = True

    def open(self, path: str, mode: str = "rb") -> BinaryIO:
        return open(path, mode)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def join(self, base: str, *parts: str) -> str:
        return os.path.join(base, *parts)

    def load_array(self, path: str, *, mmap_mode=None) -> np.ndarray:
        return np.load(path, mmap_mode=mmap_mode)


_LOCAL = LocalFS()
_REGISTRY: Dict[str, StoreFS] = {}


def register_scheme(scheme: str, fs: StoreFS) -> None:
    """Make ``scheme://...`` store paths resolve through ``fs``."""
    _REGISTRY[scheme.lower()] = fs


def registered_schemes() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_store_path(path: str) -> Tuple[StoreFS, str]:
    """Split a store path into (filesystem, backend-native path).

    Bare paths, ``file://`` URIs and one-letter "schemes" (Windows
    drives) map to :class:`LocalFS`; anything else must have been
    :func:`register_scheme`-d.
    """
    parts = urlsplit(path)
    scheme = parts.scheme.lower()
    if scheme in ("", "file") or len(scheme) == 1:
        return _LOCAL, parts.path if scheme == "file" else path
    fs = _REGISTRY.get(scheme)
    if fs is None:
        raise KeyError(
            f"no opener registered for scheme {scheme!r} (store path "
            f"{path!r}); call repro.store.uri.register_scheme({scheme!r}, fs) "
            f"with a StoreFS implementation. Registered: "
            f"{registered_schemes() or '(none)'}")
    return fs, path
