"""Pass orchestration: drive the CCA data passes from an on-disk store.

``PassRunner`` is the glue between three layers — the store
(:mod:`repro.store.format`), the topology-aware pass engine
(:mod:`repro.exec`, which owns the canonical chunk → merge-group →
tree fold) and fault tolerance (:mod:`repro.ckpt`):

- every pass streams ``ViewStoreReader.iter_chunks`` through a
  double-buffered :class:`~repro.store.prefetch.ChunkPrefetcher`, so
  the next chunk's shard read + ``jax.device_put`` overlap the current
  chunk's fused Pallas update;
- a persistent PASS CURSOR — the pass accumulator state (current
  merge-group fold + pairwise-tree stack, see
  ``rcca.SegmentedAccumulator``) plus ``Qa``/``Qb`` and
  ``{pass_idx, next_chunk}`` metadata — is checkpointed through
  ``repro.ckpt.CheckpointManager`` every ``ckpt_every`` chunks.  A
  killed pass resumes from the manifest + latest cursor alone
  (``fit(..., resume=True)``), seeking the store to ``next_chunk``
  without re-reading the folded prefix, and reproduces the
  uninterrupted result BIT-IDENTICALLY (same update sequence on the
  same f32 accumulators — exercised by tests/test_store_resume.py);
- per-pass diagnostics (rows/s, producer read seconds, consumer IO
  stall seconds) land in ``RCCAResult.diagnostics["io"]`` — the same
  numbers the IO-overlap benchmark reports, and under ``RCCA_TRACE``
  the same pipeline emits ``io`` counters into the unified
  :mod:`repro.obs` trace (one clock domain — see rule RCCA007).

``prefetch="auto"`` / ``sync_chunks="auto"`` pick the pipeline depth
and the in-flight bound from a short calibration window instead of
fixed defaults: the first few chunks are read synchronously, the
per-chunk read and (blocked) update times are measured, and
:func:`choose_pipeline` maps the read/compute ratio to the knobs — the
same ratio ``result.diagnostics["io"]`` reports after every fit.

The cursor embeds the store fingerprint, the engine and the merge-group
size, so resuming against swapped data, a different engine or a
different canonical merge structure fails loudly instead of silently
mixing accumulator histories.
"""

from __future__ import annotations

import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro import obs
from repro.ckpt import CheckpointManager
from repro.core.rcca import (
    DEFAULT_ENGINE,
    RCCAConfig,
    RCCAResult,
    algo_meta,
    resolve_engine,
    resolve_omega,
    stats_init_fn,
)
from repro.exec import MERGE_GROUP_CHUNKS, PassEngine, SegmentedAccumulator

from .format import ViewStoreReader
from .prefetch import prefetched

#: Cursor layout version — bumped when the checkpointed pass state
#: changes shape (v2: segmented accumulator state instead of one flat
#: stats fold).  A cursor from another layout fails loudly.
CURSOR_FMT = 2


def choose_pipeline(read_chunk_s: float, compute_chunk_s: float):
    """Map a measured per-chunk (read, compute) pair to
    ``(prefetch depth, sync_chunks)``.

    - read ≪ compute (page-cache reads on a small host): a prefetch
      thread is pure overhead — run synchronously, allow a few chunks
      of async dispatch queueing.
    - otherwise: depth ≈ read/compute + 1 keeps the producer far
      enough ahead to hide the reads (classic double buffering at
      ratio ≈ 1), capped at 8 so a badly IO-bound pass can't pin
      unbounded chunk buffers; once IO dominates, a strict
      ``sync_chunks=1`` pipeline costs nothing (compute is not the
      bottleneck) and bounds live chunks exactly.
    """
    ratio = read_chunk_s / max(compute_chunk_s, 1e-9)
    if ratio < 0.05:
        return 0, 4
    depth = min(8, max(2, math.ceil(ratio) + 1))
    sync = 1 if ratio >= 0.5 else 4
    return depth, sync


class _CalibratingSource:
    """Chunk source that reads its first ``runner.calib_chunks`` chunks
    synchronously (timing each) and then swaps in the prefetcher that
    the calibration chose.  Presents the same ``stats()``/``close()``
    surface as :class:`ChunkPrefetcher`."""

    def __init__(self, runner: "PassRunner", start: int):
        self._r = runner
        self._start = start
        self._consumed = 0
        self._inner = None
        self.read_s = 0.0
        self.chunks = 0
        self.rows = 0
        self.bytes = 0

    def __iter__(self) -> "_CalibratingSource":
        return self

    def __next__(self):
        if self._inner is not None:
            return next(self._inner)
        r = self._r
        if r._auto_done or self._consumed >= r.calib_chunks:
            r._finish_calibration()
            self._inner = prefetched(
                r.reader.iter_chunks(self._start + self._consumed),
                depth=r.prefetch)
            return next(self._inner)
        idx = self._start + self._consumed
        if idx >= r.reader.n_chunks:
            raise StopIteration
        t0 = obs.monotonic()
        a, b = r.reader.get_chunk(idx)
        a, b = jax.device_put(a), jax.device_put(b)
        dt = obs.monotonic() - t0
        r._calib_reads.append(dt)
        self.read_s += dt
        self._consumed += 1
        self.chunks += 1
        self.rows += int(a.shape[0])
        self.bytes += int(a.nbytes) + int(b.nbytes)
        return a, b

    def stats(self) -> dict:
        own = {"chunks": self.chunks, "rows": self.rows, "bytes": self.bytes,
               "read_s": round(self.read_s, 4),
               # calibration reads are inline — all of them stall
               "io_stall_s": round(self.read_s, 4)}
        if self._inner is not None:
            for k, v in self._inner.stats().items():
                own[k] = own.get(k, 0) + v
        return own

    def close(self) -> None:
        if self.chunks:
            # the calibration window's inline reads (the swapped-in
            # prefetcher emits its own "io" counter on close)
            obs.counter("io", site="calibration", chunks=self.chunks,
                        rows=self.rows, bytes=self.bytes,
                        read_s=round(self.read_s, 4),
                        io_stall_s=round(self.read_s, 4))
        if self._inner is not None:
            self._inner.close()


class PassRunner:
    """Run Algorithm 1's q+1 data passes over a view store.

    Parameters
    ----------
    reader:      an open :class:`ViewStoreReader` (or a path to one —
                 bare, ``file://`` or any registered URI scheme).
    cfg:         the :class:`RCCAConfig` hyper-parameters.
    engine:      per-chunk update implementation ("kernels" | "jnp").
    prefetch:    pipeline depth; 0 disables prefetching (synchronous
                 reads — the benchmark baseline), 2 = double buffering,
                 "auto" calibrates on the first chunks of the fit.
    ckpt_dir:    where pass cursors go; ``None`` disables checkpointing.
    ckpt_every:  cursor save period, in chunks.
    sync_chunks: bound on in-flight chunk updates.  jax dispatch is
                 async: without a bound, a pass would enqueue every
                 chunk's update — and pin every chunk's host/device
                 buffers — before any completes, which is exactly the
                 unbounded residency out-of-core must avoid.  Every
                 ``sync_chunks`` chunks the runner blocks on the
                 accumulators, capping live chunks at
                 ``sync_chunks + prefetch``.  1 = strict per-chunk
                 pipeline; 0 disables the bound (small corpora only);
                 "auto" calibrates alongside ``prefetch``.
    merge_group: chunks per canonical merge group (see
                 ``rcca.MERGE_GROUP_CHUNKS``) — a ``repro.cluster``
                 coordinator with the same value is bit-identical.
    omega:       Ω provenance (``rcca.OMEGA_MODES``).  ``"seeded"``
                 runs pass 0 from an 8-byte seed: under the kernels
                 engine the Qa/Qb cursor slots hold the seed and the
                 ``(d, k̃)`` sketch is generated tile-by-tile in-kernel.
    """

    def __init__(self, reader, cfg: RCCAConfig, *, engine: str = DEFAULT_ENGINE,
                 prefetch: Union[int, str] = 2, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 8, keep: int = 2,
                 sync_chunks: Union[int, str] = 4,
                 merge_group: int = MERGE_GROUP_CHUNKS,
                 omega: str = "materialized",
                 calib_chunks: int = 4):
        self.reader = reader if isinstance(reader, ViewStoreReader) else ViewStoreReader(reader)
        self.cfg = cfg
        self.engine = resolve_engine(engine)
        self.omega = resolve_omega(omega)
        # each knob calibrates independently: an explicit value for the
        # other one is never clobbered (prefetch=0 stays the documented
        # synchronous baseline even under sync_chunks="auto")
        self._auto_prefetch = prefetch == "auto"
        self._auto_sync = sync_chunks == "auto"
        self.auto_tune = self._auto_prefetch or self._auto_sync
        self.prefetch = 2 if self._auto_prefetch else int(prefetch)
        self.sync_chunks = 4 if self._auto_sync else int(sync_chunks)
        self.merge_group = int(merge_group)
        self.ckpt_every = int(ckpt_every)
        self.calib_chunks = int(calib_chunks)
        self.mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
        self._live = None
        self._io = {"chunks": 0, "rows": 0, "bytes": 0, "read_s": 0.0,
                    "io_stall_s": 0.0}
        self._auto_done = not self.auto_tune
        self._auto_choice: Optional[dict] = None
        self._calib_reads: list = []
        self._calib_computes: list = []
        self._calib_last_t: Optional[float] = None

    # -- chunk source (one instantiation per pass) ------------------------

    def _source(self, start: int):
        """Seekable factory handed to ``PassEngine.run_stream`` — the
        positional ``start`` makes resume seek instead of replay."""
        self._harvest_live()
        if not self._auto_done:
            self._live = _CalibratingSource(self, start)
        else:
            self._live = prefetched(self.reader.iter_chunks(start),
                                    depth=self.prefetch)
        self._calib_last_t = None  # pass boundary: no carry-over delta
        return self._live

    def _harvest_live(self) -> None:
        if self._live is not None:
            for k, v in self._live.stats().items():
                self._io[k] = self._io.get(k, 0) + v
            self._live.close()
            self._live = None

    # -- prefetch/sync auto-tuning ----------------------------------------

    def _finish_calibration(self) -> None:
        """Fix prefetch depth + sync_chunks from the calibration
        window.  The first compute sample is dropped (jit compile);
        with too few samples the configured defaults stand."""
        if self._auto_done:
            return
        self._auto_done = True
        # computes[j] is chunk j+1's blocked update (chunk 0 carries the
        # jit compile and is never sampled); reads align one ahead
        computes = self._calib_computes
        reads = self._calib_reads[1:1 + len(computes)]
        if reads and computes:
            read_s = sum(reads) / len(reads)
            compute_s = sum(computes) / len(computes)
            depth, sync = choose_pipeline(read_s, compute_s)
            if self._auto_prefetch:
                self.prefetch = depth
            if self._auto_sync:
                self.sync_chunks = sync
            self._auto_choice = {
                "prefetch": self.prefetch, "sync_chunks": self.sync_chunks,
                "read_chunk_s": round(read_s, 5),
                "compute_chunk_s": round(compute_s, 5),
            }
        else:
            self._auto_choice = {"prefetch": self.prefetch,
                                 "sync_chunks": self.sync_chunks,
                                 "read_chunk_s": None, "compute_chunk_s": None}

    # -- cursor persistence ----------------------------------------------

    def _algo_meta(self) -> dict:
        return algo_meta(self.cfg)

    def _save_cursor(self, pass_idx: int, chunk_idx: int, acc, Qa, Qb) -> None:
        step = pass_idx * 1_000_000 + chunk_idx
        self.mgr.save(
            step,
            {"acc": acc.state(), "Qa": Qa, "Qb": Qb},
            metadata={
                "cursor_fmt": CURSOR_FMT,
                "pass_idx": pass_idx,
                "next_chunk": chunk_idx + 1,  # acc already includes chunk_idx
                "engine": self.engine,
                "merge_group": self.merge_group,
                "omega": self.omega,
                "fingerprint": self.reader.fingerprint(),
                "algo": self._algo_meta(),
            },
        )

    def _acc_like(self, pass_idx: int, next_chunk: int) -> SegmentedAccumulator:
        r = self.reader
        kind = "final" if pass_idx == self.cfg.q else "power"
        return SegmentedAccumulator.structure(
            stats_init_fn(kind, r.da, r.db, self.cfg.sketch),
            r.n_chunks, self.merge_group, next_chunk)

    def restore_cursor(self) -> Optional[dict]:
        """Latest pass cursor as ``randomized_cca_iterator`` resume
        state, validated against this store/config/engine."""
        if self.mgr is None:
            return None
        # two-phase: read metadata first (it decides the accumulator
        # pytree structure), then restore against the right like-tree
        step = self.mgr.latest_step()
        meta = self.mgr.metadata(step)
        if meta is None:
            return None
        if meta.get("cursor_fmt") != CURSOR_FMT:
            raise ValueError(
                f"pass cursor layout {meta.get('cursor_fmt')} != "
                f"{CURSOR_FMT} (written by another repro version) — "
                "start fresh or use the matching code")
        if meta["fingerprint"] != self.reader.fingerprint():
            raise ValueError(
                "pass cursor was written against a different store "
                f"(fingerprint {meta['fingerprint'][:12]}… != "
                f"{self.reader.fingerprint()[:12]}…)")
        if meta["engine"] != self.engine:
            raise ValueError(
                f"pass cursor engine {meta['engine']!r} != runner engine "
                f"{self.engine!r} — bit-identical resume holds per engine")
        if meta["algo"] != self._algo_meta():
            raise ValueError(
                f"pass cursor hyper-parameters {meta['algo']} != runner "
                f"config {self._algo_meta()}")
        if meta["merge_group"] != self.merge_group:
            raise ValueError(
                f"pass cursor merge_group {meta['merge_group']} != runner "
                f"merge_group {self.merge_group} — the canonical merge "
                "structure is part of the accumulator state")
        if meta.get("omega", "materialized") != self.omega:
            raise ValueError(
                f"pass cursor omega {meta.get('omega', 'materialized')!r} != "
                f"runner omega {self.omega!r} — Ω provenance is part of the "
                "pass state (pass-0 cursors may hold seeds, not bases)")
        pass_idx, next_chunk = int(meta["pass_idx"]), int(meta["next_chunk"])
        like = self._acc_like(pass_idx, next_chunk)
        z = jnp.zeros
        r, kt = self.reader, self.cfg.sketch
        if self.omega == "seeded" and self.engine == "kernels" and pass_idx == 0:
            # seeded pass 0: the Qa/Qb cursor slots hold the (2,)-uint32
            # Ω seeds, not the (d, k̃) bases (see PassEngine.seeds_in_slots)
            q_like = {"Qa": z((2,), jnp.uint32), "Qb": z((2,), jnp.uint32)}
        else:
            q_like = {"Qa": z((r.da, kt), self.cfg.dtype),
                      "Qb": z((r.db, kt), self.cfg.dtype)}
        tree, _ = self.mgr.restore({"acc": like.state(), **q_like}, step=step)
        return {
            "pass_idx": pass_idx,
            "chunk_idx": next_chunk,
            "acc": tree["acc"],
            "Qa": tree["Qa"],
            "Qb": tree["Qb"],
        }

    # -- driving ----------------------------------------------------------

    def fit(self, key: jax.Array, *, resume: bool = False,
            on_chunk=None) -> RCCAResult:
        """All q+1 passes → :class:`RCCAResult`.

        ``resume=True`` continues from the latest cursor in ``ckpt_dir``
        (no-op if none exists).  ``on_chunk(pass_idx, chunk_idx, acc,
        Qa, Qb)`` is an optional extra per-chunk callback — it runs
        BEFORE the periodic cursor save, so a test/driver can inject a
        kill and the last published cursor stays consistent.
        """
        resume_state = self.restore_cursor() if resume else None
        r = self.reader
        # per-fit diagnostics: a reused runner must not carry the
        # previous fit's byte/row counts into this fit's rows/s
        self._io = {k: 0.0 if isinstance(v, float) else 0
                    for k, v in self._io.items()}
        counters = {"chunks": 0}
        t0 = obs.monotonic()

        def cb(pass_idx, chunk_idx, acc, Qa, Qb):
            counters["chunks"] += 1
            if not self._auto_done:
                # calibration: block every chunk; compute time is the
                # gap since the previous blocked chunk minus its read
                jax.block_until_ready(acc.state())
                now = obs.monotonic()
                if self._calib_last_t is not None and \
                        len(self._calib_reads) > len(self._calib_computes) + 1:
                    read = self._calib_reads[len(self._calib_computes) + 1]
                    self._calib_computes.append(
                        max(0.0, now - self._calib_last_t - read))
                self._calib_last_t = now
            elif self.sync_chunks and counters["chunks"] % self.sync_chunks == 0:
                jax.block_until_ready(acc.state())  # bound in-flight residency
            if on_chunk is not None:
                on_chunk(pass_idx, chunk_idx, acc, Qa, Qb)
            if self.mgr is not None and (chunk_idx + 1) % self.ckpt_every == 0:
                self._save_cursor(pass_idx, chunk_idx, acc, Qa, Qb)

        eng = PassEngine(self.cfg, engine=self.engine,
                         merge_group=self.merge_group, omega=self.omega)
        try:
            res = eng.run_stream(
                self._source, r.da, r.db, key,
                resume_state=resume_state, on_pass_end=cb,
                n_chunks=r.n_chunks,
            )
        finally:
            self._harvest_live()
        wall = obs.monotonic() - t0

        rows = self._io["rows"]
        res.diagnostics["io"] = {
            **{k: round(v, 4) if isinstance(v, float) else v
               for k, v in self._io.items()},
            "prefetch_depth": self.prefetch,
            "sync_chunks": self.sync_chunks,
            "wall_s": round(wall, 4),
            "rows_per_s": round(rows / wall, 2) if wall > 0 else float("inf"),
            "resumed": resume_state is not None,
        }
        if self._auto_choice is not None:
            res.diagnostics["io"]["auto"] = self._auto_choice
        return res

    def fit_dist(self, key: jax.Array, mesh, **dist_kwargs) -> RCCAResult:
        """Resident-mode escape hatch: materialize the store (it must
        fit in device memory) and run the shard_map driver on it."""
        from repro.core.rcca_dist import dist_randomized_cca

        A, B = self.reader.materialize()
        return dist_randomized_cca(
            jnp.asarray(A), jnp.asarray(B), self.cfg, key, mesh,
            engine=self.engine, **dist_kwargs)
