"""Pass orchestration: drive the CCA data passes from an on-disk store.

``PassRunner`` is the glue between the three existing layers — the
store (:mod:`repro.store.format`), the algorithm's pass drivers
(:mod:`repro.core.rcca` / :mod:`repro.core.rcca_dist`) and fault
tolerance (:mod:`repro.ckpt`):

- every pass streams ``ViewStoreReader.iter_chunks`` through a
  double-buffered :class:`~repro.store.prefetch.ChunkPrefetcher`, so
  the next chunk's shard read + ``jax.device_put`` overlap the current
  chunk's fused Pallas update;
- a persistent PASS CURSOR — ``{stats, Qa, Qb}`` plus
  ``{pass_idx, next_chunk}`` metadata — is checkpointed through
  ``repro.ckpt.CheckpointManager`` every ``ckpt_every`` chunks.  A
  killed pass resumes from the manifest + latest cursor alone
  (``fit(..., resume=True)``), seeking the store to ``next_chunk``
  without re-reading the folded prefix, and reproduces the
  uninterrupted result BIT-IDENTICALLY (same update sequence on the
  same f32 accumulators — exercised by tests/test_store_resume.py);
- per-pass diagnostics (rows/s, producer read seconds, consumer IO
  stall seconds) land in ``RCCAResult.diagnostics["io"]`` — the same
  numbers the IO-overlap benchmark reports.

The cursor embeds the store fingerprint and the engine, so resuming
against swapped data or a different engine fails loudly instead of
silently mixing accumulator histories.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.core.rcca import (
    DEFAULT_ENGINE,
    RCCAConfig,
    RCCAResult,
    init_final_stats,
    init_power_stats,
    randomized_cca_iterator,
    resolve_engine,
)

from .format import ViewStoreReader
from .prefetch import ChunkPrefetcher, prefetched


class PassRunner:
    """Run Algorithm 1's q+1 data passes over a view store.

    Parameters
    ----------
    reader:      an open :class:`ViewStoreReader` (or a path to one).
    cfg:         the :class:`RCCAConfig` hyper-parameters.
    engine:      per-chunk update implementation ("kernels" | "jnp").
    prefetch:    pipeline depth; 0 disables prefetching (synchronous
                 reads — the benchmark baseline), 2 = double buffering.
    ckpt_dir:    where pass cursors go; ``None`` disables checkpointing.
    ckpt_every:  cursor save period, in chunks.
    sync_chunks: bound on in-flight chunk updates.  jax dispatch is
                 async: without a bound, a pass would enqueue every
                 chunk's update — and pin every chunk's host/device
                 buffers — before any completes, which is exactly the
                 unbounded residency out-of-core must avoid.  Every
                 ``sync_chunks`` chunks the runner blocks on the
                 accumulators, capping live chunks at
                 ``sync_chunks + prefetch``.  1 = strict per-chunk
                 pipeline; 0 disables the bound (small corpora only).
    """

    def __init__(self, reader, cfg: RCCAConfig, *, engine: str = DEFAULT_ENGINE,
                 prefetch: int = 2, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 8, keep: int = 2, sync_chunks: int = 4):
        self.reader = reader if isinstance(reader, ViewStoreReader) else ViewStoreReader(reader)
        self.cfg = cfg
        self.engine = resolve_engine(engine)
        self.prefetch = int(prefetch)
        self.sync_chunks = int(sync_chunks)
        self.ckpt_every = int(ckpt_every)
        self.mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
        self._live: Optional[ChunkPrefetcher] = None
        self._io = {"chunks": 0, "rows": 0, "bytes": 0, "read_s": 0.0,
                    "io_stall_s": 0.0}

    # -- chunk source (one instantiation per pass) ------------------------

    def _source(self, start: int):
        """Seekable factory handed to ``randomized_cca_iterator`` — the
        positional ``start`` makes resume seek instead of replay."""
        self._harvest_live()
        self._live = prefetched(self.reader.iter_chunks(start), depth=self.prefetch)
        return self._live

    def _harvest_live(self) -> None:
        if self._live is not None:
            for k, v in self._live.stats().items():
                self._io[k] = self._io.get(k, 0) + v
            self._live.close()
            self._live = None

    # -- cursor persistence ----------------------------------------------

    def _algo_meta(self) -> dict:
        c = self.cfg
        return {"k": c.k, "p": c.p, "q": c.q, "center": c.center,
                "nu": c.nu, "lam_a": c.lam_a, "lam_b": c.lam_b,
                "dtype": str(jnp.dtype(c.dtype))}

    def _save_cursor(self, pass_idx: int, chunk_idx: int, stats, Qa, Qb) -> None:
        step = pass_idx * 1_000_000 + chunk_idx
        self.mgr.save(
            step,
            {"stats": stats, "Qa": Qa, "Qb": Qb},
            metadata={
                "pass_idx": pass_idx,
                "next_chunk": chunk_idx + 1,  # stats already include chunk_idx
                "engine": self.engine,
                "fingerprint": self.reader.fingerprint(),
                "algo": self._algo_meta(),
            },
        )

    def _cursor_like(self, pass_idx: int) -> dict:
        r, kt = self.reader, self.cfg.sketch
        stats = (
            init_final_stats(kt, r.da, r.db, jnp.float32)
            if pass_idx == self.cfg.q
            else init_power_stats(r.da, r.db, kt, jnp.float32)
        )
        z = jnp.zeros
        return {"stats": stats, "Qa": z((r.da, kt), self.cfg.dtype),
                "Qb": z((r.db, kt), self.cfg.dtype)}

    def restore_cursor(self) -> Optional[dict]:
        """Latest pass cursor as ``randomized_cca_iterator`` resume
        state, validated against this store/config/engine."""
        if self.mgr is None:
            return None
        # two-phase: read metadata first (it decides the stats pytree
        # structure), then restore against the right like-tree
        step = self.mgr.latest_step()
        meta = self.mgr.metadata(step)
        if meta is None:
            return None
        if meta["fingerprint"] != self.reader.fingerprint():
            raise ValueError(
                "pass cursor was written against a different store "
                f"(fingerprint {meta['fingerprint'][:12]}… != "
                f"{self.reader.fingerprint()[:12]}…)")
        if meta["engine"] != self.engine:
            raise ValueError(
                f"pass cursor engine {meta['engine']!r} != runner engine "
                f"{self.engine!r} — bit-identical resume holds per engine")
        if meta["algo"] != self._algo_meta():
            raise ValueError(
                f"pass cursor hyper-parameters {meta['algo']} != runner "
                f"config {self._algo_meta()}")
        tree, _ = self.mgr.restore(self._cursor_like(int(meta["pass_idx"])),
                                   step=step)
        return {
            "pass_idx": int(meta["pass_idx"]),
            "chunk_idx": int(meta["next_chunk"]),
            "stats": tree["stats"],
            "Qa": tree["Qa"],
            "Qb": tree["Qb"],
        }

    # -- driving ----------------------------------------------------------

    def fit(self, key: jax.Array, *, resume: bool = False,
            on_chunk=None) -> RCCAResult:
        """All q+1 passes → :class:`RCCAResult`.

        ``resume=True`` continues from the latest cursor in ``ckpt_dir``
        (no-op if none exists).  ``on_chunk(pass_idx, chunk_idx, stats,
        Qa, Qb)`` is an optional extra per-chunk callback — it runs
        BEFORE the periodic cursor save, so a test/driver can inject a
        kill and the last published cursor stays consistent.
        """
        resume_state = self.restore_cursor() if resume else None
        r = self.reader
        # per-fit diagnostics: a reused runner must not carry the
        # previous fit's byte/row counts into this fit's rows/s
        self._io = {k: 0.0 if isinstance(v, float) else 0
                    for k, v in self._io.items()}
        counters = {"chunks": 0, "rows": 0}
        t0 = time.perf_counter()

        def cb(pass_idx, chunk_idx, stats, Qa, Qb):
            counters["chunks"] += 1
            if self.sync_chunks and counters["chunks"] % self.sync_chunks == 0:
                jax.block_until_ready(stats)  # bound in-flight residency
            if on_chunk is not None:
                on_chunk(pass_idx, chunk_idx, stats, Qa, Qb)
            if self.mgr is not None and (chunk_idx + 1) % self.ckpt_every == 0:
                self._save_cursor(pass_idx, chunk_idx, stats, Qa, Qb)

        try:
            res = randomized_cca_iterator(
                self._source, r.da, r.db, self.cfg, key,
                resume_state=resume_state, on_pass_end=cb, engine=self.engine,
            )
        finally:
            self._harvest_live()
        wall = time.perf_counter() - t0

        rows = self._io["rows"]
        res.diagnostics["io"] = {
            **{k: round(v, 4) if isinstance(v, float) else v
               for k, v in self._io.items()},
            "prefetch_depth": self.prefetch,
            "wall_s": round(wall, 4),
            "rows_per_s": round(rows / wall, 2) if wall > 0 else float("inf"),
            "resumed": resume_state is not None,
        }
        return res

    def fit_dist(self, key: jax.Array, mesh, **dist_kwargs) -> RCCAResult:
        """Resident-mode escape hatch: materialize the store (it must
        fit in device memory) and run the shard_map driver on it."""
        from repro.core.rcca_dist import dist_randomized_cca

        A, B = self.reader.materialize()
        return dist_randomized_cca(
            jnp.asarray(A), jnp.asarray(B), self.cfg, key, mesh,
            engine=self.engine, **dist_kwargs)
