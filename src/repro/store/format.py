"""Out-of-core paired-view storage: sharded .npy row files + manifest.

The paper's setting is corpora "stored either out of core or on a
distributed file system"; this module is that store.  Layout of a view
store directory::

    store/
      manifest.json           # n, da, db, dtype, chunk, shard list, hashes
      shard_00000.a.npy       # rows [0, rows_0) of view A
      shard_00000.b.npy       # rows [0, rows_0) of view B
      shard_00001.a.npy       # rows [rows_0, rows_0+rows_1) ...
      ...

Design points:

- shards are plain ``.npy`` so any numpy (or a remote worker with no
  repro install) can read them; the reader memory-maps, so a chunk read
  touches only that chunk's pages — corpora far larger than RAM stream
  at page-cache speed;
- the manifest is the single source of truth: logical chunking (the
  unit the data passes consume) is independent of physical sharding
  (the unit of IO/distribution), so ``chunk`` can be retuned without
  rewriting shards;
- every shard carries a sha256 content hash → end-to-end integrity
  (``ViewStoreReader.verify``) and a store fingerprint that pass
  checkpoints embed, so a resume against swapped-out data fails loudly;
- writes publish the manifest atomically (tmp + rename, same discipline
  as repro.ckpt) — a killed ingest never leaves a readable-but-wrong
  store;
- ``row_shard(shard, n_shards)`` gives distributed workers the same
  strided chunk assignment as ``PlantedCCAData.row_shard``; ``start=``
  seeks (worker resume) and ``group=`` stripes whole merge groups
  (``repro.cluster``'s partial unit);
- reads route through :mod:`repro.store.uri`: the reader accepts bare
  paths, ``file://`` and any registered scheme (``gs://``, ``s3://``,
  ...), so distributed-FS backends only plug an opener in.

Exotic dtypes (bf16/f8) are stored as same-width uint views with the
logical dtype recorded in the manifest — numpy round-trips them without
ml_dtypes awareness (the repro.ckpt trick).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Iterable, Iterator, Optional, Tuple

import numpy as np

STORE_VERSION = 1
MANIFEST = "manifest.json"

# numpy can't natively round-trip bf16/f8 — store a same-width uint view
# and record the logical dtype in the manifest (mirrors repro.ckpt).
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _storage_dtype(logical: str) -> np.dtype:
    return np.dtype(_EXOTIC.get(logical, logical))


def _as_logical(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _EXOTIC:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, logical)))
    return arr


def _sha256_fileobj(f, bufsize: int = 1 << 20) -> str:
    h = hashlib.sha256()
    while True:
        buf = f.read(bufsize)
        if not buf:
            break
        h.update(buf)
    return h.hexdigest()


def _sha256_file(path: str, bufsize: int = 1 << 20) -> str:
    with open(path, "rb") as f:
        return _sha256_fileobj(f, bufsize)


def store_exists(path: str) -> bool:
    """True if ``path`` (bare, ``file://`` or any registered scheme)
    holds a published view store (its manifest exists)."""
    from .uri import resolve_store_path

    fs, base = resolve_store_path(path)
    return fs.exists(fs.join(base, MANIFEST))


def shard_chunks(shard: int, n_shards: int, n_chunks: int, *,
                 start: int = 0, group: int = 1):
    """Deterministic chunk assignment of worker ``shard`` of
    ``n_shards``: chunks are striped in ``group``-sized runs (merge
    groups), so chunk ``c`` belongs to worker ``(c // group) %
    n_shards``, and the union over workers is an exact partition of the
    corpus.  ``start`` seeks (resume: chunks below it are skipped).
    ``group=1`` is the classic per-chunk striping."""
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} out of range [0, {n_shards})")
    if group <= 0:
        raise ValueError("group must be positive")
    for g in range(shard, -(-n_chunks // group), n_shards):
        for c in range(g * group, min(n_chunks, (g + 1) * group)):
            if c >= start:
                yield c


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    """One physical shard: a paired (A, B) row range on disk."""

    index: int
    rows: int
    file_a: str
    file_b: str
    sha256_a: str
    sha256_b: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "ShardInfo":
        return ShardInfo(**d)


class ViewStoreWriter:
    """Ingest paired row blocks into a store directory.

    ``append(a, b)`` takes arbitrarily-sized row blocks (they need not
    align with either chunks or shards); rows are buffered and flushed
    as ``rows_per_shard``-row shard files.  ``close()`` flushes the tail
    and atomically publishes the manifest — until then the directory is
    not a readable store.  Usable as a context manager.
    """

    def __init__(self, path: str, da: int, db: int, *, dtype="float32",
                 chunk: int = 1024, rows_per_shard: Optional[int] = None):
        self.path = path
        self.da = int(da)
        self.db = int(db)
        self.dtype = str(np.dtype(dtype)) if str(dtype) not in _EXOTIC else str(dtype)
        self.chunk = int(chunk)
        # default: 8 chunks per shard — large enough for sequential-IO
        # friendliness, small enough that distributed workers balance
        self.rows_per_shard = int(rows_per_shard or 8 * self.chunk)
        if self.rows_per_shard <= 0 or self.chunk <= 0:
            raise ValueError("chunk and rows_per_shard must be positive")
        self._tmp = path.rstrip("/") + ".tmp"
        if os.path.exists(self._tmp):
            shutil.rmtree(self._tmp)
        os.makedirs(self._tmp, exist_ok=True)
        self._base_shards: list[ShardInfo] = []
        self._base_n = 0
        self._shards: list[ShardInfo] = []
        self._buf_a: list[np.ndarray] = []
        self._buf_b: list[np.ndarray] = []
        self._buffered = 0
        self._n = 0
        self._closed = False

    @classmethod
    def append_to(cls, path: str,
                  rows_per_shard: Optional[int] = None) -> "ViewStoreWriter":
        """Open a *published* store for shard append.

        Geometry (da/db/dtype/chunk) is inherited from the existing
        manifest; new rows land in new ``shard_{idx}`` files continuing
        the index sequence.  ``close()`` moves the staged shard files
        into the published directory and then atomically replaces the
        manifest — the manifest swap is the single publish point, so:

        - readers opened before the append keep a consistent snapshot
          (their manifest references only the original, immutable shard
          files, which are never rewritten or deleted);
        - readers opened after see the extended store;
        - a kill mid-append leaves at worst unreferenced extra shard
          files next to the *old* manifest — still a consistent store
          (the delta is simply not yet published).
        """
        if not os.path.exists(os.path.join(path, MANIFEST)):
            raise FileNotFoundError(
                f"{path!r} is not a published view store; use "
                "ViewStoreWriter(...) for initial ingest")
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        if manifest.get("version") != STORE_VERSION:
            raise ValueError(
                f"unsupported store version {manifest.get('version')}")
        w = cls(path, manifest["da"], manifest["db"],
                dtype=manifest["dtype"], chunk=manifest["chunk"],
                rows_per_shard=rows_per_shard)
        w._base_shards = [ShardInfo.from_json(s) for s in manifest["shards"]]
        w._base_n = int(manifest["n"])
        w._n = w._base_n
        return w

    @property
    def _appending(self) -> bool:
        return bool(self._base_shards) or self._base_n > 0

    # -- ingestion --------------------------------------------------------

    def append(self, a, b) -> None:
        a = np.asarray(a)
        b = np.asarray(b)
        if a.ndim != 2 or b.ndim != 2 or a.shape[0] != b.shape[0]:
            raise ValueError(f"paired row blocks required, got {a.shape} / {b.shape}")
        if a.shape[1] != self.da or b.shape[1] != self.db:
            raise ValueError(
                f"feature mismatch: got ({a.shape[1]}, {b.shape[1]}), "
                f"store is (da={self.da}, db={self.db})")
        self._buf_a.append(a)
        self._buf_b.append(b)
        self._buffered += a.shape[0]
        self._n += a.shape[0]
        while self._buffered >= self.rows_per_shard:
            self._flush(self.rows_per_shard)

    def _flush(self, rows: int) -> None:
        if rows == 0:
            return
        a = np.concatenate(self._buf_a) if len(self._buf_a) != 1 else self._buf_a[0]
        b = np.concatenate(self._buf_b) if len(self._buf_b) != 1 else self._buf_b[0]
        head_a, tail_a = a[:rows], a[rows:]
        head_b, tail_b = b[:rows], b[rows:]
        self._buf_a = [tail_a] if tail_a.shape[0] else []
        self._buf_b = [tail_b] if tail_b.shape[0] else []
        self._buffered -= rows
        idx = len(self._base_shards) + len(self._shards)
        fa = f"shard_{idx:05d}.a.npy"
        fb = f"shard_{idx:05d}.b.npy"
        store_dt = _storage_dtype(self.dtype)
        for fname, block in ((fa, head_a), (fb, head_b)):
            block = np.ascontiguousarray(block)
            if self.dtype in _EXOTIC:
                import ml_dtypes

                block = block.astype(np.dtype(getattr(ml_dtypes, self.dtype)))
                block = block.view(store_dt)
            else:
                block = block.astype(store_dt, copy=False)
            # inside the staging dir — published atomically by close()
            np.save(os.path.join(self._tmp, fname), block)  # rcca: noqa[RCCA005]
        self._shards.append(ShardInfo(
            index=idx, rows=rows, file_a=fa, file_b=fb,
            sha256_a=_sha256_file(os.path.join(self._tmp, fa)),
            sha256_b=_sha256_file(os.path.join(self._tmp, fb)),
        ))

    # -- publish ----------------------------------------------------------

    def close(self) -> dict:
        if self._closed:
            raise RuntimeError("writer already closed")
        self._flush(self._buffered)
        manifest = {
            "version": STORE_VERSION,
            "n": self._n,
            "da": self.da,
            "db": self.db,
            "dtype": self.dtype,
            "chunk": self.chunk,
            "shards": [s.to_json()
                       for s in (*self._base_shards, *self._shards)],
        }
        # staging-dir write; the rename/replace below IS the atomic publish
        with open(os.path.join(self._tmp, MANIFEST), "w") as f:  # rcca: noqa[RCCA005]
            json.dump(manifest, f, indent=1)
        if self._appending:
            # append publish: new shard files move into the live store
            # first (fresh names — nothing existing is touched, so open
            # readers stay consistent), then one atomic manifest replace
            # flips the store to the extended snapshot
            for s in self._shards:
                for fname in (s.file_a, s.file_b):
                    os.replace(os.path.join(self._tmp, fname),
                               os.path.join(self.path, fname))
            os.replace(os.path.join(self._tmp, MANIFEST),
                       os.path.join(self.path, MANIFEST))
            shutil.rmtree(self._tmp, ignore_errors=True)
            self._closed = True
            return manifest
        # atomic publish, also when replacing: move the old store aside
        # BEFORE the rename so a kill can never leave a directory whose
        # manifest survives with its shards half-deleted
        old = self.path.rstrip("/") + ".old"
        if os.path.exists(self.path):
            if os.path.exists(old):
                shutil.rmtree(old)
            os.rename(self.path, old)
        os.rename(self._tmp, self.path)
        shutil.rmtree(old, ignore_errors=True)
        self._closed = True
        return manifest

    def __enter__(self) -> "ViewStoreWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        elif os.path.exists(self._tmp):  # failed ingest leaves no debris
            shutil.rmtree(self._tmp, ignore_errors=True)


def ingest_chunks(path: str, chunks: Iterable[Tuple[np.ndarray, np.ndarray]],
                  *, chunk: int, dtype="float32",
                  rows_per_shard: Optional[int] = None) -> "ViewStoreReader":
    """Write any (a, b) row-block iterator — ``PlantedCCAData``, hashed
    featurized text, a ``core.harvest`` activation stream — to ``path``
    and return a reader over it.  Feature widths are taken from the
    first block."""
    it = iter(chunks)
    try:
        a0, b0 = next(it)
    except StopIteration:
        raise ValueError("cannot ingest an empty chunk stream")
    a0 = np.asarray(a0)
    b0 = np.asarray(b0)
    with ViewStoreWriter(path, a0.shape[1], b0.shape[1], dtype=dtype,
                         chunk=chunk, rows_per_shard=rows_per_shard) as w:
        w.append(a0, b0)
        for a, b in it:
            w.append(a, b)
    return ViewStoreReader(path)


def extend_chunks(path: str, chunks: Iterable[Tuple[np.ndarray, np.ndarray]],
                  *, rows_per_shard: Optional[int] = None) -> "ViewStoreReader":
    """Append an (a, b) row-block iterator to a *published* store and
    atomically re-publish (see :meth:`ViewStoreWriter.append_to`).
    Returns a reader over the extended store."""
    with ViewStoreWriter.append_to(path, rows_per_shard=rows_per_shard) as w:
        for a, b in chunks:
            w.append(a, b)
    return ViewStoreReader(path)


def ingest_planted(path: str, data, *, rows_per_shard: Optional[int] = None,
                   dtype="float32") -> "ViewStoreReader":
    """Ingest a :class:`repro.data.PlantedCCAData` corpus chunk-by-chunk
    (never materializes n × d — this is how larger-than-RAM test/bench
    corpora reach disk)."""
    return ingest_chunks(path, iter(data), chunk=data.chunk,
                         rows_per_shard=rows_per_shard, dtype=dtype)


class ViewStoreReader:
    """Random- and sequential-access reader over a published store.

    Shard files are opened as memory maps once and sliced per chunk, so
    ``get_chunk`` is O(chunk bytes) regardless of n: the OS pages in
    only what a pass actually touches.  Chunks are the logical unit the
    data passes consume — chunk ``i`` covers rows
    ``[i·chunk, min(n, (i+1)·chunk))`` and may span shard boundaries.
    """

    def __init__(self, path: str, *, mmap: bool = True):
        from .uri import resolve_store_path

        self._fs, self.path = resolve_store_path(path)
        mpath = self._fs.join(self.path, MANIFEST)
        if not self._fs.exists(mpath):
            raise FileNotFoundError(
                f"{path!r} is not a view store (no {MANIFEST}); "
                "was the writer closed?")
        with self._fs.open(mpath, "rb") as f:
            self.manifest = json.load(f)
        if self.manifest.get("version") != STORE_VERSION:
            raise ValueError(f"unsupported store version {self.manifest.get('version')}")
        self.n = int(self.manifest["n"])
        self.da = int(self.manifest["da"])
        self.db = int(self.manifest["db"])
        self.dtype = self.manifest["dtype"]
        self.chunk = int(self.manifest["chunk"])
        self.shards = [ShardInfo.from_json(s) for s in self.manifest["shards"]]
        self._mmap_mode = "r" if mmap else None
        # cumulative row offsets: shard i covers [starts[i], starts[i+1])
        self._starts = np.concatenate(
            [[0], np.cumsum([s.rows for s in self.shards])]).astype(np.int64)
        if self.n != int(self._starts[-1]):
            raise ValueError(
                f"manifest row count {self.n} != shard total {int(self._starts[-1])}")
        self._maps: dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    # -- geometry ---------------------------------------------------------

    @property
    def n_chunks(self) -> int:
        return (self.n + self.chunk - 1) // self.chunk

    @property
    def nbytes(self) -> int:
        """Logical size of both views — what materializing would cost."""
        return self.n * (self.da + self.db) * _storage_dtype(self.dtype).itemsize

    def fingerprint(self) -> str:
        """Content identity of the store (hash over shard hashes +
        geometry) — pass checkpoints embed it so a resume against
        different data fails instead of silently mixing corpora."""
        h = hashlib.sha256()
        h.update(f"{self.n}:{self.da}:{self.db}:{self.dtype}:{self.chunk}".encode())
        for s in self.shards:
            h.update(s.sha256_a.encode())
            h.update(s.sha256_b.encode())
        return h.hexdigest()

    # -- access -----------------------------------------------------------

    def _shard_arrays(self, idx: int) -> Tuple[np.ndarray, np.ndarray]:
        if idx not in self._maps:
            s = self.shards[idx]
            a = self._fs.load_array(self._fs.join(self.path, s.file_a),
                                    mmap_mode=self._mmap_mode)
            b = self._fs.load_array(self._fs.join(self.path, s.file_b),
                                    mmap_mode=self._mmap_mode)
            if self._mmap_mode is None or not self._fs.supports_mmap:
                # eager reads (mmap off, or a remote backend that can
                # only materialize) — keep only the shard being
                # streamed, or an unbounded pass would rebuild the
                # whole corpus in this cache (mmaps are just mappings,
                # caching those is free)
                self._maps.clear()
            self._maps[idx] = (a, b)
        return self._maps[idx]

    def _read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        """Rows [lo, hi) of both views as regular (non-mmap) arrays."""
        s_lo = int(np.searchsorted(self._starts, lo, side="right") - 1)
        parts_a, parts_b = [], []
        i = s_lo
        while lo < hi:
            a, b = self._shard_arrays(i)
            base = int(self._starts[i])
            take = min(hi, int(self._starts[i + 1])) - lo
            parts_a.append(a[lo - base: lo - base + take])
            parts_b.append(b[lo - base: lo - base + take])
            lo += take
            i += 1
        if len(parts_a) == 1:  # common case: chunk within one shard
            a, b = np.asarray(parts_a[0]), np.asarray(parts_b[0])
        else:
            a, b = np.concatenate(parts_a), np.concatenate(parts_b)
        return _as_logical(a, self.dtype), _as_logical(b, self.dtype)

    def get_chunk(self, idx: int) -> Tuple[np.ndarray, np.ndarray]:
        """Random access by chunk index (replay / resume / shuffle)."""
        if not 0 <= idx < self.n_chunks:
            raise IndexError(f"chunk {idx} out of range [0, {self.n_chunks})")
        lo = idx * self.chunk
        return self._read_rows(lo, min(lo + self.chunk, self.n))

    def iter_chunks(self, start: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Sequential chunk stream; ``start`` seeks (resume mid-pass
        without touching the skipped chunks' pages)."""
        for i in range(start, self.n_chunks):
            yield self.get_chunk(i)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self.iter_chunks()

    def row_shard(self, shard: int, n_shards: int, *, start: int = 0,
                  group: int = 1) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Strided chunk assignment for distributed workers — same
        contract as ``PlantedCCAData.row_shard`` (worker w streams
        chunks w, w + n_shards, ...); the union over workers is an exact
        partition of the corpus.  ``start`` seeks past already-processed
        chunks (a killed worker resumes mid-shard without re-reading its
        folded prefix); ``group`` stripes in merge-group-sized runs so
        each worker owns whole ``repro.cluster`` merge groups (see
        :func:`shard_chunks` for the index rule)."""
        for i in shard_chunks(shard, n_shards, self.n_chunks,
                              start=start, group=group):
            yield self.get_chunk(i)

    def materialize(self) -> Tuple[np.ndarray, np.ndarray]:
        """All rows in memory — only for corpora known to fit (the dist
        driver's resident mode, small-scale evaluation)."""
        return self._read_rows(0, self.n)

    # -- integrity --------------------------------------------------------

    def verify(self) -> None:
        """Re-hash every shard against the manifest; raises on mismatch
        (bit rot, truncated copy, tampering)."""
        for s in self.shards:
            for fname, want in ((s.file_a, s.sha256_a), (s.file_b, s.sha256_b)):
                with self._fs.open(self._fs.join(self.path, fname), "rb") as f:
                    got = _sha256_fileobj(f)
                if got != want:
                    raise ValueError(
                        f"shard {fname} content hash mismatch: "
                        f"manifest {want[:12]}…, file {got[:12]}…")
