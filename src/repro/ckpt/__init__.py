"""Checkpointing substrate: sharded save/restore with elastic remesh."""

from .checkpoint import (
    CheckpointManager,
    load_flat,
    load_metadata,
    restore_pytree,
    save_pytree,
)

__all__ = ["CheckpointManager", "load_flat", "load_metadata",
           "restore_pytree", "save_pytree"]
