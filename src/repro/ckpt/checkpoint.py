"""Fault-tolerant checkpointing.

Design (tensorstore-free, works on any shared FS):

- a pytree is flattened to ``key → array`` with '/'-joined paths;
- each leaf is written as .npy under a step directory, with a JSON
  manifest recording tree structure, shapes, dtypes and step metadata;
- writes go to a temp dir + atomic rename, so a killed writer never
  corrupts the latest checkpoint (restart-safe);
- ``keep`` old steps are garbage-collected;
- restore is ELASTIC: arrays are loaded host-side and re-placed with
  whatever NamedSharding the *current* mesh prescribes — restoring a
  512-chip checkpoint onto 256 or 1024 chips is the same code path
  (leaves are logical arrays, not per-device shards).
- async: ``save(..., background=True)`` snapshots to host memory and
  writes on a daemon thread, overlapping I/O with the next train step.

On a real multi-host pod each host writes only the shards it owns
(addressable_shards); in this single-process container that reduces to
the full array, so the logic stays identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't natively round-trip bf16/f8 — store as a same-width uint
# view and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save_pytree(tree, directory: str, *, metadata: Optional[dict] = None) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"leaves": {}, "metadata": metadata or {}}
    treedef = jax.tree_util.tree_structure(tree)
    manifest["treedef"] = str(treedef)
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if logical in _EXOTIC:
            np.save(os.path.join(tmp, fname), arr.view(_EXOTIC[logical]))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)  # atomic publish


def restore_pytree(like_tree, directory: str, *, shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching pytree of NamedSharding — the
    elastic-remesh path (device placement happens here, per the
    CURRENT mesh, regardless of how the checkpoint was produced).
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key in flat_like:
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(directory, info["file"]))
        if info["dtype"] in _EXOTIC:
            arr = arr.view(np.dtype(getattr(ml_dtypes, info["dtype"])))
        if shardings is not None and key in flat_shard:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild using like_tree's structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten(like_tree).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])


def load_metadata(directory: str) -> dict:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)["metadata"]


def load_flat(directory: str) -> tuple[dict, dict]:
    """Load a checkpoint as a flat ``key → numpy array`` dict plus its
    metadata, without a like-tree.  For consumers whose leaf names are
    a fixed schema (e.g. repro.cluster partials: PowerStats/FinalStats
    fields) — they rebuild their own container from the keys."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(directory, info["file"]))
        if info["dtype"] in _EXOTIC:
            arr = arr.view(np.dtype(getattr(ml_dtypes, info["dtype"])))
        out[key] = arr
    return out, manifest["metadata"]


class CheckpointManager:
    """Step-indexed checkpoints with retention + background writes."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, *, metadata: Optional[dict] = None,
             background: bool = False) -> None:
        self.wait()
        meta = {"step": step, **(metadata or {})}
        if background:
            # snapshot to host memory NOW, write on a daemon thread
            host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

            def _write():
                save_pytree(host_tree, self._step_dir(step), metadata=meta)
                self._gc()

            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            save_pytree(tree, self._step_dir(step), metadata=meta)
            self._gc()

    def restore(self, like_tree, *, step: Optional[int] = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        d = self._step_dir(step)
        return restore_pytree(like_tree, d, shardings=shardings), load_metadata(d)

    def metadata(self, step: Optional[int] = None) -> Optional[dict]:
        """Checkpoint metadata without loading any arrays — for callers
        whose restore like-tree depends on it (e.g. the pass cursor,
        whose stats pytree structure is keyed on the saved pass_idx)."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        return load_metadata(self._step_dir(step))

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
