"""Incremental delta-refits: persist a fit's accumulator state, fold
only appended shards, re-finalize.

The paper's pitch — mergeable sufficient statistics for
iteration-expensive environments — makes a fit *updatable*: every
accumulator field is an exact row-sum, so statistics over appended rows
merge into persisted state through the same canonical pairwise tree
(:mod:`repro.exec.accumulate`) that makes the topologies bitwise-equal.
This module is that path:

- :class:`FitState` — the persisted artifact (via :mod:`repro.ckpt`):
  per-pass Qa/Qb payloads + accumulator state for pass 0 and the final
  pass, a store snapshot (fingerprint, per-shard hashes), and binding
  metadata (engine / omega / merge_group / algo) so a refit against the
  wrong data or knobs fails loudly instead of silently mixing corpora;
- :func:`fit_with_state` — a cold fit that also captures state (the
  ``PassEngine.on_pass_complete`` hook);
- :func:`delta_refit` — detect appended shards via the manifest prefix,
  fold only the delta, merge, re-finalize.

Two refit modes, because the power iteration couples passes to data:

``mode="exact"`` (default)
    Pass 0's sketch Ω is derived from the fit key alone (data-
    independent), so the persisted pass-0 accumulator resumes over the
    delta chunks and yields the full-corpus pass-0 statistics bitwise.
    Every later pass p consumes Q_p computed from pass p-1's
    *full-corpus* statistics — those Q change when data arrives, so
    passes 1..q re-fold the whole store with the refreshed bases.  The
    result is bitwise identical to a cold fit over the extended store
    (the delta-refit parity contract); for the default q=1 this halves
    the work, and for q=0 it never re-touches the corpus at all.

``mode="frozen"``
    Never re-touch the old corpus: fold the delta into the pass-0
    accumulator (still exact — Ω is data-independent) AND into the
    final-pass accumulator under the *frozen* final bases, then
    re-finalize in that basis.  The projections stay rank-optimal for
    the frozen range; freshness costs only O(delta) I/O.  Because the
    pass-0 entry stays exact, a later ``mode="exact"`` refit from the
    same state still reproduces the cold fit bitwise — frozen refits
    never degrade the state.

Alignment contract: the old corpus must end on a merge-group boundary
(``old_n`` divisible by ``chunk · merge_group``).  Chunk alignment
keeps every old chunk's content identical in the extended store; group
alignment means the persisted pairwise stack is exactly the cold fit's
mid-pass state (an unaligned history would have closed its ragged tail
group early, which the canonical tree cannot reopen).  ``delta_refit``
validates both and raises otherwise.

Cluster/Hybrid delta-refits (workers folding the delta, coordinator
merging into persisted state) are a ROADMAP residual; Local and
Sharded cover the single-host serving loop this PR lands.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro import obs
from repro.ckpt import load_flat, save_pytree

from .accumulate import MERGE_GROUP_CHUNKS, SegmentedAccumulator
from .engine import PassEngine, fold_groups_on_mesh, n_full_chunks, run_fold
from .topology import Local, Sharded, Topology, as_topology

FITSTATE_VERSION = 1

#: metadata keys that bind a FitState to its fit — a refit under any
#: other value is a different computation and must fail loudly
STATE_BINDING = ("version", "engine", "omega", "merge_group", "algo")


def _config_from_algo(algo: dict):
    """Rebuild the RCCAConfig a state was fit under from its persisted
    ``algo_meta`` dict (the inverse of ``repro.core.rcca.algo_meta``)."""
    from repro.core.rcca import RCCAConfig

    return RCCAConfig(
        k=int(algo["k"]), p=int(algo["p"]), q=int(algo["q"]),
        lam_a=float(algo["lam_a"]), lam_b=float(algo["lam_b"]),
        nu=None if algo["nu"] is None else float(algo["nu"]),
        center=bool(algo["center"]), dtype=jnp.dtype(algo["dtype"]))


def _stats_cls(kind: str):
    from repro.core.rcca import FinalStats, PowerStats

    return PowerStats if kind == "power" else FinalStats


@dataclasses.dataclass
class PassCapture:
    """One persisted pass: the Qa/Qb payload it consumed (arrays, or
    (2,)-uint32 seeds on a seeded pass 0) and the accumulator snapshot
    after its last chunk."""

    kind: str
    Qa: Any
    Qb: Any
    acc_current: Any
    acc_stack: Tuple[Any, ...]

    def acc_state(self) -> Dict[str, Any]:
        return {"current": self.acc_current, "stack": self.acc_stack}


@dataclasses.dataclass
class FitState:
    """Persisted incremental-fit state (see module docstring).

    ``meta`` carries binding + the store snapshot; ``passes`` maps pass
    index → :class:`PassCapture` for pass 0 and the final pass (the
    only two an exact or frozen refit consumes — intermediate power
    passes are recomputed from refreshed bases either way).
    """

    meta: Dict[str, Any]
    passes: Dict[int, PassCapture]

    # -- persistence (repro.ckpt atomic pytree) ---------------------------

    def save(self, directory: str) -> None:
        tree = {}
        for p, cap in sorted(self.passes.items()):
            tree[f"p{p:05d}"] = {
                "Qa": cap.Qa, "Qb": cap.Qb,
                "current": dict(
                    zip(_stats_cls(cap.kind)._fields, cap.acc_current)),
                "stack": {f"{i:02d}": dict(
                    zip(_stats_cls(cap.kind)._fields, s))
                    for i, s in enumerate(cap.acc_stack)},
            }
        meta = dict(self.meta)
        meta["pass_kinds"] = {str(p): cap.kind
                              for p, cap in self.passes.items()}
        meta["stack_depths"] = {str(p): len(cap.acc_stack)
                                for p, cap in self.passes.items()}
        save_pytree(tree, directory, metadata=meta)

    @classmethod
    def load(cls, directory: str) -> "FitState":
        if not os.path.exists(os.path.join(directory, "manifest.json")):
            raise FileNotFoundError(f"no FitState at {directory!r}")
        flat, meta = load_flat(directory)
        if meta.get("version") != FITSTATE_VERSION:
            raise ValueError(
                f"unsupported FitState version {meta.get('version')}")
        passes: Dict[int, PassCapture] = {}
        for p_str, kind in meta["pass_kinds"].items():
            p = int(p_str)
            pre = f"p{p:05d}/"
            fields = _stats_cls(kind)._fields
            stats = _stats_cls(kind)

            def grab(at: str):
                return stats(**{f: jnp.asarray(flat[at + f])
                                for f in fields})

            depth = int(meta["stack_depths"][p_str])
            passes[p] = PassCapture(
                kind=kind,
                Qa=jnp.asarray(flat[pre + "Qa"]),
                Qb=jnp.asarray(flat[pre + "Qb"]),
                acc_current=grab(pre + "current/"),
                acc_stack=tuple(grab(f"{pre}stack/{i:02d}/")
                                for i in range(depth)))
        return cls(meta=meta, passes=passes)


# --------------------------------------------------------------------------
# capture: a cold fit that also emits FitState
# --------------------------------------------------------------------------


def _store_snapshot(reader) -> Dict[str, Any]:
    return {
        "fingerprint": reader.fingerprint(),
        "n": int(reader.n), "chunk": int(reader.chunk),
        "da": int(reader.da), "db": int(reader.db),
        "dtype": str(reader.dtype), "n_chunks": int(reader.n_chunks),
        "shards": [[s.sha256_a, s.sha256_b] for s in reader.shards],
    }


def fit_with_state(store, cfg, key, *, topology: Topology = Local(),
                   engine: Optional[str] = None,
                   merge_group: int = MERGE_GROUP_CHUNKS,
                   omega: str = "materialized",
                   prefetch: int = 2):
    """Cold fit over a view store that also returns the
    :class:`FitState` a later :func:`delta_refit` resumes from.

    Drives the same :class:`PassEngine` as ``exec.fit`` (bitwise-equal
    result) with the ``on_pass_complete`` capture hook attached.
    ``Local`` and ``Sharded`` topologies; cluster capture is a ROADMAP
    residual.
    """
    from repro.core.rcca import algo_meta
    from repro.store import ViewStoreReader

    topo = as_topology(topology)
    reader = store if isinstance(store, ViewStoreReader) \
        else ViewStoreReader(store)
    eng = PassEngine(cfg, engine=engine, topology=topo,
                     merge_group=merge_group, omega=omega)

    captured: Dict[int, PassCapture] = {}

    def capture(pass_idx, kind, acc, Qa, Qb):
        if pass_idx in (0, cfg.q):
            st = acc.state()
            captured[pass_idx] = PassCapture(
                kind=kind, Qa=Qa, Qb=Qb, acc_current=st["current"],
                acc_stack=tuple(st["stack"]))

    if isinstance(topo, Local):
        res = eng.run_stream(
            lambda start: reader.iter_chunks(start), reader.da, reader.db,
            key, n_chunks=reader.n_chunks, on_pass_complete=capture)
    elif isinstance(topo, Sharded):
        res = eng.run_mesh(reader, key, prefetch=prefetch,
                           on_pass_complete=capture)
    else:
        raise ValueError(
            f"fit_with_state supports Local and Sharded topologies; "
            f"{topo.name} capture is a ROADMAP residual")

    meta = {
        "version": FITSTATE_VERSION,
        "engine": eng.engine, "omega": eng.omega,
        "merge_group": int(merge_group), "algo": algo_meta(cfg),
        **_store_snapshot(reader),
    }
    return res, FitState(meta=meta, passes=captured)


# --------------------------------------------------------------------------
# delta detection + refit
# --------------------------------------------------------------------------


def delta_chunks(state: FitState, reader) -> Tuple[int, int]:
    """Validate that ``reader`` extends the state's store snapshot and
    return ``(old_n_chunks, new_n_chunks)``.

    The old store must be an exact prefix of the new one: same
    geometry, the old shard hash list leading the new shard list
    unchanged, and the old row count aligned to a merge-group boundary
    (see the module docstring for why).  ``old == new`` (no delta) is
    valid and returns equal counts.
    """
    m = state.meta
    for field in ("da", "db", "chunk", "dtype"):
        got = str(getattr(reader, field)) if field == "dtype" \
            else int(getattr(reader, field))
        want = m[field] if field == "dtype" else int(m[field])
        if got != want:
            raise ValueError(
                f"store geometry changed: {field} was {want!r}, "
                f"now {got!r} — not an append")
    old_shards = [tuple(s) for s in m["shards"]]
    new_shards = [(s.sha256_a, s.sha256_b) for s in reader.shards]
    if len(new_shards) < len(old_shards) or \
            new_shards[:len(old_shards)] != old_shards:
        raise ValueError(
            "store is not an append of the fitted snapshot: the old "
            "shard list is not a hash-identical prefix of the new "
            "manifest (rewritten or reordered shards cannot delta-refit)")
    old_n, chunk = int(m["n"]), int(m["chunk"])
    if reader.n < old_n:
        raise ValueError(f"store shrank: {old_n} rows fitted, {reader.n} now")
    group_rows = chunk * int(m["merge_group"])
    if reader.n > old_n and old_n % group_rows:
        raise ValueError(
            f"delta refit needs the fitted corpus to end on a "
            f"merge-group boundary: {old_n} rows is not a multiple of "
            f"chunk × merge_group = {group_rows} (append at group "
            "granularity, or cold-fit)")
    # ceil: a ragged old corpus is only reachable in the no-delta case
    # (the append path above required chunk alignment), where the old
    # chunk count must equal the reader's for the re-finalize shortcut
    return -(-old_n // chunk), reader.n_chunks


def _restore_acc(cap: PassCapture, init_fn, old_nc: int, new_nc: int,
                 merge_group: int) -> SegmentedAccumulator:
    """The persisted accumulator as the cold fit's mid-pass state at
    chunk ``old_nc`` of a ``new_nc``-chunk corpus."""
    acc = SegmentedAccumulator.structure(init_fn, new_nc, merge_group,
                                         old_nc)
    acc.load_state(cap.acc_state())
    return acc


def _fold_range(eng: PassEngine, reader, topo, acc, kind: str, seeded: bool,
                Qa, Qb, lo: int, hi: int, *, prefetch: int,
                pass_idx: int) -> None:
    """Fold chunks [lo, hi) of the store into ``acc`` — the same fold
    the cold fit runs, restricted to a range.  ``lo`` is always a
    merge-group boundary here (the alignment contract), so the Sharded
    form can hand whole groups to the device fold."""
    from repro.core.rcca import seeded_update_fn, update_fn

    attrs = {"kind": kind, "engine": eng.engine, "pass_idx": pass_idx,
             "site": "delta"}
    if isinstance(topo, Sharded):
        mesh = topo.build_mesh()
        raw = seeded_update_fn(kind, eng.cfg.sketch, eng.cfg.dtype) \
            if seeded else update_fn(kind, eng.engine)
        jit = eng._updaters(seeded)[kind]
        G = eng.merge_group
        fold_groups_on_mesh(
            reader.get_chunk, range(lo // G, -(-hi // G)), raw, jit,
            eng._init_fn(kind, reader.da, reader.db), Qa, Qb, mesh=mesh,
            merge_group=G, n_chunks=hi, full_chunks=n_full_chunks(reader),
            emit=acc.push_group, prefetch=prefetch, span_attrs=attrs,
            cost_fn=eng.cost_fn(kind, seeded))
    else:
        fn = eng._updaters(seeded)[kind]
        run_fold(((c, reader.get_chunk(c)) for c in range(lo, hi)),
                 fn, acc, Qa, Qb, span_attrs=attrs,
                 cost_fn=eng.cost_fn(kind, seeded))


def delta_refit(state: FitState, store, *, mode: str = "exact",
                topology: Topology = Local(), prefetch: int = 2):
    """Refit against an extended store by folding only what changed.

    Returns ``(RCCAResult, FitState)`` — the refreshed result and the
    state to persist for the *next* refit.  ``mode`` picks the
    exact-vs-frozen trade (module docstring); the topology only shapes
    the delta/re-fold execution, never the values (the canonical-tree
    argument).  With no appended rows, re-finalizes from state without
    touching the store.
    """
    from repro.core.rcca import power_update_Q
    from repro.store import ViewStoreReader

    if mode not in ("exact", "frozen"):
        raise ValueError(f"unknown mode {mode!r}; expected exact or frozen")
    topo = as_topology(topology)
    if not isinstance(topo, (Local, Sharded)):
        raise ValueError(
            f"delta_refit supports Local and Sharded topologies; "
            f"{topo.name} is a ROADMAP residual")
    reader = store if isinstance(store, ViewStoreReader) \
        else ViewStoreReader(store)

    m = state.meta
    cfg = _config_from_algo(m["algo"])
    q = cfg.q
    eng = PassEngine(cfg, engine=m["engine"], topology=topo,
                     merge_group=int(m["merge_group"]), omega=m["omega"])
    old_nc, new_nc = delta_chunks(state, reader)
    da, db = reader.da, reader.db
    G = eng.merge_group

    with obs.span("delta_refit", mode=mode, old_chunks=old_nc,
                  new_chunks=new_nc, engine=eng.engine):
        cap0 = state.passes[0]
        capF = state.passes[q]
        seeded0 = eng.seeds_in_slots

        if new_nc == old_nc:  # nothing appended: re-finalize only
            accF = _restore_acc(capF, eng._init_fn(capF.kind, da, db),
                                old_nc, new_nc, G)
            res = eng._finish(accF.result(), *eng._boundary_Q(
                capF.Qa, capF.Qb, q, da, db), da, db)
            res.diagnostics["delta"] = {"mode": mode, "delta_chunks": 0}
            return res, state

        # pass 0 over the delta only — exact for both modes, because Ω
        # is derived from the fit key, not the data
        acc0 = _restore_acc(cap0, eng._init_fn(cap0.kind, da, db),
                            old_nc, new_nc, G)
        _fold_range(eng, reader, topo, acc0, cap0.kind, seeded0,
                    cap0.Qa, cap0.Qb, old_nc, new_nc,
                    prefetch=prefetch, pass_idx=0)
        st0 = acc0.state()
        new_cap0 = PassCapture(kind=cap0.kind, Qa=cap0.Qa, Qb=cap0.Qb,
                               acc_current=st0["current"],
                               acc_stack=tuple(st0["stack"]))

        new_meta = {**{k: m[k] for k in STATE_BINDING},
                    **_store_snapshot(reader)}

        if mode == "frozen" and q > 0:
            # delta into the final accumulator under the frozen bases
            accF = _restore_acc(capF, eng._init_fn(capF.kind, da, db),
                                old_nc, new_nc, G)
            _fold_range(eng, reader, topo, accF, capF.kind, False,
                        capF.Qa, capF.Qb, old_nc, new_nc,
                        prefetch=prefetch, pass_idx=q)
            res = eng._finish(accF.result(), capF.Qa, capF.Qb, da, db)
            stF = accF.state()
            new_capF = PassCapture(kind=capF.kind, Qa=capF.Qa, Qb=capF.Qb,
                                   acc_current=stF["current"],
                                   acc_stack=tuple(stF["stack"]))
            res.diagnostics["delta"] = {
                "mode": mode, "delta_chunks": new_nc - old_nc,
                "refolded_chunks": new_nc - old_nc}
            return res, FitState(meta=new_meta,
                                 passes={0: new_cap0, q: new_capF})

        # exact mode (and q = 0, where frozen degenerates to exact):
        # rotate Q from the full-corpus pass-0 stats, then re-fold the
        # whole store for passes 1..q — exactly the cold fit's loop
        refolded = new_nc - old_nc
        if q == 0:
            Qa, Qb = eng._boundary_Q(cap0.Qa, cap0.Qb, 0, da, db)
            res = eng._finish(acc0.result(), Qa, Qb, da, db)
            res.diagnostics["delta"] = {
                "mode": "exact", "delta_chunks": new_nc - old_nc,
                "refolded_chunks": refolded}
            return res, FitState(meta=new_meta, passes={0: new_cap0})

        Qa, Qb = cap0.Qa, cap0.Qb
        if cfg.center:  # μ corrections need the actual Ω
            Qa, Qb = eng._boundary_Q(Qa, Qb, 0, da, db)
        Qa, Qb = power_update_Q(acc0.result(), Qa, Qb, cfg)
        acc = None
        for pass_idx in range(1, q + 1):
            kind = "power" if pass_idx < q else "final"
            acc = SegmentedAccumulator(eng._init_fn(kind, da, db),
                                       new_nc, G)
            _fold_range(eng, reader, topo, acc, kind, False, Qa, Qb,
                        0, new_nc, prefetch=prefetch, pass_idx=pass_idx)
            refolded += new_nc
            if kind == "power":
                Qa, Qb = power_update_Q(acc.result(), Qa, Qb, cfg)

        res = eng._finish(acc.result(), Qa, Qb, da, db)
        stF = acc.state()
        new_capF = PassCapture(kind="final", Qa=Qa, Qb=Qb,
                               acc_current=stF["current"],
                               acc_stack=tuple(stF["stack"]))
        res.diagnostics["delta"] = {
            "mode": "exact", "delta_chunks": new_nc - old_nc,
            "refolded_chunks": refolded}
        return res, FitState(meta=new_meta, passes={0: new_cap0, q: new_capF})
