"""The one pass engine every execution mode drives.

Five drivers used to re-implement (or fork) the chunk → merge-group →
tree pass structure (`randomized_cca_streaming`/`_iterator`,
`dist_randomized_cca`, ``store.PassRunner``, the ``repro.cluster``
worker/coordinator); this module is the single implementation they are
now shells over:

- :func:`run_fold` — THE canonical chunk-fold loop: left-fold (a, b)
  chunks into a :class:`~repro.exec.accumulate.SegmentedAccumulator`
  (tree mode for single-process passes, sink mode for cluster workers
  publishing per-group partials), with the per-chunk callback hook that
  cursor checkpointing, in-flight bounding and failure injection all
  hang off;
- :func:`fold_groups_on_mesh` — the device-parallel form of the same
  fold: whole merge groups are folded one-per-device under ``shard_map``
  (a ``lax.scan`` over the group's chunks on each device), and the
  per-group sums are emitted in ascending group order.  Because a merge
  group is the canonical reduction unit and each group's left-fold runs
  on a single device with the exact per-chunk update arithmetic, the
  emitted partials are bitwise identical to the sequential fold — the
  keystone of the ``Sharded`` and ``Hybrid`` topologies;
- :class:`PassEngine` — owns the q+1 pass schedule, source opening and
  seek (resume), accumulator structure/restore, and the per-topology
  pass fold;
- :func:`fit` — the one entry point over a view store for any
  :mod:`~repro.exec.topology`.

Every mode accumulates in the same canonical order, so their results
agree bitwise — see :mod:`repro.exec.accumulate` for the argument.
"""

from __future__ import annotations

import functools
import inspect
from typing import Callable, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.analysis import sanitize

import jax

from .accumulate import MERGE_GROUP_CHUNKS, SegmentedAccumulator
from .topology import Local, Sharded, Topology, as_topology


# --------------------------------------------------------------------------
# pass schedule (shared by every driver, including the resident-mesh one)
# --------------------------------------------------------------------------


def pass_schedule(q: int) -> Iterable[Tuple[int, str]]:
    """The q+1 data passes of Algorithm 1: ``q`` range-finder ("power")
    passes followed by one "final" pass.  Yields (pass_idx, kind)."""
    for pass_idx in range(q):
        yield pass_idx, "power"
    yield q, "final"


# --------------------------------------------------------------------------
# chunk sources
# --------------------------------------------------------------------------


def open_source(source_factory, start_chunk: int):
    """Instantiate the chunk source for one pass.

    Seek-aware factories opt in by naming their first positional
    parameter ``start`` (e.g. ``repro.store.PassRunner._source``); they
    are asked to begin at ``start_chunk`` directly, so a resumed pass
    never reads the skipped prefix from disk.  Anything else keeps the
    legacy contract: ``source_factory()`` yields from chunk 0 and the
    fold loop filters.  (Opt-in is by name, not arity — a factory that
    merely happens to take a defaulted positional must not silently
    receive a chunk index.)
    """
    try:
        params = list(inspect.signature(source_factory).parameters.values())
        seekable = bool(params) and params[0].name == "start" and \
            params[0].kind in (params[0].POSITIONAL_ONLY,
                               params[0].POSITIONAL_OR_KEYWORD)
    except (TypeError, ValueError):
        seekable = False
    if seekable:
        return source_factory(start_chunk), start_chunk
    return source_factory(), 0


class StackedChunks:
    """Random-access adapter over stacked in-memory chunk arrays
    ``(nc, c, d)`` — what ``randomized_cca_streaming`` consumes.  Every
    chunk is full-size, so all merge groups are uniform."""

    def __init__(self, A_chunks, B_chunks):
        if A_chunks.shape[0] != B_chunks.shape[0] or \
                A_chunks.shape[1] != B_chunks.shape[1]:
            raise ValueError(
                f"paired chunk stacks required, got {A_chunks.shape} / "
                f"{B_chunks.shape}")
        self.A, self.B = A_chunks, B_chunks
        self.n_chunks = int(A_chunks.shape[0])
        self.chunk = int(A_chunks.shape[1])
        self.n = self.n_chunks * self.chunk
        self.da = int(A_chunks.shape[2])
        self.db = int(B_chunks.shape[2])

    def get_chunk(self, i: int):
        return self.A[i], self.B[i]

    def iter_chunks(self, start: int = 0):
        for i in range(start, self.n_chunks):
            yield self.get_chunk(i)


def n_full_chunks(access) -> int:
    """Chunks of ``access`` that carry a full ``chunk`` rows — every
    chunk except a short tail.  Merge groups made only of full chunks
    are "uniform" and eligible for the device-parallel fold."""
    if access.n % access.chunk == 0:
        return access.n_chunks
    return access.n_chunks - 1


# --------------------------------------------------------------------------
# THE chunk-fold loop (sequential form)
# --------------------------------------------------------------------------


def run_fold(indexed_chunks, update_fn, acc: SegmentedAccumulator, Qa, Qb, *,
             start_chunk: int = 0, on_chunk=None, span_attrs=None,
             cost_fn=None) -> SegmentedAccumulator:
    """The canonical chunk-fold loop — the only one in the codebase.

    ``indexed_chunks`` yields ``(chunk_idx, (a, b))`` with GLOBAL chunk
    indices (sequential drivers enumerate their source; cluster workers
    zip their strided index assignment).  Chunks below ``start_chunk``
    are skipped (non-seekable resume).  Each chunk left-folds into
    ``acc``'s current merge group; ``acc`` closes groups at the
    canonical boundaries — into its pairwise tree (single-process) or
    its sink (worker partial publication).  ``on_chunk(chunk_idx, acc)``
    runs after every fold: cursor checkpointing, in-flight bounding,
    heartbeats and failure injection all live there, OUTSIDE the fold.

    Under ``RCCA_TRACE`` the loop records an ``io_wait`` span around
    each source pull and a ``chunk`` span around each fold (the
    ``on_chunk`` callback rides inside it — in-flight bounding IS the
    device-compute wait), stamped with ``span_attrs`` and, when
    ``cost_fn(a, b)`` is given, the cost-model flops/bytes; per-kernel
    totals are emitted as one ``kernel_cost`` counter at loop end.
    With tracing off the loop below runs byte-for-byte unchanged.
    """
    if not obs.enabled():
        for chunk_idx, (a, b) in indexed_chunks:
            if chunk_idx < start_chunk:
                continue
            acc.update(chunk_idx, update_fn, a, b, Qa, Qb)
            if on_chunk is not None:
                on_chunk(chunk_idx, acc)
        acc.flush_tail()
        return acc

    base = dict(span_attrs or {})
    it = iter(indexed_chunks)
    kernel_parts: list = []
    while True:
        with obs.span("io_wait", **base):
            item = next(it, None)
        if item is None:
            break
        chunk_idx, (a, b) = item
        if chunk_idx < start_chunk:
            continue
        attrs = dict(base, chunk=chunk_idx)
        if cost_fn is not None:
            cost = cost_fn(a, b)
            attrs["flops"] = cost["flops"]
            attrs["bytes"] = cost["bytes"]
            if cost.get("schedule") is not None:
                attrs["schedule"] = cost["schedule"]
            kernel_parts.extend(cost["kernels"])
        with obs.span("chunk", **attrs):
            acc.update(chunk_idx, update_fn, a, b, Qa, Qb)
            if on_chunk is not None:
                on_chunk(chunk_idx, acc)
    acc.flush_tail()
    if kernel_parts:
        from repro.obs.cost import merge_kernel_costs
        for part in merge_kernel_costs(kernel_parts):
            obs.counter("kernel_cost", **dict(base, **part))
    return acc


# --------------------------------------------------------------------------
# the device-parallel form: whole merge groups under shard_map
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _mesh_group_fold(update_fn, init_fn, mesh, axis: str):
    """The jitted one-group-per-device fold program.  Memoized on the
    (update, init, mesh) identity so repeated passes of a fit — and the
    per-batch calls within a pass — reuse one trace instead of
    recompiling the identical shard_map program every time (callers
    hoist their per-kind functions for exactly this reason)."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map

    def body(a_blk, b_blk, qa, qb):
        def step(s, ab):
            return update_fn(s, ab[0], ab[1], qa, qb), None
        s, _ = jax.lax.scan(step, init_fn(), (a_blk[0], b_blk[0]))
        return jax.tree_util.tree_map(lambda x: x[None], s)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(), P()),
        out_specs=P(axis), check_rep=False))


def fold_groups_on_mesh(get_chunk, groups: Sequence[int], update_fn,
                        update_fn_jit, init_fn, Qa, Qb, *, mesh,
                        merge_group: int, n_chunks: int, full_chunks: int,
                        emit: Callable[[int, object], None],
                        prefetch: int = 2, span_attrs=None,
                        cost_fn=None) -> None:
    """Fold whole merge groups one-per-device and emit their sums in
    ascending group order.

    Uniform groups (exactly ``merge_group`` full-size chunks) are
    batched ``D`` at a time — one group per device of the 1-D ``mesh`` —
    and folded by a ``lax.scan`` over the group's chunks inside
    ``shard_map``.  The scan body is the exact per-chunk update, so each
    group's sum is bitwise identical to the sequential left-fold (the
    same per-device arithmetic; no cross-device collective ever touches
    a partial).  The at-most-one ragged tail group falls back to the
    sequential fold with the jitted per-chunk update — the same
    function, the same result, on chunks whose shapes the uniform batch
    cannot carry.

    A short batch is padded by REPLICATING its first group so the
    shard_map program keeps one shape; padded outputs are discarded.
    ``emit(g, stats)`` may raise to abort (worker kill injection) —
    groups already emitted stay emitted, exactly like a crashed worker.

    Uniform-group chunks stream through a
    :class:`~repro.store.prefetch.ChunkPrefetcher` (``prefetch`` is its
    read-ahead depth; 0 falls back to the metered synchronous reader),
    so the next batch's reads overlap the current batch's device fold.
    The prefetcher consumes the flat ascending chunk order the gather
    loop below pops (padding only replicates an id already fetched), so
    the reads — and therefore the folded values — are bitwise unchanged
    from the old synchronous gather.  Under ``RCCA_TRACE`` each batch
    records ``gather`` and ``mesh_fold`` spans (the latter stamped with
    cost-model flops/bytes) plus one ``io`` counter from the prefetcher
    and a ``kernel_cost`` counter for the folded chunks.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"group-parallel fold needs a 1-D mesh, got axes {mesh.axis_names}")
    axis = mesh.axis_names[0]
    D = mesh.devices.size
    G = int(merge_group)

    groups = sorted(int(g) for g in groups)
    uniform = [g for g in groups if (g + 1) * G <= full_chunks]
    ragged = [g for g in groups if (g + 1) * G > full_chunks]

    base = dict(span_attrs or {})
    if uniform:
        # function-level import: repro.store imports repro.exec at
        # package load, so the reverse edge must stay lazy
        from repro.store.prefetch import prefetched

        fold_batch = _mesh_group_fold(update_fn, init_fn, mesh, axis)
        shard = NamedSharding(mesh, P(axis))
        need = (c for g in uniform for c in range(g * G, (g + 1) * G))
        src = prefetched((get_chunk(c) for c in need), depth=prefetch,
                         device_put=False, site="mesh_gather")
        chunk_cost = None
        folded = 0
        try:
            for lo in range(0, len(uniform), D):
                ids = uniform[lo:lo + D]
                padded = ids + [ids[0]] * (D - len(ids))
                blocks = {}
                with obs.span("gather", **dict(base, groups=len(ids))):
                    # dict.fromkeys, not set(): deterministic first-seen
                    # order — and the pad duplicate is never re-fetched
                    for g in dict.fromkeys(padded):
                        pairs = [next(src) for _ in range(G)]
                        blocks[g] = (
                            np.stack([np.asarray(a) for a, _ in pairs]),
                            np.stack([np.asarray(b) for _, b in pairs]))
                        if cost_fn is not None and chunk_cost is None:
                            chunk_cost = cost_fn(blocks[g][0][0],
                                                 blocks[g][1][0])
                    a_blk = jax.device_put(
                        np.stack([blocks[g][0] for g in padded]), shard)
                    b_blk = jax.device_put(
                        np.stack([blocks[g][1] for g in padded]), shard)
                fattrs = dict(base, groups=len(ids))
                if chunk_cost is not None:
                    fattrs["flops"] = chunk_cost["flops"] * len(ids) * G
                    fattrs["bytes"] = chunk_cost["bytes"] * len(ids) * G
                    if chunk_cost.get("schedule") is not None:
                        fattrs["schedule"] = chunk_cost["schedule"]
                with obs.span("mesh_fold", **fattrs):
                    out = fold_batch(a_blk, b_blk, Qa, Qb)
                    for i, g in enumerate(ids):
                        emit(g, jax.tree_util.tree_map(
                            lambda x, _i=i: x[_i], out))
                folded += len(ids) * G
        finally:
            src.close()
        if chunk_cost is not None and folded:
            from repro.obs.cost import merge_kernel_costs
            scaled = [dict(k, calls=k["calls"] * folded,
                           flops=k["flops"] * folded,
                           bytes=k["bytes"] * folded)
                      for k in chunk_cost["kernels"]]
            for part in merge_kernel_costs(scaled):
                obs.counter("kernel_cost", **dict(base, **part))

    for g in ragged:
        lo = g * G
        hi = min(n_chunks, (g + 1) * G)
        acc = SegmentedAccumulator(init_fn, n_chunks, G, sink=emit)
        run_fold(((c, get_chunk(c)) for c in range(lo, hi)),
                 update_fn_jit, acc, Qa, Qb,
                 span_attrs=base or None, cost_fn=cost_fn)


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------


class PassEngine:
    """Drive Algorithm 1's q+1 data passes under one topology.

    The engine owns what the five historical drivers each re-implemented:
    chunk iteration and source seeking, the
    :class:`~repro.exec.accumulate.SegmentedAccumulator` group fold, the
    canonical pairwise-tree reduce, resume-state restoration, and the
    per-chunk callback hook everything else (cursor checkpointing,
    prefetch metering, in-flight bounding) is wired through.

    ``topology`` selects the pass fold: :class:`Local` folds a
    sequential chunk stream; :class:`Sharded` (``col_axis=None``) folds
    whole merge groups one-per-device over the local mesh — bitwise the
    same result.  ``Cluster``/``Hybrid`` fits are driven by
    ``repro.cluster.ClusterCoordinator`` (see :func:`fit`), which calls
    back into this module for the worker-side fold.

    ``omega`` selects Ω provenance (see ``repro.core.rcca.OMEGA_MODES``).
    With ``omega="seeded"`` and the kernels engine, pass 0 runs the
    seeded per-chunk update: the (2,)-uint32 per-view seeds ride in the
    Qa/Qb operand slots (same fold/cursor/round plumbing) and the
    ``(d, k̃)`` Ω array is never materialized — tiles are generated
    inside the Pallas kernels.  The jnp engine materializes Ω locally
    from the same seeds (its documented fallback), and
    ``"seeded-materialized"`` materializes the same tile-PRNG Ω up
    front for every engine — the bitwise oracle of the seeded path.
    """

    def __init__(self, cfg, *, engine: Optional[str] = None,
                 topology: Topology = Local(),
                 merge_group: int = MERGE_GROUP_CHUNKS,
                 omega: str = "materialized"):
        from repro.core.rcca import DEFAULT_ENGINE, resolve_engine, resolve_omega

        self.cfg = cfg
        self.engine = resolve_engine(DEFAULT_ENGINE if engine is None else engine)
        self.topology = topology
        self.merge_group = int(merge_group)
        self.omega = resolve_omega(omega)

    # -- per-pass pieces --------------------------------------------------

    @property
    def seeds_in_slots(self) -> bool:
        """True when pass 0's Qa/Qb operand slots carry seeds, not
        arrays (seeded mode under the kernels engine)."""
        return self.omega == "seeded" and self.engine == "kernels"

    def _init_payload(self, key, da: int, db: int):
        """Pass-0 Qa/Qb payload: seeds for the in-kernel path, arrays
        otherwise (each omega mode's own generator)."""
        from repro.core.rcca import init_Q, omega_seeds

        if self.seeds_in_slots:
            return omega_seeds(key)
        if self.omega == "seeded":
            # jnp engine: materialize the tile-PRNG Ω locally — a
            # worker needs only the seed to re-derive it (stateless).
            return init_Q(key, da, db, self.cfg, omega="seeded")
        return init_Q(key, da, db, self.cfg, omega=self.omega)

    def _boundary_Q(self, Qa, Qb, pass_idx: int, da: int, db: int):
        """Materialize Ω at a pass boundary when the slots carry seeds
        and downstream actually needs the arrays (centering correction,
        or the q = 0 finalize).  Ya is already a (da, k̃) array at every
        boundary, so this stays in the same memory class as the stats —
        the in-pass data path is what never materializes Ω."""
        from repro.kernels import rand as krand

        if not self.seeds_in_slots or pass_idx != 0:
            return Qa, Qb
        return (krand.dense_omega(Qa, da, self.cfg.sketch, self.cfg.dtype),
                krand.dense_omega(Qb, db, self.cfg.sketch, self.cfg.dtype))

    def _updaters(self, seeded: bool):
        """Jitted per-kind chunk updates for one pass flavor family."""
        from repro.core.rcca import jit_seeded_update_fn, jit_update_fn

        kinds = ("power", "final")
        if seeded:
            return {k: jit_seeded_update_fn(k, self.cfg.sketch, self.cfg.dtype)
                    for k in kinds}
        return {k: jit_update_fn(k, self.engine) for k in kinds}

    def _init_fn(self, kind: str, da: int, db: int):
        from repro.core.rcca import stats_init_fn

        return stats_init_fn(kind, da, db, self.cfg.sketch)

    def _finish(self, fstats, Qa, Qb, da: int, db: int):
        from repro.core.rcca import finalize_result

        return finalize_result(fstats, Qa, Qb, self.cfg, da, db)

    def cost_fn(self, kind: str, seeded: bool):
        """Cost-model ``(a, b) -> flops/bytes`` closure for one pass's
        chunk updates, or ``None`` when tracing is off."""
        if not obs.enabled():
            return None
        from repro.obs.cost import chunk_cost_fn

        return chunk_cost_fn(kind, self.engine, int(self.cfg.sketch),
                             self.cfg.dtype, seeded=seeded)

    # -- sequential (Local) ----------------------------------------------

    def run_stream(self, source_factory, da: int, db: int, key, *,
                   n_chunks: Optional[int] = None, resume_state=None,
                   on_pass_end=None, on_pass_complete=None):
        """All q+1 passes over a sequential chunk source → RCCAResult.

        This is the exact contract ``randomized_cca_iterator`` has
        always exposed — see its docstring for the resume-state and
        seekable-factory details; it is now a shell over this method.
        ``on_pass_complete(pass_idx, kind, acc, Qa, Qb)`` fires once per
        pass after its fold finishes, with the accumulator and the
        Qa/Qb payload the pass consumed (seeds on a seeded pass 0) —
        the capture point ``repro.exec.delta`` persists FitState from.
        """
        with obs.span("fit", site="stream", engine=self.engine):
            return self._run_stream(source_factory, da, db, key,
                                    n_chunks=n_chunks,
                                    resume_state=resume_state,
                                    on_pass_end=on_pass_end,
                                    on_pass_complete=on_pass_complete)

    def _run_stream(self, source_factory, da, db, key, *,
                    n_chunks=None, resume_state=None, on_pass_end=None,
                    on_pass_complete=None):
        from repro.core.rcca import power_update_Q

        cfg = self.cfg
        sanitize.reset()
        Qa, Qb = self._init_payload(key, da, db)
        upd = self._updaters(False)
        upd_seeded = self._updaters(True) if self.seeds_in_slots else None

        start_pass, start_chunk, acc_state = 0, 0, None
        if resume_state is not None:
            start_pass = int(resume_state["pass_idx"])
            start_chunk = int(resume_state["chunk_idx"])
            acc_state = resume_state["acc"]
            Qa, Qb = resume_state["Qa"], resume_state["Qb"]

        for pass_idx, kind in pass_schedule(cfg.q):
            if pass_idx < start_pass:
                continue
            sanitize.set_context(pass_idx=pass_idx, kind=kind, site="stream")
            seeded = upd_seeded is not None and pass_idx == 0
            with obs.span("pass", pass_idx=pass_idx, kind=kind,
                          site="stream"):
                acc = SegmentedAccumulator.structure(
                    self._init_fn(kind, da, db), n_chunks, self.merge_group,
                    start_chunk)
                if acc_state is not None:
                    acc.load_state(acc_state)
                    acc_state = None
                source, offset = open_source(source_factory, start_chunk)
                cb = None
                if on_pass_end is not None:
                    cb = (lambda ci, a_, _p=pass_idx, _qa=Qa, _qb=Qb:
                          on_pass_end(_p, ci, a_, _qa, _qb))
                fn = upd_seeded[kind] if seeded else upd[kind]
                run_fold(enumerate(source, start=offset), fn, acc, Qa, Qb,
                         start_chunk=start_chunk, on_chunk=cb,
                         span_attrs={"kind": kind, "engine": self.engine,
                                     "pass_idx": pass_idx},
                         cost_fn=self.cost_fn(kind, seeded))
                start_chunk = 0
                if sanitize.enabled():
                    sanitize.observe("pass_end", acc.result())
                if on_pass_complete is not None:
                    on_pass_complete(pass_idx, kind, acc, Qa, Qb)
                if kind == "power":
                    if cfg.center:  # μ corrections need the actual Ω
                        Qa, Qb = self._boundary_Q(Qa, Qb, pass_idx, da, db)
                    Qa, Qb = power_update_Q(acc.result(), Qa, Qb, cfg)

        Qa, Qb = self._boundary_Q(Qa, Qb, pass_idx, da, db)  # q = 0 finalize
        res = self._finish(acc.result(), Qa, Qb, da, db)
        if sanitize.enabled():
            res.diagnostics["sanitize"] = sanitize.snapshot()
            sanitize.dump()
        return res

    # -- device-parallel (Sharded) ---------------------------------------

    def run_mesh(self, access, key, *, mesh=None, prefetch: int = 2,
                 on_pass_complete=None):
        """All q+1 passes with merge groups folded one-per-device over
        the local mesh (the in-process ``Sharded`` topology) — bitwise
        identical to :meth:`run_stream` on the same chunks.

        ``access`` needs random chunk access (``get_chunk``, ``n``,
        ``chunk``, ``n_chunks``, ``da``, ``db``) — a
        ``ViewStoreReader`` or :class:`StackedChunks`.  Mid-pass cursor
        checkpointing is a sequential-stream feature; device-parallel
        passes restart at pass granularity.  ``prefetch`` is the gather
        read-ahead depth (see :func:`fold_groups_on_mesh`).
        ``on_pass_complete`` is the same per-pass capture hook as
        :meth:`run_stream`.
        """
        with obs.span("fit", site="mesh", engine=self.engine):
            return self._run_mesh(access, key, mesh=mesh, prefetch=prefetch,
                                  on_pass_complete=on_pass_complete)

    def _run_mesh(self, access, key, *, mesh=None, prefetch: int = 2,
                  on_pass_complete=None):
        from repro.core.rcca import (power_update_Q, seeded_update_fn,
                                     update_fn)

        topo = self.topology if isinstance(self.topology, Sharded) else Sharded()
        if topo.col_axis is not None:
            raise ValueError(
                "streaming fits need col_axis=None — feature-sharded "
                "(col_axis) execution is the resident-mode path through "
                "repro.core.rcca_dist.dist_randomized_cca")
        mesh = mesh if mesh is not None else topo.build_mesh()
        cfg = self.cfg
        sanitize.reset()
        da, db = access.da, access.db
        nc = access.n_chunks
        n_groups = -(-nc // self.merge_group)
        Qa, Qb = self._init_payload(key, da, db)

        # per-kind functions hoisted out of the pass loop: repeated
        # power passes must hit one trace of the mesh fold program, not
        # recompile it per pass (see _mesh_group_fold's memoization)
        kinds = ("power", "final")
        upd_raw = {k: update_fn(k, self.engine) for k in kinds}
        upd_jit = self._updaters(False)
        sd_raw = sd_jit = None
        if self.seeds_in_slots:
            sd_raw = {k: seeded_update_fn(k, cfg.sketch, cfg.dtype)
                      for k in kinds}
            sd_jit = self._updaters(True)
        init_fns = {k: self._init_fn(k, da, db) for k in kinds}

        for pass_idx, kind in pass_schedule(cfg.q):
            sanitize.set_context(pass_idx=pass_idx, kind=kind, site="mesh")
            seeded = sd_raw is not None and pass_idx == 0
            raw = sd_raw[kind] if seeded else upd_raw[kind]
            jit = sd_jit[kind] if seeded else upd_jit[kind]
            acc = SegmentedAccumulator(init_fns[kind], nc, self.merge_group)
            with obs.span("pass", pass_idx=pass_idx, kind=kind, site="mesh"):
                fold_groups_on_mesh(
                    access.get_chunk, range(n_groups), raw,
                    jit, init_fns[kind], Qa, Qb, mesh=mesh,
                    merge_group=self.merge_group, n_chunks=nc,
                    full_chunks=n_full_chunks(access), emit=acc.push_group,
                    prefetch=prefetch,
                    span_attrs={"kind": kind, "engine": self.engine,
                                "pass_idx": pass_idx},
                    cost_fn=self.cost_fn(kind, seeded))
                if sanitize.enabled():
                    sanitize.observe("pass_end", acc.result())
                if on_pass_complete is not None:
                    on_pass_complete(pass_idx, kind, acc, Qa, Qb)
                if kind == "power":
                    if cfg.center:  # μ corrections need the actual Ω
                        Qa, Qb = self._boundary_Q(Qa, Qb, pass_idx, da, db)
                    Qa, Qb = power_update_Q(acc.result(), Qa, Qb, cfg)

        Qa, Qb = self._boundary_Q(Qa, Qb, pass_idx, da, db)  # q = 0 finalize
        res = self._finish(acc.result(), Qa, Qb, da, db)
        if sanitize.enabled():
            res.diagnostics["sanitize"] = sanitize.snapshot()
            sanitize.dump()
        res.diagnostics["topology"] = {
            "name": "sharded", "devices": int(mesh.devices.size),
            "n_groups": n_groups, "merge_group": self.merge_group,
        }
        return res

    # -- dispatch ----------------------------------------------------------

    def run(self, access, key, **kwargs):
        """Topology dispatch over a random-access chunk source."""
        if isinstance(self.topology, Local):
            return self.run_stream(
                lambda start: access.iter_chunks(start), access.da, access.db,
                key, n_chunks=access.n_chunks, **kwargs)
        if isinstance(self.topology, Sharded):
            return self.run_mesh(access, key, **kwargs)
        raise ValueError(
            f"{type(self.topology).__name__} fits are multi-process — "
            "drive them through repro.exec.fit (it needs the store path "
            "and a cluster directory)")


# --------------------------------------------------------------------------
# the one entry point
# --------------------------------------------------------------------------


def fit(store, cfg, key, *, topology: Topology = Local(),
        engine: Optional[str] = None, merge_group: int = MERGE_GROUP_CHUNKS,
        omega: str = "materialized",
        cluster_dir: Optional[str] = None, prefetch=2,
        ckpt_dir: Optional[str] = None, resume: bool = False,
        **cluster_kwargs):
    """Fit RandomizedCCA over a view store under any topology.

    ``store`` is a ``ViewStoreReader`` or a store path/URI.  ``Local``
    runs the prefetching, cursor-checkpointed ``store.PassRunner``;
    ``Sharded`` the in-process device-parallel engine; ``Cluster`` and
    ``Hybrid`` the multi-process coordinator (``cluster_dir`` required —
    extra keyword arguments are forwarded to it).  Every topology
    returns a bitwise-identical ``RCCAResult`` on the same store.

    ``omega`` selects Ω provenance (``repro.core.rcca.OMEGA_MODES``):
    ``"seeded"`` runs the first data pass from an 8-byte seed — the
    kernels engine generates Ω tiles in-kernel and cluster rounds ship
    the seed instead of the ``(d, k̃)`` bases.
    """
    from repro.core.rcca import DEFAULT_ENGINE
    from repro.store import PassRunner, ViewStoreReader

    topo = as_topology(topology)
    reader = store if isinstance(store, ViewStoreReader) else ViewStoreReader(store)
    engine = DEFAULT_ENGINE if engine is None else engine

    if isinstance(topo, Local):
        runner = PassRunner(reader, cfg, engine=engine,
                            prefetch=prefetch, ckpt_dir=ckpt_dir,
                            merge_group=merge_group, omega=omega)
        return runner.fit(key, resume=resume)

    if isinstance(topo, Sharded):
        eng = PassEngine(cfg, engine=engine, topology=topo,
                         merge_group=merge_group, omega=omega)
        return eng.run_mesh(reader, key,
                            prefetch=prefetch if isinstance(prefetch, int)
                            else 2)

    # Cluster / Hybrid
    from repro.cluster import ClusterCoordinator

    if cluster_dir is None:
        raise ValueError(
            f"{topo.name} topology needs cluster_dir= (the shared "
            "rounds/partials/heartbeats directory)")
    coord = ClusterCoordinator(
        reader, cfg, cluster_dir, n_workers=topo.n_workers,
        devices_per_worker=topo.devices_per_worker,
        engine=engine, merge_group=merge_group, omega=omega,
        prefetch=prefetch if isinstance(prefetch, int) else 2,
        **cluster_kwargs)
    return coord.fit(key)
