"""Canonical pass accumulation: chunk → merge group → pairwise tree.

This is the bit-reproducibility backbone every execution topology
shares (it moved here from ``repro.core.rcca`` when the pass loop was
unified under :mod:`repro.exec`): chunks left-fold into fixed-size
MERGE GROUPS; group sums reduce through a fixed PAIRWISE TREE whose
shape is a function of the group INDEX alone.  Any assignment of whole
merge groups to workers or devices, merged in group order, therefore
reproduces the single-process reduction bitwise — which is the whole
correctness argument of the :class:`~repro.exec.topology.Cluster`,
:class:`~repro.exec.topology.Sharded` and
:class:`~repro.exec.topology.Hybrid` topologies.

Everything here is generic over the statistics pytree: a "stats" value
is any pytree of arrays whose merge is elementwise addition (the exact
map/reduce combiner of a sum-of-per-row-statistics pass — PowerStats
and FinalStats in ``repro.core.rcca`` are the two instances).
"""

from __future__ import annotations

import operator
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional

import jax

from repro.analysis import sanitize

#: A "stats" value is any pytree of arrays whose merge is elementwise
#: addition; generic code here treats it opaquely.
Stats = Any

#: Chunks per merge group — the granularity of the canonical reduction
#: and therefore of cluster partials and device-parallel group folds.
#: A store-pass constant, NOT a function of the worker/device count:
#: bit-reproducibility across topologies holds exactly because the
#: grouping never moves.
MERGE_GROUP_CHUNKS = 8


def merge_stats(x: Stats, y: Stats) -> Stats:
    """Combine two accumulators over disjoint row sets: elementwise
    addition on every pytree leaf.  Exact as algebra (every field is a
    plain sum over rows); the fp ADD still rounds — which is why the
    reduction ORDER below is canonical."""
    return jax.tree_util.tree_map(operator.add, x, y)


class PairwiseStack:
    """Fixed-structure pairwise reduction over a sequence of partials.

    The binary-counter scheme of pairwise summation: pushing partial
    ``m`` merges stack tops of equal weight, so after ``m`` pushes the
    stack mirrors the binary digits of ``m`` and the reduction tree is a
    function of the partial INDEX alone — not of who computed each
    partial or when it arrived.  This is what makes the cluster merge
    bit-reproducible: any assignment of whole merge groups to workers,
    merged in group order, reproduces the single-process reduction
    bitwise.  Live memory is O(log #groups) stats pytrees.
    """

    def __init__(self, stack: Optional[Iterable[Stats]] = None,
                 counts: Optional[Iterable[int]] = None):
        self.stack: List[Stats] = list(stack) if stack is not None else []
        self.counts: List[int] = list(counts) if counts is not None else []

    @staticmethod
    def depth_after(m: int) -> int:
        """Stack depth after ``m`` pushes (= popcount(m)) — lets a
        checkpoint restore rebuild the like-tree from a chunk index."""
        return bin(m).count("1")

    def push(self, s: Stats) -> None:
        self.push_span(s, 1)

    def push_span(self, s: Stats, count: int) -> None:
        """Push a pre-merged ALIGNED DYADIC span: ``s`` is the canonical
        pairwise sum of ``count`` consecutive leaves where ``count`` is a
        power of two and the span starts at a multiple of ``count``.
        Such a span is exactly one subtree of the binary-counter
        reduction, so pushing it as a single weight-``count`` entry
        reproduces ``count`` individual pushes bitwise — this is what
        lets cluster workers pre-merge their own groups before
        publishing (combiner-on-the-way-out) without moving the tree.
        Alignment is the caller's contract (see
        ``SegmentedAccumulator.push_group_span``)."""
        if count < 1 or count & (count - 1):
            raise ValueError(f"span weight must be a power of two, got {count}")
        self.stack.append(s)
        self.counts.append(count)
        while len(self.counts) >= 2 and self.counts[-1] == self.counts[-2]:
            hi = self.stack.pop()
            self.stack[-1] = merge_stats(self.stack[-1], hi)
            self.counts[-1] += self.counts.pop()

    def result(self) -> Optional[Stats]:
        """Fold the leftover unequal-weight entries newest→oldest (the
        deterministic completion of the tree)."""
        if not self.stack:
            return None
        res = self.stack[-1]
        for s in reversed(self.stack[:-1]):
            res = merge_stats(s, res)
        return res


class SegmentedAccumulator:
    """Canonical accumulation of one data pass: chunks left-fold into
    the current ``group`` accumulator; each completed group (every
    ``group_chunks`` chunks, plus the ragged tail) either enters a
    :class:`PairwiseStack` or — when a ``sink`` is given — is handed to
    the sink keyed by its GLOBAL group index (the cluster worker's
    publish path).  Single-process drivers, cluster workers, the
    device-parallel group fold and the coordinator merge all share this
    structure, which is the whole bit-reproducibility argument of the
    execution topologies.
    """

    def __init__(self, init_fn: Callable[[], Stats], n_chunks: Optional[int],
                 group_chunks: int = MERGE_GROUP_CHUNKS,
                 sink: Optional[Callable[[int, Stats], None]] = None):
        if group_chunks <= 0:
            raise ValueError("merge group size must be positive")
        self.init_fn = init_fn
        self.n_chunks = None if n_chunks is None else int(n_chunks)
        self.group_chunks = int(group_chunks)
        self.sink = sink
        self.current = init_fn()
        self._tree = PairwiseStack()
        self.groups_done = 0
        self._in_group = 0  # chunks folded into ``current`` so far
        self._last_chunk = -1  # global index of the last folded chunk

    # -- geometry ---------------------------------------------------------

    @property
    def n_groups(self) -> int:
        return -(-self.n_chunks // self.group_chunks)

    @staticmethod
    def groups_completed(next_chunk: int, n_chunks: Optional[int],
                         group_chunks: int) -> int:
        """Merge groups fully folded once chunks [0, next_chunk) are in
        — with a known length, the ragged tail group completes with the
        last chunk."""
        if n_chunks is not None and next_chunk >= n_chunks:
            return -(-n_chunks // group_chunks)
        return next_chunk // group_chunks

    # -- folding ----------------------------------------------------------

    def update(self, chunk_idx: int, update_fn: Callable[..., Stats],
               a: Any, b: Any, Qa: Any, Qb: Any) -> None:
        """Fold one chunk, closing the merge group at its boundary."""
        self.current = update_fn(self.current, a, b, Qa, Qb)
        self.end_chunk(chunk_idx)

    def end_chunk(self, chunk_idx: int) -> None:
        self._in_group += 1
        self._last_chunk = chunk_idx
        nxt = chunk_idx + 1
        if nxt % self.group_chunks == 0 or nxt == self.n_chunks:
            self._push_current()

    def flush_tail(self) -> None:
        """Close a ragged tail group at end of stream — for sources of
        unknown length (a known ``n_chunks`` closes it in end_chunk)."""
        if self._in_group:
            self._push_current()

    def _push_current(self) -> None:
        if sanitize.enabled():  # merge-group boundary: the contract's unit
            sanitize.observe(
                f"group:{self._last_chunk // self.group_chunks}",
                self.current)
        if self.sink is not None:
            self.sink(self._last_chunk // self.group_chunks, self.current)
        else:
            self._tree.push(self.current)
        self.current = self.init_fn()
        self.groups_done += 1
        self._in_group = 0

    def push_group(self, group_idx: int, stats: Stats) -> None:
        """Feed a pre-computed merge-group sum (a cluster partial or a
        device-folded group) — MUST be called in ascending group order
        with no gaps."""
        self.push_group_span(group_idx, stats, 1)

    def push_group_span(self, group_idx: int, stats: Stats,
                        span: int) -> None:
        """Feed a pre-merged span of ``span`` consecutive merge groups
        starting at ``group_idx`` (a worker-combined cluster partial).
        ``span`` must be a power of two and the span aligned
        (``group_idx % span == 0``) so it is exactly one subtree of the
        canonical pairwise reduction — then the merge is bitwise
        identical to pushing the ``span`` groups individually.  Spans
        must still arrive in ascending group order with no gaps."""
        if group_idx != self.groups_done:
            raise ValueError(
                f"merge groups must arrive in order: got {group_idx}, "
                f"expected {self.groups_done}")
        if span < 1 or span & (span - 1):
            raise ValueError(f"span must be a power of two, got {span}")
        if group_idx % span:
            raise ValueError(
                f"span of {span} groups at {group_idx} is unaligned — "
                "not a subtree of the canonical reduction")
        if self.n_chunks is not None and group_idx + span > self.n_groups:
            raise ValueError(
                f"span [{group_idx}, {group_idx + span}) overruns the "
                f"{self.n_groups}-group corpus")
        if sanitize.enabled():
            key = (f"group:{group_idx}" if span == 1
                   else f"span:{group_idx}x{span}")
            sanitize.observe(key, stats)
        self._tree.push_span(stats, span)
        self.groups_done += span

    def result(self) -> Stats:
        r = self._tree.result()
        return self.init_fn() if r is None else r

    # -- checkpointing ----------------------------------------------------

    def state(self) -> Dict[str, Any]:
        """Checkpointable pytree snapshot (jax arrays are immutable, so
        no copies are needed — only the containers are frozen)."""
        return {"current": self.current, "stack": tuple(self._tree.stack)}

    def load_state(self, state: Mapping[str, Any]) -> None:
        self.current = state["current"]
        self._tree.stack = list(state["stack"])
        # counts are implied by groups_done's binary digits (descending)
        m = self.groups_done
        self._tree.counts = [1 << i for i in reversed(range(m.bit_length()))
                             if m >> i & 1]
        if len(self._tree.counts) != len(self._tree.stack):
            raise ValueError(
                f"accumulator state has {len(self._tree.stack)} stack "
                f"entries; {self.groups_done} completed groups imply "
                f"{len(self._tree.counts)}")

    @classmethod
    def structure(cls, init_fn: Callable[[], Stats], n_chunks: Optional[int], group_chunks: int,
                  next_chunk: int) -> "SegmentedAccumulator":
        """Zero-filled accumulator with the stack shape implied by a
        resume position — the like-tree for repro.ckpt restores."""
        acc = cls(init_fn, n_chunks, group_chunks)
        acc.groups_done = cls.groups_completed(next_chunk, n_chunks, group_chunks)
        acc._in_group = max(0, next_chunk - acc.groups_done * group_chunks)
        acc._last_chunk = next_chunk - 1
        depth = PairwiseStack.depth_after(acc.groups_done)
        acc.load_state({"current": init_fn(),
                        "stack": tuple(init_fn() for _ in range(depth))})
        return acc


class SpanCombiner:
    """Combiner-on-the-way-out: pre-merge runs of consecutive merge
    groups into aligned dyadic spans before they leave a worker.

    Sits between a :class:`SegmentedAccumulator` sink and the publish
    path: ``emit(g, stats)`` buffers consecutive groups of a run
    through a local :class:`PairwiseStack`; once ``span`` groups are in
    (or the run breaks — a jump to the worker's next run, or end of
    stream via :meth:`flush`), the buffered groups leave as
    ``sink(g0, count, merged)`` span partials.  Because the local stack
    is the same binary-counter reduction the coordinator would have
    run, each emitted entry is exactly one subtree of the canonical
    tree: an aligned run of 5 groups leaves as spans of 4 + 1, bitwise
    identical to 5 individual partials merged downstream.  Groups that
    start unaligned (a repair worker's arbitrary group list) pass
    through as span-1 partials — correctness never depends on the run
    shape, only fan-in does.
    """

    def __init__(self, span: int, sink: Callable[[int, int, Stats], None]):
        if span < 1 or span & (span - 1):
            raise ValueError(f"combine span must be a power of two, got {span}")
        self.span = int(span)
        self.sink = sink
        self._g0: Optional[int] = None  # run start (aligned)
        self._count = 0
        self._tree = PairwiseStack()

    def emit(self, g: int, stats: Stats) -> None:
        if self._g0 is not None and g != self._g0 + self._count:
            self.flush()  # run broke: the worker jumped to its next run
        if self._g0 is None:
            if self.span == 1 or g % self.span:
                self.sink(g, 1, stats)  # unaligned start: no combining
                return
            self._g0 = g
        self._tree.push(stats)
        self._count += 1
        if self._count == self.span:
            self.flush()

    def flush(self) -> None:
        """Publish whatever is buffered.  The local stack entries after
        ``count`` pushes mirror count's binary digits, and each is an
        aligned dyadic block (the run starts at a multiple of ``span``),
        so they emit directly as span partials."""
        if self._g0 is None:
            return
        g = self._g0
        for entry, weight in zip(self._tree.stack, self._tree.counts):
            self.sink(g, weight, entry)
            g += weight
        self._g0 = None
        self._count = 0
        self._tree = PairwiseStack()


def reduce_group_partials(partials: Mapping[int, Stats],
                          init_fn: Callable[[], Stats], n_chunks: int,
                          group_chunks: int = MERGE_GROUP_CHUNKS) -> Stats:
    """Deterministic fixed-order tree-reduce of per-group partials:
    ``partials`` maps group index → stats and must cover every group.
    Reproduces the single-process segmented accumulation bitwise
    regardless of which worker computed which group or in what order
    they completed.  (The cluster coordinator streams the same tree
    from disk instead — see ``ClusterCoordinator`` — so only O(log G)
    partials are ever resident there; this eager form remains for
    in-memory partial sets.)"""
    acc = SegmentedAccumulator(init_fn, n_chunks, group_chunks)
    for g in range(acc.n_groups):
        if g not in partials:
            raise ValueError(f"merge group {g} missing from partial set")
        acc.push_group(g, partials[g])
    return acc.result()
