"""Execution topologies: WHERE the canonical pass structure is cut.

Every topology runs the same algorithm over the same canonical
accumulation structure (chunk → merge group → pairwise tree, see
:mod:`repro.exec.accumulate`); they differ only in which physical
resources fold which merge groups:

- :class:`Local` — one process, one device: chunks fold sequentially,
  groups push straight into the pairwise tree.
- :class:`Sharded` — one process, shard_map over the local device
  mesh: whole merge groups are folded data-parallel (one group per
  device per step); group sums still enter the SAME tree in the SAME
  order, so the result is bitwise that of :class:`Local`.  A non-None
  ``col_axis`` additionally shards the FEATURE axis for resident-mode
  fits (the ``repro.core.rcca_dist`` path — feature psums reassociate
  the row sums, so that mode trades bitwise reproducibility for
  per-device HBM headroom).
- :class:`Cluster` — one process per worker, each folding whole merge
  groups sequentially and publishing per-group partials; the
  coordinator streams the tree from disk.
- :class:`Hybrid` — the ROADMAP's row-parallelism × device-parallelism
  marriage: cluster workers that each run their merge groups through
  shard_map over their local device mesh and publish already-reduced
  group partials in the same versioned-partial format.  The
  coordinator's fixed tree merge — and therefore the final result —
  is bit-identical to single-process streaming for any
  (workers × devices) layout.

Topologies are frozen declarative values: they carry the layout, not
operational knobs (timeouts, checkpoint periods stay with the drivers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class Local:
    """Single-process, single-device sequential execution."""

    name: str = dataclasses.field(default="local", init=False, repr=False)


@dataclasses.dataclass(frozen=True)
class Sharded:
    """Single-process execution over the local device mesh.

    ``mesh``:     a ``jax.sharding.Mesh`` whose FIRST axis is the
                  group-parallel axis; ``None`` builds a 1-D mesh over
                  all visible devices at fit time (use
                  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
                  to fake N devices on CPU).
    ``col_axis``: optional mesh axis name sharding the FEATURE
                  dimension — only meaningful for resident-mode fits
                  through ``repro.core.rcca_dist`` (streaming fits
                  require ``col_axis=None``; feature psums break the
                  bitwise contract).
    """

    mesh: Optional[object] = None  # jax.sharding.Mesh; untyped to stay importable pre-jax
    col_axis: Optional[str] = None
    name: str = dataclasses.field(default="sharded", init=False, repr=False)

    def build_mesh(self) -> object:
        """The group-parallel mesh: the given one, or all local devices
        on a single ``"dev"`` axis."""
        if self.mesh is not None:
            return self.mesh
        import jax
        from jax.sharding import Mesh

        devs = jax.devices()
        return Mesh(np.array(devs), ("dev",))

    @property
    def group_axis(self) -> str:
        mesh = self.mesh
        if mesh is None:
            return "dev"
        return mesh.axis_names[0]


@dataclasses.dataclass(frozen=True)
class Cluster:
    """Multi-process execution: ``n_workers`` map tasks per pass, each
    a single-device process (``python -m repro.cluster.worker``)."""

    n_workers: int = 2
    name: str = dataclasses.field(default="cluster", init=False, repr=False)

    def __post_init__(self) -> None:
        if self.n_workers < 1:
            raise ValueError("need at least one worker")

    @property
    def devices_per_worker(self) -> int:
        return 1


@dataclasses.dataclass(frozen=True)
class Hybrid:
    """Row parallelism across worker processes × group parallelism
    across each worker's local device mesh.  Workers are spawned with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=
    devices_per_worker`` on hosts without real accelerators, so the
    layout is exercisable anywhere."""

    n_workers: int = 2
    devices_per_worker: int = 4
    name: str = dataclasses.field(default="hybrid", init=False, repr=False)

    def __post_init__(self) -> None:
        if self.n_workers < 1:
            raise ValueError("need at least one worker")
        if self.devices_per_worker < 1:
            raise ValueError("need at least one device per worker")


Topology = Union[Local, Sharded, Cluster, Hybrid]


def as_topology(spec: Union[str, Topology], **kwargs: object) -> Topology:
    """Coerce a CLI-style spec (``"local"``, ``"sharded"``,
    ``"cluster"``, ``"hybrid"``) or an existing topology value."""
    if isinstance(spec, (Local, Sharded, Cluster, Hybrid)):
        return spec
    table = {"local": Local, "sharded": Sharded, "cluster": Cluster,
             "hybrid": Hybrid}
    if spec not in table:
        raise ValueError(
            f"unknown topology {spec!r}; expected one of {sorted(table)}")
    return table[spec](**kwargs)
