"""Topology-aware pass execution — the one engine behind every driver.

The paper's two-pass design is a sum of per-row sufficient statistics,
so one canonical accumulation structure (chunk → merge group → pairwise
tree, :mod:`repro.exec.accumulate`) serves every way of cutting the
work across hardware (:mod:`repro.exec.topology`):

======== ====================================================
Local     one process, one device (sequential fold)
Sharded   one process, merge groups one-per-device (shard_map)
Cluster   worker processes, each folding whole merge groups
Hybrid    worker processes × per-worker device meshes
======== ====================================================

All four produce bitwise-identical results on the same store —
``repro.exec.fit(store, cfg, key, topology=...)`` is the single entry
point; :class:`PassEngine` is the in-process core the drivers and the
cluster workers are shells over.
"""

from .accumulate import (
    MERGE_GROUP_CHUNKS,
    PairwiseStack,
    SegmentedAccumulator,
    SpanCombiner,
    merge_stats,
    reduce_group_partials,
)
from .delta import FitState, delta_refit, fit_with_state
from .engine import (
    PassEngine,
    StackedChunks,
    fit,
    fold_groups_on_mesh,
    n_full_chunks,
    open_source,
    pass_schedule,
    run_fold,
)
from .topology import Cluster, Hybrid, Local, Sharded, Topology, as_topology

__all__ = [
    "Cluster",
    "FitState",
    "Hybrid",
    "Local",
    "MERGE_GROUP_CHUNKS",
    "PairwiseStack",
    "PassEngine",
    "SegmentedAccumulator",
    "Sharded",
    "SpanCombiner",
    "StackedChunks",
    "Topology",
    "as_topology",
    "delta_refit",
    "fit",
    "fit_with_state",
    "fold_groups_on_mesh",
    "merge_stats",
    "n_full_chunks",
    "open_source",
    "pass_schedule",
    "reduce_group_partials",
    "run_fold",
]
