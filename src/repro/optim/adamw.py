"""AdamW + gradient clipping + cosine LR schedule.

Pytree-native: optimizer state mirrors the param tree, so it shards
with the same PartitionSpecs as the params (optimizer sharding comes
for free under pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # bf16 moments halve optimizer memory — required to fit trillion-
    # param MoE on 512×16GB even fully sharded (see DESIGN.md §5)
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment  (pytree like params)
    nu: Any  # second moment


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)
    mu = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(mdt),
        state.mu, grads,
    )
    nu = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g).astype(mdt),
        state.nu, grads,
    )

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / b1c
        vhat = v.astype(jnp.float32) / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay only on matrices (dim ≥ 2)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics
