"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array, transpose_lhs: bool = False) -> jax.Array:
    x32, y32 = x.astype(jnp.float32), y.astype(jnp.float32)
    return (x32.T if transpose_lhs else x32) @ y32


def projgram_ref(x: jax.Array, q: jax.Array) -> tuple[jax.Array, jax.Array]:
    p = x.astype(jnp.float32) @ q.astype(jnp.float32)
    return p, p.T @ p


def power_pass_ref(a, b, Qa, Qb):
    """One chunk of the range-finder pass: (ΔYa, ΔYb)."""
    f32 = jnp.float32
    pb = b.astype(f32) @ Qb.astype(f32)
    pa = a.astype(f32) @ Qa.astype(f32)
    return a.astype(f32).T @ pb, b.astype(f32).T @ pa


def final_pass_ref(a, b, Qa, Qb):
    """One chunk of the final pass: (ΔCa, ΔCb, ΔF)."""
    f32 = jnp.float32
    pa = a.astype(f32) @ Qa.astype(f32)
    pb = b.astype(f32) @ Qb.astype(f32)
    return pa.T @ pa, pb.T @ pb, pa.T @ pb
