"""Fused project+accumulate Pallas kernel for the range-finder pass.

The dominant data pass of Algorithm 1 (lines 7-8) updates, per row
chunk, ``ΔYa = Aᵀ(B Qb)`` and ``ΔYb = Bᵀ(A Qa)``.  Issued as separate
matmuls that is four ``pallas_call``s per chunk, with each view read
from HBM twice and the projected activations P making an HBM
round-trip.  This kernel fuses one view's update — the projection tile
``P = B Qb`` stays in a VMEM scratch accumulator and ``ΔYa = AᵀP`` is
emitted directly — the same fusion :mod:`repro.kernels.projgram`
applies to the final pass.  A full ``power_pass_chunk`` is then two
``pallas_call``s, each reading A and B exactly once.

Column-bucketed grid (da_t, n_t, db_t), output buckets outermost and
the contraction (db) innermost:

- the ΔY output columns (the da rows of ΔY) are split into buckets of
  ``bda`` with ``bda·k̃p ≤ VMEM_BLOCK_ELEMS`` (the shared per-buffer
  budget from :mod:`repro.kernels.matmul`);
- per bucket, per row tile, ``P = Σ_db B_tile Qb_tile`` accumulates in
  VMEM scratch; on the last db step ``ΔY_bucket += A_bucketᵀ P``;
- each bucket's (bda, k̃p) block has an index map constant in (n_t,
  db_t), so it stays VMEM-resident across all row steps of its bucket
  and is written back to HBM exactly once.

When ``dap·k̃p`` fits a single block the bucket covers all of ΔY and
the schedule is identical to the old 2-axis grid — small shapes lose
nothing.  Arbitrarily large ``da`` (Europarl's d = 2^19) now runs
fused, and Halko et al. 2011 guarantee blockwise accumulation is
exact.

TWO SCHEDULES, ONE COST MODEL (be honest about it).  The bucketed
*recompute* schedule above re-reads B and Q and re-accumulates the
projection ``P = B Qb`` once per bucket, so a chunk costs
``n_buckets·proj + acc`` FLOPs versus the unfused pair's
``proj + acc`` (which instead pays the P HBM round-trip).  That wins
when ``n_buckets`` is small and/or the projection is cheap relative to
accumulation (db ≪ da); at Europarl's da = db with ~2k buckets the
recompute dominates.  The *staged* schedule
(:func:`power_project_accumulate` with ``schedule="staged"``) removes
the recompute: phase 1 (``proj_stage`` kernel, grid (n_t, db_t))
computes each row tile's ``P = B Qb`` exactly once, accumulating f32
directly in the (bn, k̃p) output block (index map constant in the
inner contraction axis, so the block stays VMEM-resident and hits HBM
once); phase 2 (``powerpass_sweep`` kernel, grid (da_t, n_t)) sweeps
the ΔY buckets reloading the staged P tiles instead of recomputing
them.  Cost: ``proj + acc`` FLOPs — bucket-count-independent — plus
one ``n×k̃`` f32 HBM round-trip and ``n_buckets`` re-reads of P.  The
two schedules issue bitwise-identical f32 dot sequences (P is staged
in full f32 precision), so the choice is pure performance: the
crossover rule (:func:`choose_powerpass_schedule`, built on
:func:`repro.kernels.matmul.pick_schedule`) compares the modelled
``max(flops/roofline, bytes)`` of each schedule per shape, and an
autotuned ``op="powerpass-staged"`` cache entry (measured by
``benchmarks/sweep_blocks.py``) overrides the model.  The unfused
matmul-pair fallback remains only for genuinely degenerate shapes —
``k̃p > VMEM_BLOCK_ELEMS/128`` (= 8192), where even a 128-row block of
ΔY or P blows the budget and fusion is pointless (k̃ ~ d).

Block caps resolve from the autotune cache (``op="powerpass"``, keyed
by the padded (n, db, k̃) problem plus the bucketed dap) — see
:func:`repro.kernels.autotune.autotune_powerpass` and
``benchmarks/sweep_blocks.py``.  The staged schedule resolves blocks
through the *same* lookup, so both schedules tile identically and
parity is structural.

Ω-RESIDENCY ACCOUNTING (the ``omega="seeded"`` variant): with a
materialized sketch the power pass holds Ω = ``d·k̃`` elements resident
in HBM for the whole fit (Europarl: 2^19 × 2060 ≈ 4.3 GB f32, or
2.2 GB bf16) and every chunk's kernel launch streams ``bdb·k̃p`` Q
tiles from HBM — ``d·k̃·bytes`` of Ω reads per chunk per bucket, on
top of the A/B reads.  :func:`power_project_accumulate_seeded` instead
regenerates each Q tile inside the kernel from a 64-bit seed
(:mod:`repro.kernels.rand`): Ω's HBM residency drops from ``d·k̃·bytes``
to 8 bytes and its read traffic to zero, at the cost of ~40 uint32
ALU ops per generated element (Threefry-2x32 + Box–Muller) — VPU work
that overlaps the MXU dot on real hardware.  Per power-pass chunk the
HBM bytes are then ``n·(da+db)·bytes`` (the data reads) instead of
``n·(da+db)·bytes + n_buckets·d·k̃·bytes`` with materialized Ω tiles,
and cluster rounds ship the 8-byte seed instead of the 4 GB array.
Under the staged schedule the same applies per *phase*: the seeded
stage kernel generates each Ω tile exactly once (phase 1 is the only
consumer — the sweep touches no Ω at all), which is the seeded analogue
of removing the materialized-Ω bucket re-reads.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune, rand
from .compat import tpu_compiler_params
from .matmul import (_pad2, _pick_block, _round_up, pallas_matmul,
                     pick_schedule, vmem_row_cap)
from .plan import BlockDef, KernelPlan, ScalarDef, ScratchDef, launch_args


def _powerpass_kernel(a_ref, b_ref, q_ref, y_ref, p_acc, *, n_k_steps: int):
    """y_bucket += a_bucketᵀ(b q); grid (da_t, n_t, db_t), db innermost."""
    n_step = pl.program_id(1)
    k_step = pl.program_id(2)

    @pl.when(jnp.logical_and(n_step == 0, k_step == 0))
    def _init_y():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(k_step == 0)
    def _init_p():
        p_acc[...] = jnp.zeros_like(p_acc)

    p_acc[...] += jax.lax.dot_general(
        b_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k_steps - 1)
    def _accumulate():
        y_ref[...] += jax.lax.dot_general(  # aᵀ p without materializing aᵀ
            a_ref[...], p_acc[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


def resolve_blocks(
    np_: int, dap: int, dbp: int, ktp: int,
    block_n: int, block_db: int, block_da: int,
) -> tuple[int, int, int] | None:
    """Effective (bn, bdb, bda) for the bucketed grid, or ``None`` when
    the shape is degenerate (k̃p > 8192: no 128-row block fits VMEM).

    Every block obeys the shared budget: bda·k̃p (ΔY bucket), bn·k̃p
    (P scratch), bn·bda (A tile) and bdb·k̃p (Q tile) all stay within
    ``VMEM_BLOCK_ELEMS``.  A bucket covering all of dap is preferred
    when it fits, reproducing the unbucketed single-block schedule.
    """
    row_cap = vmem_row_cap(ktp)
    if row_cap < 128:
        return None
    cap_da = min(block_da, row_cap)
    bda = dap if dap <= cap_da else _pick_block(dap, cap_da)
    bdb = _pick_block(dbp, min(block_db, row_cap))
    bn = _pick_block(np_, min(block_n, row_cap, vmem_row_cap(bda), vmem_row_cap(bdb)))
    return bn, bdb, bda


def plan_powerpass(n: int, da: int, db: int, kt: int, dtype, *,
                   block_n: int | None = None, block_db: int | None = None,
                   block_da: int | None = None) -> KernelPlan | None:
    """Launch plan for the fused project+accumulate kernel, or ``None``
    for the degenerate unfused-fallback shapes (k̃p > 8192).  Resolves
    blocks exactly as the wrapper does (autotune cache, then the shared
    VMEM budget) — the static checker consumes the same plan."""
    dap = _round_up(da, 128)
    ktp = _round_up(kt, 128)
    np_, dbp = _round_up(n, 128), _round_up(db, 128)
    if block_n is None or block_db is None or block_da is None:
        tuned = autotune.lookup("powerpass", np_, dbp, ktp, dtype, extra=dap)
        block_n = tuned[0] if block_n is None else block_n
        block_db = tuned[1] if block_db is None else block_db
        block_da = tuned[2] if block_da is None else block_da
    blocks = resolve_blocks(np_, dap, dbp, ktp, block_n, block_db, block_da)
    if blocks is None:
        return None
    bn, bdb, bda = blocks
    in_dt = str(jnp.dtype(dtype))
    return KernelPlan(
        name="powerpass",
        grid=(dap // bda, np_ // bn, dbp // bdb),
        in_specs=(
            BlockDef((bn, bda), lambda j, i, k: (i, j), (np_, dap), in_dt),
            BlockDef((bn, bdb), lambda j, i, k: (i, k), (np_, dbp), in_dt),
            BlockDef((bdb, ktp), lambda j, i, k: (k, 0), (dbp, ktp), in_dt),
        ),
        out_specs=(
            BlockDef((bda, ktp), lambda j, i, k: (j, 0), (dap, ktp),
                     "float32"),
        ),
        scratch=(ScratchDef((bn, ktp), "float32"),),
        out_shape=((da, kt),),
        accum_outputs=(0,),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_db", "block_da", "schedule",
                     "interpret"),
)
def power_project_accumulate(
    a: jax.Array,
    b: jax.Array,
    q: jax.Array,
    *,
    block_n: int | None = None,
    block_db: int | None = None,
    block_da: int | None = None,
    schedule: str | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Return ΔY = aᵀ (b @ q) with a and b each read from HBM once.

    a: (n, da), b: (n, db), q: (db, k̃) → (da, k̃) in f32.

    ``block_da`` caps the output-column bucket (rows of ΔY resident in
    VMEM at once); ``None`` caps resolve from the autotune cache
    (``op="powerpass"``) and then from the shared VMEM budget.

    ``schedule`` picks ``"recompute"`` (P re-accumulated per bucket) or
    ``"staged"`` (P staged through HBM once, buckets reload it); the
    default ``None`` resolves per shape via
    :func:`choose_powerpass_schedule`.  Both schedules are bitwise
    equal — P is carried in full f32 precision either way.
    """
    n, da = a.shape
    n2, db = b.shape
    db2, kt = q.shape
    assert n == n2, f"row mismatch {n} vs {n2}"
    assert db == db2, f"contraction mismatch {db} vs {db2}"

    plan = plan_powerpass(n, da, db, kt, a.dtype, block_n=block_n,
                          block_db=block_db, block_da=block_da)
    if plan is None:
        # k̃p > 8192: even a 128-row block blows VMEM — unfused pair
        p = pallas_matmul(b, q, out_dtype=jnp.float32, interpret=interpret)
        return pallas_matmul(a, p, transpose_lhs=True, out_dtype=jnp.float32,
                             interpret=interpret)
    if schedule is None:
        schedule = choose_powerpass_schedule(
            n, da, db, kt, a.dtype, block_n=block_n, block_db=block_db,
            block_da=block_da)
    if schedule == "staged":
        plans = plan_powerpass_staged(n, da, db, kt, a.dtype,
                                      block_n=block_n, block_db=block_db,
                                      block_da=block_da)
        if plans is not None:
            stage, sweep = plans
            ap = _pad2(a, *sweep.in_specs[0].padded)
            bp = _pad2(b, *stage.in_specs[0].padded)
            qp = _pad2(q, *stage.in_specs[1].padded)
            out = _staged_call(ap, bp, qp, stage, sweep, interpret)
            return out[:da, :kt]
    ap = _pad2(a, *plan.in_specs[0].padded)
    bp = _pad2(b, *plan.in_specs[1].padded)
    qp = _pad2(q, *plan.in_specs[2].padded)

    out = pl.pallas_call(
        functools.partial(_powerpass_kernel, n_k_steps=plan.grid[2]),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(ap, bp, qp)
    return out[:da, :kt]


def _powerpass_seeded_kernel(seed_ref, a_ref, b_ref, y_ref, p_acc, *,
                             n_k_steps: int, bdb: int, ktp: int,
                             db: int, kt: int, q_dtype):
    """y_bucket += a_bucketᵀ(b Ω_tile(seed)); Ω never touches HBM.

    Identical schedule to :func:`_powerpass_kernel`; the (bdb, k̃p) Q
    tile is regenerated from the SMEM seed at global row offset
    ``k_step·bdb`` instead of being streamed from HBM.  The tile is
    generated in f32, masked to zero outside the logical (db, k̃)
    bounds, and cast once to the data dtype — bitwise identical to a
    zero-padded materialized ``rand.dense_omega`` tile.
    """
    n_step = pl.program_id(1)
    k_step = pl.program_id(2)

    @pl.when(jnp.logical_and(n_step == 0, k_step == 0))
    def _init_y():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(k_step == 0)
    def _init_p():
        p_acc[...] = jnp.zeros_like(p_acc)

    q_tile = rand.normal_tile(
        seed_ref[0], seed_ref[1],
        (k_step * bdb).astype(rand.U32), rand.U32(0),
        (bdb, ktp), row_limit=db, col_limit=kt,
    ).astype(q_dtype)
    p_acc[...] += jax.lax.dot_general(
        b_ref[...], q_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k_steps - 1)
    def _accumulate():
        y_ref[...] += jax.lax.dot_general(
            a_ref[...], p_acc[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


def plan_powerpass_seeded(n: int, da: int, db: int, kt: int, dtype, *,
                          block_n: int | None = None,
                          block_db: int | None = None,
                          block_da: int | None = None) -> KernelPlan | None:
    """Launch plan for the seeded fused kernel: the materialized plan's
    geometry with the Q operand replaced by a (2,)-uint32 SMEM seed
    scalar — Ω has no HBM block, which is the point."""
    base = plan_powerpass(n, da, db, kt, dtype, block_n=block_n,
                          block_db=block_db, block_da=block_da)
    if base is None:
        return None
    return dataclasses.replace(
        base,
        name="powerpass_seeded",
        in_specs=base.in_specs[:2],
        scalars=(ScalarDef((2,), "uint32"),),
    )


@functools.partial(
    jax.jit,
    static_argnames=("kt", "q_dtype", "block_n", "block_db", "block_da",
                     "schedule", "interpret"),
)
def power_project_accumulate_seeded(
    a: jax.Array,
    b: jax.Array,
    seed: jax.Array,
    *,
    kt: int,
    q_dtype=None,
    block_n: int | None = None,
    block_db: int | None = None,
    block_da: int | None = None,
    schedule: str | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Return ΔY = aᵀ (b @ Ω(seed)) with Ω generated inside the kernel.

    a: (n, da), b: (n, db), seed: (2,) uint32 → (da, k̃) in f32.
    Bitwise identical to ``power_project_accumulate(a, b, Q)`` where
    ``Q = rand.dense_omega(seed, db, kt, q_dtype)`` — the materialized
    oracle — because the in-kernel tiles are the same counter-PRNG
    values cast the same way.  Only the degenerate unfused fallback
    (k̃p > 8192) materializes Ω transiently.

    ``schedule`` as in :func:`power_project_accumulate`; under
    ``"staged"`` each Ω tile is generated exactly once, in phase 1.
    """
    n, da = a.shape
    n2, db = b.shape
    assert n == n2, f"row mismatch {n} vs {n2}"
    q_dtype = a.dtype if q_dtype is None else jnp.dtype(q_dtype)

    plan = plan_powerpass_seeded(n, da, db, kt, a.dtype, block_n=block_n,
                                 block_db=block_db, block_da=block_da)
    if plan is None:
        # k̃p > 8192: unfused pair; Ω materialized transiently (documented)
        q = rand.dense_omega(seed, db, kt, q_dtype)
        p = pallas_matmul(b, q, out_dtype=jnp.float32, interpret=interpret)
        return pallas_matmul(a, p, transpose_lhs=True, out_dtype=jnp.float32,
                             interpret=interpret)
    if schedule is None:
        schedule = choose_powerpass_schedule(
            n, da, db, kt, a.dtype, block_n=block_n, block_db=block_db,
            block_da=block_da)
    if schedule == "staged":
        plans = plan_powerpass_staged(n, da, db, kt, a.dtype,
                                      block_n=block_n, block_db=block_db,
                                      block_da=block_da, seeded=True)
        if plans is not None:
            stage, sweep = plans
            ap = _pad2(a, *sweep.in_specs[0].padded)
            bp = _pad2(b, *stage.in_specs[0].padded)
            bd = stage.in_specs[0].shape[1]
            ktp = stage.out_specs[0].shape[1]
            out = _staged_call(
                ap, bp, jnp.asarray(seed, jnp.uint32), stage, sweep,
                interpret,
                seeded_kwargs=dict(bd=bd, ktp=ktp, d=db, kt=kt,
                                   q_dtype=q_dtype))
            return out[:da, :kt]
    ap = _pad2(a, *plan.in_specs[0].padded)
    bp = _pad2(b, *plan.in_specs[1].padded)
    bdb = plan.in_specs[1].shape[1]
    ktp = plan.out_specs[0].shape[1]

    out = pl.pallas_call(
        functools.partial(_powerpass_seeded_kernel, n_k_steps=plan.grid[2],
                          bdb=bdb, ktp=ktp, db=db, kt=kt, q_dtype=q_dtype),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(jnp.asarray(seed, jnp.uint32), ap, bp)
    return out[:da, :kt]


# --------------------------------------------------------------------------
# staged (P-reuse) schedule: stage P through HBM once, sweep buckets
# --------------------------------------------------------------------------


def _proj_stage_kernel(x_ref, q_ref, p_ref):
    """Phase 1: P = Σ_k x_tile q_tile, f32, accumulated in the output
    block itself; grid (n_t, k_t) with the contraction innermost.  The
    (bn, k̃p) block's index map is constant in k, so it stays
    VMEM-resident across the contraction and is written to HBM exactly
    once — the one ``n×k̃`` round-trip the staged schedule pays."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)

    p_ref[...] += jax.lax.dot_general(
        x_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _proj_stage_seeded_kernel(seed_ref, x_ref, p_ref, *,
                              bd: int, ktp: int, d: int, kt: int, q_dtype):
    """Seeded phase 1: the (bd, k̃p) Ω tile is regenerated from the SMEM
    seed at global row offset ``k_step·bd`` — each tile is generated
    exactly once per chunk, since only phase 1 touches Ω at all."""
    k_step = pl.program_id(1)

    @pl.when(k_step == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)

    q_tile = rand.normal_tile(
        seed_ref[0], seed_ref[1],
        (k_step * bd).astype(rand.U32), rand.U32(0),
        (bd, ktp), row_limit=d, col_limit=kt,
    ).astype(q_dtype)
    p_ref[...] += jax.lax.dot_general(
        x_ref[...], q_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _powerpass_sweep_kernel(a_ref, p_ref, y_ref):
    """Phase 2: y_bucket += a_bucketᵀ p; grid (da_t, n_t), rows
    innermost.  Reloads the staged (bn, k̃p) P tiles once per bucket
    instead of recomputing them — same contraction order and f32
    accumulation as the recompute schedule's last-k step, so the two
    schedules are bitwise equal."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jax.lax.dot_general(  # aᵀ p without materializing aᵀ
        a_ref[...], p_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def plan_proj_stage(n: int, d: int, kt: int, dtype, *,
                    bn: int | None = None,
                    bd: int | None = None) -> KernelPlan | None:
    """Launch plan for the phase-1 stage kernel (P = X Q, f32).

    ``bn``/``bd`` are *resolved* blocks when given (the staged composite
    passes the recompute plan's blocks verbatim so both schedules tile
    identically); ``None`` resolves standalone from the shared VMEM
    budget — the entry point the registry and the sharded
    collective-fused path use.
    """
    np_, dp, ktp = _round_up(n, 128), _round_up(d, 128), _round_up(kt, 128)
    row_cap = vmem_row_cap(ktp)
    if row_cap < 128:
        return None
    if bd is None:
        bd = _pick_block(dp, min(512, row_cap))
    if bn is None:
        bn = _pick_block(np_, min(256, row_cap, vmem_row_cap(bd)))
    in_dt = str(jnp.dtype(dtype))
    return KernelPlan(
        name="proj_stage",
        grid=(np_ // bn, dp // bd),
        in_specs=(
            BlockDef((bn, bd), lambda i, k: (i, k), (np_, dp), in_dt),
            BlockDef((bd, ktp), lambda i, k: (k, 0), (dp, ktp), in_dt),
        ),
        out_specs=(
            BlockDef((bn, ktp), lambda i, k: (i, 0), (np_, ktp), "float32"),
        ),
        scratch=(),
        out_shape=((n, kt),),
        accum_outputs=(0,),
    )


def plan_proj_stage_seeded(n: int, d: int, kt: int, dtype, *,
                           bn: int | None = None,
                           bd: int | None = None) -> KernelPlan | None:
    """Seeded phase-1 plan: the stage plan's geometry with the Q
    operand replaced by a (2,)-uint32 SMEM seed scalar."""
    base = plan_proj_stage(n, d, kt, dtype, bn=bn, bd=bd)
    if base is None:
        return None
    return dataclasses.replace(
        base,
        name="proj_stage_seeded",
        in_specs=base.in_specs[:1],
        scalars=(ScalarDef((2,), "uint32"),),
    )


def plan_powerpass_sweep(n: int, da: int, kt: int, dtype, *,
                         bn: int | None = None,
                         bda: int | None = None,
                         p_dtype="float32") -> KernelPlan | None:
    """Launch plan for the phase-2 sweep kernel (ΔY = AᵀP, bucketed).

    ``dtype`` is A's dtype; ``p_dtype`` is the staged P's (f32 inside
    the composite, the compute dtype on the sharded collective-fused
    path where P crosses a psum).  Blocks as in :func:`plan_proj_stage`.
    """
    np_, dap, ktp = _round_up(n, 128), _round_up(da, 128), _round_up(kt, 128)
    row_cap = vmem_row_cap(ktp)
    if row_cap < 128:
        return None
    if bda is None:
        bda = dap if dap <= row_cap else _pick_block(dap, row_cap)
    if bn is None:
        bn = _pick_block(np_, min(256, row_cap, vmem_row_cap(bda)))
    in_dt = str(jnp.dtype(dtype))
    return KernelPlan(
        name="powerpass_sweep",
        grid=(dap // bda, np_ // bn),
        in_specs=(
            BlockDef((bn, bda), lambda j, i: (i, j), (np_, dap), in_dt),
            BlockDef((bn, ktp), lambda j, i: (i, 0), (np_, ktp),
                     str(jnp.dtype(p_dtype))),
        ),
        out_specs=(
            BlockDef((bda, ktp), lambda j, i: (j, 0), (dap, ktp), "float32"),
        ),
        scratch=(),
        out_shape=((da, kt),),
        accum_outputs=(0,),
    )


def plan_powerpass_staged(
    n: int, da: int, db: int, kt: int, dtype, *,
    block_n: int | None = None, block_db: int | None = None,
    block_da: int | None = None, seeded: bool = False,
) -> tuple[KernelPlan, KernelPlan] | None:
    """(stage, sweep) plan pair for the staged schedule, or ``None`` on
    the degenerate shapes.  Blocks are extracted from the *recompute*
    plan for the same shape (same autotune lookup, same VMEM budget),
    so staged and recompute tile identically — the structural basis of
    their bitwise parity."""
    base = plan_powerpass(n, da, db, kt, dtype, block_n=block_n,
                          block_db=block_db, block_da=block_da)
    if base is None:
        return None
    bn, bda = base.in_specs[0].shape
    bdb = base.in_specs[1].shape[1]
    if seeded:
        stage = plan_proj_stage_seeded(n, db, kt, dtype, bn=bn, bd=bdb)
    else:
        stage = plan_proj_stage(n, db, kt, dtype, bn=bn, bd=bdb)
    sweep = plan_powerpass_sweep(n, da, kt, dtype, bn=bn, bda=bda)
    if stage is None or sweep is None:
        return None
    return stage, sweep


def choose_powerpass_schedule(
    n: int, da: int, db: int, kt: int, dtype, *,
    block_n: int | None = None, block_db: int | None = None,
    block_da: int | None = None,
) -> str:
    """``"staged"`` or ``"recompute"`` for one powerpass shape.

    Order of authority: an autotuned schedule entry
    (``op="powerpass-staged"``, written by
    :func:`repro.kernels.autotune.autotune_powerpass_staged`), then the
    analytic roofline crossover (:func:`repro.kernels.matmul.pick_schedule`)
    over the KernelPlan-derived cost model — the same model the obs
    roofline counters charge, so the report's numbers explain the
    choice.  Single-bucket shapes always recompute: staged would add
    the P round-trip and remove nothing.
    """
    np_, dap = _round_up(n, 128), _round_up(da, 128)
    dbp, ktp = _round_up(db, 128), _round_up(kt, 128)
    tuned = autotune.lookup_schedule("powerpass-staged",
                                     (np_, dbp, ktp, dap), dtype)
    if tuned is not None:
        return tuned
    base = plan_powerpass(n, da, db, kt, dtype, block_n=block_n,
                          block_db=block_db, block_da=block_da)
    if base is None or base.grid[0] == 1:
        return "recompute"
    plans = plan_powerpass_staged(n, da, db, kt, dtype, block_n=block_n,
                                  block_db=block_db, block_da=block_da)
    if plans is None:
        return "recompute"
    from repro.obs.cost import plan_cost  # deferred: obs imports kernels.plan

    rec = plan_cost(base)
    stage, sweep = (plan_cost(p) for p in plans)
    return pick_schedule({
        "recompute": (rec["flops"], rec["bytes"]),
        "staged": (stage["flops"] + sweep["flops"],
                   stage["bytes"] + sweep["bytes"]),
    })


def _staged_call(ap, bp, qp_or_seed, stage: KernelPlan, sweep: KernelPlan,
                 interpret: bool, *, seeded_kwargs=None) -> jax.Array:
    """Launch the (stage, sweep) pallas_call pair; returns padded ΔY.
    The staged P stays padded (np_, k̃p) f32 between the phases — no
    host-side slicing, one HBM round-trip."""
    if seeded_kwargs is None:
        body = _proj_stage_kernel
        operands = (bp, qp_or_seed)
    else:
        body = functools.partial(_proj_stage_seeded_kernel, **seeded_kwargs)
        operands = (qp_or_seed, bp)  # seed scalar leads the blocked operands
    p = pl.pallas_call(
        body,
        **launch_args(stage),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(*operands)
    return pl.pallas_call(
        _powerpass_sweep_kernel,
        **launch_args(sweep),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(ap, p)


@functools.partial(jax.jit, static_argnames=("interpret",))
def proj_stage(x: jax.Array, q: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """Standalone phase-1 stage: P = x @ q in f32, staged blockwise.

    x: (n, d), q: (d, k̃) → (n, k̃) f32.  Used by the sharded
    collective-fused path (partial P on the local feature shard, psum
    at the phase boundary) and as the registry entry point for the
    ``proj_stage`` contract checks; the staged composite inlines the
    same kernel with the recompute plan's blocks.
    """
    n, d = x.shape
    d2, kt = q.shape
    assert d == d2, f"contraction mismatch {d} vs {d2}"
    plan = plan_proj_stage(n, d, kt, x.dtype)
    if plan is None:
        return pallas_matmul(x, q, out_dtype=jnp.float32, interpret=interpret)
    xp = _pad2(x, *plan.in_specs[0].padded)
    qp = _pad2(q, *plan.in_specs[1].padded)
    p = pl.pallas_call(
        _proj_stage_kernel,
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(xp, qp)
    return p[:n, :kt]


@functools.partial(jax.jit, static_argnames=("kt", "q_dtype", "interpret"))
def proj_stage_seeded(x: jax.Array, seed: jax.Array, *, kt: int,
                      q_dtype=None, interpret: bool = False) -> jax.Array:
    """Standalone seeded phase-1 stage: P = x @ Ω(seed) in f32, each Ω
    tile generated in-kernel exactly once.  Bitwise identical to
    ``proj_stage(x, rand.dense_omega(seed, d, kt, q_dtype))``."""
    n, d = x.shape
    q_dtype = x.dtype if q_dtype is None else jnp.dtype(q_dtype)
    plan = plan_proj_stage_seeded(n, d, kt, x.dtype)
    if plan is None:
        q = rand.dense_omega(seed, d, kt, q_dtype)
        return pallas_matmul(x, q, out_dtype=jnp.float32, interpret=interpret)
    xp = _pad2(x, *plan.in_specs[0].padded)
    bd = plan.in_specs[0].shape[1]
    ktp = plan.out_specs[0].shape[1]
    p = pl.pallas_call(
        functools.partial(_proj_stage_seeded_kernel, bd=bd, ktp=ktp,
                          d=d, kt=kt, q_dtype=q_dtype),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(jnp.asarray(seed, jnp.uint32), xp)
    return p[:n, :kt]


@functools.partial(jax.jit, static_argnames=("interpret",))
def powerpass_sweep(a: jax.Array, p: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """Standalone phase-2 sweep: ΔY = aᵀ p, reloading staged P tiles
    per ΔY bucket.  a: (n, da), p: (n, k̃) → (da, k̃) f32.  ``p`` may be
    f32 (local staged composite) or the compute dtype (the sharded path,
    where P crosses the ``col_axis`` psum between the phases)."""
    n, da = a.shape
    n2, kt = p.shape
    assert n == n2, f"row mismatch {n} vs {n2}"
    plan = plan_powerpass_sweep(n, da, kt, a.dtype, p_dtype=str(p.dtype))
    if plan is None:
        return pallas_matmul(a, p, transpose_lhs=True, out_dtype=jnp.float32,
                             interpret=interpret)
    ap = _pad2(a, *plan.in_specs[0].padded)
    pp = _pad2(p, *plan.in_specs[1].padded)
    out = pl.pallas_call(
        _powerpass_sweep_kernel,
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(ap, pp)
    return out[:da, :kt]
