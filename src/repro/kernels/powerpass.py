"""Fused project+accumulate Pallas kernel for the range-finder pass.

The dominant data pass of Algorithm 1 (lines 7-8) updates, per row
chunk, ``ΔYa = Aᵀ(B Qb)`` and ``ΔYb = Bᵀ(A Qa)``.  Issued as separate
matmuls that is four ``pallas_call``s per chunk, with each view read
from HBM twice and the projected activations P making an HBM
round-trip.  This kernel fuses one view's update — the projection tile
``P = B Qb`` stays in a VMEM scratch accumulator and ``ΔYa = AᵀP`` is
emitted directly — the same fusion :mod:`repro.kernels.projgram`
applies to the final pass.  A full ``power_pass_chunk`` is then two
``pallas_call``s, each reading A and B exactly once.

Grid (n_t, db_t), contraction (db) innermost:

- per row tile, P = Σ_db B_tile Qb_tile accumulates in VMEM;
- on the last db step, ΔY += AᵀP lands in the (dap, k̃p) output block,
  whose index map is constant, so it stays VMEM-resident across row
  steps and is written back to HBM once.

VMEM budget per grid step (bn=256, bdb=512, f32):
  B tile 0.5 MB + Qb tile 2 MB + P scratch 1 MB + A tile bn·dap
  + ΔY block dap·k̃p.  The wrapper falls back to the unfused matmul
  pair when dap·k̃p or bn·dap exceeds 2^20 (block over 4 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params
from .matmul import _pad2, _pick_block, _round_up, pallas_matmul


def _powerpass_kernel(a_ref, b_ref, q_ref, y_ref, p_acc, *, n_k_steps: int):
    """y += aᵀ(b q); grid (n_t, db_t) with the b-feature dim innermost."""
    n_step = pl.program_id(0)
    k_step = pl.program_id(1)

    @pl.when(jnp.logical_and(n_step == 0, k_step == 0))
    def _init_y():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(k_step == 0)
    def _init_p():
        p_acc[...] = jnp.zeros_like(p_acc)

    p_acc[...] += jax.lax.dot_general(
        b_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k_steps - 1)
    def _accumulate():
        y_ref[...] += jax.lax.dot_general(  # aᵀ p without materializing aᵀ
            a_ref[...], p_acc[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_db", "interpret")
)
def power_project_accumulate(
    a: jax.Array,
    b: jax.Array,
    q: jax.Array,
    *,
    block_n: int = 256,
    block_db: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Return ΔY = aᵀ (b @ q) with a and b each read from HBM once.

    a: (n, da), b: (n, db), q: (db, k̃) → (da, k̃) in f32.
    """
    n, da = a.shape
    n2, db = b.shape
    db2, kt = q.shape
    assert n == n2, f"row mismatch {n} vs {n2}"
    assert db == db2, f"contraction mismatch {db} vs {db2}"

    dap = _round_up(da, 128)
    ktp = _round_up(kt, 128)
    np_, dbp = _round_up(n, 128), _round_up(db, 128)
    bn, bdb = _pick_block(np_, block_n), _pick_block(dbp, block_db)
    # ΔY block (dap·k̃p) or A tile (bn·dap) over ~4 MB f32 → VMEM blows;
    # fall back to the unfused matmul pair
    if dap * ktp > 1 << 20 or bn * dap > 1 << 20:
        p = pallas_matmul(b, q, out_dtype=jnp.float32, interpret=interpret)
        return pallas_matmul(a, p, transpose_lhs=True, out_dtype=jnp.float32,
                             interpret=interpret)
    gn, gk = np_ // bn, dbp // bdb
    ap = _pad2(a, np_, dap)
    bp = _pad2(b, np_, dbp)
    qp = _pad2(q, dbp, ktp)

    out = pl.pallas_call(
        functools.partial(_powerpass_kernel, n_k_steps=gk),
        grid=(gn, gk),
        in_specs=[
            pl.BlockSpec((bn, dap), lambda i, k: (i, 0)),
            pl.BlockSpec((bn, bdb), lambda i, k: (i, k)),
            pl.BlockSpec((bdb, ktp), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((dap, ktp), lambda i, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((dap, ktp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, ktp), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(ap, bp, qp)
    return out[:da, :kt]
