"""Pallas TPU kernels for the CCA data-pass hot spots.

matmul.py   — MXU-tiled NN/TN matmul (f32 VMEM accumulator)
projgram.py — fused project+gram (one HBM read of X per final pass)
ops.py      — jitted public wrappers (interpret-mode on CPU)
ref.py      — pure-jnp oracles
"""

from . import ops, ref
from .matmul import pallas_matmul
from .projgram import projgram

__all__ = ["ops", "ref", "pallas_matmul", "projgram"]
