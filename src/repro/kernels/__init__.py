"""Pallas TPU kernels for the CCA data-pass hot spots.

compat.py    — jax-version shim (compiler params, ambient mesh)
matmul.py    — MXU-tiled NN/TN matmul (f32 VMEM accumulator) + the
               shared per-buffer VMEM budget (VMEM_BLOCK_ELEMS)
powerpass.py — fused project+accumulate (one HBM read of A and B per
               range-finder update; 2 pallas_calls per chunk, not 4);
               column-bucketed third grid axis keeps it fused at any
               da (Europarl d = 2^19 included); plus the staged
               (P-reuse) schedule — ``proj_stage`` computes P = B Q
               once into HBM scratch and ``powerpass_sweep`` reloads
               it per bucket, dropping the n_buckets·proj recompute
projgram.py  — fused project+gram (one HBM read of X per final pass);
               C-column bucketing covers sketches past k̃p = 1024;
               staged variant shares ``proj_stage`` and sweeps the
               gram buckets with ``gram_sweep``
rand.py      — counter-based tile PRNG (Threefry-2x32 + Box–Muller);
               both fused kernels have ``*_seeded`` variants that
               generate their Ω tiles in-kernel from a (2,)-uint32
               SMEM seed, bitwise identical to the materialized path
autotune.py  — persistent block-size autotuner (matmuls + the fused
               kernels' block/bucket caps; benchmarks/sweep_blocks.py)
ops.py       — jitted public wrappers (interpret-mode on CPU)
ref.py       — pure-jnp oracles

Engine selection
----------------
This package is the production default of the data-pass engine: the
drivers (``randomized_cca_streaming``, ``randomized_cca_iterator``,
``dist_randomized_cca``, ``launch.cca_fit``) take
``engine="kernels" | "jnp"`` and default to ``"kernels"``.  On hosts
without a TPU the kernels run in Pallas interpret mode (same kernel
bodies, executed on CPU), so parity against the ``ref.py`` /
``rcca.py`` jnp oracles is testable everywhere; on TPU the identical
code lowers to Mosaic.  ``engine="jnp"`` selects the pure-jnp update
path — the oracle the kernels are validated against.

Autotune cache
--------------
``pallas_matmul`` block caps resolve from a persistent JSON cache keyed
by (backend, op, dtype, padded shape); run
``autotune.autotune_matmul(x, y)`` once per hot shape on the target
hardware to populate it (``$RCCA_AUTOTUNE_CACHE`` overrides the cache
path).  Unswept shapes fall back to the 512³ heuristic.  Caps bind at
trace time: sweep before a shape's first jitted use in the process, or
the already-compiled blocks stay live until restart.

The same cache also stores *schedule* entries (``op="powerpass-staged"``
/ ``"projgram-staged"``) recording the measured staged-vs-recompute
winner per shape; unswept shapes fall back to the analytic roofline
crossover in :func:`matmul.pick_schedule`.
"""

import dataclasses
from typing import Callable, Optional, Tuple

from . import autotune, compat, ops, plan, rand, ref
from .matmul import pallas_matmul, pick_schedule, plan_matmul
from .powerpass import (choose_powerpass_schedule, plan_powerpass,
                        plan_powerpass_seeded, plan_powerpass_staged,
                        plan_powerpass_sweep, plan_proj_stage,
                        plan_proj_stage_seeded, power_project_accumulate,
                        power_project_accumulate_seeded, powerpass_sweep,
                        proj_stage, proj_stage_seeded)
from .projgram import (choose_projgram_schedule, gram_sweep, plan_gram_sweep,
                       plan_projgram, plan_projgram_seeded,
                       plan_projgram_staged, projgram, projgram_seeded)


@dataclasses.dataclass(frozen=True)
class KernelDef:
    """One registered Pallas kernel — everything the static contract
    checker (:mod:`repro.analysis.kernel_check`) needs to verify it
    with no device:

    - ``plan``: probe dict → :class:`~repro.kernels.plan.KernelPlan`
      (or ``None`` on the kernel's documented unfused-fallback shapes);
    - ``probes``: representative problem shapes, small enough that the
      checker can walk the full grid, including at least one bucketed
      and one fallback shape where the kernel has those regimes;
    - ``abstract``: probe dict → (callable, arg ShapeDtypeStructs) for
      ``jax.eval_shape`` — the abstract-eval cross-check that the live
      wrapper and the plan agree on output geometry.

    A probe is a plain dict of problem dims + ``dtype``.
    """

    name: str
    plan: Callable[[dict], Optional["plan.KernelPlan"]]
    probes: Tuple[dict, ...]
    abstract: Callable[[dict], tuple]


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _matmul_probe_plan(p: dict, transpose_lhs: bool):
    return plan_matmul(p["M"], p["K"], p["N"], p["dtype"],
                       transpose_lhs=transpose_lhs)


def _matmul_abstract(p: dict, transpose_lhs: bool):
    import functools

    fn = functools.partial(pallas_matmul, transpose_lhs=transpose_lhs,
                           interpret=True)
    if transpose_lhs:
        x = _sds((p["K"], p["M"]), p["dtype"])
    else:
        x = _sds((p["M"], p["K"]), p["dtype"])
    return fn, (x, _sds((p["K"], p["N"]), p["dtype"]))


#: The registry the kernel contract checker walks: every production
#: Pallas kernel of the data-pass engine, with plan builders and
#: abstract-eval probes.  Registering here is what puts a new kernel
#: under ``python -m repro.analysis kernels`` / the CI analyze gate.
KERNEL_REGISTRY: dict = {
    "matmul_nn": KernelDef(
        name="matmul_nn",
        plan=lambda p: _matmul_probe_plan(p, False),
        probes=(
            {"M": 512, "K": 384, "N": 256, "dtype": "float32"},
            {"M": 200, "K": 100, "N": 60, "dtype": "bfloat16"},
        ),
        abstract=lambda p: _matmul_abstract(p, False),
    ),
    "matmul_tn": KernelDef(
        name="matmul_tn",
        plan=lambda p: _matmul_probe_plan(p, True),
        probes=(
            {"M": 256, "K": 512, "N": 384, "dtype": "float32"},
            {"M": 60, "K": 200, "N": 100, "dtype": "bfloat16"},
        ),
        abstract=lambda p: _matmul_abstract(p, True),
    ),
    "powerpass": KernelDef(
        name="powerpass",
        plan=lambda p: plan_powerpass(p["n"], p["da"], p["db"], p["kt"],
                                      p["dtype"]),
        probes=(
            {"n": 256, "da": 500, "db": 300, "kt": 64, "dtype": "float32"},
            # forced multi-bucket regime: dap·k̃p blows one block
            {"n": 256, "da": 4096, "db": 256, "kt": 512, "dtype": "float32"},
            {"n": 128, "da": 256, "db": 128, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "da": 128, "db": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(power_project_accumulate,
                                            interpret=True),
            (_sds((p["n"], p["da"]), p["dtype"]),
             _sds((p["n"], p["db"]), p["dtype"]),
             _sds((p["db"], p["kt"]), p["dtype"])),
        ),
    ),
    "powerpass_seeded": KernelDef(
        name="powerpass_seeded",
        plan=lambda p: plan_powerpass_seeded(p["n"], p["da"], p["db"],
                                             p["kt"], p["dtype"]),
        probes=(
            {"n": 256, "da": 500, "db": 300, "kt": 64, "dtype": "float32"},
            # forced multi-bucket regime: dap·k̃p blows one block
            {"n": 256, "da": 4096, "db": 256, "kt": 512, "dtype": "float32"},
            {"n": 128, "da": 256, "db": 128, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "da": 128, "db": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(power_project_accumulate_seeded,
                                            kt=p["kt"], q_dtype=p["dtype"],
                                            interpret=True),
            (_sds((p["n"], p["da"]), p["dtype"]),
             _sds((p["n"], p["db"]), p["dtype"]),
             _sds((2,), "uint32")),
        ),
    ),
    "projgram": KernelDef(
        name="projgram",
        plan=lambda p: plan_projgram(p["n"], p["d"], p["kt"], p["dtype"]),
        probes=(
            {"n": 256, "d": 500, "kt": 64, "dtype": "float32"},
            # forced multi-bucket regime: k̃p² blows one block
            {"n": 256, "d": 256, "kt": 2048, "dtype": "float32"},
            {"n": 128, "d": 200, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "d": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(projgram, interpret=True),
            (_sds((p["n"], p["d"]), p["dtype"]),
             _sds((p["d"], p["kt"]), p["dtype"])),
        ),
    ),
    "projgram_seeded": KernelDef(
        name="projgram_seeded",
        plan=lambda p: plan_projgram_seeded(p["n"], p["d"], p["kt"],
                                            p["dtype"]),
        probes=(
            {"n": 256, "d": 500, "kt": 64, "dtype": "float32"},
            # forced multi-bucket regime: k̃p² blows one block
            {"n": 256, "d": 256, "kt": 2048, "dtype": "float32"},
            {"n": 128, "d": 200, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "d": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(projgram_seeded, kt=p["kt"],
                                            q_dtype=p["dtype"],
                                            interpret=True),
            (_sds((p["n"], p["d"]), p["dtype"]),
             _sds((2,), "uint32")),
        ),
    ),
    # --- staged (P-reuse) schedule family: phase-1 stage + phase-2 sweeps
    "proj_stage": KernelDef(
        name="proj_stage",
        plan=lambda p: plan_proj_stage(p["n"], p["d"], p["kt"], p["dtype"]),
        probes=(
            {"n": 256, "d": 500, "kt": 64, "dtype": "float32"},
            # wide-sketch regime: the staged P block is k̃p-row-capped
            {"n": 256, "d": 256, "kt": 2048, "dtype": "float32"},
            {"n": 128, "d": 200, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "d": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(proj_stage, interpret=True),
            (_sds((p["n"], p["d"]), p["dtype"]),
             _sds((p["d"], p["kt"]), p["dtype"])),
        ),
    ),
    "proj_stage_seeded": KernelDef(
        name="proj_stage_seeded",
        plan=lambda p: plan_proj_stage_seeded(p["n"], p["d"], p["kt"],
                                              p["dtype"]),
        probes=(
            {"n": 256, "d": 500, "kt": 64, "dtype": "float32"},
            {"n": 256, "d": 256, "kt": 2048, "dtype": "float32"},
            {"n": 128, "d": 200, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "d": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(proj_stage_seeded, kt=p["kt"],
                                            q_dtype=p["dtype"],
                                            interpret=True),
            (_sds((p["n"], p["d"]), p["dtype"]),
             _sds((2,), "uint32")),
        ),
    ),
    "powerpass_sweep": KernelDef(
        name="powerpass_sweep",
        plan=lambda p: plan_powerpass_sweep(p["n"], p["da"], p["kt"],
                                            p["dtype"]),
        probes=(
            {"n": 256, "da": 500, "kt": 64, "dtype": "float32"},
            # forced multi-bucket regime: dap·k̃p blows one block
            {"n": 256, "da": 4096, "kt": 512, "dtype": "float32"},
            {"n": 128, "da": 256, "kt": 64, "dtype": "bfloat16"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "da": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(powerpass_sweep, interpret=True),
            (_sds((p["n"], p["da"]), p["dtype"]),
             _sds((p["n"], p["kt"]), "float32")),
        ),
    ),
    "gram_sweep": KernelDef(
        name="gram_sweep",
        plan=lambda p: plan_gram_sweep(p["n"], p["kt"]),
        probes=(
            {"n": 256, "kt": 64, "dtype": "float32"},
            # forced multi-bucket regime: k̃p² blows one block
            {"n": 256, "kt": 2048, "dtype": "float32"},
            # degenerate fallback regime: k̃p > 8192 → plan is None
            {"n": 128, "kt": 8320, "dtype": "float32"},
        ),
        abstract=lambda p: (
            __import__("functools").partial(gram_sweep, interpret=True),
            (_sds((p["n"], p["kt"]), "float32"),),
        ),
    ),
}


__all__ = [
    "autotune",
    "compat",
    "ops",
    "plan",
    "rand",
    "ref",
    "KernelDef",
    "KERNEL_REGISTRY",
    "choose_powerpass_schedule",
    "choose_projgram_schedule",
    "gram_sweep",
    "pallas_matmul",
    "pick_schedule",
    "plan_gram_sweep",
    "plan_matmul",
    "plan_powerpass",
    "plan_powerpass_seeded",
    "plan_powerpass_staged",
    "plan_powerpass_sweep",
    "plan_proj_stage",
    "plan_proj_stage_seeded",
    "plan_projgram",
    "plan_projgram_seeded",
    "plan_projgram_staged",
    "power_project_accumulate",
    "power_project_accumulate_seeded",
    "powerpass_sweep",
    "proj_stage",
    "proj_stage_seeded",
    "projgram",
    "projgram_seeded",
]
