"""Pallas TPU kernels for the CCA data-pass hot spots.

compat.py    — jax-version shim (compiler params, ambient mesh)
matmul.py    — MXU-tiled NN/TN matmul (f32 VMEM accumulator) + the
               shared per-buffer VMEM budget (VMEM_BLOCK_ELEMS)
powerpass.py — fused project+accumulate (one HBM read of A and B per
               range-finder update; 2 pallas_calls per chunk, not 4);
               column-bucketed third grid axis keeps it fused at any
               da (Europarl d = 2^19 included)
projgram.py  — fused project+gram (one HBM read of X per final pass);
               C-column bucketing covers sketches past k̃p = 1024
autotune.py  — persistent block-size autotuner (matmuls + the fused
               kernels' block/bucket caps; benchmarks/sweep_blocks.py)
ops.py       — jitted public wrappers (interpret-mode on CPU)
ref.py       — pure-jnp oracles

Engine selection
----------------
This package is the production default of the data-pass engine: the
drivers (``randomized_cca_streaming``, ``randomized_cca_iterator``,
``dist_randomized_cca``, ``launch.cca_fit``) take
``engine="kernels" | "jnp"`` and default to ``"kernels"``.  On hosts
without a TPU the kernels run in Pallas interpret mode (same kernel
bodies, executed on CPU), so parity against the ``ref.py`` /
``rcca.py`` jnp oracles is testable everywhere; on TPU the identical
code lowers to Mosaic.  ``engine="jnp"`` selects the pure-jnp update
path — the oracle the kernels are validated against.

Autotune cache
--------------
``pallas_matmul`` block caps resolve from a persistent JSON cache keyed
by (backend, op, dtype, padded shape); run
``autotune.autotune_matmul(x, y)`` once per hot shape on the target
hardware to populate it (``$RCCA_AUTOTUNE_CACHE`` overrides the cache
path).  Unswept shapes fall back to the 512³ heuristic.  Caps bind at
trace time: sweep before a shape's first jitted use in the process, or
the already-compiled blocks stay live until restart.
"""

from . import autotune, compat, ops, ref
from .matmul import pallas_matmul
from .powerpass import power_project_accumulate
from .projgram import projgram

__all__ = [
    "autotune",
    "compat",
    "ops",
    "ref",
    "pallas_matmul",
    "power_project_accumulate",
    "projgram",
]
