"""Counter-based tile PRNG for the seeded-Ω path.

The randomized CCA range finder multiplies every data chunk against a
Gaussian sketch ``Ω: (d, k̃)``.  At Europarl scale that is
``2^19 × 2060`` ≈ 4 GB f32 — it dominates HBM residency in the power
pass and must be broadcast (or identically re-derived) by every
cluster worker.  This module removes the array entirely: Ω is a pure
function of a 64-bit seed and the element coordinates, so any tile of
it can be generated *inside* a Pallas kernel (or on the host) with

    ``Ω[i, j] = boxmuller(threefry2x32(seed, counter=(i, j)))``

**Bitwise contract.**  Everything here is ordinary ``jnp`` uint32 /
f32 element-wise arithmetic — no stateful PRNG primitives — so the
exact same function body runs inside a Pallas kernel, under
``interpret=True``, and as the host-side reference.  Because each
element depends only on ``(seed, i, j)``, the generated values are
invariant to block shape, bucket split and grid partitioning: a
``(bdb, k̃p)`` tile generated at row offset ``k·bdb`` is bitwise equal
to the corresponding slice of :func:`dense_omega`.  That invariance is
what makes ``omega="seeded"`` bitwise comparable to the materialized
oracle (``omega="seeded-materialized"``), and it is pinned by
``tests/test_seeded_omega.py``.

**Generator.**  Threefry-2x32 with the full 20 rounds (the same cipher
family as jax's own threefry PRNG), keyed on the two seed words, with
the global ``(row, col)`` coordinates as the 64-bit counter.  The two
output words feed one Box–Muller cosine branch:

    ``u ~ U[0,1)`` via exponent-patching (``(bits >> 9) | 0x3F800000``
    bitcast to f32 in ``[1, 2)``), then
    ``z = sqrt(-2·log(2 - f0)) · cos(2π·(f1 - 1))``.

``2 - f0`` is exact in f32 (Sterbenz) and keeps the log argument in
``[2^-23, 1]``.  One sharp edge makes the bitwise contract hold:
XLA CPU's vectorized transcendentals (``log``, ``exp``) round their
*scalar remainder lanes* differently from the vector lanes, so a
generator evaluation is only bitwise stable on lane-aligned shapes.
Kernel tiles are always ``(block, k̃p)`` with 128-multiples, and
:func:`dense_omega` generates at the 128-padded shape behind an
``optimization_barrier`` before slicing — never evaluate the
generator on a ragged shape.  Padding
rows/columns (beyond the logical ``d × k̃``) are masked to exactly 0.0
so a generated padded tile equals the zero-padded materialized Ω
bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32
_TWO_PI = 6.283185307179586


def _rot(x, r: int):
    return (x << U32(r)) | (x >> U32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds: encrypt counter ``(c0, c1)`` under key
    ``(k0, k1)``.  All operands uint32; broadcasts elementwise."""
    ks2 = k0 ^ k1 ^ U32(0x1BD11BDA)
    x0 = c0 + k0
    x1 = c1 + k1
    ks = (k0, k1, ks2)
    rotations = ((13, 15, 26, 6), (17, 29, 16, 24))
    for i in range(5):
        for r in rotations[i % 2]:
            x0 = x0 + x1
            x1 = _rot(x1, r) ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + U32(i + 1)
    return x0, x1


def _f12(bits):
    """uint32 bits → f32 in ``[1, 2)`` by exponent patching (keeps the
    top 23 bits of entropy; exact, division-free)."""
    return jax.lax.bitcast_convert_type(
        (bits >> U32(9)) | U32(0x3F800000), jnp.float32)


def normal_tile(s0, s1, r0, c0, shape, *, row_limit=None, col_limit=None):
    """One f32 ``N(0, 1)`` tile of Ω(seed): element ``(i, j)`` of the
    tile is Ω's global element ``(r0 + i, c0 + j)``.

    ``s0, s1`` are the uint32 seed words; ``r0, c0`` the uint32 global
    offsets of the tile (traced scalars inside a kernel).  When
    ``row_limit``/``col_limit`` are given, elements at or beyond the
    logical bound are exactly 0.0 — matching zero-padded materialized
    operands bit-for-bit.
    """
    rows = jax.lax.broadcasted_iota(U32, shape, 0) + r0
    cols = jax.lax.broadcasted_iota(U32, shape, 1) + c0
    b0, b1 = threefry2x32(s0, s1, rows, cols)
    f0 = _f12(b0)
    u1 = _f12(b1) - jnp.float32(1.0)
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(jnp.float32(2.0) - f0))
    z = r * jnp.cos(jnp.float32(_TWO_PI) * u1)
    if row_limit is not None or col_limit is not None:
        ok = True
        if row_limit is not None:
            ok = rows < U32(row_limit)
        if col_limit is not None:
            ok = ok & (cols < U32(col_limit))
        z = jnp.where(ok, z, jnp.float32(0.0))
    return z


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def dense_omega(seed, d: int, kt: int, dtype=jnp.float32):
    """Materialize the full ``(d, kt)`` Ω for ``seed`` — the oracle the
    seeded kernels are bitwise-compared against, and the local
    materialization used by the jnp engine.  Generated in f32, cast
    once (the same generate-in-f32-then-cast semantics as the kernels).

    Generation happens at the 128-aligned padded shape and is then
    sliced: XLA CPU's vectorized transcendentals round their scalar
    remainder lanes differently, so ragged shapes are not bitwise
    stable — every generator evaluation (here and in the kernels,
    whose tiles are (block, k̃p)) uses lane-aligned shapes only.
    """
    seed = jnp.asarray(seed, U32)
    shape = (_round_up(d, 128), _round_up(kt, 128))
    z = normal_tile(seed[0], seed[1], U32(0), U32(0), shape,
                    row_limit=d, col_limit=kt)
    # Barrier: without it XLA fuses the slice into the generation and
    # re-narrows the compute domain to the ragged (d, kt) shape.
    z = jax.lax.optimization_barrier(z)
    return z[:d, :kt].astype(dtype)


def seeds_from_key(key):
    """Per-view ``(2,)``-uint32 Ω seeds derived from a jax PRNG key,
    mirroring ``init_Q``'s split order (first half → view a)."""
    ka, kb = jax.random.split(key)
    seed_a = jax.random.bits(ka, (2,), U32)
    seed_b = jax.random.bits(kb, (2,), U32)
    return seed_a, seed_b
