"""Declarative kernel launch plans — the checkable kernel contract.

Every Pallas wrapper in this package assembles its ``pallas_call`` from
a :class:`KernelPlan` built by a pure, trace-free ``plan_*`` function
(``matmul.plan_matmul``, ``powerpass.plan_powerpass``,
``projgram.plan_projgram``).  The plan is the single source of truth
for the launch geometry: grid, block shapes, index maps, padded
operand/output shapes, scratch allocations and dtypes.  Because the
wrapper and the static checker (:mod:`repro.analysis.kernel_check`)
consume the *same* plan object, the checker verifies exactly what runs
— grid × block × index-map consistency, full output coverage, VMEM
residency against the shared budget
(:data:`repro.kernels.matmul.VMEM_BLOCK_ELEMS`) and the
bf16-in/f32-accum dtype rules — with no device and no duplicated
sizing logic that could drift.

A ``plan_*`` function returns ``None`` when the shape is degenerate
for its fused kernel (the documented unfused-fallback condition); the
wrapper then decomposes into :func:`~repro.kernels.matmul.pallas_matmul`
calls whose own plans remain checkable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

IndexMap = Callable[..., Tuple[int, ...]]


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One blocked operand of a ``pallas_call``: the block shape, the
    grid-position → block-coordinate index map, the full padded array
    shape the blocks tile, and the element dtype name."""

    shape: Tuple[int, ...]
    index_map: IndexMap
    padded: Tuple[int, ...]
    dtype: str

    @property
    def elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class ScratchDef:
    """One VMEM scratch allocation (no index map — scratch is
    grid-invariant and always fully resident)."""

    shape: Tuple[int, ...]
    dtype: str

    @property
    def elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class ScalarDef:
    """One SMEM-resident scalar operand (e.g. a PRNG seed): the full
    small array is passed to the kernel un-blocked, ahead of the
    blocked operands.  PRNG-bearing plans MUST route their seed through
    one of these — never through a trace-time constant — so the
    contract checker (rule RCCA108) can verify the plumbing."""

    shape: Tuple[int, ...]
    dtype: str

    @property
    def elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """The complete launch geometry of one fused-kernel invocation."""

    name: str
    grid: Tuple[int, ...]
    in_specs: Tuple[BlockDef, ...]
    out_specs: Tuple[BlockDef, ...]
    scratch: Tuple[ScratchDef, ...]
    #: logical (unpadded) output shapes, in out_specs order
    out_shape: Tuple[Tuple[int, ...], ...]
    #: indices into out_specs of f32 accumulator outputs (dtype rule)
    accum_outputs: Tuple[int, ...] = ()
    #: SMEM scalar operands, passed BEFORE the blocked in_specs
    scalars: Tuple[ScalarDef, ...] = ()

    @property
    def n_steps(self) -> int:
        n = 1
        for g in self.grid:
            n *= g
        return n


def launch_args(plan: KernelPlan) -> dict:
    """``pl.pallas_call`` keyword arguments realized from a plan —
    the one bridge from the declarative contract to a live launch, so
    a wrapper cannot diverge from what the checker verified."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from .compat import smem_spec, vmem

    out_specs = [pl.BlockSpec(b.shape, b.index_map) for b in plan.out_specs]
    out_shape = [jax.ShapeDtypeStruct(b.padded, jnp.dtype(b.dtype))
                 for b in plan.out_specs]
    single = len(out_specs) == 1
    in_specs = [smem_spec() for _ in plan.scalars]
    in_specs += [pl.BlockSpec(b.shape, b.index_map) for b in plan.in_specs]
    return dict(
        grid=plan.grid,
        in_specs=in_specs,
        out_specs=out_specs[0] if single else out_specs,
        out_shape=out_shape[0] if single else out_shape,
        scratch_shapes=[vmem(s.shape, jnp.dtype(s.dtype))
                        for s in plan.scratch],
    )
