"""Jitted public wrappers around the Pallas kernels.

These are the units the CCA data-pass engine calls when
``use_kernels=True``; on CPU (this container) they run in interpret
mode, on TPU they lower to Mosaic.  Every op has a pure-jnp oracle in
ref.py and a shape/dtype sweep test in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import pallas_matmul, plan_matmul
from .powerpass import (
    plan_powerpass,
    plan_powerpass_seeded,
    power_project_accumulate,
    power_project_accumulate_seeded,
)
from .projgram import plan_projgram, plan_projgram_seeded, projgram, projgram_seeded

# interpret=True on CPU hosts (including the dry-run container), False on TPU.
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def project(x: jax.Array, q: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """P = X @ Q — the projection half of a data pass."""
    interpret = _default_interpret() if interpret is None else interpret
    return pallas_matmul(x, q, out_dtype=jnp.float32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def accumulate_tn(x: jax.Array, p: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Y_delta = Xᵀ @ P — the accumulation half (contract streamed rows)."""
    interpret = _default_interpret() if interpret is None else interpret
    return pallas_matmul(x, p, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def power_pass_chunk(a, b, Qa, Qb, *, interpret: bool | None = None):
    """Fused chunk update of Algorithm 1 lines 7-8:
    ΔYa = Aᵀ(B Qb), ΔYb = Bᵀ(A Qa) — one fused project+accumulate
    kernel per view (powerpass.py); P never makes an HBM round-trip.
    The kernel buckets the ΔY output columns over a third grid axis, so
    this stays 2 pallas_calls per chunk at any da/db — including
    Europarl-scale d = 2^19 — instead of falling back to the unfused
    matmul pair.  HBM reads: with a single bucket (dap·k̃p within the
    VMEM budget) each view is read exactly once per update; with more
    buckets, B/Q re-reads and the projection recompute scale with the
    bucket count — see powerpass.py's cost model."""
    interpret = _default_interpret() if interpret is None else interpret
    dYa = power_project_accumulate(a, b, Qb, interpret=interpret)
    dYb = power_project_accumulate(b, a, Qa, interpret=interpret)
    return dYa, dYb


@functools.partial(jax.jit, static_argnames=("interpret",))
def final_pass_chunk(a, b, Qa, Qb, *, interpret: bool | None = None):
    """Fused chunk update of Algorithm 1 lines 15-17:
    ΔCa = QaᵀAᵀA Qa, ΔCb = QbᵀBᵀB Qb, ΔF = QaᵀAᵀB Qb — projgram
    fusion: P never round-trips through HBM before the Gram.  C-column
    bucketing keeps the fused path for sketches past k̃p = 1024 (the
    paper's Europarl run uses k̃ = 2060); each view is read once per
    C-column bucket (once total in the single-bucket k̃p ≤ 1024 case —
    see projgram.py's cost model)."""
    interpret = _default_interpret() if interpret is None else interpret
    pa, Ca = projgram(a, Qa, interpret=interpret)
    pb, Cb = projgram(b, Qb, interpret=interpret)
    F = pallas_matmul(pa, pb, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)
    return Ca, Cb, F


@functools.partial(jax.jit, static_argnames=("kt", "q_dtype", "interpret"))
def power_pass_chunk_seeded(a, b, seed_a, seed_b, *, kt: int, q_dtype,
                            interpret: bool | None = None):
    """Seeded-Ω variant of :func:`power_pass_chunk`:
    ΔYa = Aᵀ(B Ω(seed_b)), ΔYb = Bᵀ(A Ω(seed_a)) with both Ω generated
    tile-by-tile inside the kernels (``rand.normal_tile``) — no
    ``(d, k̃)`` array exists anywhere in this update.  Bitwise identical
    to ``power_pass_chunk(a, b, Qa, Qb)`` with
    ``Q* = rand.dense_omega(seed_*, d*, kt, q_dtype)``."""
    interpret = _default_interpret() if interpret is None else interpret
    dYa = power_project_accumulate_seeded(a, b, seed_b, kt=kt,
                                          q_dtype=q_dtype, interpret=interpret)
    dYb = power_project_accumulate_seeded(b, a, seed_a, kt=kt,
                                          q_dtype=q_dtype, interpret=interpret)
    return dYa, dYb


@functools.partial(jax.jit, static_argnames=("kt", "q_dtype", "interpret"))
def final_pass_chunk_seeded(a, b, seed_a, seed_b, *, kt: int, q_dtype,
                            interpret: bool | None = None):
    """Seeded-Ω variant of :func:`final_pass_chunk` (the q = 0 direct
    sketch): ΔCa, ΔCb, ΔF against in-kernel generated Ω(seed_a),
    Ω(seed_b).  The cross term F reuses the emitted Pa, Pb exactly as
    the materialized path does."""
    interpret = _default_interpret() if interpret is None else interpret
    pa, Ca = projgram_seeded(a, seed_a, kt=kt, q_dtype=q_dtype,
                             interpret=interpret)
    pb, Cb = projgram_seeded(b, seed_b, kt=kt, q_dtype=q_dtype,
                             interpret=interpret)
    F = pallas_matmul(pa, pb, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)
    return Ca, Cb, F


def _power_view_cost(n: int, d_out: int, d_in: int, kt: int, dtype: str,
                     seeded: bool) -> list:
    """Kernel cost entries for one view's ΔY = Xoutᵀ(Xin Ω) update."""
    from repro.obs.cost import plan_cost
    plan = (plan_powerpass_seeded(n, d_out, d_in, kt, dtype) if seeded
            else plan_powerpass(n, d_out, d_in, kt, dtype))
    if plan is not None:
        return [plan_cost(plan)]
    # degenerate k̃p: the wrapper decomposes into the unfused matmul pair
    return [plan_cost(plan_matmul(n, d_in, kt, dtype)),
            plan_cost(plan_matmul(d_out, n, kt, "float32",
                                  transpose_lhs=True))]


def _final_view_cost(n: int, d: int, kt: int, dtype: str, seeded: bool) -> list:
    """Kernel cost entries for one view's (P, ΔC) projgram update."""
    from repro.obs.cost import plan_cost
    plan = (plan_projgram_seeded(n, d, kt, dtype) if seeded
            else plan_projgram(n, d, kt, dtype))
    if plan is not None:
        return [plan_cost(plan)]
    return [plan_cost(plan_matmul(n, d, kt, dtype)),
            plan_cost(plan_matmul(kt, n, kt, "float32", transpose_lhs=True))]


@functools.lru_cache(maxsize=512)
def chunk_cost(kind: str, n: int, da: int, db: int, kt: int,
               dtype: str = "float32", *, engine: str = "kernels",
               seeded: bool = False) -> dict:
    """Cost-model flops/bytes for one fused chunk update (both views).

    ``kind`` is the pass kind ("power" or "final"); shapes are the
    logical chunk shapes a:(n, da), b:(n, db) and the sketch width k̃.
    For ``engine="kernels"`` the entries come from the same KernelPlans
    the launches use (:mod:`repro.obs.cost`), including the unfused
    matmul-pair fallback for degenerate shapes; for ``engine="jnp"``
    they are the logical dense counts (no padding, Ω always read as a
    materialized array — the jnp path re-derives it on the host).

    Memoized per shape so tracing costs a cache lookup per chunk; treat
    the returned dict as read-only.
    """
    from repro.obs.cost import merge_kernel_costs
    isize = jnp.dtype(dtype).itemsize
    if engine == "jnp":
        if kind == "power":
            flops = 2 * n * (da + db) * kt * 2  # P = XΩ and Xᵀ P, per view
            bytes_ = (2 * n * (da + db) * isize        # a, b read twice
                      + (da + db) * kt * isize         # Qa, Qb
                      + (da + db) * kt * 4)            # ΔYa, ΔYb (f32)
        elif kind == "final":
            flops = 2 * n * (da + db) * kt + 3 * 2 * n * kt * kt
            bytes_ = (n * (da + db) * isize + (da + db) * kt * isize
                      + 3 * kt * kt * 4)
        else:
            raise ValueError(f"unknown pass kind {kind!r}")
        kernels = [{"kernel": f"jnp_{kind}", "calls": 1,
                    "flops": flops, "bytes": bytes_}]
    elif kind == "power":
        kernels = (_power_view_cost(n, da, db, kt, dtype, seeded)
                   + _power_view_cost(n, db, da, kt, dtype, seeded))
    elif kind == "final":
        from repro.obs.cost import plan_cost
        kernels = (_final_view_cost(n, da, kt, dtype, seeded)
                   + _final_view_cost(n, db, kt, dtype, seeded)
                   + [plan_cost(plan_matmul(kt, n, kt, "float32",
                                            transpose_lhs=True))])
    else:
        raise ValueError(f"unknown pass kind {kind!r}")
    kernels = merge_kernel_costs(kernels)
    return {"flops": sum(k["flops"] for k in kernels),
            "bytes": sum(k["bytes"] for k in kernels),
            "kernels": kernels}
