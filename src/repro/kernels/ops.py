"""Jitted public wrappers around the Pallas kernels.

These are the units the CCA data-pass engine calls when
``use_kernels=True``; on CPU (this container) they run in interpret
mode, on TPU they lower to Mosaic.  Every op has a pure-jnp oracle in
ref.py and a shape/dtype sweep test in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import pallas_matmul
from .powerpass import power_project_accumulate
from .projgram import projgram

# interpret=True on CPU hosts (including the dry-run container), False on TPU.
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def project(x: jax.Array, q: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """P = X @ Q — the projection half of a data pass."""
    interpret = _default_interpret() if interpret is None else interpret
    return pallas_matmul(x, q, out_dtype=jnp.float32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def accumulate_tn(x: jax.Array, p: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Y_delta = Xᵀ @ P — the accumulation half (contract streamed rows)."""
    interpret = _default_interpret() if interpret is None else interpret
    return pallas_matmul(x, p, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def power_pass_chunk(a, b, Qa, Qb, *, interpret: bool | None = None):
    """Fused chunk update of Algorithm 1 lines 7-8:
    ΔYa = Aᵀ(B Qb), ΔYb = Bᵀ(A Qa) — one fused project+accumulate
    kernel per view (powerpass.py), so A and B are each read from HBM
    once per update and P never makes an HBM round-trip."""
    interpret = _default_interpret() if interpret is None else interpret
    dYa = power_project_accumulate(a, b, Qb, interpret=interpret)
    dYb = power_project_accumulate(b, a, Qa, interpret=interpret)
    return dYa, dYb


@functools.partial(jax.jit, static_argnames=("interpret",))
def final_pass_chunk(a, b, Qa, Qb, *, interpret: bool | None = None):
    """Fused chunk update of Algorithm 1 lines 15-17:
    ΔCa = QaᵀAᵀA Qa, ΔCb = QbᵀBᵀB Qb, ΔF = QaᵀAᵀB Qb — each view's
    design matrix is read from HBM exactly once (projgram fusion)."""
    interpret = _default_interpret() if interpret is None else interpret
    pa, Ca = projgram(a, Qa, interpret=interpret)
    pb, Cb = projgram(b, Qb, interpret=interpret)
    F = pallas_matmul(pa, pb, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)
    return Ca, Cb, F
