"""Jitted public wrappers around the Pallas kernels.

These are the units the CCA data-pass engine calls when
``use_kernels=True``; on CPU (this container) they run in interpret
mode, on TPU they lower to Mosaic.  Every op has a pure-jnp oracle in
ref.py and a shape/dtype sweep test in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import pallas_matmul, plan_matmul
from .powerpass import (
    choose_powerpass_schedule,
    plan_powerpass,
    plan_powerpass_seeded,
    plan_powerpass_staged,
    power_project_accumulate,
    power_project_accumulate_seeded,
    powerpass_sweep,
    proj_stage,
    proj_stage_seeded,
)
from .projgram import (
    choose_projgram_schedule,
    gram_sweep,
    plan_projgram,
    plan_projgram_seeded,
    plan_projgram_staged,
    projgram,
    projgram_seeded,
)

# interpret=True on CPU hosts (including the dry-run container), False on TPU.
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def project(x: jax.Array, q: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """P = X @ Q — the projection half of a data pass."""
    interpret = _default_interpret() if interpret is None else interpret
    return pallas_matmul(x, q, out_dtype=jnp.float32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def accumulate_tn(x: jax.Array, p: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Y_delta = Xᵀ @ P — the accumulation half (contract streamed rows)."""
    interpret = _default_interpret() if interpret is None else interpret
    return pallas_matmul(x, p, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("schedule", "interpret"))
def power_pass_chunk(a, b, Qa, Qb, *, schedule: str | None = None,
                     interpret: bool | None = None):
    """Fused chunk update of Algorithm 1 lines 7-8:
    ΔYa = Aᵀ(B Qb), ΔYb = Bᵀ(A Qa) — one fused project+accumulate
    kernel per view (powerpass.py); P never makes an HBM round-trip
    under the recompute schedule, or one staged round-trip under the
    staged schedule.  The kernel buckets the ΔY output columns, so the
    fused path holds at any da/db — including Europarl-scale d = 2^19 —
    instead of falling back to the unfused matmul pair.  ``schedule``
    (``None`` = per-shape crossover, ``"recompute"``, ``"staged"``)
    picks between P recomputed per bucket (2 pallas_calls per chunk)
    and P staged through HBM once with buckets reloading it (4
    pallas_calls per chunk, ``n_buckets·proj + acc`` → ``proj + acc``
    FLOPs) — bitwise equal either way; see powerpass.py's cost model."""
    interpret = _default_interpret() if interpret is None else interpret
    dYa = power_project_accumulate(a, b, Qb, schedule=schedule,
                                   interpret=interpret)
    dYb = power_project_accumulate(b, a, Qa, schedule=schedule,
                                   interpret=interpret)
    return dYa, dYb


@functools.partial(jax.jit, static_argnames=("schedule", "interpret"))
def final_pass_chunk(a, b, Qa, Qb, *, schedule: str | None = None,
                     interpret: bool | None = None):
    """Fused chunk update of Algorithm 1 lines 15-17:
    ΔCa = QaᵀAᵀA Qa, ΔCb = QbᵀBᵀB Qb, ΔF = QaᵀAᵀB Qb — projgram
    fusion: P never round-trips through HBM before the Gram.  C-column
    bucketing keeps the fused path for sketches past k̃p = 1024 (the
    paper's Europarl run uses k̃ = 2060); each view is read once per
    C-column bucket under the recompute schedule, once total under the
    staged schedule (``schedule`` as in :func:`power_pass_chunk`; see
    projgram.py's cost model)."""
    interpret = _default_interpret() if interpret is None else interpret
    pa, Ca = projgram(a, Qa, schedule=schedule, interpret=interpret)
    pb, Cb = projgram(b, Qb, schedule=schedule, interpret=interpret)
    F = pallas_matmul(pa, pb, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)
    return Ca, Cb, F


@functools.partial(jax.jit, static_argnames=("kt", "q_dtype", "schedule",
                                             "interpret"))
def power_pass_chunk_seeded(a, b, seed_a, seed_b, *, kt: int, q_dtype,
                            schedule: str | None = None,
                            interpret: bool | None = None):
    """Seeded-Ω variant of :func:`power_pass_chunk`:
    ΔYa = Aᵀ(B Ω(seed_b)), ΔYb = Bᵀ(A Ω(seed_a)) with both Ω generated
    tile-by-tile inside the kernels (``rand.normal_tile``) — no
    ``(d, k̃)`` array exists anywhere in this update.  Bitwise identical
    to ``power_pass_chunk(a, b, Qa, Qb)`` with
    ``Q* = rand.dense_omega(seed_*, d*, kt, q_dtype)``.  Under
    ``schedule="staged"`` each Ω tile is generated exactly once, in the
    stage phase."""
    interpret = _default_interpret() if interpret is None else interpret
    dYa = power_project_accumulate_seeded(a, b, seed_b, kt=kt,
                                          q_dtype=q_dtype, schedule=schedule,
                                          interpret=interpret)
    dYb = power_project_accumulate_seeded(b, a, seed_a, kt=kt,
                                          q_dtype=q_dtype, schedule=schedule,
                                          interpret=interpret)
    return dYa, dYb


@functools.partial(jax.jit, static_argnames=("kt", "q_dtype", "schedule",
                                             "interpret"))
def final_pass_chunk_seeded(a, b, seed_a, seed_b, *, kt: int, q_dtype,
                            schedule: str | None = None,
                            interpret: bool | None = None):
    """Seeded-Ω variant of :func:`final_pass_chunk` (the q = 0 direct
    sketch): ΔCa, ΔCb, ΔF against in-kernel generated Ω(seed_a),
    Ω(seed_b).  The cross term F reuses the emitted Pa, Pb exactly as
    the materialized path does."""
    interpret = _default_interpret() if interpret is None else interpret
    pa, Ca = projgram_seeded(a, seed_a, kt=kt, q_dtype=q_dtype,
                             schedule=schedule, interpret=interpret)
    pb, Cb = projgram_seeded(b, seed_b, kt=kt, q_dtype=q_dtype,
                             schedule=schedule, interpret=interpret)
    F = pallas_matmul(pa, pb, transpose_lhs=True, out_dtype=jnp.float32, interpret=interpret)
    return Ca, Cb, F


# --------------------------------------------------------------------------
# sharded collective-fused ops (col_axis meshes): stage → psum → sweep
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def stage_project(x: jax.Array, q: jax.Array, *,
                  interpret: bool | None = None) -> jax.Array:
    """Phase-1 partial projection P_part = X_shard @ Q_shard (f32) on
    the local feature shard — the collective-fused path psums these
    partials at the phase boundary instead of wrapping a full-width
    psum in unfused matmuls."""
    interpret = _default_interpret() if interpret is None else interpret
    return proj_stage(x, q, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("kt", "q_dtype", "interpret"))
def stage_project_seeded(x: jax.Array, seed: jax.Array, *, kt: int, q_dtype,
                         interpret: bool | None = None) -> jax.Array:
    """Seeded variant of :func:`stage_project`: the shard's Ω tiles are
    generated in-kernel, once, in phase 1."""
    interpret = _default_interpret() if interpret is None else interpret
    return proj_stage_seeded(x, seed, kt=kt, q_dtype=q_dtype,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sweep_accumulate(x: jax.Array, p: jax.Array, *,
                     interpret: bool | None = None) -> jax.Array:
    """Phase-2 sweep ΔY = Xᵀ P over the psummed P, reloading its tiles
    per output bucket (powerpass_sweep kernel)."""
    interpret = _default_interpret() if interpret is None else interpret
    return powerpass_sweep(x, p, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram_accumulate(p: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """ΔC = Pᵀ P over the psummed P (gram_sweep kernel) — the final
    pass's collective-fused Gram update."""
    interpret = _default_interpret() if interpret is None else interpret
    return gram_sweep(p, interpret=interpret)


def _power_view_cost(n: int, d_out: int, d_in: int, kt: int, dtype: str,
                     seeded: bool, schedule: str | None = None) -> tuple:
    """(kernel cost entries, resolved schedule) for one view's
    ΔY = Xoutᵀ(Xin Ω) update — resolved exactly as the wrapper resolves
    it, so the roofline counters charge what actually launches (and
    stop charging the recompute when the launch is staged)."""
    from repro.obs.cost import plan_cost
    plan = (plan_powerpass_seeded(n, d_out, d_in, kt, dtype) if seeded
            else plan_powerpass(n, d_out, d_in, kt, dtype))
    if plan is None:
        # degenerate k̃p: the wrapper decomposes into the unfused pair
        return ([plan_cost(plan_matmul(n, d_in, kt, dtype)),
                 plan_cost(plan_matmul(d_out, n, kt, "float32",
                                       transpose_lhs=True))], None)
    sched = schedule or choose_powerpass_schedule(n, d_out, d_in, kt, dtype)
    if sched == "staged":
        plans = plan_powerpass_staged(n, d_out, d_in, kt, dtype,
                                      seeded=seeded)
        if plans is not None:
            return [plan_cost(p) for p in plans], "staged"
    return [plan_cost(plan)], "recompute"


def _final_view_cost(n: int, d: int, kt: int, dtype: str, seeded: bool,
                     schedule: str | None = None) -> tuple:
    """(kernel cost entries, resolved schedule) for one view's (P, ΔC)
    projgram update."""
    from repro.obs.cost import plan_cost
    plan = (plan_projgram_seeded(n, d, kt, dtype) if seeded
            else plan_projgram(n, d, kt, dtype))
    if plan is None:
        return ([plan_cost(plan_matmul(n, d, kt, dtype)),
                 plan_cost(plan_matmul(kt, n, kt, "float32",
                                       transpose_lhs=True))], None)
    sched = schedule or choose_projgram_schedule(n, d, kt, dtype)
    if sched == "staged":
        plans = plan_projgram_staged(n, d, kt, dtype, seeded=seeded)
        if plans is not None:
            return [plan_cost(p) for p in plans], "staged"
    return [plan_cost(plan)], "recompute"


def _join_schedules(*scheds) -> str | None:
    """Collapse per-view schedule choices to one chunk label: the common
    choice, a "a/b" composite when the views disagree, None when no
    fused launch carries a schedule (degenerate / jnp)."""
    seen = sorted({s for s in scheds if s is not None})
    if not seen:
        return None
    return seen[0] if len(seen) == 1 else "/".join(seen)


@functools.lru_cache(maxsize=512)
def chunk_cost(kind: str, n: int, da: int, db: int, kt: int,
               dtype: str = "float32", *, engine: str = "kernels",
               seeded: bool = False, schedule: str | None = None) -> dict:
    """Cost-model flops/bytes for one fused chunk update (both views).

    ``kind`` is the pass kind ("power" or "final"); shapes are the
    logical chunk shapes a:(n, da), b:(n, db) and the sketch width k̃.
    For ``engine="kernels"`` the entries come from the same KernelPlans
    the launches use (:mod:`repro.obs.cost`), including the unfused
    matmul-pair fallback for degenerate shapes; for ``engine="jnp"``
    they are the logical dense counts (no padding, Ω always read as a
    materialized array — the jnp path re-derives it on the host).

    ``schedule`` forces staged/recompute accounting; the default
    ``None`` resolves per shape through the same crossover the kernel
    wrappers use, and the resolved choice is reported back under the
    ``"schedule"`` key (None for jnp / degenerate launches).

    Memoized per shape so tracing costs a cache lookup per chunk; treat
    the returned dict as read-only.
    """
    from repro.obs.cost import merge_kernel_costs
    isize = jnp.dtype(dtype).itemsize
    sched: str | None = None
    if engine == "jnp":
        if kind == "power":
            flops = 2 * n * (da + db) * kt * 2  # P = XΩ and Xᵀ P, per view
            bytes_ = (2 * n * (da + db) * isize        # a, b read twice
                      + (da + db) * kt * isize         # Qa, Qb
                      + (da + db) * kt * 4)            # ΔYa, ΔYb (f32)
        elif kind == "final":
            flops = 2 * n * (da + db) * kt + 3 * 2 * n * kt * kt
            bytes_ = (n * (da + db) * isize + (da + db) * kt * isize
                      + 3 * kt * kt * 4)
        else:
            raise ValueError(f"unknown pass kind {kind!r}")
        kernels = [{"kernel": f"jnp_{kind}", "calls": 1,
                    "flops": flops, "bytes": bytes_}]
    elif kind == "power":
        ka, sa = _power_view_cost(n, da, db, kt, dtype, seeded, schedule)
        kb, sb = _power_view_cost(n, db, da, kt, dtype, seeded, schedule)
        kernels = ka + kb
        sched = _join_schedules(sa, sb)
    elif kind == "final":
        from repro.obs.cost import plan_cost
        ka, sa = _final_view_cost(n, da, kt, dtype, seeded, schedule)
        kb, sb = _final_view_cost(n, db, kt, dtype, seeded, schedule)
        kernels = (ka + kb
                   + [plan_cost(plan_matmul(kt, n, kt, "float32",
                                            transpose_lhs=True))])
        sched = _join_schedules(sa, sb)
    else:
        raise ValueError(f"unknown pass kind {kind!r}")
    kernels = merge_kernel_costs(kernels)
    return {"flops": sum(k["flops"] for k in kernels),
            "bytes": sum(k["bytes"] for k in kernels),
            "kernels": kernels,
            "schedule": sched}
