"""Pallas TPU matmul kernels for the CCA data pass.

The data pass of Algorithm 1 is three matmul shapes (see DESIGN.md §3):

  NN: P = X @ Q            (rows × features) @ (features × k̃)
  TN: Y = Xᵀ @ P           contraction over the streamed row dimension
  (gram) C = Pᵀ @ P        TN with X == P — reuses the TN kernel

Both kernels use an f32 VMEM scratch accumulator with the contraction
dimension innermost in the grid, MXU-aligned blocks (multiples of 128 on
every matmul dim), and cast to the output dtype only on the final
contraction step.  Validated against ref.py in interpret mode; on real
TPUs the same code lowers to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune
from .compat import tpu_compiler_params
from .plan import BlockDef, KernelPlan, ScratchDef, launch_args


# --------------------------------------------------------------------------
# kernel bodies
# --------------------------------------------------------------------------


def _mm_nn_kernel(x_ref, q_ref, o_ref, acc_ref, *, n_k_steps: int):
    """o[i,j] = Σ_k x[i,k] q[k,j]; grid (i, j, k) with k innermost."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        q_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_tn_kernel(x_ref, p_ref, o_ref, acc_ref, *, n_k_steps: int):
    """o[d,j] = Σ_n x[n,d] p[n,j]  (contract over leading/stream dim)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        p_ref[...],
        (((0,), (0,)), ((), ())),  # xᵀ p without materializing the transpose
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# --------------------------------------------------------------------------
# host-side wrappers (padding + BlockSpec assembly)
# --------------------------------------------------------------------------

#: Per-buffer VMEM budget, in elements (f32 ⇒ ~4 MB per block).  Single
#: source of truth for every fused kernel's block sizing: the bucketed
#: powerpass/projgram wrappers size their output-column buckets so each
#: VMEM-resident block stays within this budget, and fall back to the
#: unfused matmul pair only when even a 128-row block cannot fit.
VMEM_BLOCK_ELEMS = 1 << 20

#: Modelled accelerator balance point (peak MXU FLOP/s ÷ HBM bytes/s)
#: used by the staged-vs-recompute schedule crossover.  The default is
#: the benchmark target's ratio (~197 TFLOP/s ÷ 819 GB/s ≈ 240 — the
#: same constants ``benchmarks/kernel_bench.py`` rooflines against); an
#: autotuned schedule entry (``op="powerpass-staged"`` /
#: ``"projgram-staged"``) always overrides the analytic rule, so this
#: constant only decides unswept shapes.
ROOFLINE_FLOPS_PER_BYTE = 240.0


def pick_schedule(costs: dict, *,
                  roofline: float = ROOFLINE_FLOPS_PER_BYTE) -> str:
    """Shared-budget crossover rule between kernel schedules.

    ``costs`` maps a schedule name to its modelled ``(flops, bytes)``
    for one launch (or launch pair).  A schedule's modelled wall time in
    HBM-byte units is ``max(flops / roofline, bytes)`` — compute-bound
    schedules are charged their FLOPs at the balance point, memory-bound
    ones their traffic — and the cheaper schedule wins.  Ties break
    deterministically by name order, so the choice is reproducible
    across processes.
    """
    def t(c) -> float:
        flops, bytes_ = c
        return max(float(flops) / roofline, float(bytes_))

    return min(sorted(costs), key=lambda k: t(costs[k]))


def vmem_row_cap(cols: int) -> int:
    """Largest multiple-of-128 row count ``r`` with ``r·cols`` inside
    :data:`VMEM_BLOCK_ELEMS`; 0 when even 128 rows do not fit."""
    return (VMEM_BLOCK_ELEMS // max(cols, 1)) // 128 * 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, cap: int) -> int:
    """Largest power-of-two multiple of 128 that divides the padded dim
    and is ≤ cap.  Padding is always to a multiple of 128 first."""
    b = 128
    while b * 2 <= cap and dim % (b * 2) == 0:
        b *= 2
    return b


def _pad2(x: jax.Array, r: int, c: int) -> jax.Array:
    pr, pc = r - x.shape[0], c - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def plan_matmul(M: int, K: int, N: int, dtype, *, transpose_lhs: bool = False,
                block_m: int | None = None, block_n: int | None = None,
                block_k: int | None = None,
                out_dtype=jnp.float32) -> KernelPlan:
    """Launch plan for ``pallas_matmul`` on an (M, K) @ (K, N) problem
    — grid, blocks, index maps and scratch, resolved exactly as the
    wrapper resolves them (autotune cache, then the 512³ heuristic).
    Pure and trace-free: the static kernel checker consumes the same
    plan the wrapper launches."""
    Mp, Np, Kp = _round_up(M, 128), _round_up(N, 128), _round_up(K, 128)
    if block_m is None or block_n is None or block_k is None:
        op = "matmul_tn" if transpose_lhs else "matmul_nn"
        tuned = autotune.lookup(op, Mp, Kp, Np, dtype)
        block_m = tuned[0] if block_m is None else block_m
        block_n = tuned[1] if block_n is None else block_n
        block_k = tuned[2] if block_k is None else block_k
    bm, bn, bk = _pick_block(Mp, block_m), _pick_block(Np, block_n), _pick_block(Kp, block_k)
    gm, gn, gk = Mp // bm, Np // bn, Kp // bk
    in_dt = str(jnp.dtype(dtype))
    if transpose_lhs:
        x_spec = BlockDef((bk, bm), lambda i, j, k: (k, i), (Kp, Mp), in_dt)
    else:
        x_spec = BlockDef((bm, bk), lambda i, j, k: (i, k), (Mp, Kp), in_dt)
    return KernelPlan(
        name="matmul_tn" if transpose_lhs else "matmul_nn",
        grid=(gm, gn, gk),
        in_specs=(x_spec,
                  BlockDef((bk, bn), lambda i, j, k: (k, j), (Kp, Np), in_dt)),
        out_specs=(BlockDef((bm, bn), lambda i, j, k: (i, j), (Mp, Np),
                            str(jnp.dtype(out_dtype))),),
        scratch=(ScratchDef((bm, bn), "float32"),),
        out_shape=((M, N),),
        accum_outputs=(0,) if jnp.dtype(out_dtype) == jnp.float32 else (),
    )


@functools.partial(
    jax.jit,
    static_argnames=("transpose_lhs", "block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def pallas_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    transpose_lhs: bool = False,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """MXU-tiled ``x @ y`` (or ``xᵀ @ y``) with f32 accumulation.

    Shapes: NN — x (M, K), y (K, N) → (M, N);
            TN — x (K, M), y (K, N) → (M, N)  (contraction = dim 0).
    Inputs are zero-padded to multiples of 128; the result is sliced
    back, so any shape is accepted.

    Block caps left as ``None`` resolve from the autotune cache for this
    (backend, op, dtype, padded shape) — see :mod:`repro.kernels.autotune`
    — falling back to the 512³ heuristic for unswept shapes.
    """
    if transpose_lhs:
        K, M = x.shape
        K2, N = y.shape
    else:
        M, K = x.shape
        K2, N = y.shape
    assert K == K2, f"contraction mismatch {K} vs {K2}"

    plan = plan_matmul(M, K, N, x.dtype, transpose_lhs=transpose_lhs,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       out_dtype=out_dtype)
    body = _mm_tn_kernel if transpose_lhs else _mm_nn_kernel
    kernel = functools.partial(body, n_k_steps=plan.grid[2])
    xp = _pad2(x, *plan.in_specs[0].padded)
    yp = _pad2(y, *plan.in_specs[1].padded)

    out = pl.pallas_call(
        kernel,
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(xp, yp)
    return out[:M, :N]
